"""MatmulPlan.evolve -- incremental plan mutation for dynamic sparse
training (RigL-style topology updates on static plans).

The tentpole invariants under test:

* an in-threshold evolve re-runs only host pattern phases: ZERO route
  decisions and ZERO measurement events (asserted via cache counters);
* values round-trip through ``carry_values`` (carried blocks keep their
  values exactly, grown blocks start at zero);
* drift past ``PlanContext.evolve_drift`` (or ``rerace=True``) re-races;
* evolved plans are jit/grad-safe and register in the plan cache, so
  ``sparse.spmm`` on the new pattern is a decision-free hit;
* the disk record at the evolved key carries the evolution lineage and
  replays (fwd + bwd) on a simulated restart with zero measurements;
* a v4 (pre-evolution-schema) cache file is invalidated wholesale.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import masks, partitioner
from repro.core.bsr import BlockSparseMatrix

M = K = 256
B = 16
N = 32


@pytest.fixture(autouse=True)
def _fresh_cache():
    sparse.reset()
    yield
    sparse.reset()


def _problem(density=0.25, seed=0):
    mask = masks.random_block_mask(M, K, B, density, seed=seed)
    bsr = BlockSparseMatrix.from_mask(mask, B, init="normal",
                                      key=jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (K, N))
    return mask, bsr, x


def _move_one(mask):
    """Constant-nnz single-block move (the minimal topology update)."""
    new = mask.copy()
    r, c = np.nonzero(new)
    zr, zc = np.nonzero(~new)
    new[r[0], c[0]] = False
    new[zr[0], zc[0]] = True
    return new


# -- verdict reuse (the tentpole acceptance criterion) ---------------------------------

def test_evolve_runs_zero_decisions_and_measurements():
    mask, bsr, x = _problem()
    p = sparse.plan(bsr, N, x=x, ctx=sparse.PlanContext())
    s0 = sparse.cache_stats()
    p2 = p.evolve(_move_one(mask))
    s1 = sparse.cache_stats()
    assert s1["decisions"] == s0["decisions"]
    assert s1["measurements"] == s0["measurements"]
    assert s1["plans_built"] == s0["plans_built"] + 1
    assert p2.route == p.route
    ev = p2.explain()["evolution"]
    assert ev["generation"] == 1 and not ev["reraced"]
    assert ev["carried"] == bsr.nnz_blocks - 1
    assert ev["dropped"] == 1 and ev["grown"] == 1


def test_evolve_reuses_backward_verdicts():
    mask, bsr, x = _problem()
    p = sparse.plan(bsr, N, x=x, ctx=sparse.PlanContext())
    g = p.explain()["grad"]
    assert g["mode"] == "planned"
    p2 = p.evolve(_move_one(mask))
    g2 = p2.explain()["grad"]
    assert g2["mode"] == "planned" and g2["evolved"]
    assert g2["dx"]["route"] == g["dx"]["route"]
    assert g2["dvalues"]["route"] == g["dvalues"]["route"]
    # inherited from the parent in memory, not read from disk
    assert not g2["from_disk"]


def test_evolved_plan_registers_in_plan_cache():
    mask, bsr, x = _problem()
    p = sparse.plan(bsr, N, x=x, ctx=sparse.PlanContext())
    new_mask = _move_one(mask)
    p2 = p.evolve(new_mask)
    bsr2 = BlockSparseMatrix.from_mask(new_mask, B, init="normal",
                                       key=jax.random.PRNGKey(9))
    s0 = sparse.cache_stats()
    y = sparse.spmm(bsr2, x)          # must be a plan-cache hit
    s1 = sparse.cache_stats()
    assert s1["decisions"] == s0["decisions"]
    assert s1["plan_hits"] == s0["plan_hits"] + 1
    assert sparse.plan(bsr2, N, ctx=sparse.PlanContext()) is p2
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(bsr2.to_dense() @ x),
                               rtol=1e-4, atol=1e-4)


# -- value carry -----------------------------------------------------------------------

def test_carry_values_round_trip():
    # grow-only superset B of A: evolving A -> B -> A must hand back
    # every original value exactly
    mask, bsr, x = _problem(density=0.125)
    sup = mask.copy()
    zr, zc = np.nonzero(~sup)
    sup[zr[:5], zc[:5]] = True        # 5 grown blocks
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext())
    p_up = p.evolve(sup)
    v_up = p_up.carry_values(bsr.values)
    assert v_up.shape[0] == bsr.nnz_blocks + 5
    p_back = p_up.evolve(mask)
    v_back = p_back.carry_values(v_up)
    np.testing.assert_array_equal(np.asarray(v_back),
                                  np.asarray(bsr.values))
    # grown blocks start at zero on the way up
    ep = p_up.artifacts["_evolve"]
    grown_rows = np.asarray(v_up)[np.asarray(ep.src_slot) < 0]
    assert grown_rows.shape[0] == 5 and not grown_rows.any()


def test_evolved_plan_matches_dense_reference():
    mask, bsr, x = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext())
    new_mask = _move_one(mask)
    p2 = p.evolve(new_mask)
    vals = p2.carry_values(bsr.values)
    rows, cols = p2.pattern
    dense = BlockSparseMatrix(vals, rows, cols, (M, K), B).to_dense()
    np.testing.assert_allclose(np.asarray(p2(vals, x)),
                               np.asarray(dense @ x),
                               rtol=1e-4, atol=1e-4)


# -- drift guardrail -------------------------------------------------------------------

def test_drift_trip_reraces():
    mask, bsr, x = _problem(density=1 / 16)
    p = sparse.plan(bsr, N, x=x, ctx=sparse.PlanContext())
    dense_mask = masks.random_block_mask(M, K, B, 0.5, seed=3)
    s0 = sparse.cache_stats()
    p2 = p.evolve(dense_mask)         # 8x the density: way past 0.25
    s1 = sparse.cache_stats()
    ev = p2.explain()["evolution"]
    assert ev["drift_tripped"] and ev["reraced"]
    assert ev["drift"] > 0.25
    assert s1["decisions"] > s0["decisions"]  # a real re-race happened
    # the drift reference reset to the re-raced profile
    assert ev["ref_density"] == ev["density"]
    totals = sparse.plan_report()["totals"]["evolution"]
    assert totals["reraces"] == 1 and totals["drift_trips"] == 1


def test_rerace_flag_forces_rerace():
    mask, bsr, x = _problem()
    p = sparse.plan(bsr, N, x=x, ctx=sparse.PlanContext())
    s0 = sparse.cache_stats()
    p2 = p.evolve(_move_one(mask), rerace=True)
    s1 = sparse.cache_stats()
    assert p2.explain()["evolution"]["reraced"]
    assert not p2.explain()["evolution"]["drift_tripped"]
    assert s1["decisions"] > s0["decisions"]


def test_evolve_drift_knob():
    mask, bsr, x = _problem()
    # 0.0: any change trips; None: never trips
    for thr, expect_trip in ((0.0, True), (None, False)):
        sparse.reset()
        ctx = sparse.PlanContext(evolve_drift=thr)
        p = sparse.plan(bsr, N, x=x, ctx=ctx)
        new = mask.copy()
        r, c = np.nonzero(new)
        new[r[0], c[0]] = False       # drop one block: density changes
        ev = p.evolve(new).explain()["evolution"]
        assert ev["drift_tripped"] is expect_trip, thr
        assert ev["reraced"] is expect_trip
    with pytest.raises(ValueError):
        sparse.PlanContext(evolve_drift=-0.5)


def test_evolve_drift_in_mem_key():
    # same pattern, different drift policy -> different cached plans
    mask, bsr, x = _problem()
    p1 = sparse.plan(bsr, N, ctx=sparse.PlanContext(evolve_drift=0.25))
    p2 = sparse.plan(bsr, N, ctx=sparse.PlanContext(evolve_drift=None))
    assert p1 is not p2


# -- jit / grad safety ------------------------------------------------------------------

def test_evolved_plan_jit_and_grad_safe():
    mask, bsr, x = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext())
    p2 = p.evolve(_move_one(mask))
    vals = p2.carry_values(bsr.values)
    rows, cols = p2.pattern
    def dense_ref(v):
        return BlockSparseMatrix(v, rows, cols, (M, K), B).to_dense()

    fwd = jax.jit(lambda v, xx: p2(v, xx))
    np.testing.assert_allclose(np.asarray(fwd(vals, x)),
                               np.asarray(dense_ref(vals) @ x),
                               rtol=1e-4, atol=1e-4)
    g = jax.jit(jax.grad(lambda v, xx: jnp.sum(p2(v, xx) ** 2)))(vals, x)
    g_ref = jax.grad(
        lambda v, xx: jnp.sum((dense_ref(v) @ xx) ** 2))(vals, x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-3)


# -- a real dynamic-sparse-training loop ------------------------------------------------

def test_rigl_training_loop_constant_nnz_zero_reraces():
    from repro.train.step import rigl_evolve
    mask, bsr, x = _problem(density=0.25)
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext())
    vals = bsr.values
    nnz = vals.shape[0]
    key = jax.random.PRNGKey(0)
    s0 = sparse.cache_stats()
    for step in range(20):
        key, kr, kx = jax.random.split(key, 3)
        xb = jax.random.normal(kx, (K, N))
        y = p(vals, xb)
        p, vals = rigl_evolve(p, vals, y @ xb.T, fraction=0.2, rng=kr)
        assert vals.shape[0] == nnz           # constant-nnz invariant
    s1 = sparse.cache_stats()
    assert s1["measurements"] == s0["measurements"]
    assert s1["decisions"] == s0["decisions"]
    totals = sparse.plan_report()["totals"]["evolution"]
    assert totals["evolves"] == 20 and totals["reraces"] == 0
    assert p.explain()["evolution"]["generation"] == 20
    # numerics still exact after 20 topology updates
    rows, cols = p.pattern
    dense = BlockSparseMatrix(vals, rows, cols, (M, K), B).to_dense()
    np.testing.assert_allclose(np.asarray(p(vals, x)),
                               np.asarray(dense @ x),
                               rtol=1e-4, atol=1e-4)


def test_sparse_linear_evolve_hook():
    from repro.core.sparse_layers import SparseLinear
    lyr = SparseLinear.random_pattern(None, K, M, B, 0.25, seed=1)
    params = lyr.init(jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (8, K))
    y0 = lyr.apply(params, x)
    assert y0.shape == (8, M)
    new_mask = _move_one(lyr.pattern)
    s0 = sparse.cache_stats()
    lyr2, params2 = lyr.evolve(new_mask, params)
    y2 = lyr2.apply(params2, x)
    s1 = sparse.cache_stats()
    assert s1["decisions"] == s0["decisions"]     # evolve, not re-plan
    assert np.array_equal(lyr2.pattern, new_mask)
    assert params2["values"].shape == params["values"].shape
    ref = (x @ lyr2.as_bsr(params2).to_dense().T)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# -- validation -------------------------------------------------------------------------

def test_evolve_rejects_wrong_geometry():
    mask, bsr, x = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext())
    with pytest.raises(ValueError, match="grid"):
        p.evolve(np.ones((4, 4), bool))
    with pytest.raises(ValueError, match="duplicate"):
        p.evolve((np.array([0, 0], np.int32), np.array([0, 0], np.int32)))


def test_duplicate_blocks_rejected_everywhere():
    dup_r = np.array([0, 1, 0], np.int32)
    dup_c = np.array([2, 3, 2], np.int32)
    with pytest.raises(ValueError, match="duplicate"):
        partitioner.plan_packing(dup_r, dup_c, (64, 64), 16)
    with pytest.raises(ValueError, match="duplicate"):
        partitioner.plan_evolution(dup_r, dup_c, dup_r[:1], dup_c[:1],
                                   (4, 4))
    vals = jnp.zeros((3, 16, 16))
    with pytest.raises(ValueError, match="duplicate"):
        BlockSparseMatrix(vals, dup_r, dup_c, (64, 64),
                          16).validate_pattern()


def test_balance_report_empty_counts():
    rep = partitioner.balance_report(np.array([], np.int64))
    assert rep == {"max": 0, "min": 0, "mean": 0.0, "imbalance": 0.0,
                   "padding_waste": 0.0, "frac_empty": 0.0, "cv": 0.0}


# -- persistence ------------------------------------------------------------------------

def test_evolution_lineage_persists_and_replays(tmp_path):
    mask, bsr, x = _problem()
    ctx = sparse.PlanContext(cache_dir=str(tmp_path))
    p = sparse.plan(bsr, N, x=x, ctx=ctx)
    new_mask = _move_one(mask)
    p2 = p.evolve(new_mask)
    path = os.path.join(
        str(tmp_path), f"sparse-plans-v{sparse.SCHEMA_VERSION}.json")
    with open(path) as f:
        rec = json.load(f)["entries"][p2.key]
    assert rec["evolution"]["generation"] == 1
    assert rec["evolution"]["reraced"] is False
    assert rec["route"] == p2.route and "grad" in rec

    # simulated restart: the evolved pattern replays fwd + bwd verdicts
    # from disk with zero measurements
    sparse.reset()
    bsr2 = BlockSparseMatrix.from_mask(new_mask, B, init="normal",
                                       key=jax.random.PRNGKey(5))
    p3 = sparse.plan(bsr2, N, ctx=ctx)
    s = sparse.cache_stats()
    assert p3.from_disk and s["measurements"] == 0
    assert p3.route == p2.route
    assert p3.explain()["grad"]["from_disk"]


def test_pre_evolution_v4_cache_file_invalidated(tmp_path):
    """A v4 (pre-evolution-schema) file is ignored wholesale: its
    records carry no evolution lineage, so an evolved pattern's verdict
    provenance would be unrecorded after a restart."""
    mask, bsr, x = _problem()
    ctx = sparse.PlanContext(cache_dir=str(tmp_path))
    key = sparse.plan(bsr, N, ctx=ctx).key
    sparse.reset()
    os.remove(os.path.join(
        str(tmp_path), f"sparse-plans-v{sparse.SCHEMA_VERSION}.json"))
    old = {"env": {"schema": 4, "backend": jax.default_backend(),
                   "jax": jax.__version__},
           "entries": {key: {"route": "dense_xla", "source": "measured",
                             "est_seconds": {}}}}
    with open(os.path.join(str(tmp_path), "sparse-plans-v4.json"),
              "w") as f:
        json.dump(old, f)
    p = sparse.plan(bsr, N, ctx=ctx)
    assert not p.from_disk                 # old tag never satisfies
    assert p.route != "dense_xla" or p.source != "measured"
