"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes, block sizes, densities and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dynamic_sparse as dsp
from repro.core import masks
from repro.core.bsr import BlockSparseMatrix
from repro.kernels.bs_attn import ops as bsa_ops
from repro.kernels.bs_attn.ref import bs_attn_ref
from repro.kernels.bsmm import ops as bsmm_ops
from repro.kernels.bsmm.ref import bsmm_ref
from repro.kernels.dense_mm import ops as dmm_ops
from repro.kernels.dense_mm.ref import dense_mm_ref
from repro.kernels.dsmm import ops as dsmm_ops
from repro.kernels.dsmm.ref import dsmm_ref
from repro.kernels.gmm import ops as gmm_ops
from repro.kernels.gmm.ref import gmm_ref


# interpret-mode Pallas kernel sweeps: excluded from the fast tier-1 run (see pytest.ini)
pytestmark = pytest.mark.slow


def _tol(dtype):
    # fp32 accumulation-order differences grow with K; bf16 inputs coarser
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("m,k,n", [(128, 128, 64), (256, 512, 128),
                                   (384, 256, 96)])
@pytest.mark.parametrize("b", [1, 4, 8, 16])
@pytest.mark.parametrize("density", [0.0625, 0.25, 1.0])
def test_bsmm_shapes(m, k, n, b, density):
    key = jax.random.PRNGKey(hash((m, k, n, b)) % 2**31)
    bsr = BlockSparseMatrix.random(key, m, k, b, density)
    x = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    got = bsmm_ops.bsmm(bsr, x, interpret=True)
    want = bsmm_ref(bsr, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsmm_dtypes(dtype):
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 256, 256, 16,
                                   0.25, dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 64), dtype)
    got = bsmm_ops.bsmm(bsr, x, interpret=True)
    want = bsmm_ref(bsr, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_bsmm_empty_rows_covered():
    """Rows with no non-zero blocks must still produce zero output."""
    mask = np.zeros((4, 4), bool)
    mask[0, 0] = mask[2, 1] = True      # rows 1, 3 empty
    bsr = BlockSparseMatrix.from_mask(mask, 16, init="normal",
                                      key=jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    got = bsmm_ops.bsmm(bsr, x, interpret=True)
    want = bsmm_ref(bsr, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(got)[16:32]).max() == 0


@pytest.mark.parametrize("b", [4, 16])
@pytest.mark.parametrize("density", [0.1, 0.5])
def test_dsmm(b, density):
    m = k = 256
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), m, k, b, density)
    cap = bsr.nnz_blocks + 7
    op = dsp.encode_from_bsr(bsr, nnz_max=cap)
    x = jax.random.normal(jax.random.PRNGKey(1), (k, 64))
    got = dsmm_ops.dsmm(op, x, interpret=True)
    want = dsmm_ref(op, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("e,tm", [(4, 32), (8, 64)])
def test_gmm(e, tm):
    t, d, f = 256, 128, 96
    x = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (e, d, f))
    ids = jax.random.randint(jax.random.PRNGKey(2), (t // tm,), 0, e)
    got = gmm_ops.gmm(x, w, ids, tm=tm, interpret=True)
    want = gmm_ref(x, w, ids, tm=tm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 128, 64)])
def test_dense_mm(m, k, n):
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    got = dmm_ops.dense_mm(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense_mm_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("pattern", ["causal_local", "banded", "full"])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_bs_attn(pattern, softcap):
    h, s, dh = 2, 512, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (h, s, dh)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(1), (h, s, dh)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(2), (h, s, dh))
    nb = s // 128
    if pattern == "causal_local":
        bm = masks.local_global_attention_mask(nb, nb, window_blocks=2,
                                               global_blocks=1)
    elif pattern == "banded":
        bm = masks.banded_block_mask(s, s, 128, 1)
        bm = np.tril(bm)
        bm[np.diag_indices(nb)] = True
    else:
        bm = np.tril(np.ones((nb, nb), bool))
    got = bsa_ops.bs_attn(q, k, v, bm, softcap=softcap, interpret=True)
    want = bs_attn_ref(q, k, v, bm, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
