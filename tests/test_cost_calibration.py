"""Calibrated cost model: CostCoeffs load/apply/digest semantics, the
calibrate fit, the cost_check CI gate, roofline-efficiency reporting,
and the report.load_records missing-dir fix."""
import dataclasses
import json
import os
import sys

import jax
import numpy as np
import pytest

from repro import sparse
from repro.analysis import calibrate, report
from repro.analysis.hlo_cost import sddmm_cost_dict, spmm_cost_dict
from repro.analysis.roofline import V5E, route_efficiency
from repro.core import dispatch
from repro.core.bsr import BlockSparseMatrix

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "tools"))
import cost_check  # noqa: E402


@pytest.fixture
def _restore_coeffs():
    prev = dispatch.cost_coeffs()
    try:
        yield
    finally:
        dispatch.set_cost_coeffs(prev)


def _bsr(m=256, n=256, b=16, density=0.25, seed=0):
    return BlockSparseMatrix.random(
        jax.random.PRNGKey(seed), m, n, b, density=density)


# ---------------------------------------------------------------------------
# CostCoeffs: load / apply / digest / cache-key join
# ---------------------------------------------------------------------------

def test_load_missing_file_is_identity():
    c = dispatch.load_cost_coeffs("/nonexistent/cost_coeffs.json")
    assert c.is_identity
    assert c.digest == ""
    assert c.apply("static_pallas", 1e-6) == 1e-6


def test_load_garbage_is_identity(tmp_path):
    bad = tmp_path / "cost_coeffs.json"
    bad.write_text("{not json")
    assert dispatch.load_cost_coeffs(str(bad)).is_identity
    bad.write_text('{"routes": 42}')
    assert dispatch.load_cost_coeffs(str(bad)).is_identity


def test_apply_affine_and_unknown_route_passthrough():
    c = dispatch.CostCoeffs(route_scale={"static_xla": 2.0},
                            route_fixed_us={"static_xla": 5.0},
                            digest="abc")
    assert c.apply("static_xla", 1e-6) == pytest.approx(7e-6)
    # a route the fit never saw stays on the hand-tuned model
    assert c.apply("dynamic_xla", 3e-6) == pytest.approx(3e-6)


def test_digest_deterministic_and_sensitive():
    routes = {"static_xla": {"scale": 1.1, "fixed_us": 2.0, "n_obs": 9}}
    skew = {"imb_slope": 0.4}
    d1 = dispatch.coeffs_digest(routes, skew, 1)
    assert d1 == dispatch.coeffs_digest(routes, skew, 1)
    # diagnostic fields are excluded: same coefficients, same digest
    routes2 = {"static_xla": {"scale": 1.1, "fixed_us": 2.0,
                              "n_obs": 1, "median_rel_err": 0.5}}
    assert dispatch.coeffs_digest(routes2, skew, 1) == d1
    # any coefficient value change moves it
    routes3 = {"static_xla": {"scale": 1.2, "fixed_us": 2.0}}
    assert dispatch.coeffs_digest(routes3, skew, 1) != d1
    assert dispatch.coeffs_digest(routes, {"imb_slope": 0.5}, 1) != d1
    assert dispatch.coeffs_digest(routes, skew, 2) != d1


def test_file_roundtrip_through_loader(tmp_path):
    blob = {"version": 1,
            "routes": {"static_xla": {"scale": 1.5, "fixed_us": 2.5}},
            "skew": {"imb_knee": 1.5, "imb_slope": 0.5, "cv_knee": 0.3,
                     "cv_slope": 0.2, "cap": 2.5}}
    path = tmp_path / "cost_coeffs.json"
    path.write_text(json.dumps(blob))
    c = dispatch.load_cost_coeffs(str(path))
    assert not c.is_identity
    assert c.route_scale == {"static_xla": 1.5}
    assert c.route_fixed_us == {"static_xla": 2.5}
    assert (c.skew_imb_knee, c.skew_imb_slope) == (1.5, 0.5)
    assert (c.skew_cv_knee, c.skew_cv_slope, c.skew_cap) == (0.3, 0.2, 2.5)
    assert c.digest == dispatch.coeffs_digest(
        blob["routes"], blob["skew"], 1)


def test_calibrated_estimate_applies_affine(_restore_coeffs):
    dispatch.set_cost_coeffs(dispatch.IDENTITY_COEFFS)
    raw = dispatch._estimate("static_xla", 1024, 1024, 256, 16, 0.25,
                             "float32")
    dispatch.set_cost_coeffs(dispatch.CostCoeffs(
        route_scale={"static_xla": 2.0},
        route_fixed_us={"static_xla": 10.0}, digest="t"))
    cal = dispatch._estimate("static_xla", 1024, 1024, 256, 16, 0.25,
                             "float32")
    assert cal == pytest.approx(2.0 * raw + 10e-6)


def test_cache_key_joins_nonidentity_digest(_restore_coeffs):
    ctx = dispatch.DispatchContext()
    args = ("static", 1024, 1024, 256, 16, 0.25, "float32", ctx)
    dispatch.set_cost_coeffs(dispatch.IDENTITY_COEFFS)
    key_id = dispatch._cache_key(*args)
    assert "coeffs" not in key_id
    dispatch.set_cost_coeffs(dispatch.CostCoeffs(digest="deadbeef0000"))
    key_cal = dispatch._cache_key(*args)
    assert key_cal[-2:] == ("coeffs", "deadbeef0000")
    assert key_cal[:-2] == key_id


def test_plan_fingerprint_changes_on_refit(tmp_path, _restore_coeffs):
    sparse.configure(str(tmp_path))
    bsr = _bsr()
    try:
        dispatch.set_cost_coeffs(dispatch.IDENTITY_COEFFS)
        k1 = sparse.plan(bsr, 64).key
        sparse.reset()
        dispatch.set_cost_coeffs(dispatch.CostCoeffs(digest="deadbeef0000"))
        k2 = sparse.plan(bsr, 64).key
    finally:
        sparse.reset()
        sparse.configure(None)
    assert k1 != k2          # a refit orphans persisted verdicts


def test_set_cost_coeffs_none_reloads_committed_file(_restore_coeffs):
    dispatch.set_cost_coeffs(dispatch.CostCoeffs(digest="t"))
    dispatch.set_cost_coeffs(None)
    committed = json.load(open(os.path.join(
        REPO, "benchmarks", "baselines", "cost_coeffs.json")))
    assert dispatch.cost_coeffs().digest == committed["digest"]


# ---------------------------------------------------------------------------
# calibrate: corpus extraction + fit
# ---------------------------------------------------------------------------

def test_committed_corpus_loads_and_fit_is_committed_coeffs():
    obs = calibrate.load_corpus()
    assert len(obs) >= 50
    assert {o.fig for o in obs} <= set(calibrate.EXTRACTORS)
    blob = calibrate.fit(obs)
    # the corpus is the analytic model's own output, so every fitted
    # correction snaps to identity...
    for route, c in blob["routes"].items():
        assert c["scale"] == 1.0, route
        assert c["fixed_us"] == 0.0, route
    assert blob["fit_median_rel_err"] < 0.01
    # ...and a refit of the unchanged corpus reproduces the committed
    # file exactly (idempotence: CI can re-run `calibrate --update`)
    committed = json.load(open(os.path.join(
        calibrate.BASELINE_DIR, "cost_coeffs.json")))
    assert blob["digest"] == committed["digest"]
    assert blob["routes"] == committed["routes"]
    assert blob["skew"] == committed["skew"]


def test_load_corpus_bad_glob_raises():
    with pytest.raises(FileNotFoundError, match="matched nothing"):
        calibrate.load_corpus(["/nonexistent/BENCH_*.json"])


def test_fit_recovers_synthetic_scale(_restore_coeffs):
    # measurements at 1.3x the raw model (well outside SCALE_SNAP) over
    # shapes with real spread: OLS must recover scale~1.3, intercept~0
    shapes = [(256, 64), (512, 128), (1024, 256), (2048, 256), (4096, 512)]
    obs = []
    with calibrate._identity_model():
        for m, n in shapes:
            o = calibrate.Observation(
                fig="dispatch", route="static_xla", m=m, k=m, n=n,
                b=16, density=0.25)
            obs.append(dataclasses.replace(
                o, measured_us=1.3 * calibrate._raw_us(o)))
    blob = calibrate.fit(obs)
    c = blob["routes"]["static_xla"]
    assert c["scale"] == pytest.approx(1.3, abs=0.02)
    assert c["fixed_us"] == 0.0
    assert c["median_rel_err"] < 0.01


def test_fit_empty_corpus_raises():
    with pytest.raises(ValueError, match="empty corpus"):
        calibrate.fit([])


# ---------------------------------------------------------------------------
# cost_check: the CI gate
# ---------------------------------------------------------------------------

def test_cost_check_passes_at_head():
    rep = cost_check.run_check()
    assert rep["pass"], rep
    assert rep["n_obs"] >= 50
    assert rep["median_rel_err"] <= 0.15
    assert rep["crossover_flips"] == []
    assert rep["coeffs"]["digest"] == dispatch.cost_coeffs().digest


def test_cost_check_catches_broken_calibration(_restore_coeffs):
    # 5x-ing one route must both blow the error gate and flip at least
    # one corpus race -- the two failure modes the gate exists for
    dispatch.set_cost_coeffs(dispatch.CostCoeffs(
        route_scale={r: 5.0 for r in dispatch.ROUTES},
        digest="broken000000"))
    rep = cost_check.run_check()
    assert not rep["pass"]
    assert rep["median_rel_err"] > 0.15


def test_cost_check_detects_crossover_flip(_restore_coeffs):
    # slow down only the static routes: dense wins races it lost in the
    # corpus -> flips reported even though many estimates stay exact
    dispatch.set_cost_coeffs(dispatch.CostCoeffs(
        route_scale={"static_xla": 4.0, "static_pallas": 4.0,
                     "static_balanced": 4.0}, digest="flip00000000"))
    rep = cost_check.run_check()
    assert rep["crossover_flips"], "expected at least one flipped race"
    assert not rep["pass"]
    flip = rep["crossover_flips"][0]
    assert {"fig", "point", "corpus", "model"} <= set(flip)


def test_cost_check_rc2_without_coeffs_file(tmp_path):
    import subprocess
    env = dict(os.environ,
               REPRO_COST_COEFFS=str(tmp_path / "nope.json"),
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "cost_check.py")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert r.returncode == 2, r.stdout + r.stderr
    assert "NO COEFFICIENTS" in r.stdout


# ---------------------------------------------------------------------------
# roofline efficiency
# ---------------------------------------------------------------------------

def test_route_efficiency_at_bound():
    cost = {"flops": V5E.peak_flops_bf16, "bytes": 0,
            "collective_bytes": 0}           # exactly 1s compute bound
    eff = route_efficiency(1.0, cost)
    assert eff["dominant"] == "compute"
    assert eff["efficiency"] == pytest.approx(1.0)
    assert eff["headroom"] == pytest.approx(1.0)
    assert not eff["flagged"]


def test_route_efficiency_flags_headroom():
    cost = {"flops": V5E.peak_flops_bf16, "bytes": 0,
            "collective_bytes": 0}
    eff = route_efficiency(10.0, cost)
    assert eff["headroom"] == pytest.approx(10.0)
    assert eff["efficiency"] == pytest.approx(0.1)
    assert eff["flagged"]
    assert not route_efficiency(10.0, cost, flag_headroom=20.0)["flagged"]


def test_route_efficiency_memory_bound():
    cost = {"flops": 1.0, "bytes": V5E.hbm_bw,
            "collective_bytes": 0}           # exactly 1s memory bound
    eff = route_efficiency(2.0, cost)
    assert eff["dominant"] == "memory"
    assert eff["bound_seconds"] == pytest.approx(1.0)


def test_spmm_sddmm_cost_dicts():
    c = spmm_cost_dict(64, 128, 32, density=0.25, bytes_el=4)
    assert c["flops"] == 2 * 64 * 128 * 32 * 0.25
    assert c["bytes"] == (64 * 128 * 0.25 + 128 * 32 + 64 * 32) * 4
    s = sddmm_cost_dict(64, 128, 32, density=0.25, bytes_el=2)
    assert s["flops"] == 2 * 64 * 128 * 32 * 0.25
    assert s["bytes"] == (64 * 32 + 128 * 32 + 64 * 128 * 0.25) * 2
    for d in (c, s):     # analyzer-shaped: roofline_terms accepts both
        assert d["collective_bytes"] == 0 and d["warnings"] == []


def test_plan_explain_reports_roofline(tmp_path):
    sparse.configure(str(tmp_path))
    try:
        p = sparse.plan(_bsr(), 64)
        roof = p.explain()["roofline"]
    finally:
        sparse.reset()
        sparse.configure(None)
    assert roof["hw"] == V5E.name
    assert roof["chosen"] is not None
    assert roof["chosen"] == roof["routes"][p.route]
    for r, e in roof["routes"].items():
        assert r not in ("static_tp", "static_tp_shardmap")
        assert e["bound_us"] > 0
        assert 0 < e["efficiency"] <= 1.0
        assert e["flagged"] == (e["headroom"] > roof["flag_headroom"])
    assert roof["kernel_work"] == sorted(
        r for r, e in roof["routes"].items() if e["flagged"])
    assert "roofline:" in sparse.format_plan(p)


def test_roofline_report_totals(tmp_path):
    sparse.configure(str(tmp_path))
    try:
        sparse.plan(_bsr(), 64)
        sparse.plan(_bsr(m=512, n=512, seed=1), 128)
        rep = sparse.roofline_report()
    finally:
        sparse.reset()
        sparse.configure(None)
    assert rep["totals"]["plans"] == 2
    assert rep["totals"]["min_chosen_efficiency"] is not None
    assert 0 < rep["totals"]["min_chosen_efficiency"] <= 1.0
    assert isinstance(rep["totals"]["kernel_work_routes"], list)
    for per in rep["per_plan"].values():
        assert {"route", "chosen", "kernel_work"} <= set(per)


def test_dense_routes_priced_at_full_density(tmp_path):
    # dense_xla executes the full m*k*n product regardless of operand
    # sparsity: its bound must not borrow the sparse discount, or every
    # dense route would flag as kernel work on sparse problems
    sparse.configure(str(tmp_path))
    try:
        p = sparse.plan(_bsr(density=0.125), 64)
    finally:
        sparse.reset()
        sparse.configure(None)
    dense = p.spec.roofline_cost("dense_xla")
    sparse_c = p.spec.roofline_cost("static_xla")
    assert dense["flops"] == pytest.approx(8 * sparse_c["flops"], rel=0.01)


# ---------------------------------------------------------------------------
# report.load_records missing-dir fix
# ---------------------------------------------------------------------------

def test_load_records_missing_dir_raises(tmp_path, monkeypatch):
    missing = str(tmp_path / "dryrun")
    monkeypatch.setattr(report, "DRYRUN_DIR", missing)
    with pytest.raises(FileNotFoundError, match="dry-run records"):
        report.load_records()
    try:
        report.load_records()
    except FileNotFoundError as e:     # the path must be actionable
        assert os.path.normpath(missing) in str(e)


def test_load_records_empty_dir_returns_empty(tmp_path, monkeypatch):
    d = tmp_path / "dryrun"
    d.mkdir()
    monkeypatch.setattr(report, "DRYRUN_DIR", str(d))
    assert report.load_records() == []
