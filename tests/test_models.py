"""Per-architecture smoke tests (reduced same-family configs): one
forward/train step on CPU asserting output shapes + no NaNs, plus
prefill/decode cache consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.model import LM

ARCHS = configs.ARCH_IDS


# model-level integration: excluded from the fast tier-1 run (see pytest.ini)
pytestmark = pytest.mark.slow


def _inputs(cfg, B, S, key=0):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    kw = {}
    if cfg.frontend == "vision":
        kw["frontend"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, cfg.frontend_len, cfg.d_model),
            jnp.bfloat16)
    if cfg.encoder_layers:
        kw["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 2), (B, cfg.frontend_len, cfg.d_model),
            jnp.bfloat16)
    batch.update(kw)
    return batch, kw


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name):
    cfg = configs.smoke(name)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    batch, kw = _inputs(cfg, B, S)
    logits, _ = lm.forward(params, batch["tokens"], **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, metrics = jax.jit(lm.loss)(params, batch)
    assert np.isfinite(float(loss))
    # one gradient step moves the loss
    g = jax.grad(lambda p: lm.loss(p, batch)[0])(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_consistency(name):
    """prefill(S-1) + decode(1) == forward(S) at the last position.

    deepseek_v2_lite_16b runs the check in fp32.  Bisection (PR 5) of
    the old rel~0.15 bf16 divergence: MLA decode scores through the
    absorbed-latent formulation in fp32 while forward expands
    k_nope/v through ``kv_b`` in bf16 -- a ~0.5% per-layer numeric
    difference (both paths are mathematically identical), which flips a
    top-k expert in the first MoE router and swaps a whole expert FFN.
    Not a decode bug: in fp32 decode matches forward to ~1e-6, and
    ``test_mla_decode_absorbed_parity`` guards the layer-level bf16
    budget where no router discontinuity can amplify it.
    """
    cfg = configs.smoke(name)
    if name == "deepseek_v2_lite_16b":
        cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:   # avoid capacity-drop divergence in the check
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    B, S = 2, 33
    batch, kw = _inputs(cfg, B, S)
    tokens = batch["tokens"]
    full, _ = lm.forward(params, tokens, **kw)
    off = cfg.frontend_len if cfg.frontend == "vision" else 0
    last, caches = lm.prefill(params, tokens[:, :S - 1],
                              max_len=S + off + 3, **kw)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(full[:, S - 2], np.float32),
                               rtol=1e-2, atol=1e-2)
    pos = jnp.full((B,), S - 1 + off, jnp.int32)
    lg, _ = lm.decode_step(params, tokens[:, S - 1:S], caches, pos)
    ref = np.asarray(full[:, -1], np.float32)
    got = np.asarray(lg, np.float32)
    rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.08, f"decode diverges from forward: rel={rel}"


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_positive(name):
    cfg = configs.get(name)
    n = cfg.param_count()
    na = cfg.active_param_count()
    assert n > 0 and 0 < na <= n
    # spot-check magnitudes against the arch ids
    expected = {"deepseek-v2-lite-16b": (14e9, 18e9),
                "qwen3-moe-30b-a3b": (28e9, 33e9),
                "jamba-v0.1-52b": (49e9, 56e9),
                "llama3.2-1b": (1.0e9, 1.6e9),
                "qwen2-1.5b": (1.2e9, 1.9e9),
                "gemma2-2b": (2.0e9, 3.3e9),
                "glm4-9b": (8e9, 10.5e9),
                "mamba2-130m": (0.1e9, 0.2e9)}
    if cfg.name in expected:
        lo, hi = expected[cfg.name]
        assert lo < n < hi, f"{cfg.name}: {n/1e9:.2f}B params out of range"


def test_mla_decode_absorbed_parity():
    """Targeted regression for the deepseek decode finding: the MLA
    absorbed-latent decode (fp32 score math over the latent cache) must
    stay within a tight budget of the expanded bf16 train path at the
    *layer* level -- the full-model bf16 divergence was this numeric
    difference amplified by an MoE router top-k flip, so the layer
    budget is the quantity that guards the decode math itself."""
    from repro.models import attention as attn
    cfg = configs.smoke("deepseek_v2_lite_16b")
    params = attn.mla_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 33
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.bfloat16)
    pos = jnp.tile(jnp.arange(S)[None, :], (B, 1))
    full = attn.mla_train(params, cfg, x, positions=pos)
    _, cache = attn.mla_prefill(params, cfg, x[:, :S - 1],
                                positions=pos[:, :S - 1], max_len=S + 3)
    p = jnp.full((B,), S - 1, jnp.int32)
    y_dec, _ = attn.mla_decode(params, cfg, x[:, S - 1:S], cache,
                               positions=p)
    ref = np.asarray(full[:, -1], np.float32)
    got = np.asarray(y_dec[:, 0], np.float32)
    rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
    # observed ~0.005 (bf16-expanded vs fp32-absorbed reassociation);
    # 0.02 budget leaves room for seed jitter, not for a real math bug
    assert rel < 0.02, f"MLA absorbed decode diverges at layer: {rel}"


def test_retained_decode_runs():
    """long_500k path: ring-buffer cache + window-filter-off decode."""
    cfg = configs.smoke("llama3_2_1b")
    cfg = dataclasses.replace(cfg, retained_prefix=8, retained_window=32)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    caches = lm.init_cache(2, 8 + 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for pos in [0, 5, 39, 40, 100, 5000]:
        p = jnp.full((2,), pos, jnp.int32)
        lg, caches = lm.decode_step(params, tok, caches, p, retained=True)
        assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())


def test_input_specs_cover_all_cells():
    for name in ARCHS:
        for shape in configs.SHAPES:
            kind, kw = configs.input_specs(name, shape)
            assert kind in ("train", "prefill", "decode")
            leaves = jax.tree.leaves(kw)
            assert all(hasattr(l, "shape") for l in leaves
                       if not isinstance(l, bool))
