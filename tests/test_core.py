"""Core library invariants: BSR container, static partitioner, TP SpMM.
Structural invariants are exercised as seeded parametrize sweeps (no
hypothesis dependency -- the sweeps are deterministic and CI-friendly)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masks, partitioner, static_sparse as ssp
from repro.core.bsr import BlockSparseMatrix


def _sweep(seed: int, n: int, *axes):
    """Deterministic pseudo-random parameter sweep: ``n`` tuples drawn
    from the cartesian space of ``axes`` (each axis a list of values)."""
    rng = np.random.RandomState(seed)
    return [tuple(ax[rng.randint(len(ax))] for ax in axes)
            for _ in range(n)]


# -- BSR ------------------------------------------------------------------------

@pytest.mark.parametrize(
    "mb,kb,b,density",
    _sweep(0, 12, list(range(1, 9)), list(range(1, 9)), [1, 4, 8, 16],
           [0.05, 0.2, 0.5, 0.8, 1.0]))
def test_bsr_dense_roundtrip(mb, kb, b, density):
    m, k = mb * b, kb * b
    mask = masks.random_block_mask(m, k, b, density, seed=mb * 7 + kb)
    bsr = BlockSparseMatrix.from_mask(mask, b, init="normal",
                                      key=jax.random.PRNGKey(0))
    dense = bsr.to_dense()
    back = BlockSparseMatrix.from_dense(dense, b)
    np.testing.assert_allclose(np.asarray(back.to_dense()),
                               np.asarray(dense), rtol=1e-6)
    assert back.nnz_blocks <= bsr.nnz_blocks  # zero-valued blocks may drop


def test_bsr_block_mask_roundtrip():
    mask = masks.random_block_mask(128, 256, 16, 0.3, seed=3)
    bsr = BlockSparseMatrix.from_mask(mask, 16)
    assert (bsr.block_mask() == mask).all()


# -- static partitioner ------------------------------------------------------------

@pytest.mark.parametrize(
    "kb,q,seed",
    _sweep(1, 16, list(range(4, 65)), list(range(1, 9)),
           list(range(100))))
def test_balanced_splits_cover_and_monotone(kb, q, seed):
    q = min(q, kb)
    mask = masks.random_block_mask(kb * 4, kb * 4, 4, 0.3, seed=seed)
    bounds = partitioner.balanced_k_splits(mask, q)
    assert bounds[0] == 0 and bounds[-1] == mask.shape[1]
    assert (np.diff(bounds) >= 1).all()


def test_balanced_beats_even_on_skewed_pattern():
    """The paper's Fig 1a claim: nnz-balanced uneven splits beat fixed
    equal splits on a skewed pattern."""
    kb = 64
    mask = np.zeros((32, kb), bool)
    mask[:, :8] = True          # all nnz in the first 8 block-cols
    mask[0, :] = True
    q = 8
    bounds_bal = partitioner.balanced_k_splits(mask, q)
    col_nnz = mask.sum(0)
    loads_bal = [col_nnz[a:z].sum() for a, z in
                 zip(bounds_bal[:-1], bounds_bal[1:])]
    bounds_even = partitioner.even_k_splits(kb, q)
    loads_even = [col_nnz[a:z].sum() for a, z in
                  zip(bounds_even[:-1], bounds_even[1:])]
    assert max(loads_bal) < max(loads_even)


def test_balanced_splits_power_law_columns_no_worse_than_even():
    """PR 8 regression: on power-law column mass the nnz-balanced walk
    must not report worse shard imbalance than fixed even splits (the
    old greedy emitted forced 1-column sliver shards)."""
    kb, q = 64, 8
    mask = np.zeros((64, kb), bool)
    for j in range(kb):
        c = max(1, int(64 * (j + 1) ** -1.2))
        mask[:c, j] = True
    col_nnz = mask.sum(0)

    def loads(bounds):
        return np.array([col_nnz[a:z].sum() for a, z in
                         zip(bounds[:-1], bounds[1:])])

    rep_bal = partitioner.balance_report(
        loads(partitioner.balanced_k_splits(mask, q)))
    rep_even = partitioner.balance_report(
        loads(partitioner.even_k_splits(kb, q)))
    assert rep_bal["imbalance"] <= rep_even["imbalance"] + 1e-9


@pytest.mark.parametrize("where", ["prefix", "suffix"])
def test_balanced_splits_spread_empty_columns(where):
    """Degenerate skew: all nnz in a zero-column suffix/prefix used to
    force 1-column sliver shards; empty columns must now spread evenly
    across shards instead."""
    kb, q = 8, 4
    mask = np.zeros((4, kb), bool)
    mask[:, -1 if where == "prefix" else 0] = True
    bounds = partitioner.balanced_k_splits(mask, q)
    widths = np.diff(bounds)
    assert widths.max() - widths.min() <= 1      # near-even widths


@pytest.mark.parametrize(
    "seed,q", _sweep(2, 9, list(range(51)), [2, 4, 8]))
def test_shard_blocks_partition_of_blocks(seed, q):
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(seed), 128, 256, 8,
                                   0.4, pattern_seed=seed)
    sb = partitioner.shard_blocks_by_k(bsr, q)
    assert sb.real_counts.sum() == bsr.nnz_blocks
    # every real block's column lies within its shard's bounds
    for s in range(q):
        cnt = sb.real_counts[s]
        cols = np.asarray(sb.col_idx[s][:cnt])
        assert (cols >= sb.boundaries[s]).all()
        assert (cols < sb.boundaries[s + 1]).all()


def test_sharded_spmm_matches_dense():
    """Stacked shard layout computes the same product (the paper's
    distribute->local-dot->reduce equals the undistributed matmul)."""
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 128, 256, 8, 0.3)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    sb = partitioner.shard_blocks_by_k(bsr, 4)
    from repro.core.tp import tp_spmm_gspmd
    y = tp_spmm_gspmd(sb, x)
    want = jnp.asarray(bsr.to_dense()) @ x
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_pack_tiles_reconstruction():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 256, 256, 16, 0.2)
    packing = partitioner.pack_tiles(bsr, 128, 128)
    # scatter tiles back into a dense matrix
    dense = np.zeros(packing.shape, np.float32)
    for t in range(packing.num_tiles):
        r, c = int(packing.tile_rows[t]), int(packing.tile_cols[t])
        dense[r * 128:(r + 1) * 128, c * 128:(c + 1) * 128] += \
            np.asarray(packing.values[t])
    np.testing.assert_allclose(dense, np.asarray(bsr.to_dense()), rtol=1e-6)
    assert 0 < packing.occupancy <= 1.0


# -- static SpMM + autodiff ----------------------------------------------------------

def test_spmm_grads_match_dense():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 64, 96, 8, 0.5)
    x = jax.random.normal(jax.random.PRNGKey(1), (96, 16))
    rows, cols = np.asarray(bsr.row_idx), np.asarray(bsr.col_idx)
    f = ssp.make_spmm(rows, cols, bsr.grid, bsr.block_size)

    def loss_sparse(values, x):
        return (f(values, x) ** 2).sum()

    def loss_dense(values, x):
        d = bsr.with_values(values).to_dense()
        return ((d @ x) ** 2).sum()

    gv_s, gx_s = jax.grad(loss_sparse, argnums=(0, 1))(
        jnp.asarray(bsr.values), x)
    gv_d, gx_d = jax.grad(loss_dense, argnums=(0, 1))(
        jnp.asarray(bsr.values), x)
    np.testing.assert_allclose(np.asarray(gx_s), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gv_s), np.asarray(gv_d),
                               rtol=1e-4, atol=1e-4)


def test_spmm_t_and_sddmm():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 64, 96, 8, 0.5)
    dy = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    x = jax.random.normal(jax.random.PRNGKey(2), (96, 16))
    got_t = ssp.spmm_t(bsr, dy)
    want_t = jnp.asarray(bsr.to_dense()).T @ dy
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(want_t),
                               rtol=1e-4, atol=1e-4)
    got_s = ssp.sddmm(bsr, dy, x)
    full = dy @ x.T                         # [m, k]
    b = bsr.block_size
    for z in range(bsr.nnz_blocks):
        r, c = int(bsr.row_idx[z]), int(bsr.col_idx[z])
        np.testing.assert_allclose(
            np.asarray(got_s[z]),
            np.asarray(full[r * b:(r + 1) * b, c * b:(c + 1) * b]),
            rtol=1e-4, atol=1e-4)


def test_flops_accounting():
    from repro.core.bsr import dense_flops, sparse_flops
    assert dense_flops(64, 64, 8) == 2 * 64 * 64 * 8
    # paper §3: sparse FLOPs do not depend on block size
    assert sparse_flops(64, 64, 8, 0.25) == 2 * 64 * 64 * 8 * 0.25
