"""repro-lint conformance: every rule fires on a seeded violation and
stays quiet on a clean twin; suppressions work; every dispatch route has
a kernel CONTRACT and the checker rejects mis-declared ones."""
import json
import os
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from tools.lint.engine import FileContext, lint_paths  # noqa: E402
from tools.lint import rules as R  # noqa: E402
from tools.lint.contracts import check_contracts  # noqa: E402
from repro.kernels.contract import KernelContract  # noqa: E402

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _ctx(path, source):
    return FileContext(path, textwrap.dedent(source))


def _findings(rule, path, source):
    return rule.check(_ctx(path, source))


# ---------------------------------------------------------------------------
# R001 dispatch-bypass
# ---------------------------------------------------------------------------

def test_r001_fires_on_direct_kernel_import():
    src = """\
    import numpy as np
    from repro.kernels.bsmm import ops as bsmm_ops
    """
    out = _findings(R.DispatchBypass(), "src/repro/serve/engine.py", src)
    assert len(out) == 1
    assert out[0].rule == "R001" and out[0].line == 2
    assert "repro.kernels.bsmm" in out[0].message


def test_r001_clean_on_dispatch_entry():
    src = """\
    from repro.core import dispatch
    from repro import sparse
    """
    assert _findings(R.DispatchBypass(), "src/repro/serve/engine.py",
                     src) == []


def test_r001_allows_dispatch_plan_kernels_and_kernel_tests():
    src = "from repro.kernels.gmm import ops as gmm_ops\n"
    for path in ("src/repro/core/dispatch.py", "src/repro/sparse/plan.py",
                 "src/repro/kernels/gmm/ops.py", "tests/test_kernels.py"):
        assert _findings(R.DispatchBypass(), path, src) == []


def test_r001_allows_contract_metadata_import():
    src = "from repro.kernels.contract import KernelContract\n"
    assert _findings(R.DispatchBypass(), "src/repro/serve/engine.py",
                     src) == []


# ---------------------------------------------------------------------------
# R002 tracer-unsafe branching
# ---------------------------------------------------------------------------

def test_r002_fires_on_value_branch_in_jit():
    src = """\
    import jax

    @jax.jit
    def f(x):
        if x > 0:
            return x
        return -x
    """
    out = _findings(R.TracerUnsafeBranch(), "src/repro/core/foo.py", src)
    assert [f.rule for f in out] == ["R002"]
    assert out[0].line == 5


def test_r002_fires_in_plan_execute_closure():
    src = """\
    def build(meta):
        def run(values, x):
            while values:
                x = x + 1
            return x
        return run
    """
    out = _findings(R.TracerUnsafeBranch(), "src/repro/sparse/foo.py", src)
    assert [f.rule for f in out] == ["R002"]


def test_r002_clean_on_static_properties_and_plain_functions():
    src = """\
    import jax

    @jax.jit
    def f(x, y):
        if x.ndim == 3:
            return x
        if y is None:
            return x
        assert isinstance(x, object)
        return x * 2

    def not_jitted(x):
        if x > 0:
            return x
        return -x

    class Engine:
        def run(self, x):
            if x > 0:
                return x
            return -x
    """
    assert _findings(R.TracerUnsafeBranch(), "src/repro/core/foo.py",
                     src) == []


def test_r002_scoped_to_src_repro():
    src = """\
    import jax

    @jax.jit
    def f(x):
        if x > 0:
            return x
        return -x
    """
    assert _findings(R.TracerUnsafeBranch(), "benchmarks/foo.py", src) == []


# ---------------------------------------------------------------------------
# R003 host sync in hot path
# ---------------------------------------------------------------------------

def test_r003_fires_on_block_until_ready_in_jit_scope():
    src = """\
    import jax

    def build():
        def run(values, x):
            y = values @ x
            y.block_until_ready()
            return y
        return run
    """
    out = _findings(R.HostSyncInHotPath(), "src/repro/sparse/foo.py", src)
    assert [f.rule for f in out] == ["R003"]
    assert out[0].line == 6


def test_r003_fires_on_non_telemetry_callback():
    src = """\
    import jax

    def build():
        def run(values, x):
            jax.debug.callback(print, values)
            return values @ x
        return run
    """
    out = _findings(R.HostSyncInHotPath(), "src/repro/sparse/foo.py", src)
    assert [f.rule for f in out] == ["R003"]


def test_r003_allows_telemetry_record_callback():
    src = """\
    import jax

    def build(stats):
        def run(values, x):
            jax.debug.callback(stats.record, 0, 0, 0, 0.0)
            return values @ x
        return run
    """
    assert _findings(R.HostSyncInHotPath(), "src/repro/sparse/foo.py",
                     src) == []


def test_r003_allows_host_sync_outside_jit_scope():
    src = """\
    def measure(fn, x):
        y = fn(x)
        y.block_until_ready()
        return y
    """
    assert _findings(R.HostSyncInHotPath(), "src/repro/core/foo.py",
                     src) == []


# ---------------------------------------------------------------------------
# R004 persisted-schema drift
# ---------------------------------------------------------------------------

def test_r004_fingerprint_matches_committed_baseline():
    current = R.compute_schema_fingerprint(REPO_ROOT)
    with open(R.BASELINE_PATH) as f:
        baseline = json.load(f)
    assert current == baseline, (
        "persisted schema drifted from tools/lint/schema_baseline.json: "
        "bump sparse/cache.py SCHEMA_VERSION and run "
        "`python -m tools.lint --update-baseline`")


def test_r004_detects_drift_without_version_bump(monkeypatch, tmp_path):
    baseline = R.compute_schema_fingerprint(REPO_ROOT)
    baseline["fields"]["OpSpec"] = [
        f for f in baseline["fields"]["OpSpec"] if f != "density"]
    fake = tmp_path / "schema_baseline.json"
    fake.write_text(json.dumps(baseline))
    monkeypatch.setattr(R, "BASELINE_PATH", str(fake))
    out = R.PersistedSchemaDrift().check_repo([], REPO_ROOT)
    assert [f.rule for f in out] == ["R004"]
    assert "without a SCHEMA_VERSION bump" in out[0].message
    assert "+density" in out[0].message


def test_r004_detects_stale_baseline_after_version_bump(monkeypatch,
                                                        tmp_path):
    baseline = R.compute_schema_fingerprint(REPO_ROOT)
    baseline["schema_version"] -= 1
    fake = tmp_path / "schema_baseline.json"
    fake.write_text(json.dumps(baseline))
    monkeypatch.setattr(R, "BASELINE_PATH", str(fake))
    out = R.PersistedSchemaDrift().check_repo([], REPO_ROOT)
    assert [f.rule for f in out] == ["R004"]
    assert "--update-baseline" in out[0].message


# ---------------------------------------------------------------------------
# R005 nondeterministic benchmark code
# ---------------------------------------------------------------------------

def test_r005_fires_on_wallclock_and_unseeded_rng():
    src = """\
    import time
    import numpy as np

    def bench():
        t0 = time.time()
        x = np.random.rand(4, 4)
        rng = np.random.default_rng()
        return time.perf_counter() - t0, x, rng
    """
    out = _findings(R.NondeterministicBenchmark(), "benchmarks/foo.py", src)
    assert sorted((f.rule, f.line) for f in out) == [
        ("R005", 5), ("R005", 6), ("R005", 7), ("R005", 8)]


def test_r005_clean_on_seeded_rng_and_harness_file():
    seeded = """\
    import numpy as np

    def bench():
        rng = np.random.default_rng(0)
        return rng.normal(size=(4, 4))
    """
    assert _findings(R.NondeterministicBenchmark(), "benchmarks/foo.py",
                     seeded) == []
    harness = """\
    import time

    def measure(fn):
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0
    """
    assert _findings(R.NondeterministicBenchmark(),
                     "benchmarks/bench_walltime.py", harness) == []


def test_r005_scoped_to_benchmarks():
    src = """\
    import time

    def f():
        return time.time()
    """
    assert _findings(R.NondeterministicBenchmark(), "src/repro/foo.py",
                     src) == []


# ---------------------------------------------------------------------------
# suppressions + engine
# ---------------------------------------------------------------------------

def _write(tmp_path, rel, source):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))


def test_suppression_same_line(tmp_path):
    _write(tmp_path, "src/repro/foo.py",
           "from repro.kernels.bsmm import ops  "
           "# repro-lint: disable=R001\n")
    findings, _ = lint_paths(["src"], repo_root=str(tmp_path))
    assert findings == []


def test_suppression_next_line(tmp_path):
    _write(tmp_path, "src/repro/foo.py", """\
    # repro-lint: disable-next-line=R001
    from repro.kernels.bsmm import ops
    """)
    findings, _ = lint_paths(["src"], repo_root=str(tmp_path))
    assert findings == []


def test_suppression_file_level(tmp_path):
    _write(tmp_path, "src/repro/foo.py", """\
    # repro-lint: disable-file=R001
    from repro.kernels.bsmm import ops
    from repro.kernels.gmm import ops as gmm_ops
    """)
    findings, _ = lint_paths(["src"], repo_root=str(tmp_path))
    assert findings == []


def test_suppression_wrong_rule_id_does_not_mask(tmp_path):
    _write(tmp_path, "src/repro/foo.py",
           "from repro.kernels.bsmm import ops  "
           "# repro-lint: disable=R005\n")
    findings, _ = lint_paths(["src"], repo_root=str(tmp_path))
    assert [f.rule for f in findings] == ["R001"]


def test_engine_reports_findings_with_location(tmp_path):
    _write(tmp_path, "src/repro/foo.py", """\
    import jax
    from repro.kernels.bsmm import ops
    """)
    findings, files = lint_paths(["src"], repo_root=str(tmp_path))
    assert len(files) == 1
    assert [(f.rule, f.path, f.line) for f in findings] == [
        ("R001", "src/repro/foo.py", 2)]
    assert findings[0].format().startswith("src/repro/foo.py:2: R001")
    assert findings[0].to_json()["rule"] == "R001"


def test_repo_at_head_is_clean():
    """The acceptance gate: `python -m tools.lint src tools benchmarks`
    exits 0 on HEAD."""
    findings, files = lint_paths(["src", "tools", "benchmarks"],
                                 repo_root=REPO_ROOT)
    assert findings == [], "\n".join(f.format() for f in findings)
    assert len(files) > 50


# ---------------------------------------------------------------------------
# kernel contract checker
# ---------------------------------------------------------------------------

def test_every_route_has_a_contract():
    from repro.core import dispatch
    from repro.kernels import contract
    registry = contract.load_all()
    for route in dispatch.ROUTES + dispatch.SDDMM_ROUTES:
        c = contract.contract_for_route(route)
        assert c is not None, f"route {route!r} has no kernel CONTRACT"
        assert c.grid.strip(), f"route {route!r} contract lacks a grid"
        for dt in dispatch.SUPPORTED_DTYPES:
            assert dt in c.dtypes, f"route {route!r} misses dtype {dt}"


def test_contract_checker_clean_on_head():
    assert check_contracts() == []


def test_misdeclared_route_fails_with_route_naming_error():
    bad = KernelContract(
        kernel="bsmm_typo",
        routes=("static_palas",),  # deliberate typo
        dtypes=("float32", "bfloat16", "float16"),
        min_block=1, max_block=128,
        divisibility=("m % b == 0",),
        grid="x", capacity="exact", pallas=True)
    out = check_contracts(registry={"bsmm_typo": bad})
    naming = [f for f in out if f.rule == "C001"
              and "unknown route 'static_palas'" in f.message]
    assert naming, [f.message for f in out]
    # and the real routes are now uncovered
    assert any(f.rule == "C001" and "no declared kernel CONTRACT"
               in f.message for f in out)


def test_contract_gate_disagreement_detected():
    """A contract that rejects shapes the admissibility gate offers the
    route for must fail C003."""
    from repro.kernels import contract
    registry = dict(contract.load_all())
    narrow = KernelContract(
        kernel="bsmm_narrow",
        routes=("static_pallas",),
        dtypes=("float32", "bfloat16", "float16"),
        min_block=1, max_block=128,
        divisibility=("m % 999 == 0",),   # rejects every probe
        grid="x", capacity="exact", pallas=True)
    registry = {k: v for k, v in registry.items() if k != "bsmm"}
    registry["bsmm_narrow"] = narrow
    out = check_contracts(registry=registry)
    assert any(f.rule == "C003" and "static_pallas" in f.message
               for f in out), [f.message for f in out]


def test_contract_validator_agreement_detected():
    """A grouped contract that admits shapes grouped_tile_size rejects
    (or vice versa) must fail C003."""
    from repro.kernels import contract
    registry = dict(contract.load_all())
    lax = KernelContract(
        kernel="gmm_lax",
        routes=("dynamic_grouped",),
        dtypes=("float32", "bfloat16", "float16"),
        min_block=1, max_block=128,
        divisibility=(),                  # admits un-tileable shapes
        grid="x", capacity="planned_bucket", pallas=True)
    registry = {k: v for k, v in registry.items() if k != "gmm"}
    registry["gmm_lax"] = lax
    out = check_contracts(registry=registry)
    assert any(f.rule == "C003" and "dynamic_grouped" in f.message
               and "grouped_tile_size" in f.message for f in out), \
        [f.message for f in out]


def test_wrong_pallas_flag_detected():
    from repro.kernels import contract
    registry = dict(contract.load_all())
    flipped = KernelContract(
        kernel="dense_xla_flipped",
        routes=("dense_xla",),
        dtypes=("float32", "bfloat16", "float16"),
        min_block=1, max_block=1024,
        divisibility=(),
        grid="x", capacity="dense", pallas=True)  # xla route, pallas flag
    registry = {k: v for k, v in registry.items() if k != "dense_xla"}
    registry["dense_xla_flipped"] = flipped
    out = check_contracts(registry=registry)
    assert any(f.rule == "C004" and "dense_xla" in f.message
               for f in out), [f.message for f in out]


def test_contract_admits_reports_reasons():
    from repro.kernels import contract
    c = contract.load_all()["gmm"]
    assert c.admits(128, 128, 64, 32) is None
    assert "dtype" in c.admits(128, 128, 64, 32, "int8")
    assert "block" in c.admits(128, 128, 64, 256)
    reason = c.admits(100, 64, 64, 32)
    assert reason is not None and "constraint" in reason


def test_cli_exits_nonzero_on_seeded_violation(tmp_path):
    """End-to-end: the module CLI exits 1 when pointed at a violation."""
    import subprocess
    bad = tmp_path / "bad_bench.py"
    bad.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    # the CLI lints repo-relative paths; hand it the absolute file but a
    # benchmarks-like name is required for R005 -- use R001 instead,
    # which only needs a src/repro-external path
    bad.write_text("from repro.kernels.bsmm import ops\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.lint", str(bad)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "R001" in proc.stdout
