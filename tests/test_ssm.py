"""Mamba-2 SSD: chunked scan vs naive recurrence; decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_scan


# model-level SSM blocks: excluded from the fast tier-1 run (see pytest.ini)
pytestmark = pytest.mark.slow


def _naive_recurrence(x, dt, A, B, C):
    """Token-by-token SSM: h = h*exp(dt*A) + dt*B x; y = C.h"""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(B), rep, axis=2)
    Ch = np.repeat(np.asarray(C), rep, axis=2)
    xs, dts = np.asarray(x), np.asarray(dt)
    Ah = np.asarray(A)
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    for t in range(s):
        decay = np.exp(dts[:, t] * Ah)                   # [b, h]
        state = state * decay[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dts[:, t], xs[:, t], Bh[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return ys, state


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 16)])
@pytest.mark.parametrize("groups", [1, 2])
def test_ssd_scan_matches_recurrence(s, chunk, groups):
    b, h, p, n = 2, 4, 8, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, groups, n)) * 0.5
    C = jax.random.normal(jax.random.PRNGKey(9), (b, s, groups, n)) * 0.5
    y, state = ssd_scan(x, dt, A, B, C, chunk=chunk)
    y_ref, state_ref = _naive_recurrence(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref,
                               rtol=2e-4, atol=2e-4)


def test_ssm_train_decode_parity():
    """Running the block one token at a time reproduces the full-seq
    output (conv cache + state handoff)."""
    from repro.configs import mamba2_130m
    from repro.models.ssm import ssm_init, ssm_train, ssm_decode, \
        ssm_cache_init
    cfg = mamba2_130m.make_smoke_config()
    params = ssm_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32) * 0.5
    y_full = ssm_train(params, cfg, x)
    cache = ssm_cache_init(cfg, b, dtype=jnp.float32)
    outs = []
    for t in range(s):
        y_t, cache = ssm_decode(params, cfg, x[:, t:t + 1], cache)
        outs.append(y_t)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=5e-3, atol=5e-3)


def test_ssd_scan_long_state_stability():
    """Decay keeps the state bounded over long sequences."""
    b, s, h, p, n = 1, 512, 2, 4, 8
    x = jnp.ones((b, s, h, p)) * 0.1
    dt = jnp.ones((b, s, h)) * 0.5
    A = -jnp.ones((h,))
    B = jnp.ones((b, s, 1, n)) * 0.1
    C = jnp.ones((b, s, 1, n)) * 0.1
    y, state = ssd_scan(x, dt, A, B, C, chunk=64)
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(state)).max() < 10.0
