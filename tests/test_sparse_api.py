"""Plan-first sparse API (repro.sparse): two-phase plan/execute
lifecycle, route parity, jit/grad/vmap safety, disk-cache round trip +
stale invalidation, deprecation-shim parity, and the DynamicOperand
grid/validation fixes that ride along."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import dispatch, dynamic_sparse as dsp, \
    static_sparse as ssp
from repro.core.bsr import BlockSparseMatrix

M, K, N, B, DENSITY = 128, 256, 64, 16, 0.25


@pytest.fixture(autouse=True)
def _fresh_state():
    sparse.reset()
    sparse.configure(None)
    yield
    sparse.reset()
    sparse.configure(None)


def _bsr(seed=0, m=M, k=K, b=B, d=DENSITY, dtype=jnp.float32):
    return BlockSparseMatrix.random(jax.random.PRNGKey(seed), m, k, b, d,
                                    dtype=dtype, pattern_seed=seed)


def _problem(seed=0, dtype=jnp.float32):
    bsr = _bsr(seed, dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100),
                          (K, N)).astype(dtype)
    oracle = jnp.asarray(bsr.to_dense()) @ x
    return bsr, x, oracle


# -- plan construction + route parity -----------------------------------------

STATIC_ROUTES = ["static_xla", "dense_xla", "dynamic_xla"]
STATIC_INTERPRET = ["static_pallas", "dense_pallas", "dynamic_pallas",
                    "dynamic_grouped"]


@pytest.mark.parametrize("route", STATIC_ROUTES)
def test_static_plan_route_parity(route):
    bsr, x, oracle = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mode=route))
    assert p.route == route and p.executable
    np.testing.assert_allclose(np.asarray(p(bsr.values, x)),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("route", STATIC_INTERPRET)
def test_static_plan_route_parity_interpret(route):
    bsr, x, oracle = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mode=route,
                                                   interpret=True))
    np.testing.assert_allclose(np.asarray(p(bsr.values, x)),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("route", ["dynamic_xla", "dense_xla"])
def test_dynamic_plan_route_parity(route):
    bsr, x, oracle = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 4)
    p = sparse.plan(op, N, ctx=sparse.PlanContext(mode=route))
    np.testing.assert_allclose(np.asarray(p(op, x)), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)
    # bucket sizing ran at plan time
    assert p.artifacts["bucket_blocks"] >= 1


@pytest.mark.parametrize("route", ["dynamic_pallas", "dynamic_grouped"])
def test_dynamic_plan_pallas_parity_interpret(route):
    bsr, x, oracle = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 4)
    p = sparse.plan(op, N, ctx=sparse.PlanContext(mode=route,
                                                  interpret=True))
    np.testing.assert_allclose(np.asarray(p(op, x)), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_static_tp_plan_parity():
    """Mesh-aware route: nnz-balanced k-shards + one reduction."""
    bsr, x, oracle = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mode="static_tp",
                                                   tp_q=4))
    assert p.route == "static_tp"
    assert p.artifacts["tp_q"] == 4
    np.testing.assert_allclose(np.asarray(p(bsr.values, x)),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)


def test_auto_plan_parity_and_artifacts():
    bsr, x, oracle = _problem()
    p = sparse.plan(bsr, N)
    np.testing.assert_allclose(np.asarray(p.apply(bsr, x)),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)
    rep = p.explain()
    assert rep["chosen"] == p.route and rep["chosen"] in rep["candidates"]
    assert "plan" in rep and rep["plan"]["executable"]
    assert "dispatch" in sparse.format_plan(p)   # renders the report


def test_spec_only_static_plan_is_report_only():
    spec = sparse.OpSpec(kind="static", m=M, k=K, n=N, block_size=B,
                         density=DENSITY)
    p = sparse.plan(spec)
    assert not p.executable and p.route in sparse.PLAN_ROUTES
    with pytest.raises(ValueError, match="report-only|OpSpec"):
        p(jnp.zeros((1, B, B)), jnp.zeros((K, N)))


def test_spec_only_dynamic_and_dense_plans_execute():
    bsr, x, oracle = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    spec = sparse.OpSpec.from_operand(op, N)
    p = sparse.plan(spec, ctx=sparse.PlanContext(mode="dynamic_xla"))
    np.testing.assert_allclose(np.asarray(p(op, x)), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


# -- plan reuse: cache hits, jit, grad, vmap ----------------------------------

def test_plan_cache_reuse_same_pattern():
    bsr, x, _ = _problem()
    p1 = sparse.plan(bsr, N)
    p2 = sparse.plan(bsr.with_values(bsr.values * 2), N)
    assert p2 is p1                       # same pattern -> same plan obj
    assert sparse.cache_stats()["plan_hits"] == 1
    # a *different* pattern with the same fingerprint must NOT collide
    other = _bsr(seed=7)
    p3 = sparse.plan(other, N)
    assert p3 is not p1
    np.testing.assert_allclose(
        np.asarray(p3(other.values, x)),
        np.asarray(jnp.asarray(other.to_dense()) @ x), rtol=1e-4,
        atol=1e-4)


def test_plan_under_jit_grad_vmap():
    bsr, x, oracle = _problem()
    p = sparse.plan(bsr, N)

    # jit: the plan is closed over; the route is baked into the program
    f = jax.jit(lambda v, xx: p(v, xx))
    np.testing.assert_allclose(np.asarray(f(jnp.asarray(bsr.values), x)),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)

    # grad matches the dense formulation
    def loss_sparse(values, xx):
        return (p(values, xx) ** 2).sum()

    def loss_dense(values, xx):
        return ((bsr.with_values(values).to_dense() @ xx) ** 2).sum()

    gv_s, gx_s = jax.grad(loss_sparse, argnums=(0, 1))(
        jnp.asarray(bsr.values), x)
    gv_d, gx_d = jax.grad(loss_dense, argnums=(0, 1))(
        jnp.asarray(bsr.values), x)
    np.testing.assert_allclose(np.asarray(gv_s), np.asarray(gv_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx_s), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-4)

    # vmap over a batch of activations
    xb = jax.random.normal(jax.random.PRNGKey(9), (3, K, 8))
    yv = jax.vmap(lambda xx: p(jnp.asarray(bsr.values), xx))(xb)
    want = jnp.einsum("mk,bkn->bmn", jnp.asarray(bsr.to_dense()), xb)
    np.testing.assert_allclose(np.asarray(yv), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_plan_vjp_helper():
    bsr, x, _ = _problem()
    p = sparse.plan(bsr, N)
    y, vjp_fn = p.vjp(jnp.asarray(bsr.values), x)
    gv, gx = vjp_fn(jnp.ones_like(y))
    assert gv.shape == bsr.values.shape and gx.shape == x.shape


def test_steady_state_is_decision_free():
    """After the first plan, repeated calls make NO new decisions."""
    bsr, x, _ = _problem()
    sparse.spmm(bsr, x)
    base = sparse.cache_stats()
    for _ in range(5):
        sparse.spmm(bsr, x)
    now = sparse.cache_stats()
    assert now["decisions"] == base["decisions"]
    assert now["plans_built"] == base["plans_built"]
    assert now["plan_hits"] == base["plan_hits"] + 5


# -- persistent cache ---------------------------------------------------------

def test_disk_cache_round_trip(tmp_path):
    """Write in 'process 1', reset all in-memory state, re-plan in
    'process 2' with zero measurements (the acceptance criterion)."""
    bsr, x, _ = _problem()
    ctx = sparse.PlanContext(measure=True, cache_dir=str(tmp_path))
    p1 = sparse.plan(bsr, N, x=x, ctx=ctx)
    s1 = sparse.cache_stats()
    # two measurement events: the forward route race + the backward
    # (dx/dvalues) race -- both verdicts persist in one record
    assert s1["measurements"] == 2 and s1["disk_writes"] >= 1
    assert p1.source == "measured" and not p1.from_disk

    sparse.reset()                        # fresh-process simulation
    p2 = sparse.plan(bsr, N, x=x, ctx=ctx)
    s2 = sparse.cache_stats()
    assert s2["measurements"] == 0        # zero re-measurement
    assert s2["disk_hits"] == 1
    assert p2.from_disk and p2.route == p1.route
    assert p2.executable
    np.testing.assert_allclose(np.asarray(p2(bsr.values, x)),
                               np.asarray(p1(bsr.values, x)),
                               rtol=1e-5, atol=1e-5)


def test_disk_cache_stale_version_invalidated(tmp_path):
    bsr, x, _ = _problem()
    ctx = sparse.PlanContext(measure=True, cache_dir=str(tmp_path))
    sparse.plan(bsr, N, x=x, ctx=ctx)
    path = os.path.join(str(tmp_path),
                        f"sparse-plans-v{sparse.SCHEMA_VERSION}.json")
    blob = json.load(open(path))
    blob["env"]["jax"] = "0.0.0-stale"
    json.dump(blob, open(path, "w"))

    sparse.reset()
    p = sparse.plan(bsr, N, x=x, ctx=ctx)
    s = sparse.cache_stats()
    assert not p.from_disk and s["stale_drops"] == 1
    assert s["measurements"] == 2         # re-measured (fwd + bwd), then
    #                                       re-persisted
    blob2 = json.load(open(path))
    assert blob2["env"]["jax"] != "0.0.0-stale"


def test_disk_cache_carries_capacity_fields(tmp_path):
    """Persisted dynamic_grouped plans carry the planned-capacity
    section (tile, tiles_cap, headroom, ...) and a fresh process
    re-plans to the identical bucket."""
    bsr, x, _ = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 4)
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             cache_dir=str(tmp_path))
    p1 = sparse.plan(op, N, ctx=ctx)
    path = os.path.join(str(tmp_path),
                        f"sparse-plans-v{sparse.SCHEMA_VERSION}.json")
    blob = json.load(open(path))
    rec = blob["entries"][p1.key]
    assert rec["route"] == "dynamic_grouped"
    cap = rec["capacity"]
    assert cap["tiles_cap"] == p1.artifacts["grouped_tiles_cap"]
    assert cap["headroom"] == ctx.resolved_headroom()
    assert {"tile", "expected_tiles", "worst_tiles", "overflow_p",
            "policy"} <= set(cap)

    sparse.reset()                        # fresh-process simulation
    p2 = sparse.plan(op, N, ctx=ctx)
    assert p2.from_disk
    assert p2.artifacts["grouped_tiles_cap"] == cap["tiles_cap"]
    np.testing.assert_allclose(np.asarray(p2(op, x)),
                               np.asarray(p1(op, x)), rtol=0, atol=0)


def test_pre_capacity_cache_version_invalidated(tmp_path):
    """A cache written before the capacity schema (old version tag in
    the file name AND env) must be ignored -- never mis-read as a
    planned-capacity verdict."""
    bsr, x, _ = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 4)
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             cache_dir=str(tmp_path))
    key = sparse.plan(op, N, ctx=ctx).key
    sparse.reset()
    # simulate the pre-PR cache: v1 file name, v1 env tag, a record for
    # the same key with NO capacity section and a different route
    old = {"env": {"schema": 1, "backend": "cpu", "jax": "0.4.0"},
           "entries": {key: {"route": "dynamic_xla",
                             "source": "analytic", "est_seconds": {}}}}
    os.remove(os.path.join(
        str(tmp_path), f"sparse-plans-v{sparse.SCHEMA_VERSION}.json"))
    with open(os.path.join(str(tmp_path), "sparse-plans-v1.json"),
              "w") as f:
        json.dump(old, f)
    p = sparse.plan(op, N, ctx=ctx)
    assert not p.from_disk                    # old tag never satisfies
    assert p.route == "dynamic_grouped"
    assert "capacity" in p.artifacts


def test_disk_cache_corrupt_file_ignored(tmp_path):
    bsr, x, _ = _problem()
    path = os.path.join(str(tmp_path),
                        f"sparse-plans-v{sparse.SCHEMA_VERSION}.json")
    with open(path, "w") as f:
        f.write("{not json")
    ctx = sparse.PlanContext(cache_dir=str(tmp_path))
    p = sparse.plan(bsr, N, ctx=ctx)
    assert not p.from_disk
    assert sparse.cache_stats()["stale_drops"] == 1


def test_no_persistence_without_cache_dir():
    bsr, x, _ = _problem()
    sparse.plan(bsr, N, ctx=sparse.PlanContext(measure=True), x=x)
    s = sparse.cache_stats()
    assert s["disk_writes"] == 0 and s["disk_hits"] == 0


def test_explicit_persist_without_dir_raises():
    bsr, _, _ = _problem()
    with pytest.raises(ValueError, match="no cache directory"):
        sparse.plan(bsr, N, ctx=sparse.PlanContext(persist=True))


def test_use_ctx_ambient_planning_context(tmp_path):
    bsr, x, _ = _problem()
    ctx = sparse.PlanContext(cache_dir=str(tmp_path))
    with sparse.use_ctx(ctx):
        sparse.spmm(bsr, x)               # picks up the ambient ctx
    assert sparse.cache_stats()["disk_writes"] >= 1
    # outside the scope, persistence is off again
    sparse.reset()
    sparse.spmm(bsr, x)
    assert sparse.cache_stats()["disk_writes"] == 0


def test_format_plan_dynamic_grouped_no_crash():
    bsr, x, _ = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    p = sparse.plan(op, N, ctx=sparse.PlanContext(mode="dynamic_grouped",
                                                  interpret=True))
    assert "grouped" in sparse.format_plan(p)


# -- deprecation-shim parity --------------------------------------------------

def test_dispatch_spmm_shim_matches_plan():
    bsr, x, oracle = _problem()
    y_shim = dispatch.spmm(bsr, x)
    p = sparse.plan(bsr, N)
    np.testing.assert_allclose(np.asarray(y_shim),
                               np.asarray(p(bsr.values, x)), rtol=0,
                               atol=0)
    np.testing.assert_allclose(np.asarray(y_shim), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)
    # the shim went through the plan cache
    assert sparse.cache_stats()["plans_built"] >= 1


def test_static_sparse_spmm_shim_matches_plan():
    bsr, x, oracle = _problem()
    y_shim = ssp.spmm(bsr, x, backend="xla")
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mode="static_xla"))
    np.testing.assert_allclose(np.asarray(y_shim),
                               np.asarray(p(bsr.values, x)), rtol=0,
                               atol=0)
    np.testing.assert_allclose(np.asarray(y_shim), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_dspmm_shim_matches_plan_and_supports_grouped():
    bsr, x, oracle = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 2)
    for backend, route in (("xla", "dynamic_xla"),):
        y_shim = dsp.dspmm(op, x, backend=backend)
        p = sparse.plan(op, N, ctx=sparse.PlanContext(mode=route))
        np.testing.assert_allclose(np.asarray(y_shim),
                                   np.asarray(p(op, x)), rtol=0, atol=0)
    y_grp = dsp.dspmm(op, x, backend="grouped", interpret=True)
    np.testing.assert_allclose(np.asarray(y_grp), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_sparse_matmul_and_batched_matmul():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    np.testing.assert_allclose(np.asarray(sparse.matmul(x, w)),
                               np.asarray(x @ w), rtol=1e-5, atol=1e-5)
    a = jax.random.normal(jax.random.PRNGKey(2), (3, 8, 16))
    b = jax.random.normal(jax.random.PRNGKey(3), (3, 16, 24))
    np.testing.assert_allclose(np.asarray(sparse.batched_matmul(a, b)),
                               np.asarray(jnp.matmul(a, b)), rtol=1e-5,
                               atol=1e-5)
    # second calls are plan-cache hits
    base = sparse.cache_stats()["plans_built"]
    sparse.matmul(x, w)
    sparse.batched_matmul(a, b)
    assert sparse.cache_stats()["plans_built"] == base


# -- dynamic_grouped as a dispatch candidate ----------------------------------

def test_dynamic_grouped_in_candidates():
    ctx = dispatch.DispatchContext(allow_pallas=True, differentiable=False)
    assert "dynamic_grouped" in dispatch._candidates("dynamic", ctx)
    # never offered to differentiable callers (forward-only kernel)
    grad_ctx = dispatch.DispatchContext(allow_pallas=True)
    assert "dynamic_grouped" not in dispatch._candidates("dynamic",
                                                         grad_ctx)


def test_dynamic_grouped_padded_capacity_exact_cap():
    """Padding slots (capacity > nnz) must not claim a tile slot: with
    tiles_cap == the exact true tile count the result is still exact."""
    # kernel-level capacity semantics under test: direct entry is
    # the point here, like tests/test_kernels.py
    from repro.kernels.gmm import ops as gmm_ops  # repro-lint: disable=R001
    bsr = _bsr(3, m=256, k=256, b=16, d=0.1)
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 7)  # padded
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    t = gmm_ops.grouped_tile_size(256, 256, 16)
    from repro.core.partitioner import plan_packing
    true_tiles = plan_packing(np.asarray(bsr.row_idx),
                              np.asarray(bsr.col_idx), (256, 256), 16,
                              t, t).num_tiles
    y = gmm_ops.grouped_spmm(op, x, tiles_cap=true_tiles, interpret=True)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.asarray(bsr.to_dense()) @ x),
        rtol=1e-4, atol=1e-4)


def test_dynamic_grouped_empty_operand_returns_zeros():
    # kernel-level capacity semantics under test: direct entry is
    # the point here, like tests/test_kernels.py
    from repro.kernels.gmm import ops as gmm_ops  # repro-lint: disable=R001
    op = dsp.DynamicOperand(jnp.zeros((0, 16, 16)),
                            jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), jnp.int32),
                            jnp.asarray(0, jnp.int32), (128, 128), 16)
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 8))
    y = gmm_ops.grouped_spmm(op, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=0.0)


def test_persistent_ctx_not_shadowed_by_prior_plan(tmp_path):
    """A plan built WITHOUT persistence must not satisfy a later
    persistent request from the memory cache (the disk write would be
    silently skipped and restarts would re-measure)."""
    bsr, x, _ = _problem()
    sparse.plan(bsr, N)                       # non-persistent first
    ctx = sparse.PlanContext(cache_dir=str(tmp_path))
    sparse.plan(bsr, N, ctx=ctx)              # persistent same problem
    assert sparse.cache_stats()["disk_writes"] >= 1


def test_plan_call_validates_contraction_dim():
    bsr, x, _ = _problem()
    p = sparse.plan(bsr, N)
    with pytest.raises(ValueError, match=f"k={K}"):
        p(bsr.values, jnp.zeros((K // 2, N)))
    # a different n than planned is fine (tiling re-derives at trace)
    y = p(bsr.values, jax.random.normal(jax.random.PRNGKey(0), (K, 24)))
    assert y.shape == (M, 24)


def test_static_pallas_plan_handles_unplanned_n():
    bsr, _, _ = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mode="static_pallas",
                                                   interpret=True))
    x96 = jax.random.normal(jax.random.PRNGKey(2), (K, 96))   # n != N
    np.testing.assert_allclose(
        np.asarray(p(bsr.values, x96)),
        np.asarray(jnp.asarray(bsr.to_dense()) @ x96), rtol=1e-4,
        atol=1e-4)


def test_dynamic_grouped_overflow_drops_like_buckets():
    """With a tile capacity below the distinct-tile count, overflow
    tiles are dropped -- the paper's fixed-bucket overflow semantics."""
    # kernel-level capacity semantics under test: direct entry is
    # the point here, like tests/test_kernels.py
    from repro.kernels.gmm import ops as gmm_ops  # repro-lint: disable=R001
    bsr = _bsr(0, m=256, k=256, b=16, d=0.25)
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 32))
    full = gmm_ops.grouped_spmm(op, x, interpret=True)
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(jnp.asarray(bsr.to_dense()) @ x),
        rtol=1e-4, atol=1e-4)
    clipped = gmm_ops.grouped_spmm(op, x, tiles_cap=1, interpret=True)
    assert np.isfinite(np.asarray(clipped)).all()


# -- DynamicOperand grid + validation (satellite fixes) -----------------------

def test_dynamic_operand_grid_matches_bsr_grid():
    bsr = _bsr()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    assert op.grid == bsr.grid


def test_dynamic_operand_rejects_non_divisible_shape():
    with pytest.raises(ValueError, match="not divisible"):
        dsp.DynamicOperand(jnp.zeros((1, 16, 16)), jnp.zeros((1,), jnp.int32),
                           jnp.zeros((1,), jnp.int32),
                           jnp.asarray(1, jnp.int32), (60, 64), 16)


def test_encode_from_bsr_clear_capacity_error():
    bsr = _bsr()
    with pytest.raises(ValueError, match="exceeds capacity"):
        dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks - 1)


# -- moe / engine steady state ------------------------------------------------

def test_moe_expert_gemms_plan_once():
    """Expert GEMMs build their plans on the first call; later steps
    (same shapes) issue zero new dispatch decisions."""
    from repro.configs import qwen3_moe_30b_a3b
    from repro.models.moe import moe_apply, moe_init
    cfg = qwen3_moe_30b_a3b.make_smoke_config()
    params = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    moe_apply(params, cfg, x)
    base = sparse.cache_stats()
    for i in range(3):
        moe_apply(params, cfg,
                  jax.random.normal(jax.random.PRNGKey(2 + i), x.shape))
    now = sparse.cache_stats()
    assert now["decisions"] == base["decisions"]
    assert now["plans_built"] == base["plans_built"]


@pytest.mark.slow
def test_engine_builds_plans_at_startup_and_stays_decision_free():
    from repro import configs
    from repro.models.model import LM
    from repro.serve import Engine, Request
    cfg = configs.smoke("llama3_2_1b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = Engine(lm, params, batch=2, max_len=64)
    # startup warm built the decode program's plans
    assert eng.plan_stats["plans_built"] + eng.plan_stats["plan_hits"] > 0
    req = Request(uid=0, prompt=np.arange(8, dtype=np.int32),
                  max_new_tokens=4)
    eng.run([req])
    base = sparse.cache_stats()
    req2 = Request(uid=1, prompt=np.arange(8, dtype=np.int32),
                   max_new_tokens=4)
    eng.run([req2])                       # steady state: same shapes
    now = sparse.cache_stats()
    assert now["decisions"] == base["decisions"]
    assert now["plans_built"] == base["plans_built"]
    rep = eng.plan_report()
    assert "startup" in rep
    # aggregated capacity/overflow telemetry rides along (per-plan
    # planned-bucket stats + MoE drops; totals always present)
    assert "totals" in rep["capacity"]
    # roofline efficiency of every held plan rides along too
    assert rep["roofline"]["totals"]["plans"] > 0
    eff = rep["roofline"]["totals"]["min_chosen_efficiency"]
    assert eff is None or 0 < eff <= 1.0


# -- tensor-parallel plans: measured race, mesh-keyed cache, TP report --------

NDEV = len(jax.devices())
needs_mesh2 = pytest.mark.skipif(
    NDEV < 2, reason="needs >= 2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def test_static_tp_shardmap_mode_requires_concrete_mesh():
    """tp_q alone can only execute the gspmd lowering; forcing the
    shard_map route without a device-backed mesh is an error, not a
    silent substitution."""
    bsr, _, _ = _problem()
    with pytest.raises(ValueError, match="static_tp_shardmap"):
        sparse.plan(bsr, N, ctx=sparse.PlanContext(
            mode="static_tp_shardmap", tp_q=4))


def test_mesh_without_tp_axis_raises():
    """Regression: a mesh whose axes do not include tp_axis used to
    silently plan unsharded; it must raise naming the expected axis."""
    bsr, _, _ = _problem()
    mesh = jax.make_mesh((1,), ("x",))
    with pytest.raises(ValueError, match=r"tp_axis 'model'"):
        sparse.plan(bsr, N, ctx=sparse.PlanContext(mesh=mesh))
    # naming the right axis (or an explicit tp_q) fixes it
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mesh=mesh,
                                                   tp_axis="x"))
    assert p.executable


def test_tp_decision_surfaced_in_explain_and_report():
    bsr, x, oracle = _problem()
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mode="static_tp",
                                                   tp_q=4,
                                                   tp_balanced=False))
    tp = p.explain()["tp"]
    assert tp["chosen"] == "static_tp" and tp["q"] == 4
    assert tp["balanced"] is False and p.artifacts["tp_balanced"] is False
    np.testing.assert_allclose(np.asarray(p(bsr.values, x)),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)
    rep = sparse.tp_report()
    assert rep["totals"]["tp_planned"] == 1
    assert rep["totals"]["tp_chosen"] == 1
    assert "tp:" in sparse.format_plan(p)


@needs_mesh2
def test_tp_measured_race_gspmd_vs_shardmap_vs_unsharded():
    """The ROADMAP acceptance: with a real mesh, plan() races both TP
    lowerings against the unsharded candidates with wall-clock timings
    and surfaces the crossover."""
    bsr, x, oracle = _problem()
    mesh = jax.make_mesh((NDEV,), ("model",))
    p = sparse.plan(bsr, N, x=x,
                    ctx=sparse.PlanContext(mesh=mesh, measure=True))
    assert p.source == "measured"
    assert {"static_tp", "static_tp_shardmap"} <= set(p.est_seconds)
    tp = p.artifacts["tp"]
    assert tp["source"] == "measured" and tp["mesh"] == {"model": NDEV}
    assert tp["tp_speedup_vs_unsharded"] is not None
    assert tp["best_tp_route"] in sparse.TP_ROUTES
    # whatever route won the race, the numbers are right
    np.testing.assert_allclose(np.asarray(p.apply(bsr, x)),
                               np.asarray(oracle), rtol=1e-4, atol=1e-4)


@needs_mesh2
def test_tp_verdict_disk_round_trip_is_mesh_keyed(tmp_path):
    """A measured TP verdict persists, restarts re-plan with zero
    measurements, and a different mesh topology never reuses it."""
    bsr, x, _ = _problem()
    mesh = jax.make_mesh((NDEV,), ("model",))
    ctx = sparse.PlanContext(mesh=mesh, measure=True,
                             cache_dir=str(tmp_path))
    p1 = sparse.plan(bsr, N, x=x, ctx=ctx)
    assert sparse.cache_stats()["measurements"] >= 1

    sparse.reset()                        # fresh-process simulation
    p2 = sparse.plan(bsr, N, x=x, ctx=ctx)
    assert p2.from_disk and p2.route == p1.route
    assert sparse.cache_stats()["measurements"] == 0
    assert p2.artifacts["tp"]["mesh"] == {"model": NDEV}

    # same devices arranged as a different topology -> different key
    sub = jax.make_mesh((1, NDEV), ("data", "model"))
    sparse.reset()
    p3 = sparse.plan(bsr, N, x=x,
                     ctx=dataclasses.replace(ctx, mesh=sub))
    assert not p3.from_disk


def test_pre_tp_schema_cache_invalidated(tmp_path):
    """A v2 (pre-mesh-fingerprint) cache file must be ignored: its TP
    verdicts were keyed on (q, axis) only and could answer for the
    wrong mesh topology."""
    bsr, x, _ = _problem()
    ctx = sparse.PlanContext(mode="static_tp", tp_q=4,
                             cache_dir=str(tmp_path))
    key = sparse.plan(bsr, N, ctx=ctx).key
    sparse.reset()
    os.remove(os.path.join(
        str(tmp_path), f"sparse-plans-v{sparse.SCHEMA_VERSION}.json"))
    old = {"env": {"schema": 2, "backend": jax.default_backend(),
                   "jax": jax.__version__},
           "entries": {key: {"route": "static_xla",
                             "source": "measured", "est_seconds": {}}}}
    with open(os.path.join(str(tmp_path), "sparse-plans-v2.json"),
              "w") as f:
        json.dump(old, f)
    p = sparse.plan(bsr, N, ctx=ctx)
    assert not p.from_disk                    # old tag never satisfies
    assert p.route == "static_tp"


def test_tp_q_and_mesh_fingerprints_differ():
    """A tp_q-only plan (no mesh) and a mesh-backed plan of the same q
    must not share a memory-cache entry."""
    bsr, _, _ = _problem()
    import importlib
    plan_mod = importlib.import_module("repro.sparse.plan")
    spec = sparse.OpSpec.from_operand(bsr, N, mode="auto")
    fp_q = plan_mod._fingerprint(spec, sparse.PlanContext(tp_q=2))
    mesh = jax.make_mesh((1,), ("model",))
    fp_mesh = plan_mod._fingerprint(
        spec, sparse.PlanContext(mesh=mesh, tp_q=2))
    assert fp_q != fp_mesh


@needs_mesh2
def test_tp_race_remeasures_stale_analytic_unsharded_verdict():
    """A traced first plan leaves an *analytic* unsharded verdict in
    the decision cache under the measure=True key; a later concrete
    plan must re-measure that side rather than race model-seconds
    against wall-clock TP timings (incomparable units)."""
    bsr1, x, _ = _problem(seed=0)
    bsr2 = _bsr(seed=7)                   # same shapes, fresh pattern
    mesh = jax.make_mesh((NDEV,), ("model",))
    ctx = sparse.PlanContext(mesh=mesh, measure=True)
    p1 = sparse.plan(bsr1, N, ctx=ctx)    # no x -> analytic, cached
    assert p1.source == "analytic"
    p2 = sparse.plan(bsr2, N, x=x, ctx=ctx)
    assert p2.source == "measured"
    un = p2.artifacts["tp"]["best_unsharded_route"]
    # the unsharded side was wall-clocked afresh, not replayed from the
    # analytic decision-cache entry
    assert p2.est_seconds[un] != p1.est_seconds[un]


def test_abstract_mesh_plans_gspmd_only():
    """An AbstractMesh (shape-only, no devices -- what tracing-time
    warmup sees) must plan fine with the shard_map route excluded, not
    crash probing .devices."""
    from jax.sharding import AbstractMesh
    try:
        amesh = AbstractMesh((8,), ("model",))
    except TypeError:                     # older jax signature
        amesh = AbstractMesh((("model", 8),))
    bsr, _, _ = _problem()
    ctx = sparse.PlanContext(mesh=amesh)
    assert not ctx.shardmap_executable()
    p = sparse.plan(bsr, N, ctx=ctx)
    assert "static_tp_shardmap" not in p.est_seconds
    assert "static_tp" in p.est_seconds   # gspmd candidate still raced
