"""MoE dispatch: the paper's dynamic sparsity at layer scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import qwen3_moe_30b_a3b
from repro.models import moe as moe_lib
from repro.models.moe import moe_apply, moe_init


import pytest

# model-level MoE dispatch: excluded from the fast tier-1 run (see pytest.ini)
pytestmark = pytest.mark.slow


def _cfg(**over):
    cfg = qwen3_moe_30b_a3b.make_smoke_config()
    if over:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, **over))
    return cfg


def _dense_reference(params, cfg, x):
    """Route every token through its top-k experts with no capacity."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]["w"]
    scores = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(scores, m.top_k)
    if m.norm_topk_prob:
        top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(m.num_experts):
        h = jax.nn.silu(xf @ params["w_gate"][e]) * (xf @ params["w_up"][e])
        ye = h @ params["w_down"][e]
        for kk in range(m.top_k):
            w = jnp.where(top_e[:, kk] == e, top_p[:, kk], 0.0)
            out += ye.astype(jnp.float32) * w[:, None]
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference_when_no_drops():
    cfg = _cfg(capacity_factor=64.0)
    params = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    got, metrics = moe_apply(params, cfg, x)
    want = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    assert float(metrics.dropped_frac) == 0.0


def test_moe_capacity_drops_accounted():
    cfg = _cfg(capacity_factor=0.25)     # force overflow
    params = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    _, metrics = moe_apply(params, cfg, x)
    assert float(metrics.dropped_frac) > 0.0


def test_moe_aux_loss_uniform_router_is_one():
    """Switch LB loss equals 1.0 (its minimum, num_experts * (1/E)*(1/E)*E)
    under a perfectly uniform router."""
    cfg = _cfg(capacity_factor=64.0)
    params = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    _, metrics = moe_apply(params, cfg, x)
    # uniform scores: frac_e == probs_mean_e == 1/E -> aux == 1
    np.testing.assert_allclose(float(metrics.aux_loss), 1.0, rtol=1e-2)


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(capacity_factor=8.0)
    params = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))

    def loss(p):
        y, m = moe_apply(p, cfg, x)
        return (y ** 2).sum() + 0.01 * m.aux_loss

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        leaf = g[name]["w"] if name == "router" else g[name]
        assert float(jnp.abs(leaf).sum()) > 0, f"no grad into {name}"


def test_moe_flops_accounting():
    cfg = _cfg()
    f = moe_lib.moe_flops_per_token(cfg)
    m = cfg.moe
    assert f >= 2 * cfg.d_model * m.d_ff_expert * 3 * m.top_k


def test_moe_shard_map_matches_gspmd():
    """The §Perf B3 optimization is bit-exact vs the GSPMD path on a
    named mesh (local dispatch + one psum == global dispatch)."""
    import jax.numpy as jnp
    from repro.sharding import rules
    cfg = _cfg(ranking="sort")
    params = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y0, m0 = moe_apply(params, cfg, x)
    cfg_sm = _cfg(ranking="sort", impl="shard_map")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, rules.activation_mesh(mesh):
        y1, m1 = jax.jit(lambda p, xx: moe_apply(p, cfg_sm, xx))(params, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(m1.aux_loss), float(m0.aux_loss),
                               rtol=1e-5)


def test_moe_shard_map_falls_back_without_mesh():
    cfg = _cfg(impl="shard_map")
    params = moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_apply(params, cfg, x)    # no mesh installed -> gspmd path
    assert np.isfinite(np.asarray(y)).all()
