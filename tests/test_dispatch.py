"""Unified dispatch layer (repro.core.dispatch): backend parity against
the dense oracle, decision-cache behaviour, gradients through ``spmm``,
and the call-site delegations (sparse layers, dspmm, MoE helper)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, dynamic_sparse as dsp
from repro.core.bsr import BlockSparseMatrix

M, K, N, B, DENSITY = 128, 256, 64, 16, 0.25


@pytest.fixture(autouse=True)
def _fresh_cache():
    dispatch.clear_cache()
    yield
    dispatch.clear_cache()


def _bsr(seed=0, m=M, k=K, b=B, d=DENSITY, dtype=jnp.float32):
    return BlockSparseMatrix.random(jax.random.PRNGKey(seed), m, k, b, d,
                                    dtype=dtype, pattern_seed=seed)


def _problem(seed=0, dtype=jnp.float32):
    bsr = _bsr(seed, dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100),
                          (K, N)).astype(dtype)
    oracle = jnp.asarray(bsr.to_dense()) @ x
    return bsr, x, oracle


# -- backend parity: every selectable route matches the dense oracle ----------

XLA_ROUTES = ["dense_xla", "static_xla", "dynamic_xla"]
PALLAS_ROUTES = ["dense_pallas", "static_pallas", "dynamic_pallas"]


def _operand_for(route, bsr):
    if route.startswith("dynamic"):
        return dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 4)
    if route.startswith("dense"):
        return jnp.asarray(bsr.to_dense())
    return bsr


@pytest.mark.parametrize("route", XLA_ROUTES)
def test_route_parity_xla(route):
    bsr, x, oracle = _problem()
    ctx = dispatch.DispatchContext(mode=route)
    y = dispatch.spmm(_operand_for(route, bsr), x, ctx=ctx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("route", PALLAS_ROUTES)
def test_route_parity_pallas_interpret(route):
    bsr, x, oracle = _problem()
    ctx = dispatch.DispatchContext(mode=route, interpret=True)
    y = dispatch.spmm(_operand_for(route, bsr), x, ctx=ctx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ["dense", "static", "dynamic"])
def test_auto_parity(kind):
    """Whatever auto picks, the numbers must match the oracle."""
    bsr, x, oracle = _problem()
    op = {"dense": jnp.asarray(bsr.to_dense()), "static": bsr,
          "dynamic": dsp.encode_from_bsr(bsr,
                                         nnz_max=bsr.nnz_blocks)}[kind]
    y = dispatch.spmm(op, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)
    dec = dispatch.decide(op, N)
    assert dec.route.split("_")[0] in dispatch._ADMISSIBLE[kind]


def test_auto_under_jit():
    bsr, x, oracle = _problem()
    f = jax.jit(lambda v, xx: dispatch.spmm(bsr.with_values(v), xx))
    y = f(jnp.asarray(bsr.values), x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_spmm_nt_matches_transpose_form():
    bsr, x, _ = _problem()
    xa = jax.random.normal(jax.random.PRNGKey(7), (3, 5, K))
    y = dispatch.spmm_nt(bsr, xa)
    want = xa.reshape(-1, K) @ jnp.asarray(bsr.to_dense()).T
    np.testing.assert_allclose(np.asarray(y.reshape(-1, M)),
                               np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_and_batched_matmul():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    np.testing.assert_allclose(np.asarray(dispatch.matmul(x, w)),
                               np.asarray(x @ w), rtol=1e-5, atol=1e-5)
    a = jax.random.normal(jax.random.PRNGKey(2), (3, 8, 16))
    b = jax.random.normal(jax.random.PRNGKey(3), (3, 16, 24))
    np.testing.assert_allclose(np.asarray(dispatch.batched_matmul(a, b)),
                               np.asarray(jnp.matmul(a, b)),
                               rtol=1e-5, atol=1e-5)


# -- decision cache -----------------------------------------------------------

def test_decision_cache_hit_and_stability():
    bsr, x, _ = _problem()
    d1 = dispatch.decide(bsr, N)
    assert dispatch.cache_stats()["entries"] == 1
    # same logical problem, different values -> same cached decision obj
    bsr2 = _bsr(seed=5)
    d2 = dispatch.decide(bsr2, N)
    assert dispatch.cache_stats()["entries"] == 1
    assert d2 is d1 and d2.route == d1.route
    # different n -> new entry
    dispatch.decide(bsr, 2 * N)
    assert dispatch.cache_stats()["entries"] == 2


def test_density_bucket_stabilizes_key():
    """nnz jitter within a power-of-two bucket must not split the key."""
    ctx = dispatch.DispatchContext()
    a = dispatch._cache_key("static", M, K, N, B, 0.24, jnp.float32, ctx)
    b = dispatch._cache_key("static", M, K, N, B, 0.26, jnp.float32, ctx)
    assert a == b
    c = dispatch._cache_key("static", M, K, N, B, 0.06, jnp.float32, ctx)
    assert c != a


def test_cache_key_includes_context():
    """A verdict from one context must not leak into an incompatible
    one (interpret / differentiable / measure change what runs)."""
    base = dispatch.DispatchContext()
    for other in (dispatch.DispatchContext(interpret=True),
                  dispatch.DispatchContext(differentiable=False),
                  dispatch.DispatchContext(measure=True)):
        assert dispatch._cache_key(
            "static", M, K, N, B, 0.25, jnp.float32, base) != \
            dispatch._cache_key(
                "static", M, K, N, B, 0.25, jnp.float32, other)


def test_differentiable_excludes_pallas_from_auto():
    """Pallas kernels are forward-only: auto selection must never pick
    them for a differentiable caller, even when explicitly allowed."""
    bsr, x, _ = _problem()
    grad_ctx = dispatch.DispatchContext(allow_pallas=True)
    assert all(r.endswith("_xla") for r in
               dispatch.decide(bsr, N, ctx=grad_ctx).est_seconds)
    fwd_ctx = dispatch.DispatchContext(allow_pallas=True,
                                       differentiable=False)
    assert any(r.endswith("_pallas") for r in
               dispatch.decide(bsr, N, ctx=fwd_ctx).est_seconds)


def test_interpret_does_not_admit_pallas_to_auto():
    """interpret=True is a testing affordance for forced routes; it
    must not route production auto traffic through the interpreter."""
    bsr, x, _ = _problem()
    ctx = dispatch.DispatchContext(interpret=True, differentiable=False)
    if jax.default_backend() != "tpu":
        assert all(r.endswith("_xla") for r in
                   dispatch.decide(bsr, N, ctx=ctx).est_seconds)


def test_promotion_semantics_match_einsum():
    """Every route must follow jnp promotion of (operand, x) dtypes."""
    bsr = _bsr(dtype=jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(3), (K, N))  # fp32
    want = jnp.result_type(jnp.bfloat16, x.dtype)
    for mode in ("dense_xla", "static_xla", "dynamic_xla"):
        op = _operand_for(mode, bsr)
        y = dispatch.spmm(op, x, ctx=dispatch.DispatchContext(mode=mode))
        assert y.dtype == want, (mode, y.dtype)
    a = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 8),
                          dtype=jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 4)
                          ).astype(jnp.bfloat16)
    assert dispatch.batched_matmul(a, b).dtype == jnp.result_type(
        a.dtype, b.dtype)


def test_cache_respects_mode_and_dtype():
    bsr, x, _ = _problem()
    dispatch.decide(bsr, N)
    dispatch.decide(bsr, N,
                    ctx=dispatch.DispatchContext(mode="static_xla"))
    dispatch.decide(_bsr(dtype=jnp.bfloat16), N)
    assert dispatch.cache_stats()["entries"] == 3


def test_measured_autotune_memoizes():
    bsr, x, _ = _problem()
    ctx = dispatch.DispatchContext(measure=True)
    d1 = dispatch.decide(bsr, N, ctx=ctx, x=x)
    assert d1.source == "measured"
    d2 = dispatch.decide(bsr, N, ctx=ctx, x=x)
    assert d2 is d1                      # cache hit, no re-measurement


def test_measure_skips_unrunnable_pallas_candidates():
    """measure=True with allow_pallas=True off-TPU must not execute
    Pallas natively; it measures the runnable routes and keeps the
    analytic estimates for the rest (regression: used to crash)."""
    bsr, x, _ = _problem()
    ctx = dispatch.DispatchContext(measure=True, allow_pallas=True,
                                   differentiable=False)
    dec = dispatch.decide(bsr, N, ctx=ctx, x=x)
    if jax.default_backend() != "tpu":
        assert dec.source == "measured"
        assert dec.route.endswith("_xla")
        assert "static_pallas" in dec.est_seconds   # analytic, reported


def test_measure_skipped_under_trace():
    bsr, x, _ = _problem()
    ctx = dispatch.DispatchContext(measure=True, cache=False)

    @jax.jit
    def f(xx):
        dec = dispatch.decide(bsr, N, ctx=ctx, x=xx)
        assert dec.source == "analytic"   # tracer input -> no wall clock
        return dispatch.spmm(bsr, xx, ctx=ctx)

    f(x)


def test_use_ctx_ambient():
    bsr, x, oracle = _problem()
    with dispatch.use_ctx(dispatch.DispatchContext(mode="static_xla")):
        assert dispatch.current_ctx().mode == "static_xla"
        y = dispatch.spmm(bsr, x)
    assert dispatch.current_ctx().mode == "auto"
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_invalid_mode_and_route_rejected():
    with pytest.raises(ValueError):
        dispatch.DispatchContext(mode="nope")
    bsr, x, _ = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    with pytest.raises(ValueError):   # dynamic operand has no static route
        dispatch.spmm(op, x,
                      ctx=dispatch.DispatchContext(mode="static_xla"))
    dense = jnp.asarray(bsr.to_dense())
    with pytest.raises(ValueError):
        dispatch.spmm(dense, x,
                      ctx=dispatch.DispatchContext(mode="dynamic_xla"))


# -- gradients through the dispatch layer -------------------------------------

@pytest.mark.parametrize("mode", ["auto", "static_xla", "dense_xla"])
def test_grad_static_matches_dense(mode):
    bsr, x, _ = _problem()
    ctx = dispatch.DispatchContext(mode=mode)

    def loss_sparse(values, xx):
        return (dispatch.spmm(bsr.with_values(values), xx, ctx=ctx) ** 2
                ).sum()

    def loss_dense(values, xx):
        return ((bsr.with_values(values).to_dense() @ xx) ** 2).sum()

    gv_s, gx_s = jax.grad(loss_sparse, argnums=(0, 1))(
        jnp.asarray(bsr.values), x)
    gv_d, gx_d = jax.grad(loss_dense, argnums=(0, 1))(
        jnp.asarray(bsr.values), x)
    np.testing.assert_allclose(np.asarray(gv_s), np.asarray(gv_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx_s), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["auto", "dynamic_xla"])
def test_grad_dynamic_matches_dense(mode):
    bsr, x, _ = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    ctx = dispatch.DispatchContext(mode=mode)

    def loss_sparse(values, xx):
        o = dsp.DynamicOperand(values, op.row_idx, op.col_idx, op.nnz,
                               op.shape, op.block_size)
        return (dispatch.spmm(o, xx, ctx=ctx) ** 2).sum()

    def loss_dense(values, xx):
        o = dsp.DynamicOperand(values, op.row_idx, op.col_idx, op.nnz,
                               op.shape, op.block_size)
        return ((o.to_dense() @ xx) ** 2).sum()

    gv_s, gx_s = jax.grad(loss_sparse, argnums=(0, 1))(op.values, x)
    gv_d, gx_d = jax.grad(loss_dense, argnums=(0, 1))(op.values, x)
    np.testing.assert_allclose(np.asarray(gv_s), np.asarray(gv_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gx_s), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-4)


# -- call-site delegation ------------------------------------------------------

def test_sparse_linear_backends_agree():
    from repro.core.sparse_layers import SparseLinear
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    outs = []
    for backend in ("auto", "static_xla", "dense_xla", "xla"):
        layer = SparseLinear.random_pattern(None, 64, 128, 16, 0.5,
                                            seed=0, backend=backend)
        params = layer.init(jax.random.PRNGKey(0))
        outs.append(np.asarray(layer.apply(params, x)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_dspmm_backend_delegates():
    bsr, x, oracle = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 2)
    for backend in ("auto", "xla"):
        y = dsp.dspmm(op, x, backend=backend)
        np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                                   rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        dsp.dspmm(op, x, backend="bogus")


def test_explain_report():
    bsr, x, _ = _problem()
    rep = dispatch.explain(bsr, N)
    assert rep["problem"]["kind"] == "static"
    assert rep["chosen"] in rep["candidates"]
    assert set(rep["candidates"]) >= {"static_xla", "dense_xla"}
    assert rep["cached"] is False and rep["source"] == "analytic"
    dispatch.decide(bsr, N)
    assert dispatch.explain(bsr, N)["cached"] is True
    assert "dispatch" in dispatch.format_explain(rep)
