"""tools/bench_check.py: the CI benchmark regression gate.  Checked
ratios are deterministic cost-model outputs, so the gate's contract is
sharp: within tolerance passes, a >tolerance drop / a route flip / a
shrunk grid fails, ``--update`` (re)writes the baseline."""
import copy
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(REPO, "tools", "bench_check.py")

BLOB = {
    "tp_crossover": [
        {"fig": "tp_crossover", "m": 512, "b": 16, "density": 0.25,
         "n": 64, "est_tp_speedup": 2.0},
        {"fig": "tp_crossover", "m": 1024, "b": 16, "density": 0.25,
         "n": 64, "est_tp_speedup": 4.0},
    ],
    "dispatch": [
        {"fig": "dispatch", "kind": "static", "m": 1024, "b": 16,
         "density": 0.25, "n": 256, "chosen": "static_xla",
         "candidates": {"static_xla": 10.0, "dense_xla": 40.0}},
    ],
}


def _run(args, cwd=REPO):
    return subprocess.run([sys.executable, SCRIPT] + args, cwd=cwd,
                          capture_output=True, text=True)


@pytest.fixture
def setup(tmp_path):
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    cur = tmp_path / "BENCH_x.json"
    cur.write_text(json.dumps(BLOB))
    (base_dir / "BENCH_x.json").write_text(json.dumps(BLOB))
    return str(cur), str(base_dir)


def test_identical_files_pass(setup):
    cur, base_dir = setup
    r = _run([cur, "--baseline-dir", base_dir])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_small_drift_within_tolerance_passes(setup, tmp_path):
    cur, base_dir = setup
    blob = copy.deepcopy(BLOB)
    blob["tp_crossover"][0]["est_tp_speedup"] = 1.8     # -10% < 15%
    cur2 = tmp_path / "BENCH_x.json"
    cur2.write_text(json.dumps(blob))
    assert _run([str(cur2), "--baseline-dir", base_dir]).returncode == 0


def test_ratio_regression_fails(setup, tmp_path):
    cur, base_dir = setup
    blob = copy.deepcopy(BLOB)
    blob["tp_crossover"][1]["est_tp_speedup"] = 3.0     # -25% > 15%
    cur2 = tmp_path / "BENCH_x.json"
    cur2.write_text(json.dumps(blob))
    r = _run([str(cur2), "--baseline-dir", base_dir])
    assert r.returncode == 1 and "regressed" in r.stdout


def test_route_flip_fails(setup, tmp_path):
    cur, base_dir = setup
    blob = copy.deepcopy(BLOB)
    blob["dispatch"][0]["chosen"] = "dense_xla"
    blob["dispatch"][0]["candidates"]["dense_xla"] = 9.0
    cur2 = tmp_path / "BENCH_x.json"
    cur2.write_text(json.dumps(blob))
    r = _run([str(cur2), "--baseline-dir", base_dir])
    assert r.returncode == 1 and "crossover moved" in r.stdout


def test_shrunk_grid_fails(setup, tmp_path):
    cur, base_dir = setup
    blob = copy.deepcopy(BLOB)
    blob["tp_crossover"] = blob["tp_crossover"][:1]
    cur2 = tmp_path / "BENCH_x.json"
    cur2.write_text(json.dumps(blob))
    r = _run([str(cur2), "--baseline-dir", base_dir])
    assert r.returncode == 1 and "missing from current" in r.stdout


def test_missing_baseline_fails_and_update_creates_it(tmp_path):
    cur = tmp_path / "BENCH_y.json"
    cur.write_text(json.dumps(BLOB))
    base_dir = str(tmp_path / "empty")
    r = _run([str(cur), "--baseline-dir", base_dir])
    assert r.returncode == 1 and "missing baseline" in r.stdout
    assert _run([str(cur), "--baseline-dir", base_dir,
                 "--update"]).returncode == 0
    assert _run([str(cur), "--baseline-dir", base_dir]).returncode == 0


def test_committed_baselines_match_current_extractors():
    """The baselines shipped in-repo parse through every extractor (a
    schema drift in the suite must touch the baseline in the same PR)."""
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import bench_check
    finally:
        sys.path.pop(0)
    for name in ("BENCH_dispatch.json", "BENCH_grouped_capacity.json",
                 "BENCH_tp.json"):
        path = os.path.join(REPO, "benchmarks", "baselines", name)
        assert os.path.exists(path), f"{name} baseline not committed"
        with open(path) as f:
            blob = json.load(f)
        ratios = {fig: ex(blob[fig]) for fig, ex in
                  bench_check.EXTRACTORS.items() if fig in blob}
        assert ratios and all(len(v) > 0 for v in ratios.values())
        for per in ratios.values():
            for rec in per.values():
                assert rec["ratio"] > 0
