"""Optimizer: AdamW numerics, clipping, schedule, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress, global_norm, warmup_cosine)


def test_adamw_quadratic_converges():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        g = jax.grad(lambda p: ((p["w"] - target) ** 2).sum())(params)
        params, state = adamw_update(g, state, params, lr=0.05,
                                     weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_master_weights_fp32():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    new_params, new_state = adamw_update(g, state, params, lr=1e-4)
    assert new_params["w"].dtype == jnp.bfloat16
    # master moved even though the bf16 copy may round
    assert (np.asarray(new_state.master["w"]) != 1.0).all()


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    n = float(global_norm(g))
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), n, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    same, _ = clip_by_global_norm(g, 1e6)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                              total_steps=100))
    lr_peak = float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                                  total_steps=100))
    lr_end = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                                 total_steps=100))
    assert lr0 == 0.0 and abs(lr_peak - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-6


def test_compression_error_feedback_converges_like_fp32():
    """int8+EF training tracks the uncompressed trajectory on a least-
    squares problem (convergence parity -- the production claim)."""
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (64, 8))
    w_true = jnp.arange(1.0, 9.0)
    y = X @ w_true

    def run(compressed):
        params = {"w": jnp.zeros((8,))}
        state = adamw_init(params)
        ef = compress.ef_init(params)
        for _ in range(200):
            g = jax.grad(
                lambda p: ((X @ p["w"] - y) ** 2).mean())(params)
            if compressed:
                g, ef = compress.compress_grads(g, ef)
            params, state = adamw_update(g, state, params, lr=0.05,
                                         weight_decay=0.0)
        return np.asarray(params["w"])

    w_fp, w_q = run(False), run(True)
    np.testing.assert_allclose(w_q, np.asarray(w_true), atol=0.2)
    np.testing.assert_allclose(w_q, w_fp, atol=0.15)


def test_compression_wire_volume():
    g = {"w": jnp.zeros((1000,))}
    wb = compress.wire_bytes(g)
    assert wb["fp32"] == 4000
    assert wb["int8"] < wb["fp32"] / 3.5
