"""Sharding rules (divisibility fallback, spec shapes, constrain no-op)
+ tensor-parallel SpMM lowering parity: ``tp_spmm_shard_map`` vs
``tp_spmm_gspmd`` on a host-platform mesh (the multi-device CI job runs
this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
on a single device the mesh-bound cases skip)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import partitioner, tp
from repro.core.bsr import BlockSparseMatrix
from repro.sharding import rules

NDEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    NDEV < 4, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _amesh(shape, names=("data", "model")):
    """Abstract mesh: rule tests need axis sizes, not real devices.
    jax < 0.5 takes ((name, size), ...); newer takes (sizes, names)."""
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


def _sizes(mesh):
    return {n: mesh.shape[n] for n in mesh.axis_names}


@pytest.mark.parametrize("name", configs.ARCH_IDS)
def test_param_specs_exist_and_align(name, mesh):
    params = configs.param_specs(name)
    specs = rules.param_specs(params, mesh)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = {jax.tree_util.keystr(p): s for p, s in
              jax.tree_util.tree_leaves_with_path(
                  specs, is_leaf=lambda x: isinstance(x, P))}
    for path, leaf in flat_p:
        key = jax.tree_util.keystr(path)
        spec = flat_s[key]
        assert len(spec) <= leaf.ndim, f"{key}: spec longer than rank"


def test_divisibility_fallback(mesh):
    big = _amesh((1, 16))
    # 14 heads * 64 = 896 divisible by 16; but a 100-wide dim is not
    sds = {"attn": {"wq": {"w": jax.ShapeDtypeStruct((100, 100),
                                                     jnp.float32)}}}
    specs = rules.param_specs(sds, big)
    # 100 % 16 != 0 -> the model axis falls back to replication
    assert specs["attn"]["wq"]["w"][1] is None


def test_table_rule(mesh):
    big = _amesh((1, 16))
    sds = {"embed": {"table": jax.ShapeDtypeStruct((102400, 2048),
                                                   jnp.float32)}}
    specs = rules.param_specs(sds, big)
    assert specs["embed"]["table"][0] == "model"


def test_stacked_leading_dims_are_replicated():
    big = _amesh((2, 4))
    sds = {"attn": {"wq": {"w": jax.ShapeDtypeStruct((16, 128, 128),
                                                     jnp.float32)}}}
    specs = rules.param_specs(sds, big)
    s = specs["attn"]["wq"]["w"]
    assert s[0] is None and s[1] == "data" and s[2] == "model"


def test_cache_specs_batch_vs_long(mesh):
    big = _amesh((4, 4))
    caches = {"k": jax.ShapeDtypeStruct((2, 16, 1024, 4, 64), jnp.bfloat16),
              "state": jax.ShapeDtypeStruct((2, 16, 8, 64, 16),
                                            jnp.float32)}
    specs = rules.cache_specs(caches, big, batch=16)
    assert specs["k"][1] == "data" and specs["k"][2] == "model"
    # batch=1 long context: sequence takes every available axis
    caches1 = {"k": jax.ShapeDtypeStruct((2, 1, 4096, 4, 64), jnp.bfloat16)}
    specs1 = rules.cache_specs(caches1, big, batch=1)
    assert specs1["k"][2] == ("data", "model")


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = rules.constrain(x, "batch", None)
    assert y is x


def test_constrain_applies_under_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 4))
    with rules.activation_mesh(mesh):
        y = rules.constrain(x, "batch", "model")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x))


def test_train_batch_specs(mesh):
    big = _amesh((8, 2))
    batch = {"tokens": jax.ShapeDtypeStruct((16, 128), jnp.int32),
             "targets": jax.ShapeDtypeStruct((16, 128), jnp.int32)}
    specs = rules.train_batch_specs(batch, big)
    assert specs["tokens"][0] == "data"
    odd = {"tokens": jax.ShapeDtypeStruct((3, 128), jnp.int32)}
    assert rules.train_batch_specs(odd, big)["tokens"][0] is None


# -- TP SpMM lowering parity (shard_map vs gspmd vs dense oracle) -------------

def _skewed_bsr(m=128, k=256, b=16, dtype=jnp.float32, seed=0):
    """Static BSR whose nnz mass is concentrated in the left block
    columns, so nnz-balanced k-splits land at genuinely uneven
    boundaries (the paper's Fig. 1a case)."""
    rng = np.random.default_rng(seed)
    mb, kb = m // b, k // b
    col_p = np.linspace(1.0, 0.1, kb)
    mask = rng.random((mb, kb)) < 0.6 * col_p[None, :]
    mask[0, 0] = True                      # never empty
    bsr = BlockSparseMatrix.from_mask(mask, b)
    vals = jax.random.normal(jax.random.PRNGKey(seed + 1),
                             bsr.values.shape).astype(dtype)
    return bsr.with_values(vals)


@needs_mesh
@pytest.mark.parametrize("balanced", [True, False])
@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-5),          # reduction-order-only differences
    (jnp.bfloat16, 4e-2),
    (jnp.float16, 4e-2),
])
def test_tp_shard_map_vs_gspmd_parity(balanced, dtype, tol):
    q = 4
    bsr = _skewed_bsr(dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(9),
                          (bsr.shape[1], 32)).astype(dtype)
    meta = partitioner.plan_k_shards(bsr, q, balanced=balanced)
    if balanced:
        # the skewed pattern must actually exercise uneven boundaries
        widths = np.diff(meta.boundaries)
        assert widths.max() > widths.min()
    assert meta.balanced is balanced
    sb = partitioner.apply_k_shards(meta, bsr.values)
    mesh = jax.make_mesh((q,), ("model",))
    y_sm = tp.tp_spmm_shard_map(sb, x, mesh=mesh, axis="model")
    y_gs = tp.tp_spmm_gspmd(sb, x, axis="model")
    np.testing.assert_allclose(
        np.asarray(y_sm, np.float32), np.asarray(y_gs, np.float32),
        rtol=tol, atol=tol)
    oracle = jnp.asarray(bsr.to_dense()).astype(jnp.float32) \
        @ x.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(y_sm, np.float32),
                               np.asarray(oracle),
                               rtol=10 * tol, atol=10 * tol)


@needs_mesh
def test_tp_shard_map_on_two_axis_mesh():
    """shard_map TP composes with a (data, model) mesh: shards over
    'model' only, output replicated everywhere."""
    bsr = _skewed_bsr()
    x = jax.random.normal(jax.random.PRNGKey(3), (bsr.shape[1], 16))
    meta = partitioner.plan_k_shards(bsr, 4)
    sb = partitioner.apply_k_shards(meta, bsr.values)
    mesh = jax.make_mesh((NDEV // 4, 4), ("data", "model"))
    y = tp.tp_spmm_shard_map(sb, x, mesh=mesh, axis="model")
    oracle = jnp.asarray(bsr.to_dense()) @ x
    np.testing.assert_allclose(np.asarray(y), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_tp_shard_map_rejects_mismatched_mesh():
    """q != mesh axis size (or no concrete mesh at all) must fail loudly
    -- a silent mis-shard would psum garbage."""
    bsr = _skewed_bsr()
    x = jnp.zeros((bsr.shape[1], 8))
    meta = partitioner.plan_k_shards(bsr, 2)
    sb = partitioner.apply_k_shards(meta, bsr.values)
    with pytest.raises(ValueError, match="axis 'model'"):
        tp.tp_spmm_shard_map(sb, x, mesh=None, axis="model")
    mesh1 = jax.make_mesh((1,), ("model",))
    if mesh1.shape["model"] != sb.q:
        with pytest.raises(ValueError, match="size q=2"):
            tp.tp_spmm_shard_map(sb, x, mesh=mesh1, axis="model")


def test_plan_k_shards_validates_q():
    bsr = _skewed_bsr(m=64, k=64, b=16)      # kb = 4
    with pytest.raises(ValueError, match="k-shards"):
        partitioner.plan_k_shards(bsr, 5)
    with pytest.raises(ValueError, match="k-shards"):
        partitioner.plan_k_shards(bsr, 0, balanced=False)


# -- PR 8: balance assertions on the uneven-split machinery -------------------

def test_swizzled_plan_balances_power_law_rows():
    """The row-swizzle pre-pass must equalize per-lane work: on a
    power-law mask the swizzled plan's max per-step load stays within
    1.5x of the mean (the uniform row order concentrates it on the hot
    rows' lane)."""
    from repro.core import masks
    mask = masks.power_law_block_mask(4096, 4096, 16, 1 / 16, seed=0)
    counts = mask.sum(axis=1).astype(np.int64)
    sw = partitioner.plan_swizzle(counts, num_bins=8)
    assert sw.loads.max() <= 1.5 * sw.loads.mean()
    # the swizzle is a permutation and its inverse really inverts it
    r = len(counts)
    assert (np.sort(sw.order) == np.arange(r)).all()
    assert (sw.order[sw.inverse] == np.arange(r)).all()
    # unswizzled (identity-order) binning would not balance: the hot
    # rows are adjacent, so contiguous bins inherit the skew
    naive = np.array_split(counts, 8)
    naive_max = max(float(c.sum()) for c in naive)
    assert sw.loads.max() <= naive_max


def test_balanced_packing_steps_cover_all_tiles():
    from repro.core import masks
    from repro.core.partitioner import plan_packing_balanced
    mask = masks.power_law_block_mask(512, 512, 16, 1 / 8, seed=2)
    bsr = BlockSparseMatrix.from_mask(mask, 16)
    meta = plan_packing_balanced(bsr.row_idx, bsr.col_idx, bsr.shape, 16)
    # every real slot is visited exactly once; pads point at the
    # appended zero tile
    real = meta.visit_slot[meta.visit_slot < meta.base.num_tiles]
    assert len(np.unique(real)) == meta.base.num_tiles
    assert meta.visit_slot.shape == (meta.num_bins, meta.steps_per_bin)
