"""Sharding rules: divisibility fallback, spec shapes, constrain no-op."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models.model import LM
from repro.sharding import rules


from jax.sharding import AbstractMesh


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _amesh(shape, names=("data", "model")):
    """Abstract mesh: rule tests need axis sizes, not real devices.
    jax < 0.5 takes ((name, size), ...); newer takes (sizes, names)."""
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


def _sizes(mesh):
    return {n: mesh.shape[n] for n in mesh.axis_names}


@pytest.mark.parametrize("name", configs.ARCH_IDS)
def test_param_specs_exist_and_align(name, mesh):
    params = configs.param_specs(name)
    specs = rules.param_specs(params, mesh)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_s = {jax.tree_util.keystr(p): s for p, s in
              jax.tree_util.tree_leaves_with_path(
                  specs, is_leaf=lambda x: isinstance(x, P))}
    for path, leaf in flat_p:
        key = jax.tree_util.keystr(path)
        spec = flat_s[key]
        assert len(spec) <= leaf.ndim, f"{key}: spec longer than rank"


def test_divisibility_fallback(mesh):
    big = _amesh((1, 16))
    # 14 heads * 64 = 896 divisible by 16; but a 100-wide dim is not
    sds = {"attn": {"wq": {"w": jax.ShapeDtypeStruct((100, 100),
                                                     jnp.float32)}}}
    specs = rules.param_specs(sds, big)
    # 100 % 16 != 0 -> the model axis falls back to replication
    assert specs["attn"]["wq"]["w"][1] is None


def test_table_rule(mesh):
    big = _amesh((1, 16))
    sds = {"embed": {"table": jax.ShapeDtypeStruct((102400, 2048),
                                                   jnp.float32)}}
    specs = rules.param_specs(sds, big)
    assert specs["embed"]["table"][0] == "model"


def test_stacked_leading_dims_are_replicated():
    big = _amesh((2, 4))
    sds = {"attn": {"wq": {"w": jax.ShapeDtypeStruct((16, 128, 128),
                                                     jnp.float32)}}}
    specs = rules.param_specs(sds, big)
    s = specs["attn"]["wq"]["w"]
    assert s[0] is None and s[1] == "data" and s[2] == "model"


def test_cache_specs_batch_vs_long(mesh):
    big = _amesh((4, 4))
    caches = {"k": jax.ShapeDtypeStruct((2, 16, 1024, 4, 64), jnp.bfloat16),
              "state": jax.ShapeDtypeStruct((2, 16, 8, 64, 16),
                                            jnp.float32)}
    specs = rules.cache_specs(caches, big, batch=16)
    assert specs["k"][1] == "data" and specs["k"][2] == "model"
    # batch=1 long context: sequence takes every available axis
    caches1 = {"k": jax.ShapeDtypeStruct((2, 1, 4096, 4, 64), jnp.bfloat16)}
    specs1 = rules.cache_specs(caches1, big, batch=1)
    assert specs1["k"][2] == ("data", "model")


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = rules.constrain(x, "batch", None)
    assert y is x


def test_constrain_applies_under_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 4))
    with rules.activation_mesh(mesh):
        y = rules.constrain(x, "batch", "model")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x))


def test_train_batch_specs(mesh):
    big = _amesh((8, 2))
    batch = {"tokens": jax.ShapeDtypeStruct((16, 128), jnp.int32),
             "targets": jax.ShapeDtypeStruct((16, 128), jnp.int32)}
    specs = rules.train_batch_specs(batch, big)
    assert specs["tokens"][0] == "data"
    odd = {"tokens": jax.ShapeDtypeStruct((3, 128), jnp.int32)}
    assert rules.train_batch_specs(odd, big)["tokens"][0] is None
