"""Scheduled (block-visit-list) attention vs naive reference; schedule
properties; kernel/XLA agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn


def _naive(q, k, v, *, causal=True, window=0, global_prefix=0,
           softcap=None, scale=None):
    b, s, h, dh = q.shape
    skv = k.shape[1]
    g = h // k.shape[2]
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    scale = scale or 1.0 / np.sqrt(dh)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qi = np.arange(s)[:, None]
    ki = np.arange(skv)[None, :]
    mask = np.ones((s, skv), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= (qi - ki < window) | (ki < global_prefix)
    logits = jnp.where(jnp.asarray(mask)[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, vv.astype(jnp.float32)
                      ).astype(q.dtype)


@pytest.mark.parametrize("schedule", ["row", "balanced"])
@pytest.mark.parametrize("kv_heads", [1, 2, 4])
def test_attend_train_causal(schedule, kv_heads):
    b, s, h, dh = 2, 256, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh)) * 0.4
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv_heads, dh)) * 0.4
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv_heads, dh))
    got = attn.attend_train(q, k, v, tile_q=64, tile_kv=64,
                            schedule=schedule)
    want = _naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window,gp", [(64, 0), (64, 64), (128, 64)])
def test_attend_train_local_window(window, gp):
    b, s, h, dh = 1, 512, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh)) * 0.4
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh)) * 0.4
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dh))
    got = attn.attend_train(q, k, v, window=window, global_prefix=gp,
                            tile_q=64, tile_kv=64)
    want = _naive(q, k, v, window=window, global_prefix=gp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_attend_train_softcap_noncausal():
    b, s, h, dh = 1, 128, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, dh))
    got = attn.attend_train(q, k, v, causal=False, softcap=20.0,
                            tile_q=64, tile_kv=64)
    want = _naive(q, k, v, causal=False, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_attend_decode_matches_last_row():
    b, s, h, kv, dh = 2, 96, 4, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, 1, h, dh))
    ks = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, dh))
    vs = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, dh))
    lengths = jnp.array([s, s - 20])
    got = attn.attend_decode(q, ks, vs, lengths=lengths)
    for i, L in enumerate([s, s - 20]):
        want = _naive(q[i:i+1], ks[i:i+1, :L], vs[i:i+1, :L], causal=False)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want[0]),
                                   rtol=2e-3, atol=2e-3)


# -- schedule properties -------------------------------------------------------------

@pytest.mark.parametrize("nq", [1, 2, 3, 5, 7, 8, 13, 16, 21, 24])
@pytest.mark.parametrize("balanced", [False, True])
def test_schedule_covers_causal_mask(nq, balanced):
    mask = np.tril(np.ones((nq, nq), bool))
    sched = attn.build_schedule(mask, balanced=balanced)
    visited = set()
    for i in range(nq):
        r = int(sched.rows[i])
        for j in range(sched.width):
            if sched.valid[i, j]:
                visited.add((r, int(sched.cols[i, j])))
    want = {(r, c) for r in range(nq) for c in range(r + 1)}
    assert visited == want
    assert sorted(sched.rows.tolist()) == list(range(nq))


def test_balanced_schedule_cuts_waste():
    """The §Perf claim: folded pairing turns ~50% padded lanes into ~0."""
    nq = 64
    mask = np.tril(np.ones((nq, nq), bool))
    row = attn.build_schedule(mask, balanced=False)
    pair = attn.build_pair_schedule(nq)
    assert row.waste > 0.45
    assert pair.waste < 0.02
    assert pair.valid.sum() == row.valid.sum()  # same useful work
    # coverage: every (r, c<=r) visited exactly once
    visited = set()
    for i in range(pair.rows.shape[0]):
        for j in range(pair.width):
            if pair.valid[i, j]:
                r = int(pair.rows[i, int(pair.tag[i, j])])
                visited.add((r, int(pair.cols[i, j])))
    assert visited == {(r, c) for r in range(nq) for c in range(r + 1)}


def test_balanced_pair_schedule_odd_nq():
    pair = attn.build_pair_schedule(7)
    visited = set()
    for i in range(pair.rows.shape[0]):
        for j in range(pair.width):
            if pair.valid[i, j]:
                r = int(pair.rows[i, int(pair.tag[i, j])])
                visited.add((r, int(pair.cols[i, j])))
    assert visited == {(r, c) for r in range(7) for c in range(r + 1)}


def test_gqa_cache_ring_buffer():
    """Retained-cache decode: ring slot overwrites oldest window entry."""
    from repro.models.config import ModelCfg, LayerSpec
    from repro.models.model import LM
    cfg = ModelCfg(name="t", family="dense", d_model=64, vocab_size=128,
                   num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128,
                   groups=(((LayerSpec(),), 1),),
                   retained_prefix=4, retained_window=8,
                   attn_tile_q=32, attn_tile_kv=32)
    lm = LM(cfg)
    pos = jnp.array([3, 4, 11, 12, 20], jnp.int32)
    slots = lm._ring_slot(pos)
    assert slots.tolist() == [3, 4, 11, 4 + (12 - 4) % 8, 4 + (20 - 4) % 8]
