"""End-to-end training: loss decreases, checkpoint resume is exact,
grad accumulation is consistent."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import latest_step, restore, save
from repro.data import TokenPipeline
from repro.launch.train import train_loop
from repro.models.model import LM
from repro.sharding import rules
from repro.train.step import TrainHParams, init_train_state, make_train_step


# model-level training loop: excluded from the fast tier-1 run (see pytest.ini)
pytestmark = pytest.mark.slow


def _tiny_cfg():
    return configs.smoke("llama3_2_1b")


def test_loss_decreases(tmp_path):
    cfg = _tiny_cfg()
    hp = TrainHParams(peak_lr=1e-3, warmup_steps=3, total_steps=30)
    _, losses = train_loop(cfg, steps=25, batch_per_shard=8, seq=64,
                           ckpt_dir=None, hp=hp, log_every=100)
    assert losses[-1] < losses[0] - 0.02, (losses[0], losses[-1])


def test_resume_is_exact(tmp_path):
    """Train 10; train 6 + crash + resume to 10: identical final loss."""
    cfg = _tiny_cfg()
    hp = TrainHParams(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    kw = dict(batch_per_shard=4, seq=32, hp=hp, log_every=100,
              ckpt_every=3)
    _, l_straight = train_loop(cfg, steps=10, ckpt_dir=None, **kw)
    d = str(tmp_path / "ck")
    _, _ = train_loop(cfg, steps=6, ckpt_dir=d, **kw)
    _, l_resumed = train_loop(cfg, steps=10, ckpt_dir=d, **kw)
    np.testing.assert_allclose(l_resumed[-1], l_straight[-1], rtol=1e-4)


def test_grad_accumulation_matches_full_batch():
    cfg = _tiny_cfg()
    lm = LM(cfg)
    key = jax.random.PRNGKey(0)
    pipe = TokenPipeline(cfg.vocab_size, 8, 32)
    batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
    hp1 = TrainHParams(accum=1, peak_lr=1e-3, warmup_steps=1,
                       total_steps=10)
    hp4 = hp1._replace(accum=4)
    s1 = init_train_state(lm, key, hp=hp1)
    s4 = init_train_state(lm, key, hp=hp4)
    s1b, m1 = jax.jit(make_train_step(lm, hp1))(s1, batch)
    s4b, m4 = jax.jit(make_train_step(lm, hp4))(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=5e-3)
    w1 = np.asarray(s1b.opt.master["embed"]["table"], np.float32)
    w4 = np.asarray(s4b.opt.master["embed"]["table"], np.float32)
    np.testing.assert_allclose(w1, w4, rtol=1e-2, atol=1e-5)


def test_checkpoint_atomicity_and_gc(tmp_path):
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    p = str(tmp_path)
    for s in (1, 2, 3):
        save(p, tree, step=s, extra={"data": {"step": s}})
    assert latest_step(p) == 3
    got, extra, step = restore(p, jax.eval_shape(lambda: tree))
    np.testing.assert_allclose(np.asarray(got["a"]), np.arange(8.0))
    assert got["b"]["c"].dtype == jnp.bfloat16
    assert extra["data"]["step"] == 3
    # a stale .tmp dir must be ignored
    os.makedirs(os.path.join(p, "step_9.tmp"), exist_ok=True)
    assert latest_step(p) == 3


def test_elastic_restore_across_mesh(tmp_path):
    """Checkpoint written unsharded restores onto a (1,1) named mesh with
    logical specs -- the elastic-restart contract."""
    cfg = _tiny_cfg()
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    p = str(tmp_path)
    save(p, params, step=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = rules.param_specs(jax.eval_shape(lambda: params), mesh)
    got, _, _ = restore(p, jax.eval_shape(lambda: params), mesh=mesh,
                        specs=specs)
    a = jax.tree.leaves(got)[0]
    assert hasattr(a, "sharding")
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(got)[0], np.float32),
        np.asarray(jax.tree.leaves(params)[0], np.float32))


def test_data_pipeline_contract():
    pipe = TokenPipeline(100, 4, 16, num_shards=2, shard_id=0)
    pipe1 = TokenPipeline(100, 4, 16, num_shards=2, shard_id=1)
    b0 = pipe.get_batch(0)
    b0_again = pipe.get_batch(0)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    b1 = pipe1.get_batch(0)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # disjoint shards
    # targets are next-token shifted
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["targets"][:, :-1])
