"""Dynamic sparsity (paper §3.3): encoder, capacity bound, planner."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dynamic_sparse as dsp, masks, planner
from repro.core.bsr import BlockSparseMatrix


def test_encode_decode_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 96))
    mask = jnp.asarray(masks.random_block_mask(64, 96, 8, 0.4, seed=1))
    nnz = int(mask.sum())
    op = dsp.encode(w, mask, block_size=8, nnz_max=nnz + 3)
    want = np.asarray(w) * np.repeat(np.repeat(np.asarray(mask), 8, 0), 8, 1)
    np.testing.assert_allclose(np.asarray(op.to_dense()), want, rtol=1e-6)
    assert int(op.nnz) == nnz


def test_encode_overflow_drops():
    """Capacity bound: blocks beyond nnz_max are dropped (bucket
    overflow, paper A.2) -- deterministically, row-major last."""
    w = jnp.ones((64, 64))
    mask = jnp.ones((8, 8), bool)
    op = dsp.encode(w, mask, block_size=8, nnz_max=10)
    assert int(op.nnz) == 10
    dense = np.asarray(op.to_dense())
    # first 10 blocks in row-major order kept
    kept = dense.reshape(8, 8, 8, 8).transpose(0, 2, 1, 3).sum((2, 3)) > 0
    assert kept.reshape(-1)[:10].all() and not kept.reshape(-1)[10:].any()


def test_dspmm_matches_static():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 128, 128, 16, 0.3)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 32))
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 5)
    from repro.core import static_sparse as ssp
    np.testing.assert_allclose(np.asarray(dsp.dspmm(op, x)),
                               np.asarray(ssp.spmm(bsr, x)),
                               rtol=1e-4, atol=1e-4)


def test_dspmm_grad():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 64, 64, 8, 0.5)
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))

    def loss(vals, x):
        o = dsp.DynamicOperand(vals, op.row_idx, op.col_idx, op.nnz,
                               op.shape, op.block_size)
        return (dsp.dspmm(o, x) ** 2).sum()

    gv, gx = jax.grad(loss, argnums=(0, 1))(op.values, x)
    assert np.isfinite(np.asarray(gv)).all()
    assert np.isfinite(np.asarray(gx)).all()

    def loss_dense(vals, x):
        o = dsp.DynamicOperand(vals, op.row_idx, op.col_idx, op.nnz,
                               op.shape, op.block_size)
        return ((o.to_dense() @ x) ** 2).sum()
    gv_d, gx_d = jax.grad(loss_dense, argnums=(0, 1))(op.values, x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(gv_d),
                               rtol=1e-4, atol=1e-4)


# -- planner -----------------------------------------------------------------------

@pytest.mark.parametrize(
    "mkn,d_max,b,units",
    list(itertools.product(
        [(1024, 1024, 256), (4096, 4096, 512), (2048, 512, 64)],
        [1 / 32, 1 / 16, 1 / 4], [4, 8, 16], [4, 64])))
def test_planner_respects_budget(mkn, d_max, b, units):
    m, k, n = mkn
    plan = planner.plan_dynamic(m, k, n, d_max=d_max, block_size=b,
                                units=units)
    assert plan.total_partitions <= units
    # bucket capacity covers the worst admissible pattern with headroom
    total_blocks = (m // b) * (k // b) * d_max
    assert plan.nnz_max_blocks >= total_blocks


def test_planner_prefers_more_splits_for_bigger_problems():
    small = planner.plan_dynamic(512, 512, 64, d_max=1/16, block_size=16,
                                 units=64)
    large = planner.plan_dynamic(8192, 8192, 64, d_max=1/16, block_size=16,
                                 units=64)
    assert large.total_partitions >= small.total_partitions


# -- pruning / dynamic sparse training ------------------------------------------------

def test_rigl_update_preserves_density():
    from repro.core.pruning import rigl_update
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    mask = jnp.asarray(masks.random_block_mask(64, 64, 8, 0.5, seed=2))
    new = rigl_update(w, g, mask, block_size=8, fraction=0.3,
                      rng=jax.random.PRNGKey(3))
    assert int(new.sum()) == int(mask.sum())
    assert bool((new != mask).any())


def test_rigl_update_clamps_move_count_at_high_density():
    # regression: at density ~1 there are fewer inactive blocks than
    # drop candidates -- an unclamped n_move dropped more blocks than it
    # could grow, silently shrinking the active set below d_max capacity
    from repro.core.pruning import rigl_update
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    for density, fraction in ((0.9, 1.0), (1.0, 1.0), (0.95, 0.7)):
        mask = jnp.asarray(
            masks.random_block_mask(64, 64, 8, density, seed=2))
        new = rigl_update(w, g, mask, block_size=8, fraction=fraction,
                          rng=jax.random.PRNGKey(3))
        assert int(new.sum()) == int(mask.sum()), (density, fraction)


def test_rigl_update_rng_breaks_grow_ties():
    # with an all-zero gradient every inactive block is a grow tie;
    # regrowth must depend on rng (a deterministic argsort would grow
    # the lowest block indices every step, biasing the topology)
    from repro.core.pruning import rigl_update
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    g = jnp.zeros((64, 64))
    mask = jnp.asarray(masks.random_block_mask(64, 64, 8, 0.25, seed=2))
    grown = []
    for seed in range(4):
        new = rigl_update(w, g, mask, block_size=8, fraction=0.5,
                          rng=jax.random.PRNGKey(seed))
        assert int(new.sum()) == int(mask.sum())
        grown.append(tuple(np.flatnonzero(
            np.asarray(new) & ~np.asarray(mask)).tolist()))
    assert len(set(grown)) > 1, "regrowth ignored rng on tied scores"
