"""Edge-case regressions for the sparse containers and entry points:
empty patterns, capacity overflow, non-divisible shapes, and
static/dynamic representation agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, dynamic_sparse as dsp, masks, \
    static_sparse as ssp
from repro.core.bsr import BlockSparseMatrix


# -- empty BSR (0 blocks) ------------------------------------------------------

def test_empty_bsr_roundtrip_and_spmm():
    mask = np.zeros((4, 8), bool)
    bsr = BlockSparseMatrix.from_mask(mask, 16)
    assert bsr.nnz_blocks == 0 and bsr.density == 0.0
    assert not np.asarray(bsr.to_dense()).any()
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 8))
    for f in (lambda: ssp.spmm(bsr, x), lambda: dispatch.spmm(bsr, x)):
        y = f()
        assert y.shape == (64, 8)
        assert not np.asarray(y).any()


def test_empty_bsr_grad_is_zero_shaped():
    mask = np.zeros((2, 2), bool)
    bsr = BlockSparseMatrix.from_mask(mask, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    g = jax.grad(lambda v: (dispatch.spmm(bsr.with_values(v), x) ** 2
                            ).sum())(jnp.asarray(bsr.values))
    assert g.shape == (0, 8, 8)


# -- encode overflow beyond nnz_max (drop semantics) ---------------------------

def test_encode_overflow_keeps_row_major_prefix():
    w = jnp.arange(64.0 * 64).reshape(64, 64)
    mask = jnp.ones((8, 8), bool)
    op = dsp.encode(w, mask, block_size=8, nnz_max=10)
    assert op.capacity == 10 and int(op.nnz) == 10
    dense = np.asarray(op.to_dense())
    blocked = np.asarray(w).reshape(8, 8, 8, 8).transpose(0, 2, 1, 3)
    kept = dense.reshape(8, 8, 8, 8).transpose(0, 2, 1, 3)
    flat_src = blocked.reshape(64, 8, 8)
    flat_got = kept.reshape(64, 8, 8)
    np.testing.assert_allclose(flat_got[:10], flat_src[:10])   # kept as-is
    assert not flat_got[10:].any()                             # dropped


def test_encode_overflow_matmul_matches_truncated_oracle():
    """Y from an overflowed operand equals the dense product of the kept
    (row-major prefix) blocks only."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    mask = jnp.ones((8, 8), bool)
    op = dsp.encode(w, mask, block_size=8, nnz_max=12)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    want = jnp.asarray(op.to_dense()) @ x
    got = dispatch.spmm(op, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_encode_from_bsr_overflow_raises():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 64, 64, 8, 0.5)
    with pytest.raises(ValueError, match="exceeds capacity"):
        dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks - 1)


# -- non-divisible shapes raise cleanly ---------------------------------------

def test_from_dense_non_divisible_raises():
    with pytest.raises(ValueError, match="not divisible"):
        BlockSparseMatrix.from_dense(jnp.zeros((60, 64)), 16)
    with pytest.raises(ValueError, match="not divisible"):
        BlockSparseMatrix.from_dense(jnp.zeros((64, 60)), 16)


def test_encode_non_divisible_raises():
    with pytest.raises(ValueError, match="not divisible"):
        dsp.encode(jnp.zeros((60, 64)), jnp.ones((4, 4), bool),
                   block_size=16, nnz_max=4)


def test_spmm_shape_mismatch_raises():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 64, 64, 8, 0.5)
    x_bad = jnp.zeros((48, 4))
    with pytest.raises(ValueError):
        ssp.spmm(bsr, x_bad)
    with pytest.raises(ValueError):
        dispatch.spmm(bsr, x_bad)
    with pytest.raises(ValueError):
        dispatch.spmm(bsr, jnp.zeros((64,)))       # not [k, n]
    with pytest.raises(ValueError):
        dispatch.spmm(jnp.zeros((2, 3, 4)), x_bad)  # operand not 2-D


# -- static/dynamic representation agreement ----------------------------------

def test_dynamic_operand_to_dense_matches_bsr():
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), 128, 96, 8, 0.3)
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 7)
    np.testing.assert_allclose(np.asarray(op.to_dense()),
                               np.asarray(bsr.to_dense()), rtol=1e-6)


def test_encode_matches_masked_dense_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 96))
    mask = masks.random_block_mask(64, 96, 8, 0.4, seed=3)
    bsr = BlockSparseMatrix.from_dense(
        np.asarray(w) * np.repeat(np.repeat(mask, 8, 0), 8, 1), 8,
        keep_mask=mask)
    op = dsp.encode(w, jnp.asarray(mask), block_size=8,
                    nnz_max=int(mask.sum()))
    np.testing.assert_allclose(np.asarray(op.to_dense()),
                               np.asarray(bsr.to_dense()), rtol=1e-6)
