import os
import sys

# NOTE: do NOT set XLA_FLAGS device-count here -- smoke tests and benches
# must see the 1 real CPU device (the 512-device override is exclusively
# for launch/dryrun.py, per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
