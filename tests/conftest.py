import os
import sys

import numpy as np

# NOTE: do NOT set XLA_FLAGS device-count here -- smoke tests and benches
# must see the 1 real CPU device (the 512-device override is exclusively
# for launch/dryrun.py, per the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# Per-dtype tolerance helpers for the gradient-parity conformance sweep
# (tests/test_grad_parity.py) and any other numerics-vs-oracle check.
#
# The budgets are relative to the oracle's max magnitude (block-sparse
# products accumulate over nnz blocks, so per-element relative checks
# explode on near-zero entries): fp32 covers reassociation noise only;
# bf16 (8-bit mantissa) and fp16 (10-bit mantissa) budgets cover one
# round-trip through the forward product + one backward product.
# ---------------------------------------------------------------------------

GRAD_TOLS = {
    "float32": 1e-4,
    "bfloat16": 6e-2,
    "float16": 2e-2,
}


def grad_tol(dtype) -> float:
    import jax.numpy as jnp
    return GRAD_TOLS[jnp.dtype(dtype).name]


def assert_close_for_dtype(got, want, dtype, label: str = ""):
    """Max-norm relative comparison at the dtype's conformance budget."""
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = max(float(np.abs(want).max()), 1e-6)
    err = float(np.abs(got - want).max()) / scale
    tol = grad_tol(dtype)
    assert err <= tol, (f"{label or 'array'} diverges: rel-max err "
                        f"{err:.2e} > {tol:.0e} budget for {dtype}")


# ---------------------------------------------------------------------------
# Telemetry isolation: capacity_report()/plan_report() aggregate into
# process-wide registries (deliberately -- the serving engine wants
# lifetime totals), which made telemetry assertions order-dependent
# across tests.  Zero the aggregates around every test; plans, verdicts
# and disk caches survive (reset_telemetry never forgets decisions).
# ---------------------------------------------------------------------------

import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _reset_sparse_telemetry():
    from repro import sparse
    sparse.reset_telemetry()
    yield
