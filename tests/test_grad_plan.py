"""Plan-lifecycle coverage for the planned backward (differentiable
plans): disk round-trip with zero re-measurement of the backward
verdicts, v3-file invalidation, grad knobs in the fingerprint, the
no-VJP clear error (satellite fix), and vjp under jit / vmap / the
train-step microbatch gradient-accumulation scan."""
import importlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_close_for_dtype

from repro import sparse
from repro.core import dispatch, dynamic_sparse as dsp
from repro.core.bsr import BlockSparseMatrix
from repro.core.sparse_layers import SparseLinear
from repro.train.step import microbatch_grads

M, K, N, B, DENSITY = 128, 256, 64, 16, 0.25


@pytest.fixture(autouse=True)
def _fresh_state():
    sparse.reset()
    sparse.configure(None)
    yield
    sparse.reset()
    sparse.configure(None)


def _problem(seed=0):
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(seed), M, K, B,
                                   DENSITY, pattern_seed=seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (K, N))
    return bsr, x


def _grads(p, bsr, x):
    return jax.grad(lambda v, xx: (p(v, xx) ** 2).sum(),
                    argnums=(0, 1))(jnp.asarray(bsr.values), x)


# -- persistence: backward verdicts ride in the forward record ----------------

def test_grad_verdicts_disk_round_trip_zero_remeasure(tmp_path):
    """Measured fwd+bwd verdicts persist in one record; a restarted
    process re-plans both with ZERO measurements."""
    bsr, x = _problem()
    ctx = sparse.PlanContext(measure=True, cache_dir=str(tmp_path))
    p1 = sparse.plan(bsr, N, x=x, ctx=ctx)
    g1 = p1.explain()["grad"]
    assert g1["mode"] == "planned"
    assert g1["dx"]["source"] == "measured"
    assert g1["dvalues"]["source"] == "measured"
    assert not g1["from_disk"]
    assert sparse.cache_stats()["measurements"] == 2   # fwd race + bwd race

    path = os.path.join(str(tmp_path),
                        f"sparse-plans-v{sparse.SCHEMA_VERSION}.json")
    rec = json.load(open(path))["entries"][p1.key]
    assert rec["grad"]["dx"]["route"] == g1["dx"]["route"]
    assert rec["grad"]["dvalues"]["route"] == g1["dvalues"]["route"]

    sparse.reset()                        # fresh-process simulation
    p2 = sparse.plan(bsr, N, x=x, ctx=ctx)
    s2 = sparse.cache_stats()
    assert s2["measurements"] == 0        # zero re-measurement, fwd AND bwd
    g2 = p2.explain()["grad"]
    assert g2["from_disk"] and p2.from_disk
    assert g2["dx"]["route"] == g1["dx"]["route"]
    assert g2["dvalues"]["route"] == g1["dvalues"]["route"]
    assert g2["dx"]["source"] == "measured"     # provenance preserved
    # the replayed backward is numerically identical
    gv1, gx1 = _grads(p1, bsr, x)
    gv2, gx2 = _grads(p2, bsr, x)
    np.testing.assert_allclose(np.asarray(gv1), np.asarray(gv2), rtol=0,
                               atol=0)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=0,
                               atol=0)


def test_pre_grad_v3_cache_file_invalidated(tmp_path):
    """A v3 (pre-grad-schema) cache file must be ignored wholesale: its
    records carry no backward verdicts, so replaying one would skip the
    backward decisions a restart is entitled to."""
    bsr, x = _problem()
    ctx = sparse.PlanContext(cache_dir=str(tmp_path))
    key = sparse.plan(bsr, N, ctx=ctx).key
    sparse.reset()
    os.remove(os.path.join(
        str(tmp_path), f"sparse-plans-v{sparse.SCHEMA_VERSION}.json"))
    old = {"env": {"schema": 3, "backend": jax.default_backend(),
                   "jax": jax.__version__},
           "entries": {key: {"route": "dense_xla", "source": "measured",
                             "est_seconds": {}}}}
    with open(os.path.join(str(tmp_path), "sparse-plans-v3.json"),
              "w") as f:
        json.dump(old, f)
    p = sparse.plan(bsr, N, ctx=ctx)
    assert not p.from_disk                # old tag never satisfies
    assert p.explain()["grad"]["mode"] == "planned"
    assert not p.explain()["grad"]["from_disk"]


def test_grad_knobs_in_fingerprint():
    """grad_mode / sddmm_mode are part of the plan identity: forcing a
    backward route must not be answered by an auto-raced plan (and vice
    versa), in memory or on disk."""
    bsr, _ = _problem()
    plan_mod = importlib.import_module("repro.sparse.plan")
    spec = sparse.OpSpec.from_operand(bsr, N)
    fp_auto = plan_mod._fingerprint(spec, sparse.PlanContext())
    fp_dx = plan_mod._fingerprint(
        spec, sparse.PlanContext(grad_mode="dense_xla"))
    fp_dv = plan_mod._fingerprint(
        spec, sparse.PlanContext(sddmm_mode="sddmm_xla"))
    assert len({fp_auto, fp_dx, fp_dv}) == 3
    # forward-only plans carry no grad section in the fingerprint
    fp_fwd = plan_mod._fingerprint(
        spec, sparse.PlanContext(differentiable=False))
    assert not any(part == "grad" for part in
                   jax.tree_util.tree_leaves(fp_fwd))

    p_auto = sparse.plan(bsr, N)
    p_forced = sparse.plan(bsr, N,
                           ctx=sparse.PlanContext(grad_mode="dense_xla"))
    assert p_forced is not p_auto
    assert p_forced.explain()["grad"]["dx"]["route"] == "dense_xla"
    assert p_forced.explain()["grad"]["dx"]["source"] == "forced"


def test_grad_mode_validation():
    with pytest.raises(ValueError, match="grad_mode"):
        sparse.PlanContext(grad_mode="bogus")
    with pytest.raises(ValueError, match="sddmm_mode"):
        sparse.PlanContext(sddmm_mode="static_xla")


# -- satellite fix: clear no-VJP error ----------------------------------------

@pytest.mark.parametrize("mode,kind", [("dynamic_grouped", "dynamic"),
                                       ("static_pallas", "static"),
                                       ("dense_pallas", "dense")])
def test_no_vjp_routes_raise_clear_error(mode, kind):
    """Regression: differentiating a forward-only plan used to die deep
    inside Pallas (or silently fall off the fast path).  It must raise
    naming the route and the ``mode=`` workaround."""
    bsr, x = _problem()
    if kind == "dynamic":
        payload = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
        operand = payload
    elif kind == "dense":
        operand = jnp.asarray(bsr.to_dense())
        payload = operand
    else:
        operand = bsr
        payload = jnp.asarray(bsr.values)
    p = sparse.plan(operand, N, ctx=sparse.PlanContext(
        mode=mode, interpret=True, differentiable=False))
    with pytest.raises(ValueError, match=f"{mode}.*no registered VJP"):
        if kind == "dynamic":
            jax.grad(lambda v: p(dsp.DynamicOperand(
                v, payload.row_idx, payload.col_idx, payload.nnz,
                payload.shape, payload.block_size), x).sum())(
                    jnp.asarray(payload.values))
        else:
            jax.grad(lambda v: p(v, x).sum())(payload)
    # the error names the workaround
    try:
        if kind == "static":
            jax.grad(lambda v: p(v, x).sum())(payload)
    except ValueError as e:
        assert "mode=" in str(e) or "differentiable=True" in str(e)


def test_batched_matmul_dense_pallas_grad_raises():
    a = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    ctx = sparse.PlanContext(mode="dense_pallas", interpret=True)
    with pytest.raises(ValueError, match="no registered VJP"):
        jax.grad(lambda aa: sparse.batched_matmul(aa, b, ctx=ctx).sum())(a)


def test_dense_pallas_matmul_planned_backward():
    """Forced dense_pallas matmul plans (differentiable) backprop
    through the planned dense products instead of failing."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    ctx = sparse.PlanContext(mode="dense_pallas", interpret=True)
    gw, gx = jax.grad(
        lambda w_, x_: (sparse.matmul(x_, w_, ctx=ctx) ** 2).sum(),
        argnums=(0, 1))(w, x)
    gw_d, gx_d = jax.grad(
        lambda w_, x_: ((x_ @ w_) ** 2).sum(), argnums=(0, 1))(w, x)
    assert_close_for_dtype(gw, gw_d, "float32", "dense_pallas dW")
    assert_close_for_dtype(gx, gx_d, "float32", "dense_pallas dX")


# -- vjp under jit / vmap / gradient accumulation -----------------------------

def test_plan_vjp_under_jit_and_vmap():
    bsr, x = _problem()
    p = sparse.plan(bsr, N)
    v = jnp.asarray(bsr.values)

    def loss(v_, x_):
        return (p(v_, x_) ** 2).sum()

    gv_e, gx_e = jax.grad(loss, argnums=(0, 1))(v, x)
    gv_j, gx_j = jax.jit(jax.grad(loss, argnums=(0, 1)))(v, x)
    np.testing.assert_allclose(np.asarray(gv_e), np.asarray(gv_j),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gx_e), np.asarray(gx_j),
                               rtol=1e-6, atol=1e-6)
    # per-example grads: vmap over a batch of activations
    xb = jax.random.normal(jax.random.PRNGKey(7), (3, K, 8))
    gxb = jax.vmap(jax.grad(lambda x_: (p(v, x_) ** 2).sum()))(xb)
    for i in range(3):
        gi = jax.grad(lambda x_: (p(v, x_) ** 2).sum())(xb[i])
        np.testing.assert_allclose(np.asarray(gxb[i]), np.asarray(gi),
                                   rtol=1e-5, atol=1e-5)


def test_plan_grad_accumulation_microbatch_scan():
    """The planned backward composes with the production train-step
    accumulation scan (train/step.microbatch_grads): accumulated
    microbatch grads == full-batch grads."""
    bsr, _ = _problem()
    p = sparse.plan(bsr, N)
    params = {"values": jnp.asarray(bsr.values)}
    batch = jax.random.normal(jax.random.PRNGKey(3), (8, K, N))

    def loss_fn(params_, mb):
        y = jax.vmap(lambda x_: p(params_["values"], x_))(mb)
        loss = (y ** 2).mean()
        return loss, {"l2": loss}

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    loss1, m1, g1 = microbatch_grads(grad_fn, params, batch, accum=1)
    loss4, m4, g4 = jax.jit(
        lambda pp, bb: microbatch_grads(grad_fn, pp, bb, accum=4))(
            params, batch)
    np.testing.assert_allclose(float(loss1), float(loss4), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g1["values"]),
                               np.asarray(g4["values"]), rtol=1e-5,
                               atol=1e-6)
    assert np.isfinite(float(m4["l2"]))


def test_sparse_linear_trains_through_planned_backward():
    """SparseLinear's backward runs the planned siblings (and the layer
    knobs force backward routes end-to-end)."""
    layer = SparseLinear.random_pattern(None, K, M, B, DENSITY,
                                        grad_backend="static_xla",
                                        sddmm_backend="sddmm_xla")
    params = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, K))

    g = jax.grad(lambda pp: (layer.apply(pp, x) ** 2).sum())(params)
    assert np.isfinite(np.asarray(g["values"], np.float32)).all()
    rep = sparse.plan_report()
    planned = [r for r in rep["per_plan"].values()
               if (r["grad"] or {}).get("mode") == "planned"]
    assert planned
    assert planned[0]["grad"]["dx"]["route"] == "static_xla"
    assert planned[0]["grad"]["dvalues"]["route"] == "sddmm_xla"


# -- reporting ----------------------------------------------------------------

def test_grad_in_explain_format_and_report():
    bsr, x = _problem()
    p = sparse.plan(bsr, N)
    rep = p.explain()
    assert rep["grad"]["mode"] == "planned"
    assert rep["grad"]["dx"]["route"] in dispatch.ROUTES
    assert rep["grad"]["dvalues"]["route"] in dispatch.SDDMM_ROUTES
    assert "grad:" in sparse.format_plan(p)
    totals = sparse.plan_report()["totals"]
    assert totals["plans"] == 1 and totals["grad_planned"] == 1

    # forward-only plans are reported, not grad-planned
    sparse.reset()
    sparse.plan(bsr, N, ctx=sparse.PlanContext(differentiable=False))
    totals = sparse.plan_report()["totals"]
    assert totals["plans"] == 1 and totals["grad_planned"] == 0


def test_spec_only_dynamic_plan_still_differentiable():
    """Dynamic plans built from an OpSpec (no concrete pattern) keep the
    runtime-index backward."""
    bsr, x = _problem()
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks)
    spec = sparse.OpSpec.from_operand(op, N)
    p = sparse.plan(spec, ctx=sparse.PlanContext(mode="dynamic_xla"))
    gx = jax.grad(lambda x_: (p(op, x_) ** 2).sum())(x)
    gx_d = jax.grad(
        lambda x_: ((jnp.asarray(bsr.to_dense()) @ x_) ** 2).sum())(x)
    assert_close_for_dtype(gx, gx_d, "float32", "spec-only dynamic dX")
