"""Gradient-parity conformance sweep for differentiable sparse plans.

Every plannable route x {fp32, bf16, fp16} x block sizes {4, 16, 64}:
forward AND ``jax.grad`` through the plan must match dense ``jax.grad``
ground truth within the per-dtype budgets in ``tests/conftest.py``
(``assert_close_for_dtype``).  The fast tier runs the XLA-route subset;
the full grid -- including the interpret-mode Pallas forwards that the
plan-level ``custom_vjp`` makes trainable -- runs in the slow tier.
"""
import jax
import jax.numpy as jnp
import pytest
from conftest import assert_close_for_dtype

from repro import sparse
from repro.core import dynamic_sparse as dsp
from repro.core.bsr import BlockSparseMatrix

M = K = 256
N = 32
DENSITY = 0.25
BLOCKS = (4, 16, 64)
DTYPES = ("float32", "bfloat16", "float16")


@pytest.fixture(autouse=True)
def _fresh_state():
    sparse.reset()
    yield
    sparse.reset()


def _problem(b, dtype, seed=0):
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(seed), M, K, b,
                                   DENSITY, dtype=jnp.dtype(dtype),
                                   pattern_seed=seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100),
                          (K, N)).astype(dtype)
    return bsr, x


def _dense_fwd_bwd(bsr, x):
    """Ground truth: dense jax.grad at the same dtype (the conformance
    budget covers route-vs-dense reassociation, not dtype error)."""
    v = jnp.asarray(bsr.values)

    def loss(v_, x_):
        y = bsr.with_values(v_).to_dense() @ x_
        return (y.astype(jnp.float32) ** 2).sum()

    y = jnp.asarray(bsr.to_dense()) @ x
    gv, gx = jax.grad(loss, argnums=(0, 1))(v, x)
    return y, gv, gx


def _grid(routes, *, interpret=False):
    cases = []
    for route in routes:
        for dtype in DTYPES:
            for b in BLOCKS:
                fast = (not interpret
                        and (dtype == "float32"
                             or (dtype == "bfloat16" and b == 16)))
                marks = () if fast else (pytest.mark.slow,)
                cases.append(pytest.param(route, dtype, b, marks=marks,
                                          id=f"{route}-{dtype}-b{b}"))
    return cases


STATIC_XLA_ROUTES = ("auto", "static_xla", "dense_xla", "dynamic_xla")
STATIC_PALLAS_ROUTES = ("static_pallas", "dense_pallas",
                        "dynamic_pallas", "dynamic_grouped")


@pytest.mark.parametrize("route,dtype,b",
                         _grid(STATIC_XLA_ROUTES)
                         + _grid(STATIC_PALLAS_ROUTES, interpret=True))
def test_static_plan_fwd_bwd_parity(route, dtype, b):
    """Static-pattern plans: fwd + planned backward vs dense autodiff."""
    bsr, x = _problem(b, dtype)
    interpret = route in STATIC_PALLAS_ROUTES
    ctx = sparse.PlanContext(mode=route, interpret=interpret)
    p = sparse.plan(bsr, N, ctx=ctx)
    assert p.explain()["grad"]["mode"] == "planned"
    v = jnp.asarray(bsr.values)

    y_d, gv_d, gx_d = _dense_fwd_bwd(bsr, x)
    assert_close_for_dtype(p(v, x), y_d, dtype, f"{route} forward")

    def loss(v_, x_):
        return (p(v_, x_).astype(jnp.float32) ** 2).sum()

    gv, gx = jax.grad(loss, argnums=(0, 1))(v, x)
    assert_close_for_dtype(gv, gv_d, dtype, f"{route} dL/dvalues")
    assert_close_for_dtype(gx, gx_d, dtype, f"{route} dL/dx")


@pytest.mark.parametrize(
    "route,dtype,b",
    _grid(("dynamic_xla",)) + _grid(("dynamic_pallas", "dynamic_grouped"),
                                    interpret=True))
def test_dynamic_plan_fwd_bwd_parity(route, dtype, b):
    """Runtime-pattern plans: the runtime-index planned backward (and
    _dspmm's native one for dynamic_xla) vs dense autodiff.  Gradients
    are compared on the real (non-padding) slots."""
    bsr, x = _problem(b, dtype)
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 3)
    interpret = route != "dynamic_xla"
    p = sparse.plan(op, N, ctx=sparse.PlanContext(mode=route,
                                                  interpret=interpret))
    y_d, gv_d, gx_d = _dense_fwd_bwd(bsr, x)

    def loss(v_, x_):
        o = dsp.DynamicOperand(v_, op.row_idx, op.col_idx, op.nnz,
                               op.shape, op.block_size)
        return (p(o, x_).astype(jnp.float32) ** 2).sum()

    assert_close_for_dtype(p(op, x), y_d, dtype, f"{route} forward")
    gv, gx = jax.grad(loss, argnums=(0, 1))(jnp.asarray(op.values), x)
    assert_close_for_dtype(gv[:bsr.nnz_blocks], gv_d, dtype,
                           f"{route} dL/dvalues")
    assert_close_for_dtype(gx, gx_d, dtype, f"{route} dL/dx")


@pytest.mark.parametrize(
    "sddmm_mode,dtype,b",
    _grid(("sddmm_xla", "sddmm_dense"))
    + _grid(("sddmm_grouped",), interpret=True))
def test_forced_sddmm_route_parity(sddmm_mode, dtype, b):
    """Every dL/dvalues (SDDMM) backward route, forced via the plan
    knob, matches dense autodiff."""
    bsr, x = _problem(b, dtype)
    ctx = sparse.PlanContext(sddmm_mode=sddmm_mode,
                             interpret=sddmm_mode == "sddmm_grouped")
    p = sparse.plan(bsr, N, ctx=ctx)
    assert p.explain()["grad"]["dvalues"]["route"] == sddmm_mode
    _, gv_d, _ = _dense_fwd_bwd(bsr, x)
    gv = jax.grad(lambda v_: (p(v_, x).astype(jnp.float32) ** 2).sum())(
        jnp.asarray(bsr.values))
    assert_close_for_dtype(gv, gv_d, dtype, f"{sddmm_mode} dL/dvalues")


@pytest.mark.parametrize(
    "grad_mode,dtype,b",
    _grid(("static_xla", "dense_xla", "dynamic_xla"))
    + _grid(("static_pallas", "dynamic_grouped"), interpret=True))
def test_forced_dx_route_parity(grad_mode, dtype, b):
    """Every dL/dx backward route (an SpMM on the transposed pattern),
    forced via the plan knob, matches dense autodiff."""
    bsr, x = _problem(b, dtype)
    ctx = sparse.PlanContext(
        grad_mode=grad_mode,
        interpret=grad_mode in ("static_pallas", "dynamic_grouped"))
    p = sparse.plan(bsr, N, ctx=ctx)
    assert p.explain()["grad"]["dx"]["route"] == grad_mode
    _, _, gx_d = _dense_fwd_bwd(bsr, x)
    gx = jax.grad(lambda x_: (p(jnp.asarray(bsr.values), x_)
                              .astype(jnp.float32) ** 2).sum())(x)
    assert_close_for_dtype(gx, gx_d, dtype, f"{grad_mode} dL/dx")


def test_static_tp_plan_grad_native_parity():
    """TP-route plans differentiate through native autodiff (gspmd psum
    lowering); parity vs dense on one device."""
    bsr, x = _problem(16, "float32")
    p = sparse.plan(bsr, N, ctx=sparse.PlanContext(mode="static_tp",
                                                   tp_q=4))
    assert p.explain()["grad"] == {"mode": "native"}
    _, gv_d, gx_d = _dense_fwd_bwd(bsr, x)
    gv, gx = jax.grad(
        lambda v_, x_: (p(v_, x_).astype(jnp.float32) ** 2).sum(),
        argnums=(0, 1))(jnp.asarray(bsr.values), x)
    assert_close_for_dtype(gv, gv_d, "float32", "static_tp dL/dvalues")
    assert_close_for_dtype(gx, gx_d, "float32", "static_tp dL/dx")
