"""Serving engine: admission/termination semantics, bucketed prefill,
plan pools, the background re-planner, and live stats.

Fast tier: a stub LM whose next-token rule is ``tok+1 mod V`` via a
real ``sparse.matmul`` (so plan counters and pools are exercised) --
covers termination contracts, bucket compile counts, the
zero-decision acceptance criterion, and the re-planner.  Slow tier:
model-level parity and continuous batching on a real smoke LM.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, sparse as sparse_api
from repro.core.bsr import BlockSparseMatrix
from repro.models.model import LM
from repro.serve import Engine, Request
from repro.serve.engine import _auto_buckets, _pad_safe, _stack_shapes

V = 16            # stub vocab


class StubLM:
    """Duck-typed LM: next token = (last true token + 1) mod V, via a
    real ``sparse.matmul`` with a shift-permutation weight -- so the
    engine's traced programs build genuine plans (pools, counters)
    while outputs stay exactly predictable.  Reads the true last
    prompt token through ``last_index``: a pad-correctness oracle
    (wrong gather => wrong token, every test below notices)."""

    def __init__(self, cfg):
        self.cfg = cfg

    def make_params(self):
        w = np.zeros((V, V), np.float32)
        w[np.arange(V), (np.arange(V) + 1) % V] = 1.0
        return {"w": jnp.asarray(w)}

    def init_cache(self, batch, max_len, **kw):
        return {"tok": jnp.zeros((1, batch, max_len), jnp.int32)}

    def _logits(self, params, tokens):
        oh = jax.nn.one_hot(tokens, V, dtype=jnp.float32)
        return sparse_api.matmul(oh, params["w"])

    def prefill(self, params, tokens, *, max_len, last_index=None,
                **kw):
        b, s = tokens.shape
        h = self._logits(params, tokens)              # [B, S, V]
        if last_index is None:
            logits = h[:, -1]
        else:
            idx = jnp.asarray(last_index, jnp.int32).reshape(-1, 1, 1)
            logits = jnp.take_along_axis(
                h, jnp.broadcast_to(idx, (b, 1, V)), axis=1)[:, 0]
        cache = {"tok": jnp.zeros((1, b, max_len), jnp.int32)
                 .at[:, :, :s].set(tokens[None])}
        return logits, cache

    def decode_step(self, params, tokens, caches, positions,
                    retained=False):
        return self._logits(params, tokens)[:, 0], caches


class SparseStubLM(StubLM):
    """Stub whose prefill also routes through a static block-sparse
    plan (zero-weighted, so outputs are unchanged) -- gives the
    engine's pool an analytic verdict the re-planner can upgrade."""

    def __init__(self, cfg, wsp):
        super().__init__(cfg)
        self.wsp = wsp

    def prefill(self, params, tokens, *, max_len, last_index=None,
                **kw):
        logits, cache = super().prefill(
            params, tokens, max_len=max_len, last_index=last_index)
        oh = jax.nn.one_hot(tokens, V, dtype=jnp.float32)
        logits = logits + 0.0 * sparse_api.spmm_nt(self.wsp, oh)[:, -1]
        return logits, cache


def _stub_engine(batch=2, max_len=20, buckets=(4, 8, 16), lm=None,
                 **kw):
    sparse_api.reset()
    lm = lm or StubLM(configs.smoke("llama3_2_1b"))
    eng = Engine(lm, lm.make_params(), batch=batch, max_len=max_len,
                 buckets=buckets, **kw)
    return eng


def _req(prompt, uid=0, **kw):
    return Request(uid=uid, prompt=np.asarray(prompt, np.int32), **kw)


# -- admission validation (satellite bugfix 1) ------------------------------

def test_oversized_prompt_rejected():
    eng = _stub_engine(max_len=8, buckets=(4, 7))
    with pytest.raises(ValueError, match="max_len=8"):
        eng.admit(_req(np.arange(8) % V))
    with pytest.raises(ValueError, match="at most 7"):
        eng.submit(_req(np.arange(11) % V))
    with pytest.raises(ValueError, match="empty"):
        eng.admit(_req([]))
    # the limit itself admits
    assert eng.admit(_req(np.arange(7) % V, max_new_tokens=2))


# -- termination semantics (satellite bugfix 2 + tests) ---------------------

def test_eos_at_prefill_frees_slot_immediately():
    eng = _stub_engine()
    # prompt ends with 3 -> prefill generates 4 == eos
    req = _req([1, 2, 3], eos_id=4, max_new_tokens=8)
    assert eng.admit(req)
    assert req.done and req.output == [4]
    assert eng.live == {} and len(eng.free) == eng.batch
    st = eng.stats()
    assert st["admission"]["eos_at_prefill"] == 1
    assert st["steps"] == 0          # not one decode step was spent


def test_eos_at_decode():
    eng = _stub_engine()
    req = _req([1, 2, 3], eos_id=6, max_new_tokens=32)
    eng.run([req])
    assert req.output == [4, 5, 6]   # stops AT eos, slot freed
    assert eng.live == {} and len(eng.free) == eng.batch


def test_max_new_tokens_includes_prefill_token():
    eng = _stub_engine()
    req = _req([7], max_new_tokens=4)
    eng.run([req])
    # the contract: output INCLUDES the prefill-generated token, so
    # max_new_tokens=4 is exactly 4 tokens (1 prefill + 3 decode)
    assert req.output == [8, 9, 10, 11]
    one = _req([7], uid=1, max_new_tokens=1)
    assert eng.admit(one)
    assert one.done and one.output == [8]    # finished at admission


def test_padded_prefill_reads_true_last_token():
    # lengths 3 and 5 share bucket 8: pads must not leak into logits
    eng = _stub_engine()
    a, b = _req([1, 2, 3], uid=0, max_new_tokens=3), \
        _req([1, 2, 3, 4, 5], uid=1, max_new_tokens=3)
    eng.run([a, b])
    assert a.output == [4, 5, 6]
    assert b.output == [6, 7, 8]


# -- on_finish from slot-release bookkeeping (satellite bugfix 3) -----------

def test_on_finish_fires_exactly_once_per_request():
    eng = _stub_engine(batch=2)
    reqs = [_req([i % V], uid=i, max_new_tokens=2 + i % 3)
            for i in range(7)]
    # include an eos-at-prefill request: it must fire too
    reqs.append(_req([1, 2, 3], uid=99, eos_id=4, max_new_tokens=9))
    seen = []
    eng.run(reqs, on_finish=lambda r: seen.append(r.uid))
    assert sorted(seen) == sorted(r.uid for r in reqs)
    assert all(r.done for r in reqs)


# -- bucketed prefill: compiles + zero-decision acceptance ------------------

def test_prefill_compiles_once_per_bucket_not_per_length():
    eng = _stub_engine(batch=2, max_len=20, buckets=(4, 8, 16))
    assert eng.buckets == (4, 8, 16, 19)
    lengths = [2, 3, 4, 5, 7, 9, 11, 15]     # 8 lengths, 3 buckets
    reqs = [_req(np.arange(s) % V, uid=i, max_new_tokens=2)
            for i, s in enumerate(lengths)]
    eng.run(reqs)
    assert {r.bucket for r in reqs} == {4, 8, 16}
    assert eng._prefill._cache_size() == 3
    st = eng.stats()
    assert st["buckets"][4]["prefills"] == 3
    assert st["buckets"][8]["prefills"] == 2
    assert st["buckets"][16]["prefills"] == 3
    assert st["buckets"][8]["pad_tokens"] == (8 - 5) + (8 - 7)


def test_warm_serving_zero_recompiles_zero_decisions():
    """The PR acceptance criterion: after startup warmup, a
    mixed-length stream across >= 3 buckets triggers zero XLA
    recompiles and zero new dispatch decisions/measurements on the
    foreground path."""
    eng = _stub_engine(batch=2, max_len=20, buckets=(4, 8, 16),
                      warm_compile=True)
    assert eng.plan_stats["plans_built"] > 0
    compiles = (eng._prefill._cache_size(), eng._decode._cache_size())
    before = sparse_api.cache_stats()
    reqs = [_req(np.arange(s) % V, uid=i, max_new_tokens=3)
            for i, s in enumerate([2, 5, 9, 3, 15, 7, 12, 4])]
    eng.run(reqs)
    assert {r.bucket for r in reqs} == {4, 8, 16}   # >= 3 buckets hit
    after = sparse_api.cache_stats()
    assert (eng._prefill._cache_size(),
            eng._decode._cache_size()) == compiles
    assert after["decisions"] == before["decisions"]
    assert after["measurements"] == before["measurements"]
    assert after["plans_built"] == before["plans_built"]
    assert eng.stats()["admission"]["exact_prefills"] == 0


# -- queue + dropped_frac ----------------------------------------------------

def test_bounded_queue_drops_and_counts():
    eng = _stub_engine(batch=1, max_queue=2)
    reqs = [_req([i % V], uid=i, max_new_tokens=2) for i in range(5)]
    accepted = [eng.submit(r) for r in reqs]
    assert accepted == [True, True, False, False, False]
    assert [r.dropped for r in reqs] == [False, False, True, True, True]
    eng.serve()
    st = eng.stats()
    assert st["admission"]["dropped"] == 3
    assert st["admission"]["dropped_frac"] == pytest.approx(0.6)
    assert all(r.done for r in reqs[:2])
    assert not any(r.done for r in reqs[2:])


# -- stats endpoint ----------------------------------------------------------

def test_stats_and_plan_report_fields():
    eng = _stub_engine()
    eng.run([_req([1, 2, 3], max_new_tokens=4)])
    st = eng.stats()
    assert st["step_latency"]["count"] == st["steps"] == 3
    assert st["step_latency"]["p50_ms"] is not None
    assert st["step_latency"]["p99_ms"] is not None
    assert st["buckets"][4]["latency"]["count"] == 1
    assert st["padding"]["pad_tokens"] == 1          # 3 -> bucket 4
    assert 0.0 <= st["padding"]["waste_frac"] <= 1.0
    assert st["queue_depth"] == 0 and st["live_slots"] == 0
    assert "overflow_calls" in st["capacity_overflow"]
    assert st["replanner"] == {"running": False, "sweeps": 0,
                               "upgrades": 0}
    rep = eng.plan_report()
    assert rep["engine"]["steps"] == 3
    for key in ("startup", "now", "capacity", "tp", "plans",
                "roofline", "engine"):
        assert key in rep


# -- plan pools + background re-planner --------------------------------------

def _sparse_stub():
    cfg = configs.smoke("llama3_2_1b")
    wsp = BlockSparseMatrix.random(jax.random.PRNGKey(0), V, V, 4, 0.5)
    return SparseStubLM(cfg, wsp)


def test_pool_registers_engine_plans():
    eng = _stub_engine(lm=_sparse_stub())
    plans = sparse_api.pool_plans(eng.pool)
    assert plans, "warmup must register plans under the engine pool"
    assert all(p.ctx.pool == eng.pool for p in plans)
    # pool label is runtime-only: same problem, different pool label,
    # same disk fingerprint
    other = dataclasses.replace(plans[0].ctx, pool="other")
    q = sparse_api.plan(plans[0].spec, ctx=other)
    assert q.key == plans[0].key


def test_replanner_upgrades_analytic_verdicts():
    eng = _stub_engine(lm=_sparse_stub(), warm_compile=True)
    analytic = sparse_api.analytic_plans(eng.pool)
    assert analytic, "sparse stub must leave analytic verdicts to upgrade"
    before = sparse_api.cache_stats()
    n = eng.replan_once(reps=1)
    assert n == len(analytic)
    assert sparse_api.analytic_plans(eng.pool) == []
    st = eng.stats()["replanner"]
    assert st["sweeps"] == 1 and st["upgrades"] == n
    # the upgrade measured in the BACKGROUND; foreground serving stays
    # decision-free and the already-compiled programs still run
    fore = sparse_api.cache_stats()
    reqs = [_req(np.arange(s) % V, uid=i, max_new_tokens=3)
            for i, s in enumerate([2, 5, 9])]
    eng.run(reqs)
    after = sparse_api.cache_stats()
    assert after["decisions"] == fore["decisions"]
    assert after["measurements"] == fore["measurements"]
    assert after["measurements"] > before["measurements"]
    # a rebuild of the same problem now replays the measured verdict
    p = sparse_api.plan(analytic[0].spec, ctx=analytic[0].ctx)
    assert p.source == "measured" and p.from_disk


def test_replanner_thread_lifecycle():
    eng = _stub_engine(lm=_sparse_stub(), replanner=True,
                      replanner_interval=0.01, replanner_reps=1)
    deadline = 200
    while sparse_api.analytic_plans(eng.pool) and deadline:
        time.sleep(0.01)
        deadline -= 1
    assert sparse_api.analytic_plans(eng.pool) == []
    assert eng.stats()["replanner"]["running"]
    eng.stop_replanner()
    assert not eng.stats()["replanner"]["running"]


# -- SSM fallback + bucket ladder helpers ------------------------------------

def test_ssm_stack_disables_bucketing():
    cfg = configs.smoke("mamba2_130m")
    assert not _pad_safe(cfg)
    eng = _stub_engine(lm=StubLM(cfg), buckets=(4, 8, 16))
    assert eng.buckets == () and not eng.pad_safe
    req = _req([1, 2, 3], max_new_tokens=3)
    eng.run([req])
    assert req.bucket is None and req.output == [4, 5, 6]
    assert eng.stats()["admission"]["exact_prefills"] == 1


def test_auto_buckets_cover_and_end_at_top():
    shapes = _stack_shapes(configs.get("llama3_2_1b"))
    for frac in (0.25, 0.5, 0.75):
        ladder = _auto_buckets(511, shapes, frac)
        assert ladder[-1] == 511
        assert list(ladder) == sorted(set(ladder))
    # tighter waste budget => at least as many buckets
    assert len(_auto_buckets(511, shapes, 0.25)) >= \
        len(_auto_buckets(511, shapes, 0.75))
    assert _auto_buckets(8, shapes, 0.5) == (8,)


# ===========================================================================
# model-level (slow tier): parity + continuous batching on a real LM
# ===========================================================================

def _setup():
    cfg = configs.smoke("llama3_2_1b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _manual_generate(lm, params, prompt, n, max_len):
    logits, caches = lm.prefill(params, prompt[None], max_len=max_len)
    out = [int(jnp.argmax(logits[0]))]
    pos = prompt.shape[0]
    for _ in range(n - 1):
        lg, caches = lm.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), caches,
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    return out


@pytest.mark.slow
def test_engine_matches_manual_decode():
    """Bucketed (padded) prefill must reproduce exact-length decode:
    the engine pads the 12-token prompt to a bucket, yet the gathered
    last-token logits and masked decode see only real tokens."""
    cfg, lm, params = _setup()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (12,), 0,
                                cfg.vocab_size).astype(jnp.int32)
    want = _manual_generate(lm, params, prompt, 6, max_len=64)
    eng = Engine(lm, params, batch=2, max_len=64, buckets=(16, 32))
    req = Request(uid=0, prompt=np.asarray(prompt), max_new_tokens=6)
    eng.run([req])
    assert req.bucket == 16
    assert req.output[:6] == want


@pytest.mark.slow
def test_engine_continuous_batching():
    cfg, lm, params = _setup()
    reqs = []
    for i in range(5):       # more requests than the batch has slots
        prompt = jax.random.randint(jax.random.PRNGKey(i), (8 + i,), 0,
                                    cfg.vocab_size).astype(jnp.int32)
        reqs.append(Request(uid=i, prompt=np.asarray(prompt),
                            max_new_tokens=4 + i))
    eng = Engine(lm, params, batch=2, max_len=64)
    done = []
    eng.run(reqs, on_finish=lambda r: done.append(r.uid))
    assert sorted(done) == [0, 1, 2, 3, 4]
    for r in reqs:
        assert r.done and len(r.output) == r.max_new_tokens

    # slot isolation: rerun one of the requests alone -> same output
    solo = Request(uid=9, prompt=reqs[3].prompt,
                   max_new_tokens=reqs[3].max_new_tokens)
    eng2 = Engine(lm, params, batch=2, max_len=64)
    eng2.run([solo])
    assert solo.output == reqs[3].output
