"""Serving engine: continuous batching correctness on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.model import LM
from repro.serve import Engine, Request


import pytest

# model-level serving engine: excluded from the fast tier-1 run (see pytest.ini)
pytestmark = pytest.mark.slow


def _setup():
    cfg = configs.smoke("llama3_2_1b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    return cfg, lm, params


def _manual_generate(lm, params, prompt, n, max_len):
    logits, caches = lm.prefill(params, prompt[None], max_len=max_len)
    out = [int(jnp.argmax(logits[0]))]
    pos = prompt.shape[0]
    for _ in range(n - 1):
        lg, caches = lm.decode_step(
            params, jnp.asarray([[out[-1]]], jnp.int32), caches,
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    return out


def test_engine_matches_manual_decode():
    cfg, lm, params = _setup()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (12,), 0,
                                cfg.vocab_size).astype(jnp.int32)
    want = _manual_generate(lm, params, prompt, 6, max_len=64)
    eng = Engine(lm, params, batch=2, max_len=64)
    req = Request(uid=0, prompt=np.asarray(prompt), max_new_tokens=6)
    eng.run([req])
    assert req.output[:6] == want


def test_engine_continuous_batching():
    cfg, lm, params = _setup()
    reqs = []
    for i in range(5):       # more requests than the batch has slots
        prompt = jax.random.randint(jax.random.PRNGKey(i), (8 + i,), 0,
                                    cfg.vocab_size).astype(jnp.int32)
        reqs.append(Request(uid=i, prompt=np.asarray(prompt),
                            max_new_tokens=4 + i))
    eng = Engine(lm, params, batch=2, max_len=64)
    done = []
    eng.run(reqs, on_finish=lambda r: done.append(r.uid))
    assert sorted(done) == [0, 1, 2, 3, 4]
    for r in reqs:
        assert r.done and len(r.output) == r.max_new_tokens

    # slot isolation: rerun one of the requests alone -> same output
    solo = Request(uid=9, prompt=reqs[3].prompt,
                   max_new_tokens=reqs[3].max_new_tokens)
    eng2 = Engine(lm, params, batch=2, max_len=64)
    eng2.run([solo])
    assert solo.output == reqs[3].output
