"""Sparse NN layers: SparseLinear/SparseFFN (static) and
DynamicSparseLinear (runtime mask) -- the framework integration of the
paper's technique."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sparse_layers import (DynamicSparseLinear, SparseFFN,
                                      SparseLinear)


def test_sparse_linear_matches_masked_dense():
    layer = SparseLinear.random_pattern(None, 64, 128, 16, 0.5, seed=0)
    params = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    y = layer.apply(params, x)
    w = np.asarray(layer.as_bsr(params).to_dense())   # [out, in]
    want = np.asarray(x) @ w.T
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("density", [0.125, 0.25, 0.5])
@pytest.mark.parametrize("b", [8, 16])
def test_sparse_linear_density(density, b):
    layer = SparseLinear.random_pattern(None, 128, 128, b, density, seed=1)
    assert abs(layer.density - density) < 0.05


def test_sparse_ffn_trains():
    ffn = SparseFFN(d_model=64, d_ff=256, block_size=16, density=0.25)
    params = ffn.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))

    def loss(p):
        return (ffn.apply(p, x) ** 2).mean()

    l0 = float(loss(params))
    g = jax.grad(loss)(params)
    params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
    assert float(loss(params)) < l0
    # FLOP accounting matches the paper's 2*m*k*n*d convention
    assert ffn.flops_per_token() == 2 * 64 * 256 * 0.25 * 3


def test_dynamic_sparse_linear_respects_mask():
    layer = DynamicSparseLinear(64, 64, 16, d_max=0.25)
    params = layer.init(jax.random.PRNGKey(0))
    x = jnp.eye(64)
    y = layer.apply(params, x)          # y = W_masked^T  (x=I)
    w_eff = np.asarray(y).T
    mask = np.repeat(np.repeat(np.asarray(params["mask"]), 16, 0), 16, 1)
    assert (np.abs(w_eff[~mask]) < 1e-6).all()


def test_dynamic_sparse_topology_update_changes_output():
    from repro.core.pruning import rigl_update
    layer = DynamicSparseLinear(64, 64, 16, d_max=0.25)
    params = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    y0 = layer.apply(params, x)
    g = jax.grad(lambda w: (layer.apply({**params, "w": w}, x) ** 2).sum()
                 )(params["w"])
    params["mask"] = rigl_update(params["w"], g, params["mask"],
                                 block_size=16, fraction=0.5,
                                 rng=jax.random.PRNGKey(2))
    y1 = layer.apply(params, x)
    assert np.abs(np.asarray(y0) - np.asarray(y1)).max() > 1e-6
