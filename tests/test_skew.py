"""Skewed-pattern support (PR 8): the mask generator family, the
row-swizzle pre-pass, and the balanced-walk routes.

Covers the satellite regressions (``random_block_mask`` density edge
cases, ``balance_report`` skew fields), parity of the two balanced
routes against the dense oracle across dtypes x blocks (interpret
mode), and the dispatch-race crossover: a skewed pattern flips the
verdict to the balanced variant, a uniform one never does.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_close_for_dtype
from repro.core import dispatch, masks, partitioner
from repro.core import dynamic_sparse as dsp
from repro.core.bsr import BlockSparseMatrix


# -- masks.random_block_mask regressions --------------------------------------

def test_density_zero_returns_empty_mask():
    for clustered in (False, True):
        mask = masks.random_block_mask(128, 128, 16, 0.0,
                                       clustered=clustered)
        assert mask.sum() == 0


def test_power_law_density_zero_returns_empty_mask():
    assert masks.power_law_block_mask(128, 128, 16, 0.0).sum() == 0
    assert masks.dlmc_block_mask(128, 128, 16, 0.0).sum() == 0


def test_clustered_trim_uses_seeded_rng():
    """The overshoot trim must thin the cluster with the seeded rng,
    not by clearing the highest-index set bits (which systematically
    depleted bottom-right tiles)."""
    # nnz=10 < one full super-tile, so the fill overshoots and trims
    d = 10 / 256
    m1 = masks.random_block_mask(256, 256, 16, d, seed=3, clustered=True)
    m2 = masks.random_block_mask(256, 256, 16, d, seed=3, clustered=True)
    assert (m1 == m2).all() and m1.sum() == 10   # deterministic, exact
    # the old trim kept exactly the lowest flat indices of the cluster;
    # the rng trim must not (seeded, so this is a stable assertion)
    untrimmed = masks.random_block_mask(256, 256, 16, 64 / 256, seed=3,
                                        clustered=True)
    kept = set(np.flatnonzero(m1))
    assert kept <= set(np.flatnonzero(untrimmed))
    lowest = set(sorted(np.flatnonzero(untrimmed))[:10])
    assert kept != lowest


# -- skewed mask generators ---------------------------------------------------

def test_power_law_mask_is_skewed_and_deterministic():
    mask = masks.power_law_block_mask(4096, 4096, 16, 1 / 16, seed=0)
    again = masks.power_law_block_mask(4096, 4096, 16, 1 / 16, seed=0)
    assert (mask == again).all()
    assert mask.shape == (256, 256)
    target = round(256 * 256 / 16)
    assert abs(int(mask.sum()) - target) <= 1
    rep = partitioner.balance_report(mask.sum(axis=1))
    assert rep["imbalance"] >= 2.0           # genuinely skewed rows
    uni = masks.random_block_mask(4096, 4096, 16, 1 / 16, seed=0)
    uni_rep = partitioner.balance_report(uni.sum(axis=1))
    assert rep["imbalance"] > 1.5 * uni_rep["imbalance"]


def test_dlmc_mask_row_profile():
    mask = masks.dlmc_block_mask(1024, 1024, 16, 0.1, seed=1)
    assert mask.shape == (64, 64)
    assert abs(int(mask.sum()) - round(0.1 * 64 * 64)) <= 1
    assert (masks.dlmc_block_mask(1024, 1024, 16, 0.1, seed=1)
            == mask).all()
    # lognormal row profile: some spread, no all-or-nothing rows only
    counts = mask.sum(axis=1)
    assert counts.max() > counts.min()


# -- balance_report skew fields -----------------------------------------------

def test_balance_report_frac_empty_and_cv():
    rep = partitioner.balance_report(np.array([0, 2, 2, 4]))
    assert rep["frac_empty"] == pytest.approx(0.25)
    assert rep["cv"] == pytest.approx(np.sqrt(2.0) / 2.0)
    assert rep["imbalance"] == pytest.approx(2.0)
    empty = partitioner.balance_report(np.array([], dtype=np.int64))
    assert empty["frac_empty"] == 0.0 and empty["cv"] == 0.0


def test_pattern_balance_uniform_vs_skewed():
    b = 16
    skew = BlockSparseMatrix.from_mask(
        masks.power_law_block_mask(4096, 4096, b, 1 / 32, seed=0), b)
    uni = BlockSparseMatrix.from_mask(
        masks.random_block_mask(4096, 4096, b, 1 / 32, seed=0), b)
    imb_s, cv_s = dispatch.pattern_balance(skew)
    imb_u, cv_u = dispatch.pattern_balance(uni)
    assert imb_s >= 2.0 and imb_s > imb_u
    assert cv_s > cv_u >= 0.0


# -- balanced-route parity vs the dense oracle (interpret mode) ---------------

def _skewed_problem(b, dtype, m=128, k=256, n=64, density=0.25):
    mask = masks.power_law_block_mask(m, k, b, density, seed=1)
    bsr = BlockSparseMatrix.from_mask(mask, b)
    vals = jax.random.normal(jax.random.PRNGKey(2),
                             bsr.values.shape).astype(dtype)
    bsr = bsr.with_values(vals)
    x = jax.random.normal(jax.random.PRNGKey(3), (k, n)).astype(dtype)
    oracle = (jnp.asarray(bsr.to_dense()).astype(jnp.float32)
              @ x.astype(jnp.float32))
    return bsr, x, oracle


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16,
                                   jnp.float16])
@pytest.mark.parametrize("b", [8, 16])
@pytest.mark.parametrize("route", ["static_balanced",
                                   "dynamic_grouped_balanced"])
def test_balanced_route_parity(route, b, dtype):
    bsr, x, oracle = _skewed_problem(b, dtype)
    op = (bsr if route == "static_balanced"
          else dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + 4))
    ctx = dispatch.DispatchContext(mode=route, interpret=True)
    y = dispatch.spmm(op, x, ctx=ctx)
    assert_close_for_dtype(y, oracle, dtype, route)


@pytest.mark.parametrize("route", ["static_balanced",
                                   "dynamic_grouped_balanced"])
def test_balanced_plan_executes_and_reports_swizzle(route):
    from repro import sparse
    dtype = jnp.float32
    b, n = 16, 64
    bsr, x, oracle = _skewed_problem(b, dtype, n=n)
    ctx = sparse.PlanContext(mode=route, interpret=True,
                             differentiable=False, cache=False)
    p = sparse.plan(bsr, n, ctx=ctx)
    assert p.route == route
    if route == "static_balanced":
        plan_art = p.explain()["plan"]
        assert plan_art["swizzle_bins"] >= 1
        assert plan_art["swizzle_imbalance"] >= 1.0
    y = p(jnp.asarray(bsr.values), x)
    assert_close_for_dtype(y, oracle, dtype, f"plan {route}")


# -- the dispatch race: skew flips the verdict, uniformity does not -----------

def _race_bsr(kind, b=16, m=4096, density=1 / 32):
    gen = {"power_law": masks.power_law_block_mask,
           "uniform": masks.random_block_mask}[kind]
    return BlockSparseMatrix.from_mask(gen(m, m, b, density, seed=0), b)


def test_race_picks_balanced_on_skewed_pattern():
    ctx = dispatch.DispatchContext(allow_pallas=True,
                                   differentiable=False, cache=False)
    dec = dispatch.decide(_race_bsr("power_law"), 4096, ctx=ctx)
    assert dec.route == "static_balanced"


def test_race_keeps_uniform_walk_on_uniform_pattern():
    ctx = dispatch.DispatchContext(allow_pallas=True,
                                   differentiable=False, cache=False)
    dec = dispatch.decide(_race_bsr("uniform"), 4096, ctx=ctx)
    assert dec.route == "static_pallas"
    # the balanced variant was offered and priced, just not chosen
    assert "static_balanced" in dec.est_seconds


def test_skew_factor_dead_zone_and_slope():
    # Poisson-level noise prices flat; real skew prices the uniform
    # walks up fast enough that the balanced variant wins >= 1.2x at
    # imbalance 2 (the benchmark gate's acceptance slope)
    assert dispatch._skew_factor(1.0, 0.0) == 1.0
    assert dispatch._skew_factor(1.2, 0.1) == 1.0
    assert (dispatch._skew_factor(2.0, 0.0)
            / dispatch._BALANCED_OVERHEAD) >= 1.2
    assert dispatch._skew_factor(100.0, 10.0) == 3.0    # capped


def test_skew_is_part_of_the_cache_key():
    b = 16
    skew = _race_bsr("power_law", b)
    uni = _race_bsr("uniform", b)
    ctx = dispatch.DispatchContext(allow_pallas=True,
                                   differentiable=False)
    k_s = dispatch._cache_key("static", 4096, 4096, 4096, b, 1 / 32,
                              "float32", ctx,
                              skew=dispatch.pattern_balance(skew))
    k_u = dispatch._cache_key("static", 4096, 4096, 4096, b, 1 / 32,
                              "float32", ctx,
                              skew=dispatch.pattern_balance(uni))
    assert k_s != k_u
