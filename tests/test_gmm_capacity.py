"""Planned-capacity grouped dynamic SpMM: overflow contract + statistics.

The dynamic_grouped route sizes its tile bucket the paper's way
(expected tiles x headroom, §3.3 / Appendix A.2) instead of the safe
worst case, so overflow is *possible by design* and must be (a) exact --
never silent -- and (b) statistically consistent with the planner's
analytic expectation.  Everything here is interpret-mode Pallas / pure
jnp packing on small shapes: fast tier.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sparse
from repro.core import dynamic_sparse as dsp, planner
from repro.core.bsr import BlockSparseMatrix
from repro.kernels.gmm import ops as gmm_ops

M = K = 512
N = 32


@pytest.fixture(autouse=True)
def _fresh_state():
    sparse.reset()
    sparse.configure(None)
    yield
    sparse.reset()
    sparse.configure(None)


def _operand(seed, m=M, k=K, b=16, d=1 / 32, pad=4):
    bsr = BlockSparseMatrix.random(jax.random.PRNGKey(seed), m, k, b, d,
                                   pattern_seed=seed)
    op = dsp.encode_from_bsr(bsr, nnz_max=bsr.nnz_blocks + pad)
    return bsr, op


def _distinct_tiles(bsr, tile):
    """Host-side ground truth: distinct non-empty (tile x tile) tiles."""
    rpb = tile // bsr.block_size
    kt = bsr.shape[1] // tile
    lin = (np.asarray(bsr.row_idx) // rpb) * kt + \
        (np.asarray(bsr.col_idx) // rpb)
    return np.unique(lin)


def _pack(op, tile, cap):
    packed, st = gmm_ops.pack_tiles_device(op, tile=tile, tiles_cap=cap)
    return packed, {k: np.asarray(v) for k, v in st._asdict().items()}


# -- overflow contract: exact counts, never silent ----------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("b,d", [(16, 1 / 16), (16, 1 / 32), (32, 1 / 8)])
@pytest.mark.parametrize("headroom", [0.6, 1.0, 1.5])
def test_capacity_sweep_exact_counts_and_equality(seed, b, d, headroom):
    """(density x block x headroom) sweep: reported overflow is exact
    (== host ground truth), and zero reported overflow implies exact
    equality with the dense reference."""
    bsr, op = _operand(seed, b=b, d=d)
    x = jax.random.normal(jax.random.PRNGKey(seed + 50), (K, N))
    t = gmm_ops.grouped_tile_size(M, K, b)
    true_tiles = _distinct_tiles(bsr, t)
    cp = planner.plan_grouped_capacity(M, K, b, bsr.density, tile=t,
                                       slots=op.capacity,
                                       headroom=headroom)
    y, st = gmm_ops.grouped_spmm(op, x, tile=t, tiles_cap=cp.tiles_cap,
                                 interpret=True, return_stats=True)
    st = {k: np.asarray(v) for k, v in st._asdict().items()}
    assert st["tiles_total"] == len(true_tiles)
    expect_drop = max(0, len(true_tiles) - cp.tiles_cap)
    assert st["tiles_dropped"] == expect_drop
    if expect_drop == 0:
        assert st["blocks_dropped"] == 0 and st["dropped_value_frac"] == 0
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(jnp.asarray(bsr.to_dense()) @ x),
            rtol=1e-4, atol=1e-4)
    else:
        assert st["blocks_dropped"] > 0
        assert 0.0 < st["dropped_value_frac"] <= 1.0


@pytest.mark.parametrize("b", [16, 32])
def test_capacity_one_keeps_exactly_first_tile(b):
    """Property: tiles_cap=1 keeps exactly the lowest-index tile and
    reports every other tile/block as dropped -- exact counts."""
    bsr, op = _operand(7, b=b, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(8), (K, N))
    t = gmm_ops.grouped_tile_size(M, K, b)
    true_tiles = _distinct_tiles(bsr, t)
    y, st = gmm_ops.grouped_spmm(op, x, tile=t, tiles_cap=1,
                                 interpret=True, return_stats=True)
    st = {k: np.asarray(v) for k, v in st._asdict().items()}
    assert st["tiles_total"] == len(true_tiles)
    assert st["tiles_dropped"] == len(true_tiles) - 1
    # the kept tile is the first in linearized order; reference = dense
    # product of only that tile's blocks
    rpb = t // b
    kt = K // t
    lin = (np.asarray(bsr.row_idx) // rpb) * kt + \
        (np.asarray(bsr.col_idx) // rpb)
    keep = lin == true_tiles[0]
    assert st["blocks_dropped"] == int((~keep).sum())
    kept = BlockSparseMatrix(
        np.asarray(bsr.values)[keep],
        np.asarray(bsr.row_idx)[keep].astype(np.int32),
        np.asarray(bsr.col_idx)[keep].astype(np.int32), bsr.shape, b)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.asarray(kept.to_dense()) @ x),
        rtol=1e-4, atol=1e-4)


def test_capacity_at_least_worst_case_never_drops():
    bsr, op = _operand(3, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(4), (K, N))
    t = gmm_ops.grouped_tile_size(M, K, 16)
    mt_kt = (M // t) * (K // t)
    y, st = gmm_ops.grouped_spmm(op, x, tile=t, tiles_cap=mt_kt,
                                 interpret=True, return_stats=True)
    st = {k: np.asarray(v) for k, v in st._asdict().items()}
    assert st["tiles_dropped"] == 0 and st["blocks_dropped"] == 0
    assert st["dropped_value_frac"] == 0.0
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.asarray(bsr.to_dense()) @ x),
        rtol=1e-4, atol=1e-4)


def test_empty_operand_zero_output_zero_stats():
    op = dsp.DynamicOperand(jnp.zeros((0, 16, 16)),
                            jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), jnp.int32),
                            jnp.asarray(0, jnp.int32), (128, 128), 16)
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 8))
    y, st = gmm_ops.grouped_spmm(op, x, interpret=True, return_stats=True)
    st = {k: np.asarray(v) for k, v in st._asdict().items()}
    np.testing.assert_allclose(np.asarray(y), 0.0, atol=0.0)
    assert all(st[k] == 0 for k in st)


def test_all_dense_operand_planned_cap_is_worst_and_exact():
    """d_max = 1: the planner's expected tiles == the full grid, so the
    planned capacity degenerates to the worst case and nothing drops."""
    m = k = 256
    b = 16
    bsr, op = _operand(5, m=m, k=k, b=b, d=1.0, pad=0)
    x = jax.random.normal(jax.random.PRNGKey(6), (k, 16))
    t = gmm_ops.grouped_tile_size(m, k, b)
    cp = planner.plan_grouped_capacity(m, k, b, 1.0, tile=t,
                                       slots=op.capacity)
    assert cp.tiles_cap == cp.worst_tiles == (m // t) * (k // t)
    assert cp.overflow_p == 0.0
    y, st = gmm_ops.grouped_spmm(op, x, tile=t, tiles_cap=cp.tiles_cap,
                                 interpret=True, return_stats=True)
    st = {kk: np.asarray(v) for kk, v in st._asdict().items()}
    assert st["tiles_dropped"] == 0
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.asarray(bsr.to_dense()) @ x),
        rtol=1e-4, atol=1e-4)


# -- statistics: observed overflow vs the planner's analytic expectation ------

N_SEEDS = 40
STAT_B, STAT_D = 16, 1 / 32


def _overflow_trials(headroom):
    """Pack N_SEEDS random patterns at the planned capacity; return
    (cap, analytic plan, per-seed (tiles_total, tiles_dropped))."""
    t = gmm_ops.grouped_tile_size(M, K, STAT_B)
    slots = planner.nnz_max_blocks(M, K, STAT_B, STAT_D)
    cp = planner.plan_grouped_capacity(M, K, STAT_B, STAT_D, tile=t,
                                       slots=slots, headroom=headroom)
    out = []
    for seed in range(N_SEEDS):
        bsr, op = _operand(seed, b=STAT_B, d=STAT_D, pad=2)
        _, st = _pack(op, t, cp.tiles_cap)
        true_tiles = _distinct_tiles(bsr, t)
        assert st["tiles_total"] == len(true_tiles)        # exact, always
        assert st["tiles_dropped"] == max(
            0, len(true_tiles) - cp.tiles_cap)
        out.append((int(st["tiles_total"]), int(st["tiles_dropped"])))
    return cp, out


def test_observed_tile_count_matches_analytic_expectation():
    """Mean observed distinct-tile count over seeds tracks the planner's
    E[tiles] (the quantity the whole capacity plan is sized from)."""
    cp, trials = _overflow_trials(headroom=1.0)
    mean_tiles = np.mean([t for t, _ in trials])
    assert abs(mean_tiles - cp.expected_tiles) / cp.expected_tiles < 0.15


@pytest.mark.parametrize("headroom,band", [
    (1.25, (0.0, 0.25)),     # cap == grid here: overflow impossible
    (0.8, (0.6, 1.0)),       # analytic P[overflow] ~ 0.9: nearly always
])
def test_overflow_frequency_consistent_with_planner(headroom, band):
    cp, trials = _overflow_trials(headroom=headroom)
    freq = np.mean([1.0 if d > 0 else 0.0 for _, d in trials])
    lo, hi = band
    assert lo <= freq <= hi, (
        f"observed overflow frequency {freq} outside [{lo}, {hi}] "
        f"(analytic P[overflow]={cp.overflow_p}, cap={cp.tiles_cap}, "
        f"E[tiles]={cp.expected_tiles})")
    # the analytic probability must sit on the same side of 0.5 as the
    # observed frequency (the planner's model is a usable risk signal)
    if cp.overflow_p < 0.05:
        assert freq <= 0.25
    if cp.overflow_p > 0.95:
        assert freq >= 0.75


def test_overflow_probability_monotone_in_headroom():
    t = gmm_ops.grouped_tile_size(M, K, STAT_B)
    ps = [planner.plan_grouped_capacity(M, K, STAT_B, STAT_D, tile=t,
                                        headroom=h).overflow_p
          for h in (0.6, 0.8, 1.0, 1.25, 1.5)]
    assert all(a >= b for a, b in zip(ps, ps[1:]))


# -- plan layer: telemetry, guardrail, clamp signalling -----------------------

def test_plan_records_exact_overflow_and_engine_report_matches():
    """The per-plan running stats (and the engine-facing aggregate
    ``sparse.capacity_report``) carry the same exact counts the kernel
    reports."""
    bsr, op = _operand(11, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(12), (K, N))
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             headroom=0.5, overflow_threshold=0.0)
    p = sparse.plan(op, N, ctx=ctx)
    t = p.artifacts["grouped_tile"]
    cap = p.artifacts["grouped_tiles_cap"]
    true_tiles = _distinct_tiles(bsr, t)
    per_call_drop = max(0, len(true_tiles) - cap)
    assert per_call_drop > 0                  # headroom 0.5 must overflow
    for _ in range(3):
        p(op, x)
    s = p.capacity_stats.report()
    assert s["calls"] == 3
    assert s["overflow_calls"] == 3
    assert s["last_tiles_total"] == len(true_tiles)
    assert s["last_tiles_dropped"] == per_call_drop
    assert s["tiles_dropped_total"] == 3 * per_call_drop
    # the serving engine aggregates exactly this (plan_report "capacity")
    agg = sparse.capacity_report()
    assert agg["per_plan"][p.key] == s
    assert agg["totals"]["tiles_dropped_total"] == 3 * per_call_drop


def test_guardrail_escalates_to_worst_case_replan():
    bsr, op = _operand(13, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(14), (K, N))
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             headroom=0.5, overflow_threshold=0.25)
    p1 = sparse.plan(op, N, ctx=ctx)
    assert p1.artifacts["capacity"]["policy"] == "planned"
    # one overflow is not a frequency estimate: the guardrail needs at
    # least ESCALATION_MIN_CALLS observations before it may trip
    for i in range(sparse.ESCALATION_MIN_CALLS):
        p1(op, x)
        assert p1.capacity_stats.escalated == (
            i + 1 >= sparse.ESCALATION_MIN_CALLS)
    p2 = sparse.plan(op, N, ctx=ctx)          # re-plan: worst case now
    assert p2 is not p1
    assert p2.artifacts["capacity"]["policy"] == "worst"
    assert (p2.artifacts["grouped_tiles_cap"]
            == p2.artifacts["capacity"]["worst_tiles"])
    np.testing.assert_allclose(
        np.asarray(p2(op, x)),
        np.asarray(jnp.asarray(bsr.to_dense()) @ x), rtol=1e-4, atol=1e-4)
    s = p2.capacity_stats.report()            # same stats stream
    assert s["escalated"]
    assert s["calls"] == sparse.ESCALATION_MIN_CALLS + 1


def test_escalation_persists_across_restart(tmp_path):
    """An escalated (policy='worst') verdict is part of the persisted
    plan: a restarted process allocates the worst-case bucket, not the
    overflowing planned one."""
    bsr, op = _operand(29, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(30), (K, N))
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             headroom=0.5, overflow_threshold=0.25,
                             cache_dir=str(tmp_path))
    p1 = sparse.plan(op, N, ctx=ctx)
    for _ in range(sparse.ESCALATION_MIN_CALLS):
        p1(op, x)                             # overflow -> escalate
    assert p1.capacity_stats.escalated
    p2 = sparse.plan(op, N, ctx=ctx)          # re-plan + persist "worst"
    assert p2.artifacts["capacity"]["policy"] == "worst"
    sparse.reset()                            # fresh-process simulation
    p3 = sparse.plan(op, N, ctx=ctx)
    assert p3.from_disk
    assert p3.artifacts["capacity"]["policy"] == "worst"
    np.testing.assert_allclose(
        np.asarray(p3(op, x)),
        np.asarray(jnp.asarray(bsr.to_dense()) @ x), rtol=1e-4, atol=1e-4)


def test_escalation_trip_persists_without_replan(tmp_path):
    """The serving scenario: the engine holds its plan and never calls
    plan() again -- the guardrail trip itself must write the escalated
    verdict to disk."""
    import json
    import os
    bsr, op = _operand(33, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(34), (K, N))
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             headroom=0.5, overflow_threshold=0.25,
                             cache_dir=str(tmp_path))
    p1 = sparse.plan(op, N, ctx=ctx)
    for _ in range(sparse.ESCALATION_MIN_CALLS):
        p1(op, x)                             # trips the guardrail
    assert p1.capacity_stats.escalated
    path = os.path.join(str(tmp_path),
                        f"sparse-plans-v{sparse.SCHEMA_VERSION}.json")
    rec = json.load(open(path))["entries"][p1.key]
    assert rec["capacity"]["policy"] == "worst"
    assert rec["capacity"]["tiles_cap"] == rec["capacity"]["worst_tiles"]
    sparse.reset()                            # restart without re-plan
    p2 = sparse.plan(op, N, ctx=ctx)
    assert p2.from_disk
    assert p2.artifacts["capacity"]["policy"] == "worst"


def test_overflow_threshold_and_telemetry_in_plan_identity():
    """Turning the guardrail or telemetry off must not be satisfied by
    a cached plan built with them on -- but these runtime-only knobs
    must NOT split the persistent (disk) key, or restarts would
    re-measure whenever an operator toggles them."""
    _, op = _operand(31, d=1 / 16)
    base = sparse.PlanContext(mode="dynamic_grouped", interpret=True)
    p1 = sparse.plan(op, N, ctx=base)
    p2 = sparse.plan(op, N, ctx=dataclasses.replace(
        base, overflow_threshold=0.0))
    p3 = sparse.plan(op, N, ctx=dataclasses.replace(
        base, telemetry=False))
    assert p1 is not p2 and p1 is not p3      # distinct in-memory plans
    assert p1.key == p2.key == p3.key         # shared disk identity


def test_capacity_policy_worst_never_overflows():
    bsr, op = _operand(15, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(16), (K, N))
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             capacity_policy="worst")
    p = sparse.plan(op, N, ctx=ctx)
    np.testing.assert_allclose(
        np.asarray(p(op, x)),
        np.asarray(jnp.asarray(bsr.to_dense()) @ x), rtol=1e-4, atol=1e-4)
    assert p.capacity_stats.report()["overflow_calls"] == 0


def test_telemetry_works_under_jit():
    _, op = _operand(17, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(18), (K, N))
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             headroom=0.5, overflow_threshold=0.0)
    p = sparse.plan(op, N, ctx=ctx)
    f = jax.jit(lambda o, xx: p(o, xx))
    f(op, x).block_until_ready()
    f(op, x).block_until_ready()
    assert p.capacity_stats.calls == 2
    assert p.capacity_stats.tiles_dropped_total > 0


def test_telemetry_off_records_nothing():
    _, op = _operand(19, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(20), (K, N))
    ctx = sparse.PlanContext(mode="dynamic_grouped", interpret=True,
                             headroom=0.5, telemetry=False,
                             overflow_threshold=0.0)
    p = sparse.plan(op, N, ctx=ctx)
    p(op, x)
    assert p.capacity_stats.calls == 0


def test_headroom_is_part_of_plan_identity():
    _, op = _operand(21, d=1 / 16)
    p1 = sparse.plan(op, N, ctx=sparse.PlanContext(
        mode="dynamic_grouped", interpret=True, headroom=1.25))
    p2 = sparse.plan(op, N, ctx=sparse.PlanContext(
        mode="dynamic_grouped", interpret=True, headroom=2.0))
    assert p1 is not p2 and p1.key != p2.key


def test_clamp_is_warned_once_and_signalled():
    """Satellite fix: a reduced tiles_cap is never applied silently."""
    _, op = _operand(23, d=1 / 16)
    x = jax.random.normal(jax.random.PRNGKey(24), (K, N))
    t = gmm_ops.grouped_tile_size(M, K, 16)
    grid = (M // t) * (K // t)
    gmm_ops._clamp_warned.clear()
    with pytest.warns(UserWarning, match="clamped"):
        y = gmm_ops.grouped_spmm(op, x, tile=t, tiles_cap=grid + 123,
                                 interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    with warnings.catch_warnings():           # second time: warn-once
        warnings.simplefilter("error")
        gmm_ops.grouped_spmm(op, x, tile=t, tiles_cap=grid + 123,
                             interpret=True)
    eff, clamped = gmm_ops.clamped_tiles_cap(grid + 7, M, K, t,
                                             warn=False)
    assert eff == grid and clamped
    # the plan report always carries the clamp signal
    p = sparse.plan(op, N, ctx=sparse.PlanContext(mode="dynamic_grouped",
                                                  interpret=True))
    assert p.artifacts["capacity"]["clamped"] is False
    assert "clamped" in p.capacity_stats.report()


def test_dispatch_prices_planned_capacity_and_wins_low_density():
    """The tentpole payoff: with the cost model pricing the planned
    bucket (not the worst case), dynamic_grouped takes the dispatch
    race in the paper's low-density dynamic regime."""
    ctx = sparse.PlanContext(allow_pallas=True, differentiable=False)
    spec = sparse.OpSpec(kind="dynamic", m=4096, k=4096, n=256,
                         block_size=16, density=1 / 64,
                         dtype="bfloat16")
    rep = sparse.plan(spec, ctx=ctx).explain()
    assert rep["chosen"] == "dynamic_grouped"
    est = rep["candidates"]
    assert est["dynamic_grouped"] < est["dense_xla"]
    assert est["dynamic_grouped"] < est["dynamic_pallas"]
    # the planned bucket is what made it cheap: its capacity section is
    # in the plan artifacts with a sub-worst-case tiles_cap
    cap = rep["capacity"]
    assert cap["tiles_cap"] < cap["worst_tiles"]


def test_moe_dropped_frac_joins_capacity_telemetry():
    """MoE routing drops surface through the same aggregate the engine
    reports (eager calls record; traced calls no-op)."""
    sparse.record_dropped("moe_dispatch", jnp.asarray(0.125))
    rep = sparse.capacity_report()
    assert rep["per_plan"]["moe_dispatch"]["overflow_calls"] == 1
    assert rep["per_plan"]["moe_dispatch"]["max_dropped_frac"] == 0.125
