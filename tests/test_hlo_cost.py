"""Loop-aware HLO cost analyzer: validated against XLA's own
cost_analysis on loop-free programs and against analytic counts on
scanned programs (where XLA's visitor counts bodies once)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze_hlo_text, parse_hlo


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def _xla_cost(compiled) -> dict:
    """compiled.cost_analysis() returns a dict in newer jax, a
    one-element list of dicts in older releases."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matches_xla_on_loop_free():
    def f(a, b):
        return jnp.tanh(a @ b)
    c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 64), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    xla = _xla_cost(c)
    np.testing.assert_allclose(mine["flops"], xla["flops"], rtol=0.05)


def test_scan_trip_count_multiplied():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]
    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((12, 64, 64), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    expected = 12 * 2 * 64 ** 3
    assert abs(mine["flops"] - expected) / expected < 0.05
    assert not mine["warnings"]
    # XLA's own visitor counts the body once -- the reason this module
    # exists; if XLA ever fixes it, this assert flags the redundancy.
    assert _xla_cost(c)["flops"] < expected / 2


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]
    c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    expected = 15 * 2 * 32 ** 3
    assert abs(mine["flops"] - expected) / expected < 0.1


def test_dot_general_contracting_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    c = _compile(f, jax.ShapeDtypeStruct((4, 32, 48), jnp.float32),
                 jax.ShapeDtypeStruct((4, 48, 16), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    expected = 2 * 4 * 32 * 48 * 16
    assert abs(mine["flops"] - expected) / expected < 0.05


def test_parse_hlo_computations():
    def f(x):
        return jax.lax.scan(lambda c, _: (c * 2, None), x, None,
                            length=4)[0]
    c = _compile(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_hlo(c.as_text())
    assert len(comps) >= 2       # entry + loop body/cond at least
    entry = [k for k in comps if "main" in k]
    assert entry


def test_bytes_reasonable_for_elementwise():
    def f(a):
        return a * 2.0 + 1.0
    c = _compile(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    # one read + one write of 4MB, allow fusion-accounting slack
    assert 6e6 < mine["bytes"] < 2e7


# ---------------------------------------------------------------------------
# Edge cases on synthetic HLO text -- these feed the roofline numbers,
# so each accounting rule gets a direct, exactly-assertable fixture
# (compiled programs exercise them only incidentally)
# ---------------------------------------------------------------------------

_WHILE_KNOWN_TRIP = """
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64] parameter(0)
  %w = f32[64] while(%p0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %r = f32[64] add(%w, %w)
}

%body (bp: f32[64]) -> f32[64] {
  %bp = f32[64] parameter(0)
  ROOT %ba = f32[64] add(%bp, %bp)
}

%cond (cp: f32[64]) -> pred[] {
  %cp = f32[64] parameter(0)
  ROOT %cc = pred[] constant(false)
}
"""


def test_while_known_trip_count_from_backend_config():
    # XLA's own analysis (backend_config known_trip_count) outranks the
    # condition-computation heuristic: 7 body trips x 64 adds + the
    # root add, exactly
    mine = analyze_hlo_text(_WHILE_KNOWN_TRIP)
    assert mine["flops"] == 7 * 64 + 64
    assert not mine["warnings"]


_WHILE_COND_TRIP = """
ENTRY %main (p: (s32[], f32[32,32])) -> f32[32,32] {
  %p = (s32[], f32[32,32]) parameter(0)
  %w = (s32[], f32[32,32]) while(%p), condition=%cond2, body=%body2
  ROOT %out = f32[32,32] get-tuple-element(%w), index=1
}

%body2 (bp: (s32[], f32[32,32])) -> (s32[], f32[32,32]) {
  %bp = (s32[], f32[32,32]) parameter(0)
  %i = s32[] get-tuple-element(%bp), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  %x = f32[32,32] get-tuple-element(%bp), index=1
  %y = f32[32,32] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[32,32]) tuple(%ip, %y)
}

%cond2 (cp: (s32[], f32[32,32])) -> pred[] {
  %cp = (s32[], f32[32,32]) parameter(0)
  %i2 = s32[] get-tuple-element(%cp), index=0
  %k = s32[] constant(9)
  ROOT %lt = pred[] compare(%i2, %k), direction=LT
}
"""


def test_while_trip_count_from_condition_constant():
    # no backend_config: the i < 9 condition (constant compared with
    # direction=LT) recovers trip 9.  Per trip: one 32x32x32 dot, the
    # counter add, the condition compare.
    mine = analyze_hlo_text(_WHILE_COND_TRIP)
    assert mine["flops"] == 9 * (2 * 32 ** 3 + 1 + 1)
    assert not mine["warnings"]


_WHILE_UNKNOWN_TRIP = """
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64] parameter(0)
  ROOT %w = f32[64] while(%p), condition=%cond3, body=%body3
}

%body3 (bp: f32[64]) -> f32[64] {
  %bp = f32[64] parameter(0)
  ROOT %ba = f32[64] add(%bp, %bp)
}

%cond3 (cp: f32[64]) -> pred[] {
  %cp = f32[64] parameter(0)
  %s = f32[] constant(0)
  ROOT %gt = pred[] compare(%s, %s), direction=GT
}
"""


def test_while_unknown_trip_warns_and_counts_once():
    # data-dependent bound (no LT-vs-constant shape): counted exactly
    # once, and the under-count is surfaced in warnings -- never silent
    mine = analyze_hlo_text(_WHILE_UNKNOWN_TRIP)
    assert mine["flops"] == 64 + 1       # one body trip + one compare
    assert any("trip count unknown" in w for w in mine["warnings"])


_FUSION_SLICED_OPERAND = """
ENTRY %main (big: f32[1024,64], idx: s32[]) -> f32[16] {
  %big = f32[1024,64] parameter(0)
  %idx = s32[] parameter(1)
  %f = f32[16] fusion(%big, %idx), kind=kLoop, calls=%fused
  ROOT %r = f32[16] add(%f, %f)
}

%fused (fp0: f32[1024,64], fp1: s32[]) -> f32[16] {
  %fp0 = f32[1024,64] parameter(0)
  %fp1 = s32[] parameter(1)
  %ds = f32[1,16] dynamic-slice(%fp0, %fp1, %fp1), dynamic_slice_sizes={1,16}
  ROOT %rs = f32[16] reshape(%ds)
}
"""


def test_fusion_prices_sliced_operand_at_slice_size():
    # the 256KB table is consumed only by a dynamic-slice inside the
    # fusion: XLA reads 64 bytes, and so must the model -- pricing the
    # full buffer would claim a 3-orders-of-magnitude memory bound
    mine = analyze_hlo_text(_FUSION_SLICED_OPERAND)
    assert mine["bytes"] < 1e3
    full_table = 1024 * 64 * 4
    assert mine["bytes"] < full_table / 100


_FUSION_INTERNALS = """
ENTRY %main (a: f32[256,256]) -> f32[256,256] {
  %a = f32[256,256] parameter(0)
  ROOT %f = f32[256,256] fusion(%a), kind=kLoop, calls=%chain
}

%chain (cp: f32[256,256]) -> f32[256,256] {
  %cp = f32[256,256] parameter(0)
  %m = f32[256,256] multiply(%cp, %cp)
  %s = f32[256,256] add(%m, %cp)
  ROOT %t = f32[256,256] tanh(%s)
}
"""


def test_fusion_internal_operands_not_double_counted():
    # bytes touch HBM only at the fusion boundary (operand + result);
    # the three internal elementwise stages live in VMEM.  FLOPs still
    # count every internal op.
    n = 256 * 256
    mine = analyze_hlo_text(_FUSION_INTERNALS)
    assert mine["bytes"] == 2 * n * 4          # one read + one write
    assert mine["flops"] == 3 * n


def test_half_precision_byte_accounting():
    def hlo(dt):
        return (f"ENTRY %main (p: {dt}[1024]) -> {dt}[1024] {{\n"
                f"  %p = {dt}[1024] parameter(0)\n"
                f"  ROOT %a = {dt}[1024] add(%p, %p)\n"
                f"}}\n")
    by = {dt: analyze_hlo_text(hlo(dt))["bytes"]
          for dt in ("f32", "bf16", "f16")}
    assert by["f32"] == 3 * 1024 * 4           # two reads + one write
    assert by["bf16"] == by["f16"] == 3 * 1024 * 2


def test_collectives_counted_under_spmd():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(a):
        return a.sum()
    sh = NamedSharding(mesh, P("x"))
    c = jax.jit(f, in_shardings=sh).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    mine = analyze_hlo_text(c.as_text())
    assert "collective_bytes" in mine   # presence; 1-device may elide
