"""Loop-aware HLO cost analyzer: validated against XLA's own
cost_analysis on loop-free programs and against analytic counts on
scanned programs (where XLA's visitor counts bodies once)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze_hlo_text, parse_hlo


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def _xla_cost(compiled) -> dict:
    """compiled.cost_analysis() returns a dict in newer jax, a
    one-element list of dicts in older releases."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matches_xla_on_loop_free():
    def f(a, b):
        return jnp.tanh(a @ b)
    c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 64), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    xla = _xla_cost(c)
    np.testing.assert_allclose(mine["flops"], xla["flops"], rtol=0.05)


def test_scan_trip_count_multiplied():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]
    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((12, 64, 64), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    expected = 12 * 2 * 64 ** 3
    assert abs(mine["flops"] - expected) / expected < 0.05
    assert not mine["warnings"]
    # XLA's own visitor counts the body once -- the reason this module
    # exists; if XLA ever fixes it, this assert flags the redundancy.
    assert _xla_cost(c)["flops"] < expected / 2


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]
    c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    expected = 15 * 2 * 32 ** 3
    assert abs(mine["flops"] - expected) / expected < 0.1


def test_dot_general_contracting_dims():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    c = _compile(f, jax.ShapeDtypeStruct((4, 32, 48), jnp.float32),
                 jax.ShapeDtypeStruct((4, 48, 16), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    expected = 2 * 4 * 32 * 48 * 16
    assert abs(mine["flops"] - expected) / expected < 0.05


def test_parse_hlo_computations():
    def f(x):
        return jax.lax.scan(lambda c, _: (c * 2, None), x, None,
                            length=4)[0]
    c = _compile(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = parse_hlo(c.as_text())
    assert len(comps) >= 2       # entry + loop body/cond at least
    entry = [k for k in comps if "main" in k]
    assert entry


def test_bytes_reasonable_for_elementwise():
    def f(a):
        return a * 2.0 + 1.0
    c = _compile(f, jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    # one read + one write of 4MB, allow fusion-accounting slack
    assert 6e6 < mine["bytes"] < 2e7


def test_collectives_counted_under_spmd():
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(a):
        return a.sum()
    sh = NamedSharding(mesh, P("x"))
    c = jax.jit(f, in_shardings=sh).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile()
    mine = analyze_hlo_text(c.as_text())
    assert "collective_bytes" in mine   # presence; 1-device may elide
