"""Benchmark suite: one experiment per paper table/figure.

Each function returns a list of record dicts and is invoked by
``benchmarks.run``.  Patterns come from ``core.masks`` (random scattered
vs clustered -- the TPU-specific occupancy axis, DESIGN.md §2); static
tiles come from the real partitioner, so the cost model sees exactly
what the kernel would execute.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks import cost_model as cm
from repro.core import dispatch, masks
from repro.core.bsr import BlockSparseMatrix
from repro.core.partitioner import pack_tiles

BATCHES = [64, 256, 1024, 4096, 16384]


def _bsr(m, k, b, d, *, clustered=False, seed=0):
    mask = masks.random_block_mask(m, k, b, d, seed=seed,
                                   clustered=clustered)
    return BlockSparseMatrix.from_mask(mask, b, init="zeros")


def _static_time(m, k, n, b, d, *, clustered, fp32=False):
    bsr = _bsr(m, k, b, d, clustered=clustered)
    packing = pack_tiles(bsr, 128, 128)
    t = cm.bsmm_time(packing, n, dtype_bytes=cm.B32 if fp32 else cm.B16)
    return cm.fp32_time(t) if fp32 else t


def _dyn_time(m, k, n, b, d, *, fp32=False):
    t = cm.dsmm_time(m, k, n, block_size=b, d_max=d,
                     dtype_bytes=cm.B32 if fp32 else cm.B16)
    return cm.fp32_time(t) if fp32 else t


def _dense_time(m, k, n, *, fp32=False):
    t = cm.dense_time(m, k, n, dtype_bytes=cm.B32 if fp32 else cm.B16)
    return cm.fp32_time(t) if fp32 else t


def best_over_n(fn):
    """Paper methodology: best throughput over batch size n."""
    best = None
    for n in BATCHES:
        t = fn(n)
        if best is None or t.tflops > best[1].tflops:
            best = (n, t)
    return best


# -- Fig 2: dense baseline ---------------------------------------------------------

def fig2_dense_baseline():
    recs = []
    for fp32 in (False, True):
        for m in (1024, 2048, 4096, 8192):
            for n in BATCHES:
                t = _dense_time(m, m, n, fp32=fp32)
                recs.append(dict(fig="fig2", dtype="fp32" if fp32
                                 else "fp16", m=m, n=n,
                                 tflops=round(t.tflops, 2)))
    return recs


# -- Table 3: static vs dynamic vs dense, m=k=4096, d=1/16 ----------------------------

def table3_static_vs_dynamic():
    """Speedup = t_dense / t_sparse for the same logical matmul at the
    same n (the paper's 'throughput values compared with dense' -- a
    ratio > 1 means the sparse implementation finishes the operation
    faster than computing it densely)."""
    recs = []
    m = 4096
    d = 1 / 16
    for b in (1, 4, 16):
        for fp32 in (False, True):
            n_d, t_dense = best_over_n(lambda n: _dense_time(m, m, n,
                                                             fp32=fp32))
            for mode, pattern in (("static-clustered", True),
                                  ("static-scattered", False)):
                t_s = _static_time(m, m, n_d, b, d, clustered=pattern,
                                   fp32=fp32)
                recs.append(dict(
                    fig="table3", block_size=b,
                    dtype="fp32" if fp32 else "fp16", mode=mode,
                    speedup_vs_dense=round(t_dense.seconds / t_s.seconds,
                                           2)))
            t_y = _dyn_time(m, m, n_d, b, d, fp32=fp32)
            recs.append(dict(
                fig="table3", block_size=b,
                dtype="fp32" if fp32 else "fp16", mode="dynamic",
                speedup_vs_dense=round(t_dense.seconds / t_y.seconds, 2)))
            # beyond-paper TPU-native dynamic: device-side tile packing
            bsr = _bsr(m, m, b, d, clustered=True)
            packing = pack_tiles(bsr, 128, 128)
            t_g = cm.dsmm_grouped_time(
                packing, n_d, dtype_bytes=cm.B32 if fp32 else cm.B16)
            t_g = cm.fp32_time(t_g) if fp32 else t_g
            recs.append(dict(
                fig="table3", block_size=b,
                dtype="fp32" if fp32 else "fp16", mode="dynamic-grouped",
                speedup_vs_dense=round(t_dense.seconds / t_g.seconds, 2)))
    return recs


# -- Fig 3a: density sweep ------------------------------------------------------------

def fig3a_density_sweep():
    recs = []
    m = 4096
    for d in (1.0, 1 / 4, 1 / 8, 1 / 16, 1 / 32, 1 / 64):
        _, t_dense = best_over_n(lambda n: _dense_time(m, m, n))
        recs.append(dict(fig="fig3a", density=d, mode="dense",
                         tflops=round(t_dense.tflops * d, 2)))  # useful
        for b in (1, 16):
            if d < 1.0:
                _, t_s = best_over_n(
                    lambda n: _static_time(m, m, n, b, d, clustered=True))
                recs.append(dict(fig="fig3a", density=d, b=b,
                                 mode="static", tflops=round(t_s.tflops, 2)))
                _, t_y = best_over_n(lambda n: _dyn_time(m, m, n, b, d))
                recs.append(dict(fig="fig3a", density=d, b=b,
                                 mode="dynamic",
                                 tflops=round(t_y.tflops, 2)))
    return recs


# -- Fig 4a/4b: block-size and feature-size sweeps ------------------------------------

def fig4a_block_size():
    """Block-size effect, adapted to the MXU (DESIGN.md §2): for the
    *dynamic* kernel larger b directly raises slot MXU utilisation
    (paper's on-IPU effect); for *static* the 128-tile packing makes
    clustered patterns b-independent (stronger than the paper -- packing
    hides b), while scattered patterns at low density recover the
    b-dependence through tile occupancy."""
    recs = []
    m, d = 4096, 1 / 16
    d_low = 1 / 64
    for b in (1, 4, 8, 16):
        _, t = best_over_n(lambda n: _static_time(m, m, n, b, d,
                                                  clustered=True))
        recs.append(dict(fig="fig4a", b=b, mode="static-clustered",
                         tflops=round(t.tflops, 2)))
        _, t = best_over_n(lambda n: _static_time(m, m, n, b, d_low,
                                                  clustered=False))
        recs.append(dict(fig="fig4a", b=b, mode="static-scattered-lowd",
                         tflops=round(t.tflops, 2)))
        _, t = best_over_n(lambda n: _dyn_time(m, m, n, b, d))
        recs.append(dict(fig="fig4a", b=b, mode="dynamic",
                         tflops=round(t.tflops, 2)))
    return recs


def fig4b_feature_size():
    recs = []
    d, b = 1 / 16, 16
    for m in (512, 1024, 2048, 4096, 8192):
        n_d, t_dense = best_over_n(lambda n: _dense_time(m, m, n))
        t_s = _static_time(m, m, n_d, b, d, clustered=True)
        recs.append(dict(fig="fig4b", m=m,
                         static_tflops=round(t_s.tflops, 2),
                         dense_tflops=round(t_dense.tflops, 2),
                         speedup=round(t_dense.seconds / t_s.seconds, 2)))
    return recs


# -- Fig 4c: power-law fit --------------------------------------------------------------

def fig4c_power_law():
    """Fit speedup ~ a * m^alpha * d^beta * b^gamma on the model's grid
    (paper: 0.0013 * m^0.59 * d^-0.54 * b^0.50 on IPU measurements)."""
    rows = []
    for m in (1024, 2048, 4096, 8192):
        for d in (1 / 4, 1 / 8, 1 / 16, 1 / 32):
            for b in (4, 8, 16):
                n_d, t_dense = best_over_n(lambda n: _dense_time(m, m, n))
                t_s = _static_time(m, m, n_d, b, d, clustered=True)
                rows.append((m, d, b, t_dense.seconds / t_s.seconds))
    X = np.array([[1.0, np.log(m), np.log(d), np.log(b)]
                  for m, d, b, _ in rows])
    y = np.log([r[3] for r in rows])
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    a, alpha, beta, gamma = np.exp(coef[0]), coef[1], coef[2], coef[3]
    resid = float(np.sqrt(np.mean((X @ coef - y) ** 2)))
    return [dict(fig="fig4c", a=round(float(a), 5),
                 m_exp=round(float(alpha), 3), d_exp=round(float(beta), 3),
                 b_exp=round(float(gamma), 3), rmse_log=round(resid, 3),
                 paper=dict(a=0.0013, m_exp=0.59, d_exp=-0.54,
                            b_exp=0.50))]


# -- Fig 7: speedup grid -----------------------------------------------------------------

def fig7_speedup_grid():
    recs = []
    for m in (1024, 4096):
        for b in (4, 16):
            for d in (1 / 4, 1 / 16, 1 / 32):
                for n in (256, 4096):
                    t_dense = _dense_time(m, m, n)
                    t_s = _static_time(m, m, n, b, d, clustered=True)
                    recs.append(dict(fig="fig7", m=m, b=b, density=d, n=n,
                                     speedup=round(t_dense.seconds /
                                                   t_s.seconds, 2)))
    return recs


# -- dispatch: the Table-3 crossovers as runtime decisions --------------------------------

def dispatch_decisions(tiny: bool = False):
    """Ask the plan-first API what it would *run* across the Table 3 /
    Fig 3a grid and record the chosen route + per-candidate estimates.
    This is the executable form of the paper's static/dynamic/dense
    crossover table.  ``tiny=True`` is the CI benchmark-smoke grid
    (seconds, not minutes) that seeds BENCH_dispatch.json.
    """
    from repro import sparse
    recs = []
    ctx = sparse.PlanContext(allow_pallas=True, differentiable=False)
    key = jax.random.PRNGKey(0)
    ms = (1024,) if tiny else (1024, 4096)
    ds = (1 / 4, 1 / 16) if tiny else (1 / 4, 1 / 16, 1 / 32)
    ns = (256,) if tiny else (256, 4096)
    for m in ms:
        for b in (4, 16):
            for d in ds:
                bsr = BlockSparseMatrix.random(key, m, m, b, d)
                for n in ns:
                    # static pattern AND its dynamic encoding: both sides
                    # of the paper's static-vs-dynamic crossover
                    rep = sparse.plan(bsr, n, ctx=ctx).explain()
                    recs.append(dict(
                        fig="dispatch", m=m, b=b, density=d, n=n,
                        kind="static", chosen=rep["chosen"],
                        source=rep["source"],
                        candidates={r: round(s * 1e6, 3) for r, s in
                                    rep["candidates"].items()}))
                    spec = sparse.OpSpec(kind="dynamic", m=m, k=m, n=n,
                                         block_size=b, density=d,
                                         dtype="float32")
                    rep = sparse.plan(spec, ctx=ctx).explain()
                    recs.append(dict(
                        fig="dispatch", m=m, b=b, density=d, n=n,
                        kind="dynamic", chosen=rep["chosen"],
                        source=rep["source"],
                        candidates={r: round(s * 1e6, 3) for r, s in
                                    rep["candidates"].items()}))
    return recs


# -- grouped capacity: planned bucket vs safe worst case ----------------------------------

def grouped_capacity(tiny: bool = False):
    """The paper's §3.3 capacity tradeoff made concrete for the
    ``dynamic_grouped`` route: size the tile bucket at the planner's
    expected-tiles x headroom (overflow possible, priced analytically)
    vs the pre-PR-3 safe worst case, and record the speedup + overflow
    risk of each point.  ``speedup > 1`` at low density is exactly why
    planned capacity lets dynamic_grouped win the dispatch race there.
    ``tiny=True`` is the CI/nightly smoke grid.
    """
    from repro.core import planner
    # capacity sizing needs the kernel's own tile rule, not a matmul
    # entry point -- sanctioned direct import
    from repro.kernels.gmm.ops import grouped_tile_size  # repro-lint: disable=R001
    recs = []
    n = 4096
    ms = (2048,) if tiny else (2048, 4096)
    heads = (1.25,) if tiny else (1.0, 1.25, 1.5)
    for m in ms:
        for b in (16, 32):
            for d in (1 / 4, 1 / 16, 1 / 32, 1 / 64, 1 / 128):
                t = grouped_tile_size(m, m, b)

                def time_at(cap):
                    pk = type("_Pk", (), dict(
                        num_tiles=cap, tm=t, tk=t,
                        _nnz_area=int(m * m * d), shape=(m, m)))
                    return cm.dsmm_grouped_time(pk, n,
                                                capacity_factor=1.0)
                for h in heads:
                    cp = planner.plan_grouped_capacity(m, m, b, d,
                                                       tile=t, headroom=h)
                    t_p = time_at(cp.tiles_cap)
                    t_w = time_at(cp.worst_tiles)
                    recs.append(dict(
                        fig="grouped_capacity", m=m, b=b, density=d,
                        headroom=h, tile=t,
                        expected_tiles=round(cp.expected_tiles, 1),
                        tiles_cap=cp.tiles_cap,
                        worst_tiles=cp.worst_tiles,
                        overflow_p=round(cp.overflow_p, 4),
                        t_planned_us=round(t_p.seconds * 1e6, 2),
                        t_worst_us=round(t_w.seconds * 1e6, 2),
                        speedup_vs_worst=round(t_w.seconds / t_p.seconds,
                                               3)))
    return recs


# -- tp_crossover: measured tensor-parallel crossover (gspmd vs shard_map vs unsharded) ---

def tp_crossover(tiny: bool = False):
    """Where does the k-sharded TP route start beating the unsharded
    one -- and which TP lowering (gspmd vs explicit shard_map + psum)
    wins?  Each record carries two answers:

    * ``est_tp_speedup`` -- the deterministic cost-model ratio at
      q=8 (best unsharded / best TP).  This is the number
      ``tools/bench_check.py`` gates on: it moves only when the model
      or the planner changes, never with runner noise.
    * measured wall-clock of the gspmd / shard_map / unsharded
      candidates when >= 2 devices are available (the multi-device CI
      step runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``),
      via the same ``sparse.plan`` measured race serving uses --
      informational: host-platform collectives bound the trend, not
      the TPU crossover.

    ``tiny=True`` is the CI smoke grid that seeds BENCH_tp.json.
    """
    import importlib

    from repro import sparse
    # NOT `from repro.sparse import plan`: the package __init__ rebinds
    # the `plan` attribute to the function, hiding the submodule
    plan_mod = importlib.import_module("repro.sparse.plan")

    q_model = 8
    q_meas = min(q_model, len(jax.devices()))
    mesh = (jax.make_mesh((q_meas,), ("model",)) if q_meas >= 2
            else None)
    recs = []
    b = 16
    ms = (512, 1024) if tiny else (512, 1024, 2048, 4096)
    ds = (1 / 4, 1 / 16) if tiny else (1 / 4, 1 / 16, 1 / 64)
    ns = (64,) if tiny else (64, 1024)
    key = jax.random.PRNGKey(0)
    for m in ms:
        for d in ds:
            for n in ns:
                spec = sparse.OpSpec(kind="static", m=m, k=m, n=n,
                                     block_size=b, density=d,
                                     dtype="float32")
                est_tp = {r: plan_mod._tp_estimate(spec, q_model, r)
                          for r in sparse.TP_ROUTES}
                est_un = {r: dispatch._estimate(r, m, m, n, b, d,
                                                "float32")
                          for r in ("static_xla", "dense_xla")}
                best_tp = min(est_tp, key=est_tp.get)
                best_un = min(est_un, key=est_un.get)
                rec = dict(
                    fig="tp_crossover", m=m, b=b, density=d, n=n,
                    q_model=q_model, est_best_tp=best_tp,
                    est_tp_us=round(est_tp[best_tp] * 1e6, 3),
                    est_unsharded_us=round(est_un[best_un] * 1e6, 3),
                    est_tp_speedup=round(est_un[best_un] /
                                         est_tp[best_tp], 4))
                if mesh is not None:
                    bsr = BlockSparseMatrix.random(key, m, m, b, d)
                    x = jax.random.normal(jax.random.PRNGKey(1),
                                           (m, n))
                    ctx = sparse.PlanContext(mesh=mesh, measure=True,
                                             cache=False)
                    p = sparse.plan(bsr, n, x=x, ctx=ctx)
                    tp = p.artifacts["tp"]
                    # only routes that were actually wall-clocked: the
                    # race leaves analytic estimates in est_seconds for
                    # candidates this host cannot run (Pallas off-TPU)
                    dctx = ctx.dispatch_ctx()
                    meas = {r: round(s * 1e6, 1)
                            for r, s in p.est_seconds.items()
                            if r in sparse.TP_ROUTES
                            or dispatch._executable(r, dctx)}
                    rec.update(
                        q_measured=q_meas, chosen=p.route,
                        source=p.source, measured_us=meas,
                        tp_speedup_measured=tp["tp_speedup_vs_unsharded"],
                        tp_wins_measured=tp["tp_wins"])
                else:
                    rec.update(q_measured=None, chosen=None,
                               source="analytic", measured_us=None,
                               tp_speedup_measured=None,
                               tp_wins_measured=None)
                recs.append(rec)
    return recs


# -- train_grad: the training step's three products as planned decisions ------------------

def train_grad(tiny: bool = False):
    """Sparse *training* as the plan layer prices it: one static spmm
    plan per grid point with the planned backward attached, recording
    the chosen forward route plus the backward verdicts (dL/dx =
    transposed-pattern SpMM, dL/dvalues = block SDDMM) and the analytic
    fwd+bwd speedup over computing the same three products densely.
    ``speedup > 1`` at low density is the training extension of the
    paper's Table 3 claim: with the pattern fixed at compile time, the
    *backward* matmuls ride the same pre-planned fast path as the
    forward.  All gated ratios are deterministic cost-model outputs.
    ``tiny=True`` is the CI smoke grid that seeds BENCH_train_grad.json.
    """
    from repro import sparse
    recs = []
    # differentiable (the default) + allow_pallas: the plan-level
    # custom_vjp makes Pallas forwards admissible for training callers
    ctx = sparse.PlanContext(allow_pallas=True)
    key = jax.random.PRNGKey(0)
    n = 256
    ms = (1024,) if tiny else (1024, 4096)
    # the fwd+bwd crossover sits below the forward-only one (three
    # products, one of them a dense-competitive SDDMM): the grid reaches
    # 1/64 (tiny) / 1/256 (full) where the backward race leaves dense
    ds = (1 / 16, 1 / 64) if tiny else (1 / 4, 1 / 16, 1 / 64, 1 / 256)
    for m in ms:
        for b in (4, 16):
            for d in ds:
                bsr = BlockSparseMatrix.random(key, m, m, b, d)
                p = sparse.plan(bsr, n, ctx=ctx)
                g = p.explain()["grad"]
                dx, dv = g["dx"], g["dvalues"]
                fwd_t = p.est_seconds[p.route]
                dx_t = dx["est_seconds"][dx["route"]]
                dv_t = dv["est_seconds"][dv["route"]]
                dense_fwd = dispatch._estimate("dense_xla", m, m, n, b,
                                               d, "float32")
                dense_dw = dispatch._estimate("sddmm_dense", m, m, n, b,
                                              d, "float32")
                # dense dL/dx is another [m, m] @ [m, n] product
                sparse_t = fwd_t + dx_t + dv_t
                dense_t = 2 * dense_fwd + dense_dw
                recs.append(dict(
                    fig="train_grad", m=m, b=b, density=d, n=n,
                    fwd_route=p.route, dx_route=dx["route"],
                    dv_route=dv["route"],
                    fwd_us=round(fwd_t * 1e6, 3),
                    dx_us=round(dx_t * 1e6, 3),
                    dv_us=round(dv_t * 1e6, 3),
                    train_speedup_vs_dense=round(dense_t / sparse_t, 3)))
    return recs


# -- pattern evolution: dynamic sparse training via MatmulPlan.evolve --------------------

def pattern_evolution(tiny: bool = False):
    """Evolving-pattern training as the plan layer executes it: each grid
    point builds a differentiable static plan, then walks a RigL-style
    constant-nnz evolve chain (move ~5% of blocks per topology update,
    the no-drift regime) and records

    * ``evolve_measurements`` -- route decisions + measurement events
      across the whole chain (the tentpole invariant: an in-threshold
      evolve re-packs and re-uses verdicts, so this must be 0);
    * ``step_speedup_vs_dense`` -- deterministic cost-model fwd+bwd
      speedup of the *evolved* plan over the dense three-product step
      (train_grad's formula; evolving sparsity must keep the static
      training win, not just the first pattern);
    * ``replan_vs_evolve`` -- measured median wall-clock of a from-
      scratch measured re-plan over a single ``evolve`` call, capped at
      2.0 so the gated ratio is deterministic (the true ratio is far
      above the cap: evolve is host re-packing, a re-plan re-races
      kernels).
    """
    import dataclasses as _dc
    import time

    from repro import sparse

    recs = []
    ctx = sparse.PlanContext(allow_pallas=True)
    key = jax.random.PRNGKey(0)
    n = 256
    evolves = 4
    ms = (1024,) if tiny else (1024, 4096)
    ds = (1 / 16, 1 / 64) if tiny else (1 / 4, 1 / 16, 1 / 64)
    for m in ms:
        for b in (4, 16):
            for d in ds:
                sparse.reset()
                bsr = BlockSparseMatrix.random(key, m, m, b, d)
                x = jax.random.normal(key, (m, n))
                p = sparse.plan(bsr, n, ctx=ctx)
                mask = bsr.block_mask()
                rng = np.random.default_rng(0)
                s0 = sparse.cache_stats()
                evolve_ts = []
                for _ in range(evolves):
                    act_r, act_c = np.nonzero(mask)
                    off_r, off_c = np.nonzero(~mask)
                    mv = max(1, int(0.05 * len(act_r)))
                    drop = rng.choice(len(act_r), mv, replace=False)
                    grow = rng.choice(len(off_r), mv, replace=False)
                    mask[act_r[drop], act_c[drop]] = False
                    mask[off_r[grow], off_c[grow]] = True
                    # host-side plan mutation cost IS the measurand
                    # (evolve runs outside jit), so wall-clock is right
                    t0 = time.perf_counter()  # repro-lint: disable=R005
                    p = p.evolve(mask)
                    evolve_ts.append(time.perf_counter() - t0)  # repro-lint: disable=R005
                s1 = sparse.cache_stats()
                evolve_events = (s1["decisions"] - s0["decisions"]
                                 + s1["measurements"] - s0["measurements"])
                # the alternative a RigL loop would otherwise pay: a
                # measured from-scratch re-plan of the evolved pattern
                ctx_m = _dc.replace(ctx, measure=True, cache=False)
                ebsr = BlockSparseMatrix.from_mask(mask, b, init="zeros")
                replan_ts = []
                for _ in range(3):
                    t0 = time.perf_counter()  # repro-lint: disable=R005
                    sparse.plan(ebsr, n, x=x, ctx=ctx_m)
                    replan_ts.append(time.perf_counter() - t0)  # repro-lint: disable=R005
                evolve_ms = float(np.median(evolve_ts) * 1e3)
                replan_ms = float(np.median(replan_ts) * 1e3)
                g = p.explain()["grad"]
                dx, dv = g["dx"], g["dvalues"]
                sparse_t = (p.est_seconds[p.route]
                            + dx["est_seconds"][dx["route"]]
                            + dv["est_seconds"][dv["route"]])
                dense_t = (2 * dispatch._estimate("dense_xla", m, m, n,
                                                  b, d, "float32")
                           + dispatch._estimate("sddmm_dense", m, m, n,
                                                b, d, "float32"))
                ev = p.explain()["evolution"]
                recs.append(dict(
                    fig="pattern_evolution", m=m, b=b, density=d, n=n,
                    route=p.route, dx_route=dx["route"],
                    dv_route=dv["route"],
                    generations=ev["generation"],
                    reraces=sparse.plan_report()
                    ["totals"]["evolution"]["reraces"],
                    evolve_measurements=evolve_events,
                    evolve_ms=round(evolve_ms, 3),
                    replan_ms=round(replan_ms, 3),
                    replan_vs_evolve=round(
                        min(2.0, replan_ms / max(evolve_ms, 1e-9)), 3),
                    step_speedup_vs_dense=round(dense_t / sparse_t, 3)))
    return recs


# -- skewed patterns: balanced-walk routes vs the uniform walk ----------------------------

def skewed_patterns(tiny: bool = False):
    """Row-skewed patterns (power-law / DLMC-style row profiles vs
    uniform random) through the plan race: the uniform walks serialize
    on hot rows, the PR 8 balanced routes (``static_balanced`` /
    ``dynamic_grouped_balanced``) equalize per-lane work via the
    row-swizzle pre-pass.  Each record carries the pattern's measured
    ``(imbalance, cv)``, the winning route, and the deterministic
    cost-model ratio of the uniform-walk route over its balanced
    variant for both families -- >1 means the swizzle wins the race.
    ``tiny=True`` is the CI smoke grid and includes the acceptance
    point (m=4096, b=16, d=1/32 <= 1/16).
    """
    from repro import sparse
    recs = []
    ctx = sparse.PlanContext(allow_pallas=True, differentiable=False)
    n = 4096
    ms = (4096,) if tiny else (1024, 4096)
    bs = (16,) if tiny else (4, 16)
    ds = (1 / 32,) if tiny else (1 / 16, 1 / 32, 1 / 64)
    gens = {"uniform": masks.random_block_mask,
            "power_law": masks.power_law_block_mask,
            "dlmc": masks.dlmc_block_mask}
    for m in ms:
        for b in bs:
            for d in ds:
                for kind, gen in gens.items():
                    mask = gen(m, m, b, d, seed=0)
                    bsr = BlockSparseMatrix.from_mask(mask, b,
                                                      init="zeros")
                    imb, cv = dispatch.pattern_balance(bsr)
                    rep = sparse.plan(bsr, n, ctx=ctx).explain()
                    cands = rep["candidates"]
                    dyn_u = dispatch._estimate(
                        "dynamic_grouped", m, m, n, b, d, "float32",
                        imbalance=imb, cv=cv)
                    dyn_b = dispatch._estimate(
                        "dynamic_grouped_balanced", m, m, n, b, d,
                        "float32", imbalance=imb, cv=cv)
                    recs.append(dict(
                        fig="skewed_patterns", mask=kind, m=m, b=b,
                        density=d, n=n, imbalance=round(imb, 3),
                        cv=round(cv, 3), chosen=rep["chosen"],
                        static_balance_ratio=round(
                            cands["static_pallas"]
                            / cands["static_balanced"], 3),
                        dynamic_balance_ratio=round(dyn_u / dyn_b, 3),
                        candidates={r: round(s * 1e6, 3)
                                    for r, s in cands.items()}))
    return recs


# -- serving: sustained throughput at a latency SLO (PR 10) -------------------------------

def _sparsify_ffn(cfg, density):
    """The paper's static block-sparse FFN applied to a dense config --
    the serving benchmark's sparse arm prices the stack the engine
    would actually serve."""
    import dataclasses
    groups = tuple(
        (tuple(dataclasses.replace(s, ffn="sparse")
               for s in period), rep)
        for period, rep in cfg.groups)
    return dataclasses.replace(cfg, groups=groups, ffn_density=density)


def _serve_sim(shapes, buckets, lens, max_new, batch):
    """Deterministic continuous-batching simulation on a cost-model
    virtual clock: admissions pay the bucketed prefill price, every
    decode tick prices the live batch through the stack
    (``dispatch.price_tokens`` -- the engine's own admission pricing).
    Returns (total_s, p99_step_s, pad_tokens, prompt_tokens)."""
    price = {}

    def _p(n):
        if n not in price:
            price[n] = dispatch.price_tokens(shapes, n)
        return price[n]

    queue = [(int(s), max_new) for s in lens]
    live = []                      # remaining decode tokens per slot
    clock = 0.0
    pad = prompt = 0
    step_times = []
    while queue or live:
        while queue and len(live) < batch:
            s, new = queue.pop(0)
            bucket = next((b for b in buckets if b >= s), buckets[-1])
            clock += _p(bucket)
            pad += bucket - s
            prompt += s
            # the prefill-generated token counts toward max_new_tokens
            # (the engine's termination contract)
            if new - 1 > 0:
                live.append(new - 1)
        if live:
            dt = _p(len(live))
            clock += dt
            step_times.append(dt)
            live = [r - 1 for r in live if r > 1]
    p99 = (float(np.percentile(np.asarray(step_times), 99))
           if step_times else 0.0)
    return clock, p99, pad, prompt


def serving_throughput(tiny: bool = False):
    """Sustained serving throughput: requests/sec at an inter-token
    latency SLO, on the calibrated cost model's virtual clock (fully
    deterministic -- no wall clock, seeded request stream).  Uses the
    engine's own machinery (``_stack_shapes`` pricing proxy +
    ``_auto_buckets`` cost-model bucket ladder), so the gate covers the
    serving layer's analytic decisions:

    * ``rps_at_slo``             best requests/sec over the batch sweep
                                 among batches whose p99 step latency
                                 meets the SLO (4x the single-token
                                 step on the DENSE stack, fixed per
                                 model so the sparse arm's win shows as
                                 throughput, not a laxer SLO);
    * ``throughput_vs_padmax``   bucketed ladder vs pad-everything-to-
                                 max (the bucketing win);
    * ``serving_speedup_vs_dense`` sparse-FFN arm vs the dense arm at
                                 the same SLO (the paper's speedup
                                 surviving end-to-end serving).
    """
    from repro import configs
    from repro.serve.engine import _auto_buckets, _stack_shapes
    recs = []
    models = ("llama3_2_1b",) if tiny else ("llama3_2_1b",
                                            "qwen2_1_5b")
    max_len = 2048 if tiny else 4096
    n_req = 64 if tiny else 256
    max_new = 64
    for name in models:
        cfg = configs.get(name)
        dense_shapes = _stack_shapes(cfg)
        slo = 4.0 * dispatch.price_tokens(dense_shapes, 1)
        rng = np.random.default_rng(0)
        lens = rng.integers(16, max_len - 1, size=n_req)
        dense_rps = None
        for ffn, c in (("dense", cfg),
                       ("sparse_1_8", _sparsify_ffn(cfg, 1 / 8))):
            shapes = _stack_shapes(c)
            buckets = _auto_buckets(max_len - 1, shapes, 0.5)
            best = None
            sweep = {}
            for batch in (4, 8, 16, 32):
                total, p99, pad, prompt = _serve_sim(
                    shapes, buckets, lens, max_new, batch)
                rps = n_req / total
                sweep[batch] = {"rps": round(rps, 3),
                                "p99_step_us": round(p99 * 1e6, 3)}
                if p99 <= slo and (best is None or rps > best[1]):
                    best = (batch, rps, p99, pad, prompt)
            batch, rps, p99, pad, prompt = best
            pm_total, _, _, _ = _serve_sim(
                shapes, (max_len - 1,), lens, max_new, batch)
            rec = dict(
                fig="serving", model=name, ffn=ffn, max_len=max_len,
                n_req=n_req, max_new=max_new,
                buckets=[int(b) for b in buckets],
                slo_us=round(slo * 1e6, 3),
                batch_at_slo=batch,
                rps_at_slo=round(rps, 3),
                p99_step_us=round(p99 * 1e6, 3),
                padding_waste_frac=round(pad / (pad + prompt), 4),
                throughput_vs_padmax=round(rps / (n_req / pm_total), 3),
                sweep=sweep)
            if ffn == "dense":
                dense_rps = rps
            else:
                rec["serving_speedup_vs_dense"] = round(
                    rps / dense_rps, 3)
            recs.append(rec)
    return recs


# -- occupancy: the TPU-specific axis (DESIGN.md §2) --------------------------------------

def occupancy_study():
    recs = []
    m, d = 4096, 1 / 16
    for b in (4, 8, 16):
        for clustered in (False, True):
            bsr = _bsr(m, m, b, d, clustered=clustered)
            p = pack_tiles(bsr, 128, 128)
            recs.append(dict(fig="occupancy", b=b,
                             clustered=clustered,
                             tiles=p.num_tiles,
                             occupancy=round(p.occupancy, 4)))
    return recs


ALL = {
    "fig2": fig2_dense_baseline,
    "table3": table3_static_vs_dynamic,
    "fig3a": fig3a_density_sweep,
    "fig4a": fig4a_block_size,
    "fig4b": fig4b_feature_size,
    "fig4c": fig4c_power_law,
    "fig7": fig7_speedup_grid,
    "occupancy": occupancy_study,
    "dispatch": dispatch_decisions,
    "grouped_capacity": grouped_capacity,
    "tp_crossover": tp_crossover,
    "train_grad": train_grad,
    "pattern_evolution": pattern_evolution,
    "skewed_patterns": skewed_patterns,
    "serving": serving_throughput,
}

# experiments with a reduced CI smoke grid (benchmarks.run --tiny)
TINY_CAPABLE = ("dispatch", "grouped_capacity", "tp_crossover",
                "train_grad", "pattern_evolution", "skewed_patterns",
                "serving")
