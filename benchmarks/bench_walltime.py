"""CPU wall-clock cross-check of the paper's *ordering* claims.

Absolute CPU numbers mean nothing for the TPU target, but the ordering
static >= dynamic (same pattern, same math, dynamic pays runtime encode +
capacity padding) and less-work-with-lower-density are hardware-agnostic
properties of the implementations and are asserted here with real timers
on the XLA paths.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import dynamic_sparse as dsp, static_sparse as ssp
from repro.core.bsr import BlockSparseMatrix


def _time(fn, *args, iters=10):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(m=1024, n=256, b=16):
    recs = []
    for d in (1 / 4, 1 / 16):
        bsr = BlockSparseMatrix.random(jax.random.PRNGKey(0), m, m, b, d)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, n))
        dense_w = bsr.to_dense()

        f_dense = jax.jit(lambda w, x: w @ x)
        t_dense = _time(f_dense, dense_w, x)

        spmm = ssp.make_spmm(bsr.row_idx, bsr.col_idx, bsr.grid,
                             bsr.block_size)
        f_static = jax.jit(spmm)
        t_static = _time(f_static, jnp.asarray(bsr.values), x)

        cap = int(bsr.grid[0] * bsr.grid[1] * d * 1.25) + 1
        mask = jnp.asarray(bsr.block_mask())

        def f_dyn(w, mask, x):
            op = dsp.encode(w, mask, block_size=b, nnz_max=cap)
            return dsp.dspmm(op, x)
        f_dyn = jax.jit(f_dyn)
        t_dyn = _time(f_dyn, dense_w, mask, x)

        recs.append(dict(fig="cpu_walltime", density=d,
                         dense_ms=round(t_dense * 1e3, 2),
                         static_ms=round(t_static * 1e3, 2),
                         dynamic_ms=round(t_dyn * 1e3, 2),
                         static_faster_than_dynamic=t_static < t_dyn))
    return recs
