"""Run the full benchmark suite: one experiment per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3a]

Writes experiments/bench/results.json and prints a per-figure summary
with the corresponding paper claim and whether the reproduction agrees
qualitatively.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import bench_walltime, suite  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")

# per-figure artifact names for --all-tiny / --all-full: the files
# tools/bench_check.py diffs against benchmarks/baselines/ and CI
# uploads under the bench-* artifact pattern.  Adding a benchmark to
# the smoke job = adding it to suite.TINY_CAPABLE (+ a baseline).
FIG_FILES = {
    "dispatch": "BENCH_dispatch.json",
    "grouped_capacity": "BENCH_grouped_capacity.json",
    "tp_crossover": "BENCH_tp.json",
    "train_grad": "BENCH_train_grad.json",
    "pattern_evolution": "BENCH_pattern_evolution.json",
    "skewed_patterns": "BENCH_skewed_patterns.json",
    "serving": "BENCH_serving.json",
}

CLAIMS = {
    "table3": "paper Table 3: static > dynamic at every (b, dtype); "
              "speedup grows with b; fp32 ratios exceed fp16",
    "fig3a": "paper Fig 3a: sparse ~flat vs density (near-perfect "
             "scaling), dense degrades linearly in useful FLOP/s",
    "fig4a": "paper Fig 4a: throughput grows with block size "
             "(2.1x b=4, 6.6x b=16 on IPU)",
    "fig4b": "paper Fig 4b: sparse speedup improves with feature size",
    "fig4c": "paper Fig 4c power law 0.0013*m^0.59*d^-0.54*b^0.50: "
             "same exponent signs (m+, d-, b+)",
    "fig7": "paper Fig 7: speedup grid favours large m, low d, large b",
    "fig2": "paper Fig 2: dense TFLOP/s saturates with batch size",
    "occupancy": "TPU-specific (DESIGN.md S2): clustered patterns pack "
                 "into near-full MXU tiles, scattered ones do not",
    "cpu_walltime": "hardware-agnostic ordering check on real timers",
    "dispatch": "paper Table 3 as runtime plans: static routes win at "
                "low density / large blocks, dense at high density",
    "grouped_capacity": "paper §3.3/A.2 bucket sizing: expected-tiles + "
                        "headroom capacity beats the safe worst case at "
                        "low density; overflow risk is priced, not "
                        "ignored",
    "tp_crossover": "paper Fig 1a at mesh scale: k-sharded TP SpMM "
                    "(local block work + one reduction) crosses over "
                    "the unsharded route as m grows; the verdict is "
                    "measured (gspmd vs shard_map vs unsharded race), "
                    "not modeled",
    "train_grad": "paper §3.2 extended to training: the backward "
                  "products (transposed-pattern SpMM + block SDDMM) "
                  "ride the same pre-planned fast path as the forward, "
                  "so the fwd+bwd triple beats the dense triple at low "
                  "density and the win grows as density falls",
    "pattern_evolution": "dynamic sparse training on static plans: a "
                         "RigL topology update is an incremental "
                         "MatmulPlan.evolve (host re-pack, verdicts "
                         "reused, zero measurements) instead of a "
                         "from-scratch re-plan, and the evolved plan "
                         "keeps the static fwd+bwd win over dense",
    "skewed_patterns": "load-balanced walks (PR 8): on row-skewed "
                       "patterns (imbalance >= 2) the balanced routes "
                       "beat the uniform walk >= 1.2x and win the plan "
                       "race at the acceptance point; on uniform masks "
                       "they never cost more than the 2% swizzle "
                       "overhead (ratio >= 0.95)",
    "serving": "serving layer (PR 10): the paper's static-sparse FFN "
               "speedup survives end-to-end continuous-batching "
               "serving (requests/sec at the inter-token-latency SLO "
               "beats the dense stack), and the cost-model bucket "
               "ladder beats pad-to-max prefill",
}


def _check(fig, recs):
    """Qualitative agreement checks -> (ok, note)."""
    if fig == "table3":
        stat = {(r["block_size"], r["dtype"]): r["speedup_vs_dense"]
                for r in recs if r["mode"] == "static-clustered"}
        dyn = {(r["block_size"], r["dtype"]): r["speedup_vs_dense"]
               for r in recs if r["mode"] == "dynamic"}
        grp = {(r["block_size"], r["dtype"]): r["speedup_vs_dense"]
               for r in recs if r["mode"] == "dynamic-grouped"}
        ok = all(stat[k] >= dyn[k] for k in stat)          # static > dynamic
        ok &= all(stat[k] >= grp[k] for k in stat)
        ok &= dyn[(16, "fp16")] > dyn[(4, "fp16")] > dyn[(1, "fp16")]
        ok &= grp[(16, "fp16")] > 1.0   # TPU-native dynamic beats dense
        return ok, (f"b16,fp16: static={stat[(16, 'fp16')]}x "
                    f"dynamic-grouped={grp[(16, 'fp16')]}x "
                    f"dynamic-blockwise={dyn[(16, 'fp16')]}x (blockwise "
                    f"slots under-fill the 128x128 MXU -- see DESIGN.md)")
    if fig == "fig4a":
        dyn = {r["b"]: r["tflops"] for r in recs if r["mode"] == "dynamic"}
        sca = {r["b"]: r["tflops"] for r in recs
               if r["mode"] == "static-scattered-lowd"}
        ok = dyn[16] > dyn[4] > dyn[1] and sca[16] >= sca[4] >= sca[1]
        return ok, (f"dynamic tflops b1/4/16: {dyn[1]}/{dyn[4]}/{dyn[16]}; "
                    f"scattered-static: {sca[1]}/{sca[4]}/{sca[16]} "
                    f"(clustered static is b-independent on MXU -- packing)")
    if fig == "fig4b":
        sp = [r["speedup"] for r in recs]
        return all(b >= a * 0.95 for a, b in zip(sp, sp[1:])), \
            f"speedups {sp}"
    if fig == "fig4c":
        r = recs[0]
        ok = r["m_exp"] > 0 and r["d_exp"] < 0
        return ok, (f"ours m^{r['m_exp']} d^{r['d_exp']} b^{r['b_exp']} "
                    f"vs paper m^0.59 d^-0.54 b^0.50 (b-exp ~0 on MXU: "
                    f"128-tile packing absorbs the block size)")
    if fig == "fig3a":
        stat = sorted((r["density"], r["tflops"]) for r in recs
                      if r.get("mode") == "static" and r.get("b") == 16)
        lo, hi = stat[0][1], stat[-1][1]
        return hi / max(lo, 1e-9) < 4.0, \
            f"static b16 tflops across densities: {lo}..{hi}"
    if fig == "cpu_walltime":
        return all(r["static_faster_than_dynamic"] for r in recs), \
            "static < dynamic wall-clock on every config"
    if fig == "occupancy":
        by = {(r["b"], r["clustered"]): r["occupancy"] for r in recs}
        return by[(16, True)] > 5 * by[(16, False)], \
            f"b=16 occupancy clustered {by[(16, True)]} vs " \
            f"scattered {by[(16, False)]}"
    if fig == "dispatch":
        low = [r["chosen"] for r in recs if r["kind"] == "static"
               and r["density"] <= 1 / 16 and r["b"] >= 16]
        ok = bool(low) and any(c.startswith("static") for c in low)
        return ok, (f"{len(recs)} planned decisions; low-density b>=16 "
                    f"static routes: {sorted(set(low))}")
    if fig == "grouped_capacity":
        # planned capacity must never lose to the worst case, and must
        # WIN somewhere at <=10% density with the default headroom (the
        # PR acceptance criterion: dynamic_grouped can only take the
        # low-density dispatch race if its planned bucket is cheaper)
        never_worse = all(r["speedup_vs_worst"] >= 1.0 for r in recs)
        wins = [r for r in recs if r["density"] <= 0.1
                and r["headroom"] == 1.25 and r["speedup_vs_worst"] > 1.1]
        best = max(recs, key=lambda r: r["speedup_vs_worst"])
        return never_worse and bool(wins), (
            f"{len(wins)} planned-capacity wins at d<=10% "
            f"(best {best['speedup_vs_worst']}x at m={best['m']} "
            f"b={best['b']} d={best['density']:.4f} "
            f"headroom={best['headroom']}, P[overflow]="
            f"{best['overflow_p']})")
    if fig == "train_grad":
        # fwd+bwd speedup grows as density falls per (m, b), and sparse
        # training must win somewhere at d<=1/16 with b>=16; the dL/dW
        # verdict must leave the dense product at the lowest density
        by = {}
        for r in recs:
            by.setdefault((r["m"], r["b"]), []).append(
                (r["density"], r["train_speedup_vs_dense"]))
        mono = all(b2 >= a2 * 0.999 for series in by.values()
                   for (_, a2), (_, b2) in
                   zip(sorted(series, reverse=True),
                       sorted(series, reverse=True)[1:]))
        wins = [r for r in recs if r["density"] <= 1 / 16
                and r["b"] >= 16 and r["train_speedup_vs_dense"] > 1.0]
        lowd = [r for r in recs
                if r["density"] == min(x["density"] for x in recs)]
        sparse_dw = any(r["dv_route"] != "sddmm_dense" for r in lowd)
        best = max(recs, key=lambda r: r["train_speedup_vs_dense"])
        return bool(wins) and mono and sparse_dw, (
            f"{len(wins)} fwd+bwd wins at d<=1/16 b>=16 (best "
            f"{best['train_speedup_vs_dense']}x at m={best['m']} "
            f"b={best['b']} d={best['density']:.4f}: "
            f"fwd={best['fwd_route']} dx={best['dx_route']} "
            f"dW={best['dv_route']})")
    if fig == "pattern_evolution":
        # the tentpole invariant: every in-threshold evolve chain runs
        # zero route decisions / measurement events; evolve must be
        # cheaper than a measured re-plan everywhere; and the evolved
        # plan must still beat the dense training step at d<=1/16 b>=16
        no_events = all(r["evolve_measurements"] == 0 for r in recs)
        cheaper = all(r["replan_vs_evolve"] > 1.0 for r in recs)
        wins = [r for r in recs if r["density"] <= 1 / 16
                and r["b"] >= 16 and r["step_speedup_vs_dense"] > 1.0]
        best = max(recs, key=lambda r: r["step_speedup_vs_dense"])
        return no_events and cheaper and bool(wins), (
            f"{sum(r['generations'] for r in recs)} evolves, "
            f"{sum(r['evolve_measurements'] for r in recs)} measurement "
            f"events; evolve beats measured re-plan on all "
            f"{len(recs)} points; {len(wins)} evolved-plan wins at "
            f"d<=1/16 b>=16 (best {best['step_speedup_vs_dense']}x at "
            f"m={best['m']} b={best['b']} d={best['density']:.4f})")
    if fig == "skewed_patterns":
        # the PR 8 acceptance criterion: balanced routes beat the
        # uniform walk >= 1.2x wherever imbalance >= 2 (both families),
        # never lose more than the swizzle overhead on uniform masks,
        # and actually WIN the race at a power-law point with m >= 4096,
        # b = 16, d <= 1/16
        skewed = [r for r in recs if r["imbalance"] >= 2.0]
        uniform = [r for r in recs if r["mask"] == "uniform"]
        wins = (bool(skewed)
                and all(r["static_balance_ratio"] >= 1.2
                        and r["dynamic_balance_ratio"] >= 1.2
                        for r in skewed))
        holds = all(r["static_balance_ratio"] >= 0.95
                    and r["dynamic_balance_ratio"] >= 0.95
                    for r in uniform)
        acc = [r for r in skewed
               if r["mask"] == "power_law" and r["m"] >= 4096
               and r["b"] == 16 and r["density"] <= 1 / 16
               and r["chosen"].endswith("balanced")]
        best = max(recs, key=lambda r: r["static_balance_ratio"])
        return wins and holds and bool(acc), (
            f"{len(skewed)} skewed points all >= 1.2x, "
            f"{len(uniform)} uniform points all >= 0.95x; race won by "
            f"{acc[0]['chosen'] if acc else 'NOTHING'} at the "
            f"acceptance point (best {best['static_balance_ratio']}x "
            f"at mask={best['mask']} m={best['m']} b={best['b']} "
            f"imbalance={best['imbalance']})")
    if fig == "serving":
        # the serving acceptance: every arm meets its SLO somewhere on
        # the batch sweep, bucketed prefill beats pad-to-max, and the
        # sparse-FFN arm sustains more requests/sec than the dense arm
        # at the SAME (dense-derived) SLO
        slo_met = all(r["batch_at_slo"] is not None for r in recs)
        bucketing = all(r["throughput_vs_padmax"] > 1.0 for r in recs)
        sp = [r for r in recs if "serving_speedup_vs_dense" in r]
        wins = bool(sp) and all(r["serving_speedup_vs_dense"] > 1.0
                                for r in sp)
        best = max(sp, key=lambda r: r["serving_speedup_vs_dense"]) \
            if sp else None
        return slo_met and bucketing and wins, (
            f"{len(recs)} arms all meet the SLO; bucketing beats "
            f"pad-to-max on every arm; sparse serving wins "
            + (f"{best['serving_speedup_vs_dense']}x rps at the SLO "
               f"on {best['model']} (bucket ladder "
               f"{best['buckets']})" if best else "NOWHERE"))
    if fig == "tp_crossover":
        # deterministic side: analytic TP speedup grows with m per
        # (density, n) and crosses 1 somewhere on the grid; measured
        # side (when devices were available) must be finite and the
        # chosen route the argmin of its race
        by = {}
        for r in recs:
            by.setdefault((r["density"], r["n"]), []).append(
                (r["m"], r["est_tp_speedup"]))
        mono = all(b >= a * 0.999 for series in by.values()
                   for (_, a), (_, b) in zip(sorted(series),
                                             sorted(series)[1:]))
        crossed = any(r["est_tp_speedup"] > 1.0 for r in recs)
        measured = [r for r in recs if r["measured_us"]]
        meas_ok = all(
            all(v > 0 for v in r["measured_us"].values())
            for r in measured)
        n_meas_wins = sum(1 for r in measured if r["tp_wins_measured"])
        note = (f"analytic speedup at q=8 grows with m "
                f"({min(r['est_tp_speedup'] for r in recs)}x..."
                f"{max(r['est_tp_speedup'] for r in recs)}x); "
                f"{len(measured)} measured races"
                + (f", TP measured past crossover on {n_meas_wins}"
                   if measured else " (single device: analytic only)"))
        return mono and crossed and meas_ok, note
    return True, ""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-walltime", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke grid for experiments that support it "
                         f"(currently: {', '.join(suite.TINY_CAPABLE)})")
    ap.add_argument("--out", default=None,
                    help="also write the records to this JSON path "
                         "(e.g. BENCH_dispatch.json for the CI artifact)")
    ap.add_argument("--all-tiny", action="store_true",
                    help="run every TINY_CAPABLE experiment on its smoke "
                         "grid and write one BENCH_*.json per figure to "
                         "--out-dir (the CI benchmark-smoke entry point)")
    ap.add_argument("--all-full", action="store_true",
                    help="like --all-tiny but on the full grids (nightly)")
    ap.add_argument("--out-dir", default=OUT,
                    help="directory for the per-figure BENCH_*.json files "
                         "written by --all-tiny / --all-full")
    args = ap.parse_args()

    all_recs = {}
    if args.all_tiny or args.all_full:
        for fig in suite.TINY_CAPABLE:
            all_recs[fig] = suite.ALL[fig](tiny=bool(args.all_tiny))
    else:
        for fig, fn in suite.ALL.items():
            if args.only and fig != args.only:
                continue
            if args.tiny and fig in suite.TINY_CAPABLE:
                all_recs[fig] = fn(tiny=True)
            else:
                all_recs[fig] = fn()
        if not args.only and not args.skip_walltime:
            all_recs["cpu_walltime"] = bench_walltime.run()
        elif args.only == "cpu_walltime":
            all_recs["cpu_walltime"] = bench_walltime.run()

    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, "results.json"), "w") as f:
        json.dump(all_recs, f, indent=1)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(all_recs, f, indent=1)
    if args.all_tiny or args.all_full:
        # one file per figure, named exactly like the committed baseline
        # it gates against, so `tools/bench_check.py <out-dir>/BENCH_*`
        # works unmodified
        os.makedirs(args.out_dir, exist_ok=True)
        for fig, recs in all_recs.items():
            path = os.path.join(args.out_dir,
                                FIG_FILES.get(fig, f"BENCH_{fig}.json"))
            with open(path, "w") as f:
                json.dump({fig: recs}, f, indent=1)
            print(f"wrote {path}")

    failures = 0
    for fig, recs in all_recs.items():
        ok, note = _check(fig, recs)
        status = "AGREES" if ok else "DISAGREES"
        failures += 0 if ok else 1
        print(f"[{fig:12s}] {status:9s} {note}")
        print(f"              claim: {CLAIMS.get(fig, '')}")
    print(f"\nwrote {os.path.join(OUT, 'results.json')} "
          f"({sum(len(v) for v in all_recs.values())} records); "
          f"{failures} qualitative disagreements")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
