"""Analytic TPU-v5e timing model for the three matmul kernels.

The paper reports measured cycle counts on IPU hardware; this container
is CPU-only, so the benchmark harness reports *kernel-structure-derived*
cycles on the TPU target instead (the same procedure as the paper's
constant-clock conversion, with the grid/step structure of our Pallas
kernels as the cycle source), cross-checked qualitatively by CPU
wall-clock of the XLA paths (bench_walltime.py).

Model (per Pallas grid step, one TensorCore):

    step_cycles = max(mxu_cycles, dma_cycles)
    mxu_cycles  = ceil(tm/128)*ceil(tk/128)*ceil(tn/128) * 128
                  -- the 128x128 systolic array retires a 128^3 MAC block
                  in ~128 cycles; sub-128 operands still occupy full
                  passes (the TPU analogue of the paper's observation
                  that small blocks under-use IPU AMP units, §5.3)
    dma_cycles  = step_bytes / hbm_bw * clock

plus per-kernel overheads taken from the kernel structure:

  * dense_mm:  grid (M/tm, N/tn, K/tk), all tiles visited
  * bsmm:      grid (N/tn, T) -- T = *actual* packed tiles from
               ``partitioner.pack_tiles`` (captures occupancy/clustering,
               the TPU-specific effect DESIGN.md §2 documents); zero
               metadata cost at runtime (compile-time constants)
  * dsmm:      grid (N/tn, S_cap) -- capacity slots from ``d_max``
               (padding slots execute, paper's overflow cost) at logical
               block granularity (no host packing possible at runtime),
               plus the runtime encode (sort) cost on-device
"""
from __future__ import annotations

import dataclasses
import math

CLOCK = 0.94e9            # v5e TensorCore clock (Hz)
PEAK_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9            # B/s
VMEM_BW = 4.8e12          # B/s on-chip (approx; matters for small tiles)
# bytes per element
B16, B32 = 2, 4


def _mxu_cycles(m, k, n):
    return math.ceil(m / 128) * math.ceil(k / 128) * math.ceil(n / 128) * 128


def _bytes_cycles(nbytes, bw=HBM_BW):
    return nbytes / bw * CLOCK


@dataclasses.dataclass
class KernelTime:
    cycles: float
    useful_flops: float

    @property
    def seconds(self):
        return self.cycles / CLOCK

    @property
    def tflops(self):
        return self.useful_flops / self.seconds / 1e12 if self.cycles else 0.0


def dense_time(m, k, n, *, dtype_bytes=B16, tm=512, tk=512, tn=512) -> KernelTime:
    tm, tk, tn = min(tm, m), min(tk, k), min(tn, n)
    steps = math.ceil(m / tm) * math.ceil(n / tn) * math.ceil(k / tk)
    per_step = max(
        _mxu_cycles(tm, tk, tn),
        _bytes_cycles((tm * tk + tk * tn) * dtype_bytes))
    flops = 2.0 * m * k * n
    return KernelTime(steps * per_step, flops)


def bsmm_time(packing, n, *, dtype_bytes=B16, tn=512) -> KernelTime:
    """Static: T actual tiles (from pack_tiles), each tm x tk x tn."""
    tn = min(tn, n)
    steps = packing.num_tiles * math.ceil(n / tn)
    per_step = max(
        _mxu_cycles(packing.tm, packing.tk, tn),
        _bytes_cycles((packing.tm * packing.tk + packing.tk * tn)
                      * dtype_bytes))
    m, k = packing.shape
    useful = 2.0 * packing._nnz_area * n     # nnz blocks * b^2 * n * 2
    return KernelTime(steps * per_step, useful)


def dsmm_time(m, k, n, *, block_size, d_max, true_density=None,
              dtype_bytes=B16, tn=512) -> KernelTime:
    """Dynamic: capacity slots at block granularity + runtime encode."""
    b = block_size
    tn = min(tn, n)
    mb, kb = m // b, k // b
    slots = math.ceil(mb * kb * d_max) + mb      # + per-row coverage slots
    steps = slots * math.ceil(n / tn)
    per_step = max(
        _mxu_cycles(b, b, tn),
        _bytes_cycles((b * b + b * tn) * dtype_bytes, VMEM_BW))
    # runtime encode: sort slots + gather values (the paper's "host
    # utility" moved on-device); ~log-passes over slot metadata
    encode = _bytes_cycles(slots * (8 + b * b * dtype_bytes)) * \
        max(1, math.log2(max(slots, 2)) / 4)
    d = true_density if true_density is not None else d_max
    useful = 2.0 * m * k * n * d
    return KernelTime(steps * per_step + encode, useful)


def dsmm_grouped_time(packing, n, *, capacity_factor=1.25,
                      dtype_bytes=B16, tn=512) -> KernelTime:
    """Beyond-paper dynamic mode for TPU: device-side *tile packing*
    (the ``kernels/gmm`` layout generalized) -- the runtime pattern is
    packed into 128-aligned tile slots on device, so the MXU runs full
    tiles like static mode; dynamic costs are the capacity headroom
    (padded tile slots, the paper's overflow) and the on-device pack
    (scatter of nnz blocks + metadata sort).  See EXPERIMENTS.md §Perf.

    ``capacity_factor`` multiplies ``packing.num_tiles`` into the slot
    count.  Callers pricing a *planned* bucket
    (``planner.plan_grouped_capacity``, whose ``tiles_cap`` already
    contains the headroom) pass ``capacity_factor=1.0`` with
    ``num_tiles = tiles_cap`` -- this is how ``core.dispatch`` prices
    the dynamic_grouped route; the default 1.25 is the legacy
    expected-tiles x headroom shorthand used by the Table 3 records.
    """
    tn = min(tn, n)
    slots = math.ceil(packing.num_tiles * capacity_factor)
    steps = slots * math.ceil(n / tn)
    per_step = max(
        _mxu_cycles(packing.tm, packing.tk, tn),
        _bytes_cycles((packing.tm * packing.tk + packing.tk * tn)
                      * dtype_bytes))
    nnz_bytes = packing._nnz_area * dtype_bytes
    pack = _bytes_cycles(3 * nnz_bytes) + \
        _bytes_cycles(slots * 16) * max(1, math.log2(max(slots, 2)) / 4)
    m, k = packing.shape
    useful = 2.0 * packing._nnz_area * n
    return KernelTime(steps * per_step + pack, useful)


def fp32_time(t: KernelTime) -> KernelTime:
    """FP32 runs the MXU at ~1/4 rate (v5e has no fp32 systolic path;
    f32 lowers to multi-pass bf16x3 or VPU) -- the analogue of the
    paper's FP16-vs-FP32 core-arithmetic cost gap.  NOTE: multiplies the
    whole step (compute-bound kernels); DMA-bound steps keep their byte
    cost through dtype_bytes=B32 at call sites, so this is an upper
    bound on the fp32 slowdown."""
    return KernelTime(t.cycles * 4, t.useful_flops)
