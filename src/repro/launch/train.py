"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

Production behaviours exercised end-to-end (and tested in
tests/test_train.py):

* deterministic sharded data pipeline with a checkpointable cursor,
* async atomic checkpoints every ``--ckpt-every`` steps,
* automatic resume from the latest checkpoint (crash/preemption model:
  kill the process at any point; rerun the same command),
* preemption signal handler (SIGTERM -> synchronous final checkpoint),
* elastic restart: checkpoints store logical shardings, so a restart on
  a different mesh re-shards on load,
* straggler mitigation at step granularity: the jitted step is a global
  barrier; the async checkpointer bounds the extra critical-path work to
  a device->host copy (see DESIGN.md §3).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax

from repro import configs
from repro.checkpoint import Checkpointer, latest_step, restore
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models.model import LM
from repro.sharding import rules
from repro.train.step import TrainHParams, init_train_state, make_train_step


def train_loop(cfg, *, steps: int, batch_per_shard: int, seq: int,
               ckpt_dir: str | None, ckpt_every: int = 20,
               hp: TrainHParams = TrainHParams(), mesh=None,
               log_every: int = 10, on_step=None):
    lm = LM(cfg)
    mesh = mesh or make_host_mesh()
    train_step = make_train_step(lm, hp)

    pipe = TokenPipeline(cfg.vocab_size, batch_per_shard, seq)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None

    state_sds = jax.eval_shape(
        lambda: init_train_state(lm, jax.random.PRNGKey(0), hp=hp))
    state_specs = rules.train_state_specs(state_sds, mesh)

    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        state, extra, start = restore(ckpt_dir, state_sds, mesh=mesh,
                                      specs=state_specs)
        start = TokenPipeline.resume_step(extra["data"])
        print(f"[train] resumed from step {start}")
    else:
        state = init_train_state(lm, jax.random.PRNGKey(0), hp=hp)

    jit_step = jax.jit(train_step, donate_argnums=(0,))
    stop = {"now": False}

    def on_sigterm(signum, frame):
        stop["now"] = True
    old = signal.signal(signal.SIGTERM, on_sigterm)

    losses = []
    t0 = time.time()
    with mesh, rules.activation_mesh(mesh):
        for step in range(start, steps):
            batch = jax.tree.map(jax.numpy.asarray, pipe.get_batch(step))
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if on_step:
                on_step(step, metrics)
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)")
            if ckpt and ((step + 1) % ckpt_every == 0 or stop["now"]
                         or step == steps - 1):
                ckpt.save_async(state, step=step + 1,
                                extra={"data": pipe.state(step + 1)})
            if stop["now"]:
                print("[train] preemption signal: final checkpoint + exit")
                break
    if ckpt:
        ckpt.wait()
    signal.signal(signal.SIGTERM, old)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    hp = TrainHParams(peak_lr=args.lr, warmup_steps=max(1, args.steps // 10),
                      total_steps=args.steps)
    _, losses = train_loop(cfg, steps=args.steps,
                           batch_per_shard=args.batch, seq=args.seq,
                           ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every, hp=hp)
    print(f"[train] done: first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")
    if not (losses[-1] < losses[0]):
        print("[train] WARNING: loss did not improve", file=sys.stderr)


if __name__ == "__main__":
    main()
