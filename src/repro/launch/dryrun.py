import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, prove memory fits, and extract roofline
terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell this script:
  1. builds the full-size config and ShapeDtypeStruct inputs (no data),
  2. jits the entry point (train_step / prefill / serve_step) with the
     sharding rules of ``sharding/rules.py``,
  3. ``.lower().compile()`` -- any sharding mismatch / OOM / unsupported
     collective fails here, which is the point,
  4. prints ``compiled.memory_analysis()`` and ``cost_analysis()``,
  5. runs the loop-aware HLO analyzer (``analysis/hlo_cost``) on the
     per-device module and writes a JSON record under
     ``experiments/dryrun/``.
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.analysis.hlo_cost import analyze_hlo_text
from repro.analysis.roofline import (V5E, model_flops_forward,
                                     model_flops_train, roofline_terms)
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.sharding import rules
from repro.train.step import TrainHParams, init_train_state, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def build_cell(name: str, shape: str, mesh, *, cfg=None,
               hp: TrainHParams = TrainHParams()):
    """Returns (fn, in_args_sds, in_shardings, out_shardings, meta)."""
    cfg = cfg or configs.get(name)
    lm = LM(cfg)
    kind, kw = configs.input_specs(name, shape, cfg=cfg)
    sh = configs.SHAPES[shape]
    meta = dict(arch=cfg.name, shape=shape, kind=kind,
                batch=sh["batch"], seq=sh["seq"])

    if kind == "train":
        train_step = make_train_step(lm, hp)
        state_sds = jax.eval_shape(
            lambda: init_train_state(lm, jax.random.PRNGKey(0), hp=hp))
        batch_sds = kw["batch"]
        state_specs = rules.train_state_specs(state_sds, mesh)
        batch_specs = rules.train_batch_specs(batch_sds, mesh)
        out_sds = jax.eval_shape(train_step, state_sds, batch_sds)
        metric_specs = jax.tree.map(lambda _: P(), out_sds[1])
        in_sh = (_shardings(state_specs, mesh), _shardings(batch_specs, mesh))
        out_sh = (_shardings(state_specs, mesh),
                  _shardings(metric_specs, mesh))
        tokens = sh["batch"] * sh["seq"]
        meta["model_flops_device"] = model_flops_train(
            cfg.active_param_count(), tokens) / mesh.size
        return train_step, (state_sds, batch_sds), in_sh, out_sh, meta

    params_sds = configs.param_specs(name, cfg=cfg)
    p_specs = rules.param_specs(params_sds, mesh)

    if kind == "prefill":
        extras = {k: v for k, v in kw.items() if k != "tokens"}

        def prefill_fn(params, tokens, extras):
            return lm.prefill(params, tokens,
                              max_len=kw["tokens"].shape[1] +
                              (cfg.frontend_len if cfg.frontend == "vision"
                               else 0),
                              **extras)

        b_specs = rules.train_batch_specs(
            {"tokens": kw["tokens"], **extras}, mesh)
        caches_sds = jax.eval_shape(prefill_fn, params_sds, kw["tokens"],
                                    extras)[1]
        c_specs = rules.cache_specs(caches_sds, mesh, batch=sh["batch"])
        ba = rules.batch_axes(mesh)
        logit_spec = P(ba if len(ba) > 1 else (ba[0] if ba else None),
                       "model" if cfg.vocab_size % mesh.shape["model"] == 0
                       else None)
        in_sh = (_shardings(p_specs, mesh),
                 _shardings(b_specs["tokens"], mesh),
                 _shardings({k: b_specs[k] for k in extras}, mesh))
        out_sh = (NamedSharding(mesh, logit_spec), _shardings(c_specs, mesh))
        tokens = sh["batch"] * sh["seq"]
        meta["model_flops_device"] = model_flops_forward(
            cfg.active_param_count(), tokens) / mesh.size
        return (prefill_fn, (params_sds, kw["tokens"], extras), in_sh,
                out_sh, meta)

    # decode
    retained = kw["retained"]

    def serve_step(params, tokens, caches, positions):
        return lm.decode_step(params, tokens, caches, positions,
                              retained=retained)

    c_specs = rules.cache_specs(kw["caches"], mesh, batch=sh["batch"])
    ba = rules.batch_axes(mesh)
    b_fit = (sh["batch"] % (mesh.size // mesh.shape["model"])) == 0
    bfirst = (ba if len(ba) > 1 else ba[0]) if (ba and b_fit) else None
    tok_spec = P(bfirst, None)
    pos_spec = P(bfirst)
    logit_spec = P(bfirst,
                   "model" if cfg.vocab_size % mesh.shape["model"] == 0
                   else None)
    in_sh = (_shardings(p_specs, mesh), NamedSharding(mesh, tok_spec),
             _shardings(c_specs, mesh), NamedSharding(mesh, pos_spec))
    out_sh = (NamedSharding(mesh, logit_spec), _shardings(c_specs, mesh))
    meta["model_flops_device"] = model_flops_forward(
        cfg.active_param_count(), sh["batch"]) / mesh.size
    meta["retained"] = retained
    return (serve_step,
            (params_sds, kw["tokens"], kw["caches"], kw["positions"]),
            in_sh, out_sh, meta)


def run_cell(name: str, shape: str, *, multi_pod: bool, cfg=None,
             save: bool = True, verbose: bool = True,
             hp: TrainHParams = TrainHParams(), tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    fn, args, in_sh, out_sh, meta = build_cell(name, shape, mesh, cfg=cfg,
                                               hp=hp)
    with mesh, rules.activation_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
    cost = analyze_hlo_text(compiled.as_text())
    roof = roofline_terms(cost, V5E,
                          model_flops_per_device=meta["model_flops_device"])
    rec = dict(meta, mesh=mesh_name, devices=mesh.size,
               lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
               memory=dict(
                   argument_mb=mem.argument_size_in_bytes / 2**20,
                   output_mb=mem.output_size_in_bytes / 2**20,
                   temp_mb=mem.temp_size_in_bytes / 2**20,
                   code_mb=mem.generated_code_size_in_bytes / 2**20),
               xla_cost=dict(flops=ca.get("flops", 0.0),
                             bytes=ca.get("bytes accessed", 0.0)),
               hlo_cost=dict(flops=cost["flops"], bytes=cost["bytes"],
                             collective_bytes=cost["collective_bytes"],
                             collectives=cost["collectives"],
                             warnings=cost["warnings"][:5]),
               roofline=roof)
    if verbose:
        print(f"== {meta['arch']} x {shape} on {mesh_name} "
              f"({mesh.size} devices) ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory/device: args {rec['memory']['argument_mb']:.0f} MB"
              f"  temp {rec['memory']['temp_mb']:.0f} MB"
              f"  output {rec['memory']['output_mb']:.0f} MB")
        print(f"  per-device: {cost['flops']:.3e} FLOP, "
              f"{cost['bytes']:.3e} B HBM, "
              f"{cost['collective_bytes']:.3e} B collective")
        print(f"  roofline: compute {roof['t_compute']*1e3:.2f} ms | "
              f"memory {roof['t_memory']*1e3:.2f} ms | "
              f"collective {roof['t_collective']*1e3:.2f} ms "
              f"-> {roof['dominant']}-bound"
              + (f", roofline frac {roof.get('roofline_frac', 0):.3f}"
                 if "roofline_frac" in roof else ""))
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        mod = configs.ALIASES.get(name, name)
        fname = f"{mod}__{shape}__{mesh_name}{tag}.json"
        with open(os.path.join(OUT_DIR, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(configs.SHAPES) + [None])
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(configs.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, multi_pod=mp, tag=args.tag)
                except Exception as e:  # noqa: BLE001 -- report, keep going
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"!! FAIL {arch} x {shape} multi_pod={mp}: {e}")
                    traceback.print_exc(limit=3)
    print(f"\n{'='*60}\ncells: {len(archs)*len(shapes)*len(meshes)}, "
          f"failures: {len(failures)}")
    for f in failures:
        print("  FAIL:", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
