"""Production mesh factories.

A FUNCTION, not a module constant, so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh (CPU tests / examples): axes exist, size 1."""
    return jax.make_mesh((1, 1), ("data", "model"))
