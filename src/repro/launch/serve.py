"""Serving driver: continuous-batching engine over a selectable arch.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --smoke --requests 6 --batch 2 --max-len 96 [--retained]

``--retained`` serves with the ring-buffer local+global KV cache (the
paper's static block sparsity bounding long-context decode, DESIGN.md
§3); positions may then exceed the physical cache length.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models.model import LM
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--retained", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="persistent autotune cache dir (repro.sparse): "
                         "restarts skip re-planning/re-measurement")
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(args.seed))
    eng = Engine(lm, params, batch=args.batch, max_len=args.max_len,
                 retained=args.retained, plan_cache_dir=args.plan_cache)
    print(f"[serve] startup plans: {eng.plan_stats}")

    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 24))),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    done = []
    eng.run(reqs, on_finish=lambda r: done.append(
        (r.uid, time.time() - t0)))
    total_toks = sum(len(r.output) for r in reqs)
    dt = time.time() - t0
    for uid, t in done:
        r = next(r for r in reqs if r.uid == uid)
        print(f"[serve] req {uid}: {len(r.prompt)} prompt -> "
              f"{len(r.output)} tokens @ {t:.2f}s: {r.output[:6]}...")
    print(f"[serve] {len(reqs)} requests, {total_toks} tokens, "
          f"{dt:.2f}s ({total_toks/dt:.1f} tok/s on CPU, "
          f"batch={args.batch}, retained={args.retained})")


if __name__ == "__main__":
    main()
