"""Static-sparsity partitioner (PopSparse §3.2, Fig. 1a).

The paper's static partitioner knows the sparsity pattern at compile time
and exploits it twice:

1. it splits the contraction (``k``) dimension at **uneven** positions so
   every partition holds the *same number of non-zeros* (perfect load
   balance, no runtime redistribution);
2. it re-orders the non-zero values once, at weight-upload time, to match
   the on-device distribution, so no extra exchange is needed at runtime.

On TPU the two consumers of this information are

* the **Pallas grid** -- logical ``b x b`` blocks are packed into MXU-
  aligned tiles; the exact list of non-empty tiles becomes the (compile-
  time constant) grid metadata, so the kernel executes *only* useful
  steps (``pack_tiles``);
* the **mesh** -- the ``model`` axis takes one nnz-balanced k-range each
  (``balanced_k_splits`` + ``shard_blocks_by_k``), so tensor-parallel
  SpMM needs a single output ``psum`` -- the paper's "final reduction
  across tiles", lifted to the pod level.

Everything here runs on host numpy at trace time: it *is* the compile-
time step of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BlockSparseMatrix, check_unique_blocks


@dataclasses.dataclass(frozen=True)
class TilePacking:
    """Logical blocks packed into physical (tm, tk) tiles.

    ``tile_rows/tile_cols`` are host constants listing the non-empty tiles
    in row-major order (every output row-tile is covered -- empty rows get
    one zero tile so the kernel always writes every output block).
    ``num_tiles`` is the static grid extent.
    """

    tile_rows: np.ndarray     # [T] int32
    tile_cols: np.ndarray     # [T] int32
    values: jax.Array         # [T, tm, tk]
    tm: int
    tk: int
    grid: Tuple[int, int]     # (Mt, Kt) tile grid of the full matrix
    shape: Tuple[int, int]    # (m, k) logical shape

    @property
    def num_tiles(self) -> int:
        return int(self.tile_rows.shape[0])

    @property
    def occupancy(self) -> float:
        """Fraction of packed-tile area holding logical non-zero blocks."""
        dense_area = self.num_tiles * self.tm * self.tk
        return float(self._nnz_area) / dense_area if dense_area else 0.0

    # populated by pack_tiles
    _nnz_area: int = 0


@dataclasses.dataclass(frozen=True)
class PackingPlan:
    """One-time host analysis of a static pattern's tile packing.

    Splits ``pack_tiles`` into its two phases: this object is the pattern
    half (pure host metadata, computed once per pattern -- the plan-first
    contract of ``repro.sparse``); ``pack_values`` is the value half (a
    device scatter that re-runs per call while weights train).
    """

    tile_rows: np.ndarray     # [T] int32
    tile_cols: np.ndarray     # [T] int32
    block_slot: np.ndarray    # [nnz] tile-stack slot of each logical block
    in_r: np.ndarray          # [nnz] block row within its tile
    in_c: np.ndarray          # [nnz] block col within its tile
    tm: int
    tk: int
    grid: Tuple[int, int]     # (Mt, Kt)
    shape: Tuple[int, int]    # (m, k)
    block_size: int
    nnz_blocks: int

    @property
    def num_tiles(self) -> int:
        return int(self.tile_rows.shape[0])

    @property
    def occupancy(self) -> float:
        dense_area = self.num_tiles * self.tm * self.tk
        nnz_area = self.nnz_blocks * self.block_size ** 2
        return float(nnz_area) / dense_area if dense_area else 0.0


def plan_packing(row_idx: np.ndarray, col_idx: np.ndarray,
                 shape: Tuple[int, int], block_size: int,
                 tm: int = 128, tk: int = 128) -> PackingPlan:
    """Pattern phase of ``pack_tiles``: which tiles exist and where each
    logical block lands.  Host-only, runs once per pattern."""
    m, k = shape
    b = block_size
    if tm % b or tk % b:
        raise ValueError(f"tile ({tm},{tk}) not divisible by block {b}")
    mt, kt = -(-m // tm), -(-k // tk)
    rpb, cpb = tm // b, tk // b  # logical blocks per tile, each dim

    rows = np.asarray(row_idx)
    cols = np.asarray(col_idx)
    # a duplicate block would be silently summed by pack_values' .add
    # scatter -- every plan path funnels through here, so this is the
    # backstop for patterns built from raw index arrays
    check_unique_blocks(rows, cols, (-(-m // b), -(-k // b)))
    t_r, t_c = rows // rpb, cols // cpb
    lin = t_r * kt + t_c
    uniq = np.unique(lin)
    # coverage: every row-tile must appear at least once
    present_rows = set((uniq // kt).tolist())
    pad = np.asarray([r * kt for r in range(mt) if r not in present_rows],
                     dtype=uniq.dtype)
    uniq = np.sort(np.concatenate([uniq, pad]))
    slot_of = {int(v): i for i, v in enumerate(uniq)}

    return PackingPlan(
        tile_rows=(uniq // kt).astype(np.int32),
        tile_cols=(uniq % kt).astype(np.int32),
        block_slot=np.asarray([slot_of[int(v)] for v in lin], np.int64),
        in_r=(rows % rpb).astype(np.int64),
        in_c=(cols % cpb).astype(np.int64),
        tm=tm, tk=tk, grid=(mt, kt), shape=(m, k), block_size=b,
        nnz_blocks=len(rows))


def pack_values(plan: PackingPlan, values) -> jax.Array:
    """Value phase of ``pack_tiles``: scatter ``[nnz, b, b]`` blocks into
    the ``[T, tm, tk]`` tile stack laid out in kernel-visit order.
    Jit-compatible (metadata is host constants)."""
    b = plan.block_size
    rpb, cpb = plan.tm // b, plan.tk // b
    vals = jnp.asarray(values)
    tiles = jnp.zeros((plan.num_tiles, rpb, b, cpb, b), vals.dtype)
    tiles = tiles.at[jnp.asarray(plan.block_slot), jnp.asarray(plan.in_r),
                     :, jnp.asarray(plan.in_c), :].add(vals)
    return tiles.reshape(plan.num_tiles, plan.tm, plan.tk)


@dataclasses.dataclass(frozen=True)
class SwizzlePlan:
    """Row-swizzle pre-pass (Gale et al. 2020 §5.1, row binning): assign
    row-tiles to ``num_bins`` equal-work bins by sorted-snake dealing
    over their tile counts, so a balanced kernel grid can walk one bin
    per (parallel) grid lane with near-equal steps per lane.

    ``order`` is the swizzled visit order (bins concatenated, row-tiles
    ascending within a bin); ``inverse`` is its inverse permutation --
    the balanced kernels fold it into the output index map (each step
    writes its *original* row-tile), so no runtime un-permute runs.
    """

    order: np.ndarray       # [R] row-tiles in visit order
    inverse: np.ndarray     # [R] inverse permutation of ``order``
    bin_of: np.ndarray      # [R] owning bin per row-tile
    num_bins: int
    steps_per_bin: int      # max per-bin tile count (the padded lane length)
    loads: np.ndarray       # [num_bins] tile count per bin


def plan_swizzle(row_counts: np.ndarray,
                 num_bins: int | None = None) -> SwizzlePlan:
    """Bin row-tiles so per-bin work (tile counts) is equalized.

    Sorted-snake dealing: sort rows by count descending, deal them into
    bins boustrophedon (0..B-1, B-1..0, ...).  For power-law row
    profiles this bounds the max-bin load close to the mean -- the
    row-swizzle load balance of Gale et al. without any runtime cost.
    """
    counts = np.asarray(row_counts, np.int64)
    r = int(counts.size)
    nb = min(int(num_bins) if num_bins else 8, max(r, 1))
    nb = max(nb, 1)
    order_desc = np.argsort(-counts, kind="stable")
    bin_of = np.zeros(r, np.int32)
    for i, row in enumerate(order_desc):
        pos, rnd = i % nb, i // nb
        bin_of[row] = pos if rnd % 2 == 0 else nb - 1 - pos
    loads = np.bincount(bin_of, weights=counts,
                        minlength=nb).astype(np.int64)
    order = np.lexsort((np.arange(r), bin_of))
    inverse = np.argsort(order)
    steps = int(loads.max()) if r else 0
    return SwizzlePlan(order.astype(np.int64), inverse.astype(np.int64),
                       bin_of, nb, steps, loads)


@dataclasses.dataclass(frozen=True)
class BalancedPacking:
    """Swizzle-composed tile packing (plan-first contract): the base
    row-major ``PackingPlan`` (``pack_values`` layout is unchanged) plus
    the per-bin visit schedule the balanced kernels prefetch.

    ``visit_slot[g, s]`` is the tile-stack slot bin ``g`` multiplies at
    step ``s`` -- or ``base.num_tiles``, the appended all-zero pad tile,
    once the bin's real work is exhausted.  Pad steps keep the bin's
    last real row so the walk's flush fires once, at the lane end.
    ``visit_rows`` carries *original* row-tile ids: the inverse swizzle
    permutation is applied to the output by construction.
    """

    base: PackingPlan
    swizzle: SwizzlePlan
    visit_slot: np.ndarray   # [num_bins, steps] int32
    visit_rows: np.ndarray   # [num_bins, steps] int32 (original row-tiles)
    visit_cols: np.ndarray   # [num_bins, steps] int32

    @property
    def num_bins(self) -> int:
        return int(self.visit_slot.shape[0])

    @property
    def steps_per_bin(self) -> int:
        return int(self.visit_slot.shape[1])


def plan_packing_balanced(row_idx: np.ndarray, col_idx: np.ndarray,
                          shape: Tuple[int, int], block_size: int,
                          tm: int = 128, tk: int = 128,
                          num_bins: int | None = None) -> BalancedPacking:
    """Pattern phase of the balanced (row-swizzled) packing: the base
    ``plan_packing`` metadata plus the snake-binned visit schedule.
    Host-only, runs once per pattern."""
    base = plan_packing(row_idx, col_idx, shape, block_size, tm, tk)
    mt = base.grid[0]
    counts = np.bincount(base.tile_rows, minlength=mt)
    sw = plan_swizzle(counts, num_bins)
    nb, steps = sw.num_bins, sw.steps_per_bin
    # base.tile_rows is sorted row-major: each row-tile's slots are one
    # contiguous range
    starts = np.searchsorted(base.tile_rows, np.arange(mt), side="left")
    ends = np.searchsorted(base.tile_rows, np.arange(mt), side="right")
    visit_slot = np.full((nb, steps), base.num_tiles, np.int32)  # pad tile
    visit_rows = np.zeros((nb, steps), np.int32)
    visit_cols = np.zeros((nb, steps), np.int32)
    for g in range(nb):
        rows_g = np.flatnonzero(sw.bin_of == g)
        slots = np.concatenate([np.arange(starts[r], ends[r])
                                for r in rows_g]) if rows_g.size else \
            np.zeros(0, np.int64)
        t = slots.size
        visit_slot[g, :t] = slots
        visit_rows[g, :t] = base.tile_rows[slots]
        visit_cols[g, :t] = base.tile_cols[slots]
        if t:                      # pad keeps the lane's last real row
            visit_rows[g, t:] = visit_rows[g, t - 1]
    return BalancedPacking(base, sw, visit_slot, visit_rows, visit_cols)


def pack_tiles(bsr: BlockSparseMatrix, tm: int = 128, tk: int = 128) -> TilePacking:
    """Pack a static BSR matrix into MXU-aligned dense tiles.

    This is the TPU analogue of PopSparse's compile-time value re-ordering:
    the returned ``values`` tensor is laid out exactly in kernel-visit
    order, and the index arrays are baked into the grid as scalar-prefetch
    constants.  (Composition of ``plan_packing`` + ``pack_values``.)
    """
    if not bsr.is_static:
        raise ValueError("pack_tiles requires a static (host-indexed) pattern")
    meta = plan_packing(bsr.row_idx, bsr.col_idx, bsr.shape,
                        bsr.block_size, tm, tk)
    tiles = pack_values(meta, bsr.values)
    packing = TilePacking(meta.tile_rows, meta.tile_cols, tiles, tm, tk,
                          meta.grid, bsr.shape)
    object.__setattr__(packing, "_nnz_area", int(bsr.nnz_blocks)
                       * bsr.block_size ** 2)
    return packing


@dataclasses.dataclass(frozen=True)
class TransposePlan:
    """One-time host analysis of a pattern's transpose (plan-first
    contract): the backward transposed-SpMM plans run on ``W^T``'s
    pattern, which is the same nnz blocks re-sorted row-major in
    ``(col, row)`` coordinates with each block transposed.  ``perm`` is
    the value permutation (applied per call while weights train);
    ``row_idx``/``col_idx`` are the transposed pattern's host metadata.
    """

    perm: np.ndarray        # [nnz] source block for transposed slot z
    row_idx: np.ndarray     # [nnz] int32 (block rows of W^T == cols of W)
    col_idx: np.ndarray     # [nnz] int32 (block cols of W^T == rows of W)
    shape: Tuple[int, int]  # (k, m) -- the transposed logical shape
    block_size: int


def plan_transpose(row_idx: np.ndarray, col_idx: np.ndarray,
                   shape: Tuple[int, int],
                   block_size: int) -> TransposePlan:
    """Pattern phase of the backward transpose: computed once per
    pattern, shared by every sibling dL/dx plan on it.  The value phase
    (``values[perm].transpose(0, 2, 1)``) is a per-call device gather."""
    rows = np.asarray(row_idx, np.int64)
    cols = np.asarray(col_idx, np.int64)
    perm = np.lexsort((rows, cols))      # row-major in (col, row) coords
    m, k = shape
    return TransposePlan(perm, cols[perm].astype(np.int32),
                         rows[perm].astype(np.int32), (k, m), block_size)


def apply_transpose(plan: TransposePlan, values) -> jax.Array:
    """Value phase: permute the ``[nnz, b, b]`` blocks into the
    transposed pattern's row-major order and transpose each block.
    Jit-compatible (metadata is host constants)."""
    vals = jnp.asarray(values)
    return vals[jnp.asarray(plan.perm)].transpose(0, 2, 1)


def balanced_k_splits(block_mask: np.ndarray, q: int) -> np.ndarray:
    """Choose ``q`` *uneven* split positions over block-columns balancing nnz.

    Returns boundaries ``[q+1]`` over the block-column index (``k`` dim),
    with ``boundaries[0]=0`` and ``boundaries[q]=Kb``.  Faithful to paper
    Fig. 1a: split positions adapt to the known pattern.
    """
    col_nnz = np.asarray(block_mask, bool).sum(axis=0)
    kb = len(col_nnz)
    if q > kb:
        raise ValueError(f"q={q} partitions > {kb} block columns")
    total = int(col_nnz.sum())
    prefix = np.concatenate([[0], np.cumsum(col_nnz)])
    # target nnz per partition; walk boundaries greedily on the prefix
    # sum.  A boundary that lands on a *plateau* of the prefix (a run of
    # empty columns) is free to slide anywhere on the plateau without
    # changing any shard's nnz -- slide it toward the even-split
    # position so empty columns spread across shards instead of piling
    # every zero column (plus forced 1-column slivers) onto the last
    # shards when the mass sits in a prefix/suffix of the columns.
    boundaries = [0]
    for p in range(1, q):
        target = total * p / q
        e = int(round(kb * p / q))           # even-split position
        jlo = int(np.searchsorted(prefix, target, side="left"))
        jhi = jlo
        while jhi + 1 <= kb and prefix[jhi + 1] == prefix[jlo]:
            jhi += 1
        j = min(max(e, jlo), jhi)
        # leave room for the remaining partitions (each needs >= 1 col)
        j = max(j, boundaries[-1] + 1)
        hi = kb - (q - p)
        if j > hi:
            # forced clamp: whatever we ceded is empty column tail --
            # fall back toward the even position rather than hugging hi
            j = max(boundaries[-1] + 1, min(hi, e))
        boundaries.append(j)
    boundaries.append(kb)
    return np.asarray(boundaries, np.int64)


def even_k_splits(kb: int, q: int) -> np.ndarray:
    """Dynamic-mode fixed equal splits (paper §3.3): last may be smaller."""
    size = -(-kb // q)
    return np.minimum(np.arange(q + 1) * size, kb).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class ShardedBlocks:
    """Per-mesh-shard stacked block arrays for TP SpMM via shard_map.

    Arrays are stacked on a leading ``q`` axis (to be sharded over the
    ``model`` mesh axis) and padded to a common ``slots`` length with
    zero-valued blocks at (row 0, col boundaries[i]) so padded slots
    contribute exactly zero.
    """

    values: jax.Array    # [q, slots, b, b]
    row_idx: jax.Array   # [q, slots] int32
    col_idx: jax.Array   # [q, slots] int32 (GLOBAL block-col index)
    boundaries: np.ndarray
    shape: Tuple[int, int]
    block_size: int
    real_counts: np.ndarray  # [q] nnz blocks actually owned per shard

    @property
    def q(self) -> int:
        return int(self.values.shape[0])

    @property
    def slots(self) -> int:
        return int(self.values.shape[1])


@dataclasses.dataclass(frozen=True)
class KShardPlan:
    """One-time host analysis of the nnz-balanced k-partition.

    Pattern half of ``shard_blocks_by_k`` (plan-first contract): split
    boundaries + per-block shard/slot destinations, all host constants.
    ``apply_k_shards`` is the per-call value half.
    """

    boundaries: np.ndarray   # [q+1] block-col split positions
    row_idx: np.ndarray      # [q, slots] int32 (host; padding row 0)
    col_idx: np.ndarray      # [q, slots] int32 (padding -> owned column)
    dst_q: np.ndarray        # [nnz] destination shard, in src_order
    dst_slot: np.ndarray     # [nnz] destination slot, in src_order
    src_order: np.ndarray    # [nnz] source permutation (stable by owner)
    shape: Tuple[int, int]
    block_size: int
    real_counts: np.ndarray  # [q] nnz blocks actually owned per shard
    balanced: bool = True    # nnz-balanced uneven splits vs fixed even

    @property
    def q(self) -> int:
        return int(self.row_idx.shape[0])

    @property
    def slots(self) -> int:
        return int(self.row_idx.shape[1])


def plan_k_shards(bsr: BlockSparseMatrix, q: int,
                  *, balanced: bool = True) -> KShardPlan:
    """Pattern phase of ``shard_blocks_by_k``: boundaries + destinations."""
    if not bsr.is_static:
        raise ValueError("plan_k_shards requires static pattern")
    mask = bsr.block_mask()
    mb, kb = mask.shape
    if q < 1 or q > kb:
        raise ValueError(f"q={q} k-shards outside [1, {kb} block "
                         f"columns] for shape {bsr.shape} at block "
                         f"{bsr.block_size}")
    bounds = (balanced_k_splits(mask, q) if balanced else even_k_splits(kb, q))
    rows = np.asarray(bsr.row_idx)
    cols = np.asarray(bsr.col_idx)
    owner = np.searchsorted(bounds, cols, side="right") - 1
    counts = np.bincount(owner, minlength=q)
    slots = int(counts.max()) if len(counts) else 1
    slots = max(slots, 1)

    row_out = np.zeros((q, slots), np.int32)
    col_out = np.zeros((q, slots), np.int32)
    for s in range(q):
        col_out[s, :] = bounds[s]  # padding points at an owned column
    fill = np.zeros(q, np.int64)
    src_order = np.argsort(owner, kind="stable")
    dst_q = owner[src_order]
    dst_slot = np.empty_like(dst_q)
    for i, qq in enumerate(dst_q):
        dst_slot[i] = fill[qq]
        fill[qq] += 1
    row_out[dst_q, dst_slot] = rows[src_order]
    col_out[dst_q, dst_slot] = cols[src_order]
    return KShardPlan(bounds, row_out, col_out, dst_q, dst_slot, src_order,
                      bsr.shape, bsr.block_size, counts, balanced)


def apply_k_shards(plan: KShardPlan, values) -> ShardedBlocks:
    """Value phase: scatter ``[nnz, b, b]`` blocks into the stacked
    ``[q, slots, b, b]`` shard layout.  Jit-compatible."""
    b = plan.block_size
    vals = jnp.asarray(values)
    val_out = jnp.zeros((plan.q, plan.slots, b, b), vals.dtype)
    val_out = val_out.at[jnp.asarray(plan.dst_q),
                         jnp.asarray(plan.dst_slot)].set(
        vals[jnp.asarray(plan.src_order)])
    return ShardedBlocks(val_out, jnp.asarray(plan.row_idx),
                         jnp.asarray(plan.col_idx), plan.boundaries,
                         plan.shape, b, plan.real_counts)


def shard_blocks_by_k(bsr: BlockSparseMatrix, q: int,
                      *, balanced: bool = True) -> ShardedBlocks:
    """Distribute blocks over ``q`` k-partitions (static partitioner output).

    ``balanced=True`` uses nnz-balanced uneven splits (static mode);
    ``balanced=False`` uses fixed equal splits (dynamic mode) -- useful to
    measure the imbalance cost the paper attributes to dynamic sparsity.
    (Composition of ``plan_k_shards`` + ``apply_k_shards``.)
    """
    return apply_k_shards(plan_k_shards(bsr, q, balanced=balanced),
                          bsr.values)


@dataclasses.dataclass(frozen=True)
class EvolvePlan:
    """One-time host analysis of a pattern *evolution* (old -> new).

    Pattern half of a RigL-style topology update on a static plan
    (plan-first contract, same split as ``plan_packing``/``pack_values``):
    for each block of the new pattern, the source slot in the old values
    stack, or -1 for a freshly grown block.  ``apply_evolution`` is the
    per-call value half -- a device gather where carried blocks keep
    their values exactly, grown blocks start at zero, and dropped blocks
    simply have no destination (RigL semantics, Evci et al. 2019 §3).
    """

    src_slot: np.ndarray      # [nnz_new] int64; -1 marks a grown block
    carried: int              # blocks present in both patterns
    dropped: int              # old blocks absent from the new pattern
    grown: int                # new blocks absent from the old pattern


def plan_evolution(old_rows: np.ndarray, old_cols: np.ndarray,
                   new_rows: np.ndarray, new_cols: np.ndarray,
                   grid: Tuple[int, int]) -> EvolvePlan:
    """Map each new-pattern block to its old values slot (host, once per
    topology step).  Neither pattern needs to be sorted; both must be
    duplicate-free (``check_unique_blocks``)."""
    mb, kb = grid
    check_unique_blocks(old_rows, old_cols, grid)
    check_unique_blocks(new_rows, new_cols, grid)
    old_lin = np.asarray(old_rows, np.int64) * kb + np.asarray(old_cols,
                                                               np.int64)
    new_lin = np.asarray(new_rows, np.int64) * kb + np.asarray(new_cols,
                                                               np.int64)
    if old_lin.size:
        order = np.argsort(old_lin)
        pos = np.searchsorted(old_lin[order], new_lin)
        pos_c = np.minimum(pos, old_lin.size - 1)
        found = old_lin[order][pos_c] == new_lin
        src = np.where(found, order[pos_c], -1).astype(np.int64)
    else:
        src = np.full(new_lin.size, -1, np.int64)
    carried = int((src >= 0).sum())
    return EvolvePlan(src, carried,
                      int(old_lin.size) - carried,
                      int(new_lin.size) - carried)


def apply_evolution(plan: EvolvePlan, old_values) -> jax.Array:
    """Value half of a topology update: carry ``[nnz_old, b, b]`` blocks
    into the new pattern's ``[nnz_new, b, b]`` stack (grown blocks
    zero-initialized).  Jit-compatible -- the map is a host constant."""
    vals = jnp.asarray(old_values)
    nnz_new = int(plan.src_slot.shape[0])
    if vals.shape[0] == 0:
        return jnp.zeros((nnz_new,) + vals.shape[1:], vals.dtype)
    src = jnp.asarray(plan.src_slot)
    gathered = vals[jnp.clip(src, 0, vals.shape[0] - 1)]
    keep = (src >= 0).reshape((-1,) + (1,) * (vals.ndim - 1))
    return jnp.where(keep, gathered, jnp.zeros_like(gathered))


def balance_report(counts: np.ndarray) -> dict:
    """Load-balance diagnostics (used by tests + benchmarks)."""
    counts = np.asarray(counts)
    if counts.size == 0:
        # degenerate pattern (no owners): a zeroed report, not a crash
        return {"max": 0, "min": 0, "mean": 0.0, "imbalance": 0.0,
                "padding_waste": 0.0, "frac_empty": 0.0, "cv": 0.0}
    mx, mn, mean = counts.max(), counts.min(), counts.mean()
    return {
        "max": int(mx), "min": int(mn), "mean": float(mean),
        # max/mean alone hides all-empty owners (min=0 still reports a
        # finite ratio): frac_empty + cv surface that skew honestly
        "imbalance": float(mx / mean) if mean else 0.0,
        "padding_waste": float((mx * len(counts) - counts.sum())
                               / max(1, counts.sum())),
        "frac_empty": float((counts == 0).mean()),
        "cv": float(counts.std() / mean) if mean else 0.0,
    }
