"""Static block-sparse matmul (PopSparse §3.2) -- public API.

``Y = (M ⊙ W) @ X`` with the pattern ``M`` fixed at compile time.  The
pattern's index arrays are host numpy constants that get *folded into the
program*, which is the TPU analogue of PopSparse building the Poplar graph
from the known pattern: zero metadata traffic at runtime, exact grid
sizing, and one-time value re-ordering (see ``partitioner.pack_tiles``).

Two execution backends:

* ``"xla"``    -- gather / block-einsum / segment-sum formulation.  Pure
  jnp, shardable under pjit, used on CPU, in the 512-device dry-run and
  as the roofline cost model.  FLOPs are exactly ``2·nnz·b²·n``.
* ``"pallas"`` -- the ``kernels/bsmm`` TPU kernel (MXU-tiled, scalar-
  prefetch metadata).  Validated against ``"xla"`` in interpret mode.

The op is differentiable: backward needs the transpose SpMM (for ``dX``)
and a block-sampled dense-dense product (SDDMM, for ``dW``) -- both keep
the same static pattern, so sparse *training* stays sparse end-to-end.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BlockSparseMatrix


def _check_static(bsr: BlockSparseMatrix):
    if not bsr.is_static:
        raise ValueError(
            "static_sparse API requires a compile-time pattern; use "
            "repro.core.dynamic_sparse for runtime patterns")


# ---------------------------------------------------------------------------
# XLA path primitives (functions of (values, x) with indices closed over)
# ---------------------------------------------------------------------------

def _spmm_fwd_impl(values, x, *, row_idx, col_idx, grid, block_size):
    """Y[m,n] = sum_z values[z] @ X_block[col[z]] scattered to rows."""
    mb, kb = grid
    b = block_size
    n = x.shape[-1]
    xb = x.reshape(kb, b, n)
    gathered = jnp.take(xb, col_idx, axis=0)               # [nnz, b, n]
    partial = jnp.einsum("zab,zbn->zan", values, gathered)
    y = jax.ops.segment_sum(partial, row_idx, num_segments=mb,
                            indices_are_sorted=True)
    return y.reshape(mb * b, n)


def _spmm_t_impl(values, dy, *, row_idx, col_idx, grid, block_size):
    """X-grad: (M⊙W)^T @ dY  -- gather rows, scatter cols."""
    mb, kb = grid
    b = block_size
    n = dy.shape[-1]
    dyb = dy.reshape(mb, b, n)
    gathered = jnp.take(dyb, row_idx, axis=0)              # [nnz, b, n]
    partial = jnp.einsum("zab,zan->zbn", values, gathered)  # W_z^T @ dY_z
    dx = jax.ops.segment_sum(partial, col_idx, num_segments=kb)
    return dx.reshape(kb * b, n)


def _sddmm_impl(dy, x, *, row_idx, col_idx, grid, block_size):
    """W-grad: block-sampled dY @ X^T -- only masked blocks computed."""
    mb, kb = grid
    b = block_size
    n = x.shape[-1]
    dyb = dy.reshape(mb, b, n)
    xb = x.reshape(kb, b, n)
    dyg = jnp.take(dyb, row_idx, axis=0)                   # [nnz, b, n]
    xg = jnp.take(xb, col_idx, axis=0)                     # [nnz, b, n]
    return jnp.einsum("zan,zbn->zab", dyg, xg)             # [nnz, b, b]


def make_spmm(row_idx: np.ndarray, col_idx: np.ndarray,
              grid: Tuple[int, int], block_size: int):
    """Build a differentiable ``(values, x) -> y`` SpMM for a fixed pattern."""
    row_idx = np.asarray(row_idx, np.int32)
    col_idx = np.asarray(col_idx, np.int32)
    kw = dict(row_idx=row_idx, col_idx=col_idx, grid=grid,
              block_size=block_size)

    @jax.custom_vjp
    def spmm(values, x):
        return _spmm_fwd_impl(values, x, **kw)

    def fwd(values, x):
        return spmm(values, x), (values, x)

    def bwd(res, dy):
        values, x = res
        dvalues = _sddmm_impl(dy, x, **kw)
        dx = _spmm_t_impl(values, dy, **kw)
        return dvalues.astype(values.dtype), dx.astype(x.dtype)

    spmm.defvjp(fwd, bwd)
    return spmm


def make_spmm_t(row_idx: np.ndarray, col_idx: np.ndarray,
                grid: Tuple[int, int], block_size: int):
    """Build ``(values, dy) -> (M⊙W)^T @ dY`` for a fixed pattern -- the
    dL/dx backward product, promoted to a first-class builder so the
    plan layer can race it as a dispatch candidate (the transposed-SpMM
    half of sparse training, paper §3.2)."""
    kw = dict(row_idx=np.asarray(row_idx, np.int32),
              col_idx=np.asarray(col_idx, np.int32), grid=grid,
              block_size=block_size)
    return lambda values, dy: _spmm_t_impl(values, dy, **kw)


def make_sddmm(row_idx: np.ndarray, col_idx: np.ndarray,
               grid: Tuple[int, int], block_size: int):
    """Build ``(dy, x) -> [nnz, b, b]`` block-sampled ``dY @ X^T`` for a
    fixed pattern -- the dL/dvalues backward product (block SDDMM),
    promoted like ``make_spmm_t`` for the backward dispatch race."""
    kw = dict(row_idx=np.asarray(row_idx, np.int32),
              col_idx=np.asarray(col_idx, np.int32), grid=grid,
              block_size=block_size)
    return lambda dy, x: _sddmm_impl(dy, x, **kw)


# ---------------------------------------------------------------------------
# Public convenience API
# ---------------------------------------------------------------------------

def spmm(bsr: BlockSparseMatrix, x: jax.Array, *,
         backend: str = "xla", interpret: bool = False) -> jax.Array:
    """``Y = (M ⊙ W) @ X`` with ``X: [k, n]`` -> ``Y: [m, n]``.

    DEPRECATED shim: prefer ``repro.sparse.plan(bsr, n)`` -- this
    builds (or fetches) the corresponding forced-route plan and calls
    it, so the pattern analysis runs once per pattern, not per call."""
    _check_static(bsr)
    if x.shape[0] != bsr.shape[1]:
        raise ValueError(f"X rows {x.shape[0]} != k {bsr.shape[1]}")
    route = {"xla": "static_xla", "pallas": "static_pallas"}.get(backend)
    if route is None:
        raise ValueError(f"unknown backend {backend!r}")
    from repro import sparse as sparse_api
    p = sparse_api.plan(bsr, int(x.shape[1]),
                        ctx=sparse_api.PlanContext(mode=route,
                                                   interpret=interpret))
    return p(jnp.asarray(bsr.values), x)


def spmm_nt(bsr: BlockSparseMatrix, x: jax.Array, *,
            backend: str = "xla", interpret: bool = False) -> jax.Array:
    """Activation-major form: ``x: [..., k] -> [..., m]`` (y = x @ W^T)."""
    _check_static(bsr)
    lead = x.shape[:-1]
    k = bsr.shape[1]
    x2 = x.reshape(-1, k).T                                # [k, N]
    y = spmm(bsr, x2, backend=backend, interpret=interpret)
    return y.T.reshape(*lead, bsr.shape[0])


def spmm_t(bsr: BlockSparseMatrix, dy: jax.Array) -> jax.Array:
    """Transpose product ``(M⊙W)^T @ dY`` (exposed for tests/serving)."""
    _check_static(bsr)
    return _spmm_t_impl(jnp.asarray(bsr.values), dy,
                        row_idx=np.asarray(bsr.row_idx, np.int32),
                        col_idx=np.asarray(bsr.col_idx, np.int32),
                        grid=bsr.grid, block_size=bsr.block_size)


def sddmm(bsr: BlockSparseMatrix, dy: jax.Array, x: jax.Array) -> jax.Array:
    """Block-sampled ``dY @ X^T`` restricted to the pattern of ``bsr``."""
    _check_static(bsr)
    return _sddmm_impl(dy, x,
                       row_idx=np.asarray(bsr.row_idx, np.int32),
                       col_idx=np.asarray(bsr.col_idx, np.int32),
                       grid=bsr.grid, block_size=bsr.block_size)


@functools.lru_cache(maxsize=None)
def _cached_pattern_fn(row_bytes: bytes, col_bytes: bytes,
                       grid: Tuple[int, int], block_size: int):
    row = np.frombuffer(row_bytes, np.int32)
    col = np.frombuffer(col_bytes, np.int32)
    return make_spmm(row, col, grid, block_size)


def spmm_cached(bsr: BlockSparseMatrix, x: jax.Array) -> jax.Array:
    """Like ``spmm`` but caches the pattern-specialized function (avoids
    re-building the custom_vjp wrapper on every call in eager loops)."""
    _check_static(bsr)
    f = _cached_pattern_fn(np.asarray(bsr.row_idx, np.int32).tobytes(),
                           np.asarray(bsr.col_idx, np.int32).tobytes(),
                           bsr.grid, bsr.block_size)
    return f(jnp.asarray(bsr.values), x)


# ---------------------------------------------------------------------------
# Kernel contracts (tools/lint/contracts.py cross-checks these against
# the dispatch admissibility gates)
# ---------------------------------------------------------------------------

from repro.kernels.contract import KernelContract, register as _register_contract  # noqa: E402

# gather/einsum XLA formulations: any BSR pattern (m, k block-multiples
# by construction), no tile grid, differentiable, run on every backend
CONTRACT = _register_contract(KernelContract(
    kernel="static_xla",
    routes=("static_xla",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=1024,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="no tile grid: one gather + einsum + segment-sum program",
    capacity="exact",
    pallas=False,
))

SDDMM_CONTRACT = _register_contract(KernelContract(
    kernel="sddmm_xla",
    routes=("sddmm_xla",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=1024,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="no tile grid: per-pattern-block gather + einsum from make_sddmm",
    capacity="exact",
    pallas=False,
))
