"""Block pruning + dynamic sparse training utilities.

The paper's closing discussion (§6) calls for "effective block sparse
pruning algorithms"; this module supplies the two standard families so the
framework's sparse configs are trainable end-to-end:

* **one-shot magnitude block pruning** (Zhu & Gupta 2017 lifted to blocks)
  -- produces *static* patterns for ``SparseLinear``;
* **RigL-style block prune/regrow** (Evci et al. 2019, block granularity)
  -- drives the *dynamic* mode: the mask is runtime data, capacity is
  bounded by ``d_max`` exactly as dynamic PopSparse requires.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib


def magnitude_block_prune(dense_w: np.ndarray, block_size: int,
                          density: float) -> np.ndarray:
    """One-shot static pattern: keep top-|density| blocks by L1 norm."""
    return masks_lib.magnitude_block_mask(np.asarray(dense_w), block_size,
                                          density)


def _block_scores(x: jax.Array, b: int) -> jax.Array:
    m, k = x.shape
    return jnp.abs(x).reshape(m // b, b, k // b, b).sum(axis=(1, 3))


def rigl_update(w: jax.Array, grad: jax.Array, mask: jax.Array, *,
                block_size: int, fraction: float,
                rng: jax.Array) -> jax.Array:
    """One RigL block-sparse topology update (jit-compatible).

    Drop the ``fraction`` lowest-|W| active blocks, regrow the same number
    of inactive blocks with the largest |grad| -- total active count (and
    therefore ``d_max`` capacity) is preserved, so the dynamic-sparse
    compiled program never changes shape.  ``rng`` breaks ties among
    equal grow scores (RigL: early in training many inactive blocks have
    exactly zero gradient -- plain argsort would bias regrowth toward
    low block indices every step).
    """
    b = block_size
    w_score = _block_scores(w, b)
    g_score = _block_scores(grad, b)
    active = mask.astype(bool)
    total = active.size
    n_active = jnp.sum(active.astype(jnp.int32))
    n_inactive = jnp.int32(total) - n_active
    # clamp to the movable pool: at density ~1 (or fraction ~1) there
    # are fewer inactive blocks than drop candidates -- an unclamped
    # n_move would drop more blocks than it can grow, silently shrinking
    # the active count and breaking the d_max capacity invariant
    n_move = (n_active.astype(jnp.float32) * fraction).astype(jnp.int32)
    n_move = jnp.clip(n_move, 0, jnp.minimum(n_active, n_inactive))

    flat_active = active.reshape(-1)
    # drop: lowest |W| among active (deterministic -- magnitudes of live
    # weights are continuous, ties carry no information)
    drop_key = jnp.where(flat_active, w_score.reshape(-1), jnp.inf)
    drop_order = jnp.argsort(drop_key)
    drop_rank = jnp.argsort(drop_order)           # rank of each block
    dropped = flat_active & (drop_rank < n_move)
    # grow: highest |grad| among inactive, ties broken by rng -- sort a
    # random permutation of the keys (stable argsort keeps equal keys in
    # shuffled order) and map ranks back through the permutation
    grow_key = jnp.where(~flat_active, g_score.reshape(-1), -jnp.inf)
    shuffle = jax.random.permutation(rng, total)
    grow_order = shuffle[jnp.argsort(-grow_key[shuffle])]
    grow_rank = jnp.argsort(grow_order)
    grown = (~flat_active) & (grow_rank < n_move)

    new_mask = (flat_active & ~dropped) | grown
    return new_mask.reshape(mask.shape)


def apply_block_mask(w: jax.Array, mask: jax.Array, block_size: int) -> jax.Array:
    """Zero out masked-away blocks of a dense master weight."""
    m, k = w.shape
    b = block_size
    mk = jnp.repeat(jnp.repeat(mask.astype(w.dtype), b, axis=0), b, axis=1)
    return w * mk


def density_schedule(step: int, *, start_step: int, end_step: int,
                     initial: float, final: float) -> float:
    """Cubic density decay (Zhu & Gupta 2017) for gradual block pruning."""
    if step <= start_step:
        return initial
    if step >= end_step:
        return final
    t = (step - start_step) / max(1, end_step - start_step)
    return final + (initial - final) * (1 - t) ** 3
