"""Block-mask generators.

All masks are **host** ``numpy`` bool arrays over the block grid
``[m/b, k/b]`` -- they describe compile-time (static) sparsity patterns in
the sense of PopSparse §3.2.  Runtime (dynamic) patterns are produced on
device by the dynamic encoder in ``dynamic_sparse.py``.
"""
from __future__ import annotations

import numpy as np


def _grid(m: int, k: int, b: int) -> tuple[int, int]:
    if m % b or k % b:
        raise ValueError(f"({m},{k}) not divisible by block {b}")
    return m // b, k // b


def random_block_mask(m: int, k: int, b: int, density: float, *,
                      seed: int = 0, clustered: bool = False) -> np.ndarray:
    """Uniform random block mask with exactly ``round(density*Mb*Kb)`` blocks.

    ``clustered=True`` biases block placement into contiguous 128-aligned
    tiles -- the TPU-relevant regime discussed in DESIGN.md §2 (tile
    occupancy), which has no IPU analogue.
    """
    mb, kb = _grid(m, k, b)
    total = mb * kb
    # density=0.0 means *empty*, not "at least one block"
    nnz = 0 if density == 0.0 else max(1, int(round(density * total)))
    nnz = min(nnz, total)
    rng = np.random.default_rng(seed)
    mask = np.zeros((mb, kb), bool)
    if nnz == 0:
        return mask
    if not clustered:
        flat = rng.choice(total, size=nnz, replace=False)
        mask.flat[flat] = True
        return mask
    # clustered: fill whole (tile x tile) super-blocks first
    tile = max(1, 128 // b)
    mt, kt = -(-mb // tile), -(-kb // tile)
    per_tile = min(tile, mb) * min(tile, kb)
    n_tiles = max(1, nnz // per_tile)
    choice = rng.choice(mt * kt, size=min(n_tiles, mt * kt), replace=False)
    placed = 0
    for c in choice:
        ti, tj = divmod(c, kt)
        r0, c0 = ti * tile, tj * tile
        sub = mask[r0:r0 + tile, c0:c0 + tile]
        sub[...] = True
        placed += sub.size
        if placed >= nnz:
            break
    # trim overshoot with the seeded rng: clearing the highest-index set
    # bits would systematically deplete bottom-right tiles
    extra = int(mask.sum()) - nnz
    if extra > 0:
        on = np.flatnonzero(mask)
        mask.flat[rng.choice(on, size=extra, replace=False)] = False
    return mask


def _rows_from_profile(weights: np.ndarray, nnz: int, kb: int,
                       rng: np.random.Generator) -> np.ndarray:
    """Allocate ``nnz`` blocks over rows proportionally to ``weights``
    (largest-remainder rounding, per-row cap ``kb``)."""
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    ideal = w * nnz
    counts = np.floor(ideal).astype(np.int64)
    counts = np.minimum(counts, kb)
    rem = nnz - int(counts.sum())
    # hand out the remainder by largest fractional part, skipping rows
    # already at the kb cap (shuffle first so ties break by the rng)
    order = rng.permutation(len(w))
    order = order[np.argsort(-(ideal - np.floor(ideal))[order],
                             kind="stable")]
    for r in order:
        if rem <= 0:
            break
        if counts[r] < kb:
            counts[r] += 1
            rem -= 1
    while rem > 0:       # every high-remainder row capped: spill anywhere
        for r in order:
            if rem <= 0:
                break
            if counts[r] < kb:
                counts[r] += 1
                rem -= 1
    return counts


def _mask_from_row_counts(counts: np.ndarray, mb: int, kb: int,
                          rng: np.random.Generator) -> np.ndarray:
    mask = np.zeros((mb, kb), bool)
    for r in range(mb):
        c = int(counts[r])
        if c > 0:
            mask[r, rng.choice(kb, size=c, replace=False)] = True
    return mask


def power_law_block_mask(m: int, k: int, b: int, density: float, *,
                         alpha: float = 1.2, seed: int = 0) -> np.ndarray:
    """Skewed block mask with a power-law row profile (row ``i`` gets
    weight ``(i+1)^-alpha``, rows shuffled).  This is the realistic-DL
    regime of Gale et al. 2020 (arxiv 2006.10901): a few hot rows hold
    most of the nnz, so uniform tile walks serialize on them -- the
    pattern family the row-swizzle pre-pass exists for."""
    mb, kb = _grid(m, k, b)
    total = mb * kb
    nnz = 0 if density == 0.0 else max(1, int(round(density * total)))
    nnz = min(nnz, total)
    rng = np.random.default_rng(seed)
    if nnz == 0:
        return np.zeros((mb, kb), bool)
    weights = (np.arange(1, mb + 1, dtype=np.float64)) ** -alpha
    weights = weights[rng.permutation(mb)]
    counts = _rows_from_profile(weights, nnz, kb, rng)
    return _mask_from_row_counts(counts, mb, kb, rng)


def dlmc_block_mask(m: int, k: int, b: int, density: float, *,
                    sigma: float = 1.0, seed: int = 0) -> np.ndarray:
    """DLMC-style row-profile sampling: per-row nnz drawn from a
    lognormal profile (Gale et al.'s Deep Learning Matrix Collection
    shows pruned-transformer rows are heavy-tailed, not uniform).
    ``sigma`` controls the spread; ``sigma=0`` degenerates to uniform
    rows."""
    mb, kb = _grid(m, k, b)
    total = mb * kb
    nnz = 0 if density == 0.0 else max(1, int(round(density * total)))
    nnz = min(nnz, total)
    rng = np.random.default_rng(seed)
    if nnz == 0:
        return np.zeros((mb, kb), bool)
    weights = rng.lognormal(mean=0.0, sigma=sigma, size=mb)
    counts = _rows_from_profile(weights, nnz, kb, rng)
    return _mask_from_row_counts(counts, mb, kb, rng)


def banded_block_mask(m: int, k: int, b: int, bandwidth_blocks: int) -> np.ndarray:
    """Block band matrix: |i - j| <= bandwidth_blocks."""
    mb, kb = _grid(m, k, b)
    i = np.arange(mb)[:, None]
    j = np.arange(kb)[None, :]
    return np.abs(i - j) <= bandwidth_blocks


def butterfly_block_mask(m: int, k: int, b: int) -> np.ndarray:
    """Pixelated-butterfly style mask (Dao et al. 2021, cited in paper §6):
    union of a block-diagonal and a flat butterfly (stride) pattern."""
    mb, kb = _grid(m, k, b)
    n = max(mb, kb)
    mask = np.zeros((mb, kb), bool)
    i = np.arange(mb)
    mask[i, np.minimum(i, kb - 1)] = True
    stride = 1
    while stride < n:
        j = (np.arange(mb) ^ stride)
        ok = j < kb
        mask[np.arange(mb)[ok], j[ok]] = True
        stride *= 2
    return mask


def local_global_attention_mask(q_blocks: int, kv_blocks: int, *,
                                window_blocks: int, global_blocks: int,
                                causal: bool = True) -> np.ndarray:
    """Local+global block attention mask (BigBird/Longformer family).

    This is how the paper's *static* block sparsity powers the sub-
    quadratic ``long_500k`` configs (DESIGN.md §3): each query block
    attends to a local band plus the first ``global_blocks`` key blocks.
    """
    i = np.arange(q_blocks)[:, None]
    j = np.arange(kv_blocks)[None, :]
    local = np.abs(i - j) < window_blocks
    glob = j < global_blocks
    mask = local | glob
    if causal:
        mask &= j <= i
    return mask


def magnitude_block_mask(weights: np.ndarray, b: int, density: float) -> np.ndarray:
    """Top-``density`` blocks by L1 block magnitude (structured pruning,
    paper §1 'block (Gray et al., 2017)')."""
    m, k = weights.shape
    mb, kb = _grid(m, k, b)
    blocked = np.abs(np.asarray(weights, np.float64)).reshape(mb, b, kb, b)
    score = blocked.sum(axis=(1, 3))
    nnz = max(1, int(round(density * mb * kb)))
    thresh_idx = np.argsort(score, axis=None)[::-1][:nnz]
    mask = np.zeros((mb, kb), bool)
    mask.flat[thresh_idx] = True
    return mask


def block_diagonal_mask(mb: int, kb: int, groups: int) -> np.ndarray:
    """Block-diagonal (grouped GEMM) structure -- MoE's sparsity pattern."""
    mask = np.zeros((mb, kb), bool)
    rs, cs = mb // groups, kb // groups
    for g in range(groups):
        mask[g * rs:(g + 1) * rs, g * cs:(g + 1) * cs] = True
    return mask
