"""Sparse-matmul route vocabulary + decision engine (PopSparse §3,
Table 3).

The paper's central claim is that the *right execution strategy* per
(shape, block size, density, dtype) -- static pre-planned vs dynamic
bucketed vs plain dense -- is what turns sparsity into real speedups.
This module owns that choice: the route ids, the analytic cost model
hookup, measured autotune, and the process-level decision cache.

NOTE: the *public* API is now plan-first -- ``repro.sparse`` (see
docs/api.md) runs the decision once per logical problem, bakes it into
a frozen ``MatmulPlan``, and persists verdicts to disk.  The entry
points below survive as thin deprecation shims that build-and-call a
plan:

    spmm(operand, x, *, ctx=None) -> y            # Y = W @ X,  X: [k, n]

``operand`` may be

* a dense ``[m, k]`` array            -> dense routes
* a static ``BlockSparseMatrix``      -> static routes (pattern folded)
* a ``DynamicOperand`` (or a BSR with
  device-resident indices)            -> dynamic routes (d_max capacity)

Routes (the execution strategies of Table 3, plus the TPU dense kernel):

    dense_xla       jnp matmul (XLA fuses/pads; the paper's dense baseline)
    dense_pallas    kernels/dense_mm MXU-tiled kernel
    static_xla      static_sparse gather/einsum/segment-sum formulation
    static_pallas   kernels/bsmm tile-packed kernel (compile-time metadata)
    static_balanced kernels/bsmm balanced walk (row-swizzle binned lanes)
    dynamic_xla     dynamic_sparse._dspmm scatter-add formulation
    dynamic_pallas  kernels/dsmm slot-walk kernel (runtime metadata)
    dynamic_grouped kernels/gmm device-side tile packing -> full-tile walk
    dynamic_grouped_balanced
                    kernels/gmm pack + row-swizzled slot visit order

The decision is autotuned per *logical problem*, not per call: first the
analytic TPU cost model (``benchmarks.cost_model``, the same one the
benchmark suite prices Table 3 with) ranks the admissible routes; when
``ctx.measure`` is set and the inputs are concrete the candidates are
wall-clock measured once.  Either way the verdict is memoized in a
process-level decision cache keyed on

    (m, k, n, block_size, density-bucket, dtype, mode)

so steady-state dispatch is a dict hit.  ``explain(...)`` returns the
full decision report (candidates, estimates, chosen route, cache state)
for tools such as ``tools/perf_cell.py``.

All decisions are made at trace time from static data (shapes, dtypes,
host-side density); under ``jax.jit`` the chosen route is baked into the
compiled program, exactly like PopSparse's ahead-of-time planning.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import math
import os
import threading
import time
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bsr import BlockSparseMatrix
from repro.core.dynamic_sparse import DynamicOperand, _dspmm
from repro.core import static_sparse as _ssp

Operand = Union[jax.Array, np.ndarray, BlockSparseMatrix, DynamicOperand]

ROUTES = ("dense_xla", "dense_pallas", "static_xla", "static_pallas",
          "static_balanced", "dynamic_xla", "dynamic_pallas",
          "dynamic_grouped", "dynamic_grouped_balanced")
MODES = ("auto", "dense", "static", "dynamic") + ROUTES

# backward-only route vocabulary: the dL/dvalues product of a static
# sparse matmul is a block-sampled dense-dense matmul (SDDMM) -- a
# different op shape than SpMM, so it carries its own route ids.  The
# dL/dx product is an SpMM on the transposed pattern and reuses ROUTES.
#   sddmm_xla      static_sparse make_sddmm gather/einsum formulation
#   sddmm_grouped  kernels/sddmm tile-grid Pallas kernel (plan_packing
#                  metadata; gated like the other Pallas routes)
#   sddmm_dense    full dense dY @ X^T then gather the pattern blocks
SDDMM_ROUTES = ("sddmm_xla", "sddmm_grouped", "sddmm_dense")

# the authoritative operand-dtype vocabulary every route must cover:
# kernel CONTRACT declarations (repro.kernels.contract) are checked
# against this list by tools/lint/contracts.py
SUPPORTED_DTYPES = ("float32", "bfloat16", "float16")


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchContext:
    """Per-call-site dispatch policy (ambient default: ``default_ctx``).

    mode          "auto" (cost-model choice), a family ("dense" /
                  "static" / "dynamic"), or an explicit route id.
    measure       measure candidate routes once (wall clock, concrete
                  inputs only) instead of trusting the analytic model.
    allow_pallas  None = TPU backend only; True/False force-include/
                  exclude Pallas routes from auto selection.
    interpret     run Pallas kernels in interpret mode (CPU testing).
                  Does NOT admit Pallas routes to auto selection --
                  interpret mode is for forced routes in tests.
    differentiable  the caller may take gradients through the result
                  (the default -- training).  The Pallas kernels are
                  forward-only, so auto/family selection excludes them
                  unless this is False; explicit route ids always run.
    cache         consult/fill the process-level decision cache.
    """

    mode: str = "auto"
    measure: bool = False
    allow_pallas: Optional[bool] = None
    interpret: bool = False
    differentiable: bool = True
    cache: bool = True

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown dispatch mode {self.mode!r}; "
                             f"expected one of {MODES}")


default_ctx = DispatchContext()
_ctx_state = threading.local()


def current_ctx() -> DispatchContext:
    return getattr(_ctx_state, "ctx", None) or default_ctx


@contextlib.contextmanager
def use_ctx(ctx: DispatchContext):
    """Install ``ctx`` as the ambient dispatch context (trace-scoped)."""
    prev = getattr(_ctx_state, "ctx", None)
    _ctx_state.ctx = ctx
    try:
        yield ctx
    finally:
        _ctx_state.ctx = prev


def _pallas_ok(ctx: DispatchContext) -> bool:
    """May auto/family selection consider Pallas routes?  Requires a
    TPU backend (or an explicit allow_pallas=True, e.g. for analytic
    what-would-run reports) AND a forward-only caller: the Pallas
    kernels define no VJPs, so differentiable call sites must stay on
    the XLA routes.  (The plan layer -- ``repro.sparse`` -- registers a
    plan-level ``custom_vjp`` with planned backward products, so
    *plans* admit Pallas forwards for differentiable callers; this
    dispatch-level gate covers the raw shim entry points only.)"""
    if ctx.differentiable:
        return False
    if ctx.allow_pallas is not None:
        return ctx.allow_pallas
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Calibrated cost coefficients (fitted by repro.analysis.calibrate)
# ---------------------------------------------------------------------------

_COEFFS_ENV = "REPRO_COST_COEFFS"
_COEFFS_DEFAULT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "..", "..",
    "benchmarks", "baselines", "cost_coeffs.json")


@dataclasses.dataclass(frozen=True)
class CostCoeffs:
    """Corrections to the hand-tuned analytic model, fitted from the
    committed benchmark corpus by ``repro.analysis.calibrate``.

    ``_estimate`` prices a route as ``scale[route] * t_raw +
    fixed_us[route]`` over the hand-tuned kernel-structure time
    ``t_raw`` (``_estimate_raw``); the skew knee/slope/cap fields
    replace the ``_skew_factor`` constants.  ``digest`` -- a content
    hash of the fitted values -- joins every decision cache key and
    (through ``_cache_key``) every plan fingerprint, so a coefficient
    refit invalidates stale verdicts exactly like a schema bump.  The
    identity instance (no ``cost_coeffs.json``) reproduces the
    hand-tuned model bit-for-bit and leaves cache keys untouched.
    """

    route_scale: Dict[str, float] = dataclasses.field(default_factory=dict)
    route_fixed_us: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    skew_imb_knee: float = 1.25
    skew_imb_slope: float = 0.35
    skew_cv_knee: float = 0.25
    skew_cv_slope: float = 0.15
    skew_cap: float = 3.0
    version: int = 0
    digest: str = ""             # "" == identity (no coefficients file)

    @property
    def is_identity(self) -> bool:
        return not self.digest

    def apply(self, route: str, seconds: float) -> float:
        return (self.route_scale.get(route, 1.0) * seconds
                + self.route_fixed_us.get(route, 0.0) * 1e-6)


IDENTITY_COEFFS = CostCoeffs()


def coeffs_digest(routes: Dict[str, dict], skew: Dict[str, float],
                  version: int) -> str:
    """Content hash over the values that change estimates (diagnostic
    fields like per-route n_obs / residuals are excluded, so a refit
    that lands identical coefficients keeps cached verdicts valid)."""
    payload = {
        "version": int(version),
        "routes": {r: [round(float(v.get("scale", 1.0)), 6),
                       round(float(v.get("fixed_us", 0.0)), 6)]
                   for r, v in sorted(routes.items())},
        "skew": [round(float(skew.get(k, d)), 6) for k, d in
                 (("imb_knee", 1.25), ("imb_slope", 0.35),
                  ("cv_knee", 0.25), ("cv_slope", 0.15), ("cap", 3.0))],
    }
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()[:12]


def load_cost_coeffs(path: Optional[str] = None) -> CostCoeffs:
    """Parse ``cost_coeffs.json`` ($REPRO_COST_COEFFS overrides the
    committed default location).  Any read/parse failure falls back to
    the hand-tuned identity -- an installed library without the
    benchmarks tree keeps working, just uncalibrated."""
    path = path or os.environ.get(_COEFFS_ENV) or _COEFFS_DEFAULT_PATH
    try:
        with open(path) as f:
            blob = json.load(f)
        routes = blob.get("routes", {})
        skew = blob.get("skew", {})
        version = int(blob.get("version", 1))
        return CostCoeffs(
            route_scale={r: float(v.get("scale", 1.0))
                         for r, v in routes.items()},
            route_fixed_us={r: float(v.get("fixed_us", 0.0))
                            for r, v in routes.items()},
            skew_imb_knee=float(skew.get("imb_knee", 1.25)),
            skew_imb_slope=float(skew.get("imb_slope", 0.35)),
            skew_cv_knee=float(skew.get("cv_knee", 0.25)),
            skew_cv_slope=float(skew.get("cv_slope", 0.15)),
            skew_cap=float(skew.get("cap", 3.0)),
            version=version,
            digest=coeffs_digest(routes, skew, version))
    except (OSError, ValueError, TypeError, AttributeError):
        return IDENTITY_COEFFS


_coeffs = load_cost_coeffs()


def cost_coeffs() -> CostCoeffs:
    """The active calibration (identity when no coefficients file)."""
    return _coeffs


def set_cost_coeffs(coeffs: Optional[CostCoeffs]):
    """Install ``coeffs`` as the active calibration (None reloads from
    disk).  Clears the decision cache: every estimate changes, and the
    digest component of the cache key changes with it."""
    global _coeffs
    _coeffs = coeffs if coeffs is not None else load_cost_coeffs()
    clear_cache()


# ---------------------------------------------------------------------------
# Decision cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Decision:
    route: str
    est_seconds: Dict[str, float]     # per-candidate estimate
    source: str                       # "analytic" | "measured" | "forced"
    key: Tuple


_decision_cache: Dict[Tuple, Decision] = {}
_cache_lock = threading.Lock()


def cache_stats() -> dict:
    return {"entries": len(_decision_cache),
            "keys": sorted(_decision_cache)}


def clear_cache():
    with _cache_lock:
        _decision_cache.clear()


def _density_bucket(density: float) -> float:
    """Bucket density to the nearest power of two (Table 3 uses 1/2^k
    grids); keeps the cache key stable across nnz jitter."""
    if density <= 0:
        return 0.0
    if density >= 1.0:
        return 1.0
    return 2.0 ** round(math.log2(density))


def _ctx_fingerprint(ctx: DispatchContext) -> Tuple:
    """The context fields that change what decide() would answer or how
    the chosen route executes -- all of them must be part of the cache
    key or one context's verdict leaks into an incompatible one."""
    return (ctx.mode, ctx.measure, ctx.interpret, ctx.differentiable,
            _pallas_ok(ctx))


def _cache_key(kind: str, m: int, k: int, n: int, b: int, density: float,
               dtype, ctx: DispatchContext,
               skew: Tuple[float, float] = (1.0, 0.0)) -> Tuple:
    """``skew`` is the pattern's (imbalance, cv) from
    ``pattern_balance``: a skewed pattern's verdict (balanced route
    wins) must not answer for a uniform one of the same shape/density.
    Bucketed to one decimal so nnz jitter does not split the key."""
    key = (kind, m, k, n, b, _density_bucket(density),
           jnp.dtype(dtype).name) + _ctx_fingerprint(ctx)
    imb, cv = (round(float(skew[0]), 1), round(float(skew[1]), 1))
    if (imb, cv) != (1.0, 0.0):
        key += ("skew", imb, cv)
    if not _coeffs.is_identity:
        # a coefficient refit changes every estimate, so it must orphan
        # cached verdicts the same way a schema bump does
        key += ("coeffs", _coeffs.digest)
    return key


def pattern_balance(operand) -> Tuple[float, float]:
    """(imbalance, cv) of a static pattern's per-row-tile work at the
    packed-walk granularity (``plan_packing`` row-tiles) -- the skew
    signal the cost model prices the uniform walks with.  Runtime
    (dynamic/dense) operands report (1.0, 0.0): their skew is only
    knowable on device, so pricing stays profile-free."""
    if not (isinstance(operand, BlockSparseMatrix) and operand.is_static):
        return (1.0, 0.0)
    from repro.core import partitioner as _partitioner
    m, k = operand.shape
    b = operand.block_size
    # the packed walk's row-tile height (mirrors bsmm._pick_tiles)
    tm = min(128, m) if m % 128 else 128
    tm = max(b, tm - tm % b)
    while m % tm:
        tm //= 2
    tm = max(tm, b)
    rpb = max(1, tm // b)
    rows = np.asarray(operand.row_idx, np.int64)
    mt = max(1, m // tm)
    counts = np.bincount(rows // rpb, minlength=mt)
    rep = _partitioner.balance_report(counts)
    return (rep["imbalance"], rep["cv"])


# ---------------------------------------------------------------------------
# Analytic estimates (benchmarks.cost_model when importable)
# ---------------------------------------------------------------------------

def _cost_model():
    try:
        from benchmarks import cost_model as cm
        return cm
    except ImportError:
        return None


def _expected_tiles(m: int, k: int, b: int, density: float,
                    tm: int = 128, tk: int = 128) -> int:
    """Expected non-empty (tm, tk) tiles for a random pattern: the
    analytic stand-in for ``partitioner.pack_tiles`` occupancy (the
    real packing is only computed on the execution path)."""
    mt, kt = max(1, math.ceil(m / tm)), max(1, math.ceil(k / tk))
    per_tile = max(1, (min(tm, m) // b) * (min(tk, k) // b))
    p_nonempty = 1.0 - (1.0 - min(density, 1.0)) ** per_tile
    # every output row-tile is covered (empty rows get one zero tile)
    return max(mt, math.ceil(mt * kt * p_nonempty))


def _roofline_fallback(route: str, m, k, n, b, density, bytes_el) -> float:
    """Crude FLOP/bandwidth roofline used only when benchmarks.cost_model
    is not importable (library installed without the benchmarks tree)."""
    peak, bw = 197e12, 819e9
    if route.startswith("dense"):
        flops, mem = 2.0 * m * k * n, (m * k + k * n + m * n) * bytes_el
    elif route.startswith("static"):
        flops = 2.0 * m * k * n * density
        mem = (m * k * density + k * n + m * n) * bytes_el
    else:
        flops = 2.0 * m * k * n * density * 1.5   # capacity + encode pad
        mem = (m * k * density * 1.5 + k * n + m * n) * bytes_el + m * k / 64
    return max(flops / peak, mem / bw)


# balanced (row-swizzled) walks price as their parent's *un-skewed*
# kernel time plus a small constant for the pad tiles / visit-schedule
# bookkeeping; they never pay the skew factor -- equal-work lanes are
# the point of the swizzle
_BALANCED_PARENT = {"static_balanced": "static_pallas",
                    "dynamic_grouped_balanced": "dynamic_grouped"}
_BALANCED_OVERHEAD = 1.02

# the uniform sparse walks serialize on hot rows: a run of same-row
# steps pipelines its flush/init bubbles onto one lane (the row-swizzle
# motivation of Gale et al. 2020), so their estimates scale with the
# pattern's row imbalance.  Dense routes and the SDDMM family are
# pattern-order-free and stay flat.
_SKEW_SENSITIVE = ("static_xla", "static_pallas", "dynamic_xla",
                   "dynamic_pallas", "dynamic_grouped")


def _skew_factor(imbalance: float, cv: float) -> float:
    # a uniform random mask carries Poisson sampling noise (imbalance
    # ~1.2, cv ~0.1 at realistic sizes) that the walk absorbs for free;
    # the dead zones (knees) keep that noise from flipping uniform
    # verdicts.  Knee/slope/cap come from the active calibration and
    # default to the hand-tuned constants.
    c = _coeffs
    return min(c.skew_cap,
               1.0 + c.skew_imb_slope * max(0.0, imbalance - c.skew_imb_knee)
               + c.skew_cv_slope * max(0.0, cv - c.skew_cv_knee))


def _estimate(route: str, m: int, k: int, n: int, b: int,
              density: float, dtype, *, imbalance: float = 1.0,
              cv: float = 0.0) -> float:
    """Calibrated estimate: the hand-tuned kernel-structure time
    (``_estimate_raw``) corrected by the fitted per-route affine terms.
    Identity when no ``cost_coeffs.json`` is present."""
    return _coeffs.apply(route, _estimate_raw(
        route, m, k, n, b, density, dtype, imbalance=imbalance, cv=cv))


def price_tokens(shapes, n_tokens: int, *, dtype="float32",
                 route: str = "dense_xla") -> float:
    """Calibrated model-seconds for pushing ``n_tokens`` tokens through a
    stack of ``[m, k]`` matmuls -- the serving engine's admission /
    padding price.

    ``shapes`` is an iterable of ``(m, k)`` pairs (one per matmul the
    token batch flows through; repeated layers repeat their pairs).
    Prices with the same calibrated ``_estimate`` the dispatch race
    uses -- ``cost_coeffs.json`` corrections included -- so a bucket
    choice priced here is consistent with the verdicts the plans
    themselves were raced on.  Analytic by construction: pricing an
    admission decision must never trigger a measurement.
    """
    n_tokens = int(n_tokens)
    if n_tokens <= 0:
        return 0.0
    total = 0.0
    for m, k in shapes:
        total += _estimate(route, int(m), int(k), n_tokens, 1, 1.0, dtype)
    return total


def _estimate_raw(route: str, m: int, k: int, n: int, b: int,
                  density: float, dtype, *, imbalance: float = 1.0,
                  cv: float = 0.0) -> float:
    """Estimated seconds for one route on the TPU target.  XLA and Pallas
    variants of a family share the kernel-structure estimate; the XLA
    variant carries a small constant penalty so that on equal footing the
    purpose-built kernel wins (mirrors measured behaviour).

    ``imbalance``/``cv`` (from ``pattern_balance`` /
    ``partitioner.balance_report``) scale the uniform sparse walks by
    ``_skew_factor``; the balanced routes price flat at their parent's
    un-skewed time x ``_BALANCED_OVERHEAD``, so on skewed patterns the
    race flips to the balanced variant and on uniform ones it never
    does.

    SDDMM routes price the backward dL/dW product: a block-sampled
    ``dY[m, n] @ X[k, n]^T`` at block density ``d`` (the contraction is
    over ``n``, the sampled output is the ``[m, k]`` pattern grid)."""
    parent = _BALANCED_PARENT.get(route)
    if parent is not None:
        return _estimate_raw(parent, m, k, n, b, density,
                             dtype) * _BALANCED_OVERHEAD
    skew = (_skew_factor(imbalance, cv)
            if route in _SKEW_SENSITIVE else 1.0)
    bytes_el = max(1, jnp.dtype(dtype).itemsize)
    fp32 = jnp.dtype(dtype).itemsize >= 4
    cm = _cost_model()
    if cm is None:
        fam = {"sddmm_dense": "dense", "sddmm_grouped": "static",
               "sddmm_xla": "dynamic"}.get(route, route)
        t = _roofline_fallback(fam, m, k, n, b, density, bytes_el)
        return t * (4.0 if fp32 else 1.0) * \
            (1.15 if route.endswith("_xla") else 1.0) * skew
    db = cm.B32 if fp32 else cm.B16
    if route in SDDMM_ROUTES:
        if route == "sddmm_dense":
            # full [m, n] @ [n, k] product; the pattern gather is noise
            t = cm.dense_time(m, n, k, dtype_bytes=db)
        elif route == "sddmm_grouped":
            # tile-grid kernel: one (t, tn) x (t, tn)^T accumulation
            # chain per non-empty pattern tile (kernels/sddmm)
            tiles = _expected_tiles(m, k, b, density)
            tn = min(512, n)
            steps = tiles * math.ceil(n / tn)
            per_step = max(cm._mxu_cycles(128, tn, 128),
                           cm._bytes_cycles(2 * 128 * tn * db))
            t = cm.KernelTime(steps * per_step,
                              2.0 * m * k * n * density)
        else:
            # sddmm_xla: logical-block gather/einsum walk -- b-granular
            # MXU passes, like the dynamic slot walk
            slots = max(1, math.ceil((m // b) * (k // b) * density))
            tn = min(512, n)
            steps = slots * math.ceil(n / tn)
            per_step = max(cm._mxu_cycles(b, tn, b),
                           cm._bytes_cycles(2 * b * tn * db, cm.VMEM_BW))
            t = cm.KernelTime(steps * per_step,
                              2.0 * m * k * n * density)
        if fp32:
            t = cm.fp32_time(t)
        return t.seconds * (1.15 if route.endswith("_xla") else 1.0)
    if route.startswith("dense"):
        t = cm.dense_time(m, k, n, dtype_bytes=db)
    elif route == "dynamic_grouped":
        # price the *planned* tile bucket (expected occupancy at the
        # real grouped tile size, plus the planner's headroom), not the
        # worst case -- the estimate matches what the plan layer will
        # actually allocate, so dynamic_grouped wins the dispatch race
        # exactly where the planned capacity makes it cheap
        from repro.core import planner as _planner
        try:
            from repro.kernels.gmm.ops import grouped_tile_size
            tile = grouped_tile_size(m, k, b)
        except (ImportError, ValueError):
            tile = b
        capplan = _planner.plan_grouped_capacity(m, k, b, density,
                                                 tile=tile)
        pk = type("_Pk", (), dict(
            num_tiles=capplan.tiles_cap, tm=tile, tk=tile,
            _nnz_area=int(m * k * density), shape=(m, k)))
        # headroom is already inside tiles_cap: price it at factor 1
        t = cm.dsmm_grouped_time(pk, n, dtype_bytes=db,
                                 capacity_factor=1.0)
    elif route.startswith("static"):
        tiles = _expected_tiles(m, k, b, density)
        tm = min(128, m)
        tk = min(128, k)
        tn = min(512, n)
        steps = tiles * math.ceil(n / tn)
        per_step = max(cm._mxu_cycles(tm, tk, tn),
                       cm._bytes_cycles((tm * tk + tk * tn) * db))
        t = cm.KernelTime(steps * per_step, 2.0 * m * k * n * density)
    else:
        t = cm.dsmm_time(m, k, n, block_size=b, d_max=density,
                         true_density=density, dtype_bytes=db)
    if fp32:
        t = cm.fp32_time(t)
    return t.seconds * (1.15 if route.endswith("_xla") else 1.0) * skew


# ---------------------------------------------------------------------------
# Route execution
# ---------------------------------------------------------------------------

def _normalize(operand: Operand):
    """-> (kind, m, k, block_size, density) with kind in
    {dense, static, dynamic}."""
    if isinstance(operand, BlockSparseMatrix):
        m, k = operand.shape
        if operand.is_static:
            return "static", m, k, operand.block_size, operand.density
        return "dynamic", m, k, operand.block_size, operand.density
    if isinstance(operand, DynamicOperand):
        m, k = operand.shape
        b = operand.block_size
        density = operand.capacity / max(1, (m // b) * (k // b))
        return "dynamic", m, k, b, density
    arr = jnp.asarray(operand) if not hasattr(operand, "ndim") else operand
    if arr.ndim != 2:
        raise ValueError(f"dense operand must be 2-D, got shape {arr.shape}")
    m, k = arr.shape
    return "dense", m, k, 1, 1.0


# families an operand kind can execute (static can always be *run*
# densely or through the dynamic path; dense/dynamic cannot recover a
# compile-time pattern)
_ADMISSIBLE = {"dense": ("dense",),
               "static": ("static", "dense", "dynamic"),
               "dynamic": ("dynamic", "dense")}


def _candidates(kind: str, ctx: DispatchContext) -> Tuple[str, ...]:
    if ctx.mode in ROUTES:
        fam = ctx.mode.split("_")[0]
        if fam not in _ADMISSIBLE[kind]:
            raise ValueError(f"route {ctx.mode!r} cannot execute a "
                             f"{kind} operand")
        return (ctx.mode,)
    if ctx.mode in ("dense", "static", "dynamic"):
        if ctx.mode not in _ADMISSIBLE[kind]:
            raise ValueError(f"mode {ctx.mode!r} cannot execute a "
                             f"{kind} operand")
        fams = [ctx.mode]
    elif kind == "static":
        # a static pattern may still be cheaper to run densely (Table 3:
        # dense wins at high density / tiny blocks)
        fams = ["static", "dense"]
    elif kind == "dynamic":
        fams = ["dynamic", "dense"]
    else:
        fams = ["dense"]
    cands = []
    for f in fams:
        cands.append(f"{f}_xla")
        if _pallas_ok(ctx):
            cands.append(f"{f}_pallas")
            if f == "static":
                # row-swizzled walk (kernels/bsmm balanced): same
                # operand constraints as static_pallas
                cands.append("static_balanced")
            if f == "dynamic":
                # device-side tile packing (kernels/gmm) -- runs the
                # full-tile Pallas walk, so it is gated like the other
                # Pallas routes -- plus its row-swizzled visit order
                cands.append("dynamic_grouped")
                cands.append("dynamic_grouped_balanced")
    return tuple(cands)


def _as_dense(operand: Operand) -> jax.Array:
    if isinstance(operand, (BlockSparseMatrix, DynamicOperand)):
        return operand.to_dense()
    return jnp.asarray(operand)


def _run_route(route: str, operand: Operand, x: jax.Array,
               ctx: DispatchContext) -> jax.Array:
    # dtype contract: every route follows jnp promotion of
    # (operand dtype, x dtype), like the einsum formulations it replaces
    if route == "dense_xla":
        w = _as_dense(operand)
        rt = jnp.result_type(w.dtype, x.dtype)
        return jnp.matmul(w.astype(rt), x.astype(rt))
    if route == "dense_pallas":
        from repro.kernels.dense_mm import ops as dmm_ops
        w = _as_dense(operand)
        rt = jnp.result_type(w.dtype, x.dtype)
        return dmm_ops.dense_mm(w.astype(rt), x.astype(rt),
                                interpret=ctx.interpret)
    if route == "static_xla":
        return _ssp.spmm_cached(operand, x)
    if route == "static_pallas":
        from repro.kernels.bsmm import ops as bsmm_ops
        return bsmm_ops.bsmm(operand, x, interpret=ctx.interpret)
    if route == "static_balanced":
        from repro.kernels.bsmm import ops as bsmm_ops
        return bsmm_ops.bsmm_balanced(operand, x, interpret=ctx.interpret)
    if route in ("dynamic_xla", "dynamic_pallas", "dynamic_grouped",
                 "dynamic_grouped_balanced"):
        op = operand
        if isinstance(op, BlockSparseMatrix):   # device-resident indices
            op = DynamicOperand(
                jnp.asarray(op.values), jnp.asarray(op.row_idx, jnp.int32),
                jnp.asarray(op.col_idx, jnp.int32),
                jnp.asarray(op.nnz_blocks, jnp.int32), op.shape,
                op.block_size)
        if route == "dynamic_xla":
            mb = op.shape[0] // op.block_size
            return _dspmm(op.values, op.row_idx, op.col_idx, x, mb,
                          op.block_size)
        if route in ("dynamic_grouped", "dynamic_grouped_balanced"):
            # execute at the planned bucket (same sizing _estimate
            # prices), so measured autotune wall-clocks the capacity the
            # plan layer will actually allocate -- not the worst case
            from repro.core import planner as _planner
            from repro.kernels.gmm import ops as gmm_ops
            m_, k_ = op.shape
            b_ = op.block_size
            t = gmm_ops.grouped_tile_size(m_, k_, b_)
            d_ = op.capacity / max(1, (m_ // b_) * (k_ // b_))
            cap = _planner.plan_grouped_capacity(
                m_, k_, b_, d_, tile=t, slots=op.capacity).tiles_cap
            if route == "dynamic_grouped_balanced":
                from repro.kernels.gmm import balanced as gmm_balanced
                return gmm_balanced.balanced_spmm(
                    op, x, tile=t, tiles_cap=cap, interpret=ctx.interpret)
            return gmm_ops.grouped_spmm(op, x, tile=t, tiles_cap=cap,
                                        interpret=ctx.interpret)
        from repro.kernels.dsmm import ops as dsmm_ops
        return dsmm_ops.dsmm(op, x, interpret=ctx.interpret)
    raise ValueError(f"unknown route {route!r}")


# ---------------------------------------------------------------------------
# Decide + dispatch
# ---------------------------------------------------------------------------

def _is_concrete(*arrays) -> bool:
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _dtype_of(operand: Operand):
    if isinstance(operand, (BlockSparseMatrix, DynamicOperand)):
        return jnp.dtype(operand.values.dtype)
    return jnp.dtype(getattr(operand, "dtype", None) or
                     np.asarray(operand).dtype)


def _executable(route: str, ctx: DispatchContext) -> bool:
    """Can this host actually run the route?  Pallas needs a TPU (or
    interpret mode); analytic candidates from allow_pallas=True
    what-would-run reports are not executable off-TPU."""
    if route.endswith("_xla") or route == "sddmm_dense":
        return True
    return ctx.interpret or jax.default_backend() == "tpu"


def sddmm_candidates(ctx: DispatchContext) -> Tuple[str, ...]:
    """Admissible dL/dvalues (block-SDDMM) backward routes.  The
    backward products run inside a plan-level ``custom_vjp`` and are
    never differentiated again, so the Pallas kernel is gated only on
    the backend, not on ``ctx.differentiable``."""
    cands = ["sddmm_xla", "sddmm_dense"]
    fwd_only = dataclasses.replace(ctx, differentiable=False)
    if _pallas_ok(fwd_only):
        cands.insert(1, "sddmm_grouped")
    return tuple(cands)


def measure_callable(fn, *args, reps: int = 3) -> float:
    """Wall-clock ``jit(fn)(*args)`` (compile + warm excluded): the one
    timing harness every measured-autotune race uses -- the unsharded
    dispatch race below and the plan-level TP race in
    ``repro.sparse.plan`` -- so verdicts are comparable across layers."""
    run = jax.jit(fn)
    run(*args).block_until_ready()                # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        y = run(*args)
    y.block_until_ready()
    return (time.perf_counter() - t0) / reps


def _measure_route(route, operand, x, ctx, *, reps: int = 3) -> float:
    # operand is closed over, not passed: static patterns must stay host
    # constants (a jit argument would trace the index arrays).
    return measure_callable(lambda xx: _run_route(route, operand, xx, ctx),
                            x, reps=reps)


def decide(operand: Operand, n: int, *,
           ctx: Optional[DispatchContext] = None,
           x: Optional[jax.Array] = None) -> Decision:
    """Pick the route for ``operand @ [k, n]``.  Pure function of the
    cache key; fills the process-level cache.  ``x`` is only used when
    ``ctx.measure`` is set and the inputs are concrete."""
    ctx = ctx or current_ctx()
    kind, m, k, b, density = _normalize(operand)
    dtype = _dtype_of(operand)
    imb, cv = pattern_balance(operand)
    key = _cache_key(kind, m, k, n, b, density, dtype, ctx,
                     skew=(imb, cv))
    if ctx.cache:
        hit = _decision_cache.get(key)
        if hit is not None:
            return hit
    cands = _candidates(kind, ctx)
    if len(cands) == 1:
        dec = Decision(cands[0], {cands[0]: _estimate(
            cands[0], m, k, n, b, density, dtype, imbalance=imb,
            cv=cv)}, "forced", key)
    else:
        est = {r: _estimate(r, m, k, n, b, density, dtype,
                            imbalance=imb, cv=cv) for r in cands}
        source = "analytic"
        pick_from = est
        if ctx.measure and x is not None and _is_concrete(
                x, *(jax.tree_util.tree_leaves(operand))):
            # only wall-clock routes this host can run; unrunnable
            # candidates keep their analytic estimate but are never
            # chosen by a measured verdict
            runnable = [r for r in cands if _executable(r, ctx)]
            if runnable:
                measured = {r: _measure_route(r, operand, x, ctx)
                            for r in runnable}
                est = {**est, **measured}
                pick_from = measured
                source = "measured"
        dec = Decision(min(pick_from, key=pick_from.get), est, source, key)
    if ctx.cache:
        with _cache_lock:
            _decision_cache.setdefault(key, dec)
            dec = _decision_cache[key]
    return dec


def spmm(operand: Operand, x: jax.Array, *,
         ctx: Optional[DispatchContext] = None) -> jax.Array:
    """``Y = W @ X`` with ``X: [k, n]``.

    DEPRECATED entry point: prefer the plan-first API --
    ``repro.sparse.plan(operand, n)`` once, then call the plan.  This
    shim builds (or fetches from the plan cache) that plan and calls it,
    so behaviour and numerics match the plan path exactly.

    Differentiable w.r.t. the operand values and ``x`` on every route:
    the plan layer attaches a planned backward (transposed-SpMM +
    SDDMM custom_vjp) when ``ctx.differentiable`` is set."""
    ctx = ctx or current_ctx()
    _, _, k, _, _ = _normalize(operand)
    if x.ndim != 2:
        raise ValueError(f"x must be [k, n], got shape {x.shape}")
    if x.shape[0] != k:
        raise ValueError(f"X rows {x.shape[0]} != operand k {k}")
    from repro import sparse as sparse_api
    p = sparse_api.plan(operand, int(x.shape[1]), x=x,
                        ctx=sparse_api.PlanContext.from_dispatch(ctx))
    return p.apply(operand, x)


def spmm_nt(operand: Operand, x: jax.Array, *,
            ctx: Optional[DispatchContext] = None) -> jax.Array:
    """Activation-major form ``x: [..., k] -> [..., m]`` (y = x @ W^T)."""
    _, m, k, _, _ = _normalize(operand)
    lead = x.shape[:-1]
    y = spmm(operand, x.reshape(-1, k).T, ctx=ctx)
    return y.T.reshape(*lead, m)


def matmul(x: jax.Array, w: Operand, *,
           ctx: Optional[DispatchContext] = None) -> jax.Array:
    """``y = x @ w`` for activation-major dense layers: ``x: [..., k]``,
    ``w: [k, n]`` (dense).  DEPRECATED shim over
    ``repro.sparse.matmul`` (plan cached per logical shape)."""
    ctx = ctx or current_ctx()
    from repro import sparse as sparse_api
    return sparse_api.matmul(x, w,
                             ctx=sparse_api.PlanContext.from_dispatch(ctx))


def batched_matmul(a: jax.Array, b: jax.Array, *,
                   ctx: Optional[DispatchContext] = None) -> jax.Array:
    """Batched dense ``[..., C, D] @ [..., D, F]`` (MoE expert GEMMs).
    DEPRECATED shim over ``repro.sparse.batched_matmul`` (one plan for
    the per-slice problem, vmapped over the batch axes)."""
    ctx = ctx or current_ctx()
    from repro import sparse as sparse_api
    return sparse_api.batched_matmul(
        a, b, ctx=sparse_api.PlanContext.from_dispatch(ctx))


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

def explain(operand: Operand, n: int, *,
            ctx: Optional[DispatchContext] = None) -> dict:
    """Full decision report for ``operand @ [k, n]`` -- what would run,
    why, and what it would cost.  Non-caching unless the decision is
    already cached."""
    ctx = ctx or current_ctx()
    kind, m, k, b, density = _normalize(operand)
    dtype = _dtype_of(operand)
    imb, cv = pattern_balance(operand)
    key = _cache_key(kind, m, k, n, b, density, dtype, ctx,
                     skew=(imb, cv))
    cached = _decision_cache.get(key)
    dec = cached or decide(operand, n,
                           ctx=dataclasses.replace(ctx, cache=False))
    return {
        "problem": {"kind": kind, "m": m, "k": k, "n": n, "block_size": b,
                    "density": round(density, 5),
                    "density_bucket": _density_bucket(density),
                    "imbalance": round(imb, 3), "cv": round(cv, 3),
                    "dtype": jnp.dtype(dtype).name},
        "mode": ctx.mode,
        "pallas_admissible": _pallas_ok(ctx),
        "candidates": {r: dec.est_seconds[r] for r in
                       sorted(dec.est_seconds, key=dec.est_seconds.get)},
        "chosen": dec.route,
        "source": dec.source,
        "cached": cached is not None,
        "cache_key": key,
    }


def format_explain(report: dict) -> str:
    p = report["problem"]
    lines = [f"dispatch {p['kind']} ({p['m']}x{p['k']}) @ ({p['k']}x"
             f"{p['n']}) b={p['block_size']} d={p['density']} "
             f"{p['dtype']} [mode={report['mode']}]"]
    for route, sec in report["candidates"].items():
        mark = "->" if route == report["chosen"] else "  "
        lines.append(f"  {mark} {route:<15} {sec * 1e6:10.2f} us")
    lines.append(f"   ({report['source']}"
                 f"{', cached' if report['cached'] else ''})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Kernel contracts for the routes dispatch itself implements
# ---------------------------------------------------------------------------

from repro.kernels.contract import KernelContract, register as _register_contract  # noqa: E402

# dense_xla: plain jnp.matmul after densify -- no constraints at all
DENSE_XLA_CONTRACT = _register_contract(KernelContract(
    kernel="dense_xla",
    routes=("dense_xla",),
    dtypes=SUPPORTED_DTYPES,
    min_block=1,
    max_block=1024,
    divisibility=(),
    grid="no tile grid: one XLA dot",
    capacity="dense",
    pallas=False,
))

# sddmm_dense: full dense dY @ X^T then gather the pattern blocks; the
# gather indexes block-rows, so shapes must stay block multiples
SDDMM_DENSE_CONTRACT = _register_contract(KernelContract(
    kernel="sddmm_dense",
    routes=("sddmm_dense",),
    dtypes=SUPPORTED_DTYPES,
    min_block=1,
    max_block=1024,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="no tile grid: one XLA dot + block gather",
    capacity="dense",
    pallas=False,
))
