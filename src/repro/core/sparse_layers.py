"""Neural-network layers backed by PopSparse-style block-sparse matmul.

The framework uses a light functional module convention throughout:
each layer is a small class holding *static* configuration (shapes,
patterns -- compile-time data, exactly what PopSparse fixes at graph
construction) with two methods:

    init(key)            -> params pytree (trainable leaves only)
    apply(params, x, ..) -> output

Static patterns (np index arrays) live on the layer object, NOT in the
params pytree, so they are trace-time constants -- the compile-time
contract of static sparsity.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dynamic_sparse as dsp
from repro.core import masks as masks_lib
from repro.core.bsr import BlockSparseMatrix


def _fan_in_init(key, nnz, b, fan_in, dtype):
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, (nnz, b, b)) * scale).astype(dtype)


@dataclasses.dataclass(frozen=True)
class SparseLinear:
    """y = x @ (M ⊙ W)^T (+ bias) with static block pattern M.

    ``pattern`` is a host block mask ``[out/b, in/b]``; effective density
    after masking is the paper's ``d``.
    """

    in_features: int
    out_features: int
    block_size: int
    pattern: np.ndarray                 # [out/b, in/b] bool (host)
    use_bias: bool = False
    dtype: object = jnp.float32
    backend: str = "auto"     # dispatch mode ("auto" / route id / family)
    # backward route policies for the plan-level custom_vjp (training
    # runs the planned transposed-SpMM + SDDMM siblings; "auto" races
    # the candidates, a route id forces one -- see PlanContext)
    grad_backend: str = "auto"
    sddmm_backend: str = "auto"

    def __post_init__(self):
        ob, ib = self.out_features // self.block_size, \
            self.in_features // self.block_size
        if self.pattern.shape != (ob, ib):
            raise ValueError(
                f"pattern {self.pattern.shape} != grid {(ob, ib)}")

    @property
    def nnz_blocks(self) -> int:
        return int(self.pattern.sum())

    @property
    def density(self) -> float:
        return self.nnz_blocks / self.pattern.size

    def _indices(self):
        rows, cols = np.nonzero(self.pattern)
        order = np.lexsort((cols, rows))
        return rows[order].astype(np.int32), cols[order].astype(np.int32)

    def init(self, key) -> dict:
        # fan-in of a sparse layer: expected nnz inputs per output row
        fan_in = self.in_features * self.density
        params = {"values": _fan_in_init(key, self.nnz_blocks,
                                         self.block_size, fan_in, self.dtype)}
        if self.use_bias:
            params["bias"] = jnp.zeros((self.out_features,), self.dtype)
        return params

    def as_bsr(self, params) -> BlockSparseMatrix:
        rows, cols = self._indices()
        return BlockSparseMatrix(params["values"], rows, cols,
                                 (self.out_features, self.in_features),
                                 self.block_size)

    def _plan_ctx(self):
        from repro import sparse as sparse_api
        mode = (f"static_{self.backend}"
                if self.backend in ("xla", "pallas")  # historical names
                else self.backend)
        return sparse_api.PlanContext(mode=mode,
                                      grad_mode=self.grad_backend,
                                      sddmm_mode=self.sddmm_backend)

    def apply(self, params, x: jax.Array) -> jax.Array:
        # plan-first: the pattern analysis + route decision happen once
        # per (pattern, shape) in the sparse plan cache; training steps
        # re-enter with fresh values only
        from repro import sparse as sparse_api
        bsr = self.as_bsr(params)
        y = sparse_api.spmm_nt(bsr, x.astype(params["values"].dtype),
                               ctx=self._plan_ctx())
        if self.use_bias:
            y = y + params["bias"]
        return y

    def evolve(self, new_pattern: np.ndarray, params: Optional[dict] = None):
        """Topology update (RigL drop/grow): returns ``(layer, params)``
        for ``new_pattern`` ``[out/b, in/b]``.

        Values of carried blocks are copied into their new slot order,
        grown blocks start at zero (RigL's convention), and every cached
        plan built on the old pattern is ``sparse.evolve``-d onto the new
        one -- so the next ``apply`` is a plan-cache hit with zero route
        decisions (unless the pattern drifted past the context's
        ``evolve_drift`` guardrail, which re-races).
        """
        from repro import sparse as sparse_api
        from repro.core import partitioner
        new_pattern = np.asarray(new_pattern, bool)
        layer = dataclasses.replace(self, pattern=new_pattern)
        if params is not None:
            old_r, old_c = self._indices()
            new_r, new_c = layer._indices()
            eplan = partitioner.plan_evolution(
                old_r, old_c, new_r, new_c, new_pattern.shape)
            new_params = dict(params)
            new_params["values"] = partitioner.apply_evolution(
                eplan, params["values"])
            params = new_params
        # migrate every cached plan (any n) onto the new pattern
        dummy = jnp.zeros((self.nnz_blocks, self.block_size,
                           self.block_size), self.dtype)
        old_bsr = BlockSparseMatrix(
            dummy, *self._indices(),
            (self.out_features, self.in_features), self.block_size)
        new_bsr = BlockSparseMatrix(
            jnp.zeros((layer.nnz_blocks, self.block_size,
                       self.block_size), self.dtype),
            *layer._indices(),
            (self.out_features, self.in_features), self.block_size)
        sparse_api.evolve_plans(old_bsr, new_bsr)
        return layer, params

    @classmethod
    def random_pattern(cls, key_unused, in_features, out_features,
                       block_size, density, *, seed=0, **kw):
        pattern = masks_lib.random_block_mask(
            out_features, in_features, block_size, density, seed=seed)
        return cls(in_features, out_features, block_size, pattern, **kw)


@dataclasses.dataclass(frozen=True)
class DynamicSparseLinear:
    """Dense master weight + runtime block mask (dynamic sparse training).

    Matches PopSparse dynamic mode: capacity fixed by ``d_max`` at compile
    time; the mask is data and may change every step (RigL-style regrowth,
    see ``pruning.py``).  Params carry the dense master weight and the
    mask; ``apply`` encodes + multiplies through the dynamic path.
    """

    in_features: int
    out_features: int
    block_size: int
    d_max: float
    use_bias: bool = False
    dtype: object = jnp.float32
    backend: str = "auto"     # forwarded to dispatch via dspmm

    @property
    def nnz_max(self) -> int:
        grid = (self.out_features // self.block_size) * \
            (self.in_features // self.block_size)
        return max(1, int(np.ceil(grid * self.d_max)))

    def init(self, key) -> dict:
        kw, km = jax.random.split(key)
        scale = 1.0 / np.sqrt(self.in_features * self.d_max)
        w = (jax.random.normal(
            kw, (self.out_features, self.in_features)) * scale).astype(self.dtype)
        mask = masks_lib.random_block_mask(
            self.out_features, self.in_features, self.block_size,
            self.d_max, seed=int(jax.random.randint(km, (), 0, 2**31 - 1)))
        params = {"w": w, "mask": jnp.asarray(mask)}
        if self.use_bias:
            params["bias"] = jnp.zeros((self.out_features,), self.dtype)
        return params

    def apply(self, params, x: jax.Array) -> jax.Array:
        op = dsp.encode(params["w"], params["mask"],
                        block_size=self.block_size, nnz_max=self.nnz_max)
        y = dsp.dspmm_nt(op, x.astype(params["w"].dtype),
                         backend=self.backend)
        if self.use_bias:
            y = y + params["bias"]
        return y


@dataclasses.dataclass(frozen=True)
class SparseFFN:
    """Transformer FFN with block-sparse weights (gated or plain).

    This is the framework's first-class integration of the paper: swap a
    dense FFN for a sparse one via config (``ffn_density``,
    ``ffn_block_size``) -- see configs/*.py sparse variants.
    """

    d_model: int
    d_ff: int
    block_size: int
    density: float
    gated: bool = True
    seed: int = 0
    dtype: object = jnp.float32

    def _layers(self):
        def mk(i, o, s):
            return SparseLinear.random_pattern(
                None, i, o, self.block_size, self.density,
                seed=self.seed + s, dtype=self.dtype)
        up = mk(self.d_model, self.d_ff, 1)
        down = mk(self.d_ff, self.d_model, 2)
        gate = mk(self.d_model, self.d_ff, 3) if self.gated else None
        return up, down, gate

    def init(self, key) -> dict:
        up, down, gate = self._layers()
        ks = jax.random.split(key, 3)
        params = {"up": up.init(ks[0]), "down": down.init(ks[1])}
        if gate is not None:
            params["gate"] = gate.init(ks[2])
        return params

    def apply(self, params, x: jax.Array) -> jax.Array:
        up, down, gate = self._layers()
        h = up.apply(params["up"], x)
        if gate is not None:
            g = gate.apply(params["gate"], x)
            h = jax.nn.silu(g) * h
        else:
            h = jax.nn.gelu(h)
        return down.apply(params["down"], h)

    def flops_per_token(self) -> float:
        n_mats = 3 if self.gated else 2
        return 2.0 * self.d_model * self.d_ff * self.density * n_mats
