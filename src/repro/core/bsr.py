"""Block-sparse (BSR-like) matrix container.

The paper defines the sparse operand as ``(M ⊙ W)`` where ``M`` is derived
from a block mask ``M_hat`` of block size ``b`` (PopSparse §3).  This module
provides the canonical container used across the library:

* ``values``  -- ``[nnz, b, b]`` the non-zero blocks, row-major ordered
* ``row_idx`` -- ``[nnz]`` block-row index of each block
* ``col_idx`` -- ``[nnz]`` block-col index of each block

For **static** sparsity (pattern fixed at compile time, paper §3.2) the
index arrays are host ``numpy`` arrays: they are trace-time constants and
get folded into the compiled program, exactly like PopSparse's ahead-of-
time partitioning.  For **dynamic** sparsity (paper §3.3) the indices are
device arrays and only ``nnz_max`` (from ``d_max``) is static.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = Union[np.ndarray, jax.Array]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def check_unique_blocks(row_idx, col_idx, grid: Tuple[int, int]) -> None:
    """Reject duplicate ``(row, col)`` block coordinates in a static
    pattern.  ``pack_values`` / ``to_dense`` scatter with ``.add``, so a
    duplicate block would be silently *summed* -- a corrupted evolved
    pattern (e.g. a drop/grow step that re-grows a live block) must fail
    loudly here, not as wrong numerics three layers down."""
    rows = np.asarray(row_idx, np.int64)
    cols = np.asarray(col_idx, np.int64)
    mb, kb = grid
    if rows.size and (rows.min() < 0 or rows.max() >= mb
                      or cols.min() < 0 or cols.max() >= kb):
        raise ValueError(
            f"block indices out of range for grid {grid}: rows in "
            f"[{rows.min() if rows.size else 0}, "
            f"{rows.max() if rows.size else 0}], cols in "
            f"[{cols.min() if cols.size else 0}, "
            f"{cols.max() if cols.size else 0}]")
    lin = rows * kb + cols
    uniq, counts = np.unique(lin, return_counts=True)
    if uniq.size != lin.size:
        dup = uniq[counts > 1][0]
        raise ValueError(
            f"duplicate block coordinates in static pattern: block "
            f"(row={int(dup // kb)}, col={int(dup % kb)}) appears "
            f"{int(counts.max())} times ({lin.size - uniq.size} "
            f"duplicate entries total).  pack_values/to_dense would "
            f"silently sum duplicate blocks; deduplicate the pattern "
            f"(a drop/grow topology update must produce unique "
            f"(row, col) pairs)")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockSparseMatrix:
    """A block-sparse matrix of logical shape ``(m, k)`` with ``b x b`` blocks.

    ``values[z]`` is the dense content of block ``(row_idx[z], col_idx[z])``.
    Blocks are expected in row-major (row, then col) order; ``sort_blocks``
    enforces this.  ``m`` and ``k`` must be multiples of ``block_size`` (the
    library pads upstream if needed, mirroring the paper's ceil-div masks).
    """

    values: Array          # [nnz, b, b]
    row_idx: Array         # [nnz] int32 (block row)
    col_idx: Array         # [nnz] int32 (block col)
    shape: Tuple[int, int] # (m, k) -- static aux data
    block_size: int        # b      -- static aux data

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.values, self.row_idx, self.col_idx), (self.shape, self.block_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, row_idx, col_idx = children
        shape, block_size = aux
        return cls(values, row_idx, col_idx, shape, block_size)

    # -- basic properties --------------------------------------------------
    @property
    def nnz_blocks(self) -> int:
        return int(self.values.shape[0])

    @property
    def grid(self) -> Tuple[int, int]:
        m, k = self.shape
        b = self.block_size
        return (_ceil_div(m, b), _ceil_div(k, b))

    @property
    def density(self) -> float:
        mb, kb = self.grid
        if mb * kb == 0:
            return 0.0
        return self.nnz_blocks / float(mb * kb)

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def is_static(self) -> bool:
        """True when the pattern is a host constant (compile-time known)."""
        return isinstance(self.row_idx, np.ndarray)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_dense(cls, dense: Array, block_size: int,
                   *, keep_mask: np.ndarray | None = None,
                   static: bool = True) -> "BlockSparseMatrix":
        """Extract non-zero ``b x b`` blocks from a dense ``[m, k]`` matrix.

        ``keep_mask`` (block grid, bool) overrides automatic non-zero
        detection; with ``static=True`` the pattern is computed on host.
        """
        m, k = dense.shape
        b = block_size
        if m % b or k % b:
            raise ValueError(f"shape {dense.shape} not divisible by block {b}")
        mb, kb = m // b, k // b
        if keep_mask is None:
            host = np.asarray(dense)
            blocked = host.reshape(mb, b, kb, b).transpose(0, 2, 1, 3)
            keep_mask = np.abs(blocked).sum(axis=(2, 3)) != 0
        keep_mask = np.asarray(keep_mask, dtype=bool)
        if keep_mask.shape != (mb, kb):
            raise ValueError(f"mask shape {keep_mask.shape} != grid {(mb, kb)}")
        rows, cols = np.nonzero(keep_mask)  # row-major order guaranteed
        order = np.lexsort((cols, rows))
        rows, cols = rows[order], cols[order]
        check_unique_blocks(rows, cols, (mb, kb))
        blocked = jnp.asarray(dense).reshape(mb, b, kb, b).transpose(0, 2, 1, 3)
        values = blocked[rows, cols]
        if static:
            return cls(values, rows.astype(np.int32), cols.astype(np.int32),
                       (m, k), b)
        return cls(values, jnp.asarray(rows, jnp.int32),
                   jnp.asarray(cols, jnp.int32), (m, k), b)

    @classmethod
    def from_mask(cls, mask: np.ndarray, block_size: int, *,
                  dtype=jnp.float32, init: str = "zeros",
                  key: jax.Array | None = None) -> "BlockSparseMatrix":
        """Allocate a BSR matrix for a given block mask (values zero/random)."""
        mb, kb = mask.shape
        b = block_size
        rows, cols = np.nonzero(np.asarray(mask, bool))
        order = np.lexsort((cols, rows))
        rows, cols = rows[order].astype(np.int32), cols[order].astype(np.int32)
        check_unique_blocks(rows, cols, (mb, kb))
        nnz = len(rows)
        if init == "zeros":
            values = jnp.zeros((nnz, b, b), dtype)
        elif init == "normal":
            if key is None:
                raise ValueError("init='normal' requires key")
            values = jax.random.normal(key, (nnz, b, b), dtype)
        else:
            raise ValueError(init)
        return cls(values, rows, cols, (mb * b, kb * b), b)

    @classmethod
    def random(cls, key: jax.Array, m: int, k: int, block_size: int,
               density: float, *, dtype=jnp.float32,
               pattern_seed: int = 0) -> "BlockSparseMatrix":
        """Random pattern + normal values, PopSparse benchmark style."""
        from repro.core import masks  # local import to avoid cycle
        mask = masks.random_block_mask(m, k, block_size, density,
                                       seed=pattern_seed)
        return cls.from_mask(mask, block_size, dtype=dtype, init="normal",
                             key=key)

    # -- conversions ---------------------------------------------------------
    def to_dense(self) -> jax.Array:
        m, k = self.shape
        b = self.block_size
        mb, kb = self.grid
        out = jnp.zeros((mb, kb, b, b), self.values.dtype)
        rows = jnp.asarray(self.row_idx)
        cols = jnp.asarray(self.col_idx)
        out = out.at[rows, cols].add(jnp.asarray(self.values))
        return out.transpose(0, 2, 1, 3).reshape(m, k)

    def block_mask(self) -> np.ndarray:
        """Host-side block mask (static patterns only)."""
        if not self.is_static:
            raise ValueError("block_mask() requires a static pattern")
        mb, kb = self.grid
        mask = np.zeros((mb, kb), bool)
        mask[self.row_idx, self.col_idx] = True
        return mask

    def validate_pattern(self) -> "BlockSparseMatrix":
        """Check static-pattern invariants (unique in-range ``(row, col)``
        pairs) and return self.  Deliberately NOT run per construction:
        pytree unflatten re-builds this object on every traced call, so
        the O(nnz log nnz) host check runs only at the explicit entry
        points (static constructors, ``partitioner.plan_packing``,
        ``MatmulPlan.evolve``)."""
        if not self.is_static:
            raise ValueError("validate_pattern() requires a static "
                             "(host-indexed) pattern")
        check_unique_blocks(self.row_idx, self.col_idx, self.grid)
        return self

    def with_values(self, values: Array) -> "BlockSparseMatrix":
        return BlockSparseMatrix(values, self.row_idx, self.col_idx,
                                 self.shape, self.block_size)

    def astype(self, dtype) -> "BlockSparseMatrix":
        return self.with_values(jnp.asarray(self.values).astype(dtype))


def dense_flops(m: int, k: int, n: int) -> int:
    return 2 * m * k * n


def sparse_flops(m: int, k: int, n: int, density: float) -> float:
    """Useful FLOPs per the paper (§3): ``2*m*k*n*d`` -- block-size free."""
    return 2.0 * m * k * n * density
