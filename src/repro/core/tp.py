"""Tensor-parallel SpMM -- the paper's partitioning lifted to the mesh.

PopSparse Fig. 1a distributes non-zero blocks over IPU tiles with uneven,
nnz-balanced k-splits, computes local dot products, then reduces partial
outputs.  At pod scale the same scheme maps onto the ``model`` mesh axis:

* each model shard owns one nnz-balanced k-partition of the blocks
  (``partitioner.shard_blocks_by_k`` -> stacked ``[q, slots, ...]``),
* each shard computes its partial ``Y`` from its blocks,
* one ``psum`` over ``model`` produces the final output -- the paper's
  "final reduction across tiles".

Two entry points:

* ``tp_spmm_shard_map`` -- explicit shard_map + psum (paper-faithful,
  collective schedule fully pinned down; the ``static_tp_shardmap``
  plan route).
* ``tp_spmm_gspmd``     -- same math under plain jit with sharding
  constraints (GSPMD inserts the psum); composes freely inside larger
  pjit programs, used by model layers (the ``static_tp`` plan route).

Which one wins is a *measured* question (the all-reduce schedule and
the local-work overlap differ), so ``repro.sparse.plan`` races both --
plus the unsharded candidates -- under measured autotune when a mesh is
given (see docs/api.md, "Tensor-parallel plans").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.partitioner import ShardedBlocks


def _shard_map():
    """jax moved shard_map out of experimental around 0.5/0.6; support
    both homes (the repo floor is jax>=0.4.30)."""
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:              # jax >= 0.6: top-level only
        from jax import shard_map
    return shard_map


def shard_map_executable(mesh, axis: str, q: int) -> bool:
    """Can ``tp_spmm_shard_map`` actually run on this mesh?  Needs a
    concrete (device-backed) mesh whose ``axis`` size equals the shard
    count ``q`` -- an ``AbstractMesh`` or a tp_q forced past the real
    device count can only execute the gspmd lowering."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return False
    try:
        # AbstractMesh either lacks .devices or raises ValueError from
        # the property (jax-version dependent) -- both mean "no devices"
        if mesh.devices is None:
            return False
    except (AttributeError, ValueError):
        return False
    return int(mesh.shape[axis]) == int(q)


def _local_spmm(values, row_idx, col_idx, x, *, mb: int, b: int):
    """Per-shard partial product: [slots,b,b] blocks against full X."""
    n = x.shape[-1]
    kb = x.shape[0] // b
    xb = x.reshape(kb, b, n)
    gathered = jnp.take(xb, col_idx, axis=0)
    partial = jnp.einsum("zab,zbn->zan", values, gathered)
    y = jax.ops.segment_sum(partial, row_idx, num_segments=mb)
    return y.reshape(mb * b, n)


def tp_spmm_shard_map(sb: ShardedBlocks, x: jax.Array, *, mesh,
                      axis: str = "model") -> jax.Array:
    """Explicit paper-style TP SpMM.  ``sb.q`` must equal the axis size
    (validated -- a mismatched shard plan would silently mis-shard)."""
    if not shard_map_executable(mesh, axis, sb.q):
        raise ValueError(
            f"tp_spmm_shard_map needs a concrete mesh with axis "
            f"{axis!r} of size q={sb.q}; got mesh axes "
            f"{tuple(getattr(mesh, 'axis_names', ()))} "
            f"{dict(getattr(mesh, 'shape', {}))}")
    mb = sb.shape[0] // sb.block_size
    b = sb.block_size

    def shard_fn(values, row_idx, col_idx, x_full):
        # leading q axis is sharded to size 1 locally
        y = _local_spmm(values[0], row_idx[0], col_idx[0], x_full,
                        mb=mb, b=b)
        return jax.lax.psum(y, axis)

    fn = _shard_map()(shard_fn, mesh=mesh,
                      in_specs=(P(axis), P(axis), P(axis), P()),
                      out_specs=P(), check_rep=False)
    return fn(sb.values, sb.row_idx, sb.col_idx, x)


def tp_spmm_gspmd(sb: ShardedBlocks, x: jax.Array, *,
                  axis: str = "model") -> jax.Array:
    """Same computation expressed for GSPMD: values sharded on the stacked
    ``q`` axis, X replicated over ``model``; the trailing sum over ``q``
    lowers to an all-reduce on the ``model`` axis."""
    from repro.sharding.rules import constrain
    mb = sb.shape[0] // sb.block_size
    b = sb.block_size
    q = sb.q
    vals = constrain(sb.values, axis)   # no-op outside a mesh context
    n = x.shape[-1]
    kb = x.shape[0] // b
    xb = x.reshape(kb, b, n)
    gathered = jnp.take(xb, sb.col_idx.reshape(-1), axis=0)  # [q*slots,b,n]
    gathered = gathered.reshape(q, sb.slots, b, n)
    partial = jnp.einsum("qzab,qzbn->qzan", vals, gathered)
    flat_rows = sb.row_idx + (jnp.arange(q, dtype=jnp.int32) * mb)[:, None]
    y = jax.ops.segment_sum(partial.reshape(q * sb.slots, b, n),
                            flat_rows.reshape(-1), num_segments=q * mb)
    y = y.reshape(q, mb, b, n).sum(axis=0)   # -> all-reduce over model
    return y.reshape(mb * b, n)
