"""PopSparse-on-TPU core: block-sparse matmul library (the paper's contribution).

Public surface:

* ``BlockSparseMatrix``        -- BSR container (static or dynamic pattern)
* ``repro.sparse``             -- THE public matmul API (plan-first:
                                  ``plan()`` once, execute decision-free;
                                  persistent autotune -- see docs/api.md)
* ``dispatch``                 -- route vocabulary + decision engine
                                  (``spmm`` etc. are plan-backed shims)
* ``static_sparse.spmm(_nt)``  -- compile-time-pattern SpMM (paper §3.2, shim)
* ``dynamic_sparse.dspmm(_nt)``-- runtime-pattern SpMM with d_max capacity (§3.3, shim)
* ``partitioner`` / ``planner``-- compile-time work distribution (§3.2/§3.3)
* ``tp``                       -- the partitioning lifted to the mesh
* ``sparse_layers``            -- SparseLinear / SparseFFN / DynamicSparseLinear
* ``masks`` / ``pruning``      -- pattern generation + sparse training
"""
from repro.core.bsr import BlockSparseMatrix, dense_flops, sparse_flops  # noqa: F401
from repro.core import (  # noqa: F401
    dispatch,
    dynamic_sparse,
    masks,
    partitioner,
    planner,
    pruning,
    sparse_layers,
    static_sparse,
    tp,
)
