"""PopSparse-on-TPU core: block-sparse matmul library (the paper's contribution).

Public surface:

* ``BlockSparseMatrix``        -- BSR container (static or dynamic pattern)
* ``dispatch.spmm(_nt)``       -- THE matmul entry point: routed + autotuned
                                  across dense / static / dynamic backends
* ``static_sparse.spmm(_nt)``  -- compile-time-pattern SpMM (paper §3.2)
* ``dynamic_sparse.dspmm(_nt)``-- runtime-pattern SpMM with d_max capacity (§3.3)
* ``partitioner`` / ``planner``-- compile-time work distribution (§3.2/§3.3)
* ``tp``                       -- the partitioning lifted to the mesh
* ``sparse_layers``            -- SparseLinear / SparseFFN / DynamicSparseLinear
* ``masks`` / ``pruning``      -- pattern generation + sparse training
"""
from repro.core.bsr import BlockSparseMatrix, dense_flops, sparse_flops  # noqa: F401
from repro.core import (  # noqa: F401
    dispatch,
    dynamic_sparse,
    masks,
    partitioner,
    planner,
    pruning,
    sparse_layers,
    static_sparse,
    tp,
)
