"""Dynamic-sparsity planner (PopSparse §3.3, Appendix A.2).

With dynamic sparsity only ``d_max`` is known at compile time.  The paper's
planner chooses how many **equal** parts to divide each of (m, k, n) into
(``q^m, q^k, q^n``), each partition mapping to one compute unit, and sizes
fixed *buckets* for metaInfo + non-zero values:

    N_nonzero = m * k * d_max / (q^m * q^k)        (+ headroom)

On TPU the "compute units" are (a) grid steps of the dsmm Pallas kernel on
one chip and (b) chips on the ``model`` mesh axis.  The planner here keeps
the paper's structure -- an analytic cost model over (q^m, q^k, q^n)
triples, evaluated at compile time -- with TPU constants (MXU rate, HBM
and ICI bandwidth) instead of IPU tile/exchange cycles.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# TPU v5e single-chip constants (see system brief)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HEADROOM = 1.25  # paper: "some extra headroom is given in the size of these buckets"


@dataclasses.dataclass(frozen=True)
class DynamicPlan:
    q_m: int
    q_k: int
    q_n: int
    bucket_blocks: int     # non-zero-block capacity per (q_m x q_k) bucket
    nnz_max_blocks: int    # total block slots across buckets (>= true nnz)
    est_seconds: float
    shape: Tuple[int, int, int]   # (m, k, n)
    block_size: int
    d_max: float

    @property
    def total_partitions(self) -> int:
        return self.q_m * self.q_k * self.q_n


def _divisor_candidates(dim_blocks: int, limit: int) -> list[int]:
    cands = set()
    q = 1
    while q <= min(dim_blocks, limit):
        cands.add(q)
        q *= 2
    for q in range(1, min(dim_blocks, limit) + 1):
        if dim_blocks % q == 0:
            cands.add(q)
    return sorted(cands)


def _cost(m: int, k: int, n: int, d_max: float, b: int,
          q_m: int, q_k: int, q_n: int, bytes_per_el: int,
          units: int) -> float:
    """Estimated step time for one unit, paper-style phase decomposition."""
    parts_mk = q_m * q_k
    bucket_blocks = math.ceil(m * k * d_max / (b * b) / parts_mk * HEADROOM)
    # compute: bucket FLOPs on this unit's n-slice
    flops = 2.0 * bucket_blocks * b * b * (n / q_n)
    t_compute = flops / PEAK_FLOPS_BF16
    # distribution phase: move dense input slice + bucket into local memory
    in_bytes = (k / q_k) * (n / q_n) * bytes_per_el
    bucket_bytes = bucket_blocks * b * b * bytes_per_el + bucket_blocks * 8
    t_dist = (in_bytes + bucket_bytes) / HBM_BW
    # reduction across q_k partial outputs (log-tree on ICI when sharded)
    out_bytes = (m / q_m) * (n / q_n) * bytes_per_el
    t_reduce = out_bytes * max(0, q_k - 1) / max(q_k, 1) / ICI_BW
    # propagation headroom: imbalance risk grows with parts_mk (paper worst
    # case needs up to q_m*q_k extra exchange+compute steps); model the
    # expected overhead as a mild superlinear penalty.
    t_prop = t_compute * 0.1 * math.log2(max(2, parts_mk))
    return t_compute + t_dist + t_reduce + t_prop


def plan_dynamic(m: int, k: int, n: int, *, d_max: float, block_size: int,
                 units: int = 16, bytes_per_el: int = 2) -> DynamicPlan:
    """Pick (q^m, q^k, q^n) minimizing the analytic cost model.

    ``units`` is the parallel-unit budget (q^m*q^k*q^n <= units), e.g. the
    ``model`` mesh-axis size for a TP deployment or a per-chip grid budget.
    """
    b = block_size
    mb, kb, nb = m // b, k // b, max(1, n // b)
    best = None
    for q_m in _divisor_candidates(mb, units):
        for q_k in _divisor_candidates(kb, units // q_m):
            rem = units // (q_m * q_k)
            if rem < 1:
                continue
            for q_n in _divisor_candidates(nb, rem):
                c = _cost(m, k, n, d_max, b, q_m, q_k, q_n,
                          bytes_per_el, units)
                if best is None or c < best[0]:
                    best = (c, q_m, q_k, q_n)
    assert best is not None
    c, q_m, q_k, q_n = best
    parts_mk = q_m * q_k
    bucket = math.ceil(m * k * d_max / (b * b) / parts_mk * HEADROOM)
    return DynamicPlan(q_m, q_k, q_n, bucket, bucket * parts_mk, c,
                       (m, k, n), b, d_max)


def nnz_max_blocks(m: int, k: int, block_size: int, d_max: float) -> int:
    """Total block-slot budget implied by ``d_max`` (no partitioning)."""
    grid = (m // block_size) * (k // block_size)
    return max(1, math.ceil(grid * d_max))


# ---------------------------------------------------------------------------
# Grouped-route capacity planning (paper §3.3 bucket sizing applied to the
# dynamic_grouped tile slots): capacity = expected occupancy + headroom,
# NOT the safe worst case -- overflow is accepted and accounted for.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupedCapacityPlan:
    """Planned tile capacity for the ``dynamic_grouped`` route.

    tile            physical tile side (MXU-aligned block multiple)
    expected_tiles  analytic E[#distinct non-empty tiles] for a uniform
                    random pattern at ``d_max``
    worst_tiles     safe worst case: every slot in its own tile, capped
                    at the tile grid (what PR 2 always allocated)
    tiles_cap       the planned capacity actually allocated:
                    min(worst, ceil(expected * headroom))
    headroom        the multiplicative slack over the expectation (the
                    paper's "some extra headroom")
    overflow_p      analytic P[#distinct tiles > tiles_cap] (normal
                    approximation over per-tile occupancy)
    """

    tile: int
    expected_tiles: float
    worst_tiles: int
    tiles_cap: int
    headroom: float
    overflow_p: float

    def as_dict(self) -> dict:
        return {"tile": self.tile,
                "expected_tiles": round(self.expected_tiles, 3),
                "worst_tiles": self.worst_tiles,
                "tiles_cap": self.tiles_cap,
                "headroom": self.headroom,
                "overflow_p": round(self.overflow_p, 6)}


def expected_grouped_tiles(m: int, k: int, block_size: int, density: float,
                           tile: int) -> float:
    """E[#distinct non-empty (tile x tile) tiles] for a uniform random
    block pattern: each tile holds ``(tile/b)^2`` logical blocks and is
    non-empty with probability ``1 - (1 - d)^per_tile``."""
    mt, kt = max(1, m // tile), max(1, k // tile)
    per_tile = (tile // block_size) ** 2
    d = min(max(density, 0.0), 1.0)
    p = 1.0 - (1.0 - d) ** per_tile
    return mt * kt * p


def grouped_overflow_probability(m: int, k: int, block_size: int,
                                 density: float, tile: int,
                                 tiles_cap: int,
                                 slots: Optional[int] = None) -> float:
    """Analytic P[#distinct non-empty tiles > tiles_cap] under the same
    random-pattern model (normal approximation with per-tile Bernoulli
    variance -- slightly conservative vs the true without-replacement
    pattern, which has less spread).  ``slots`` is the operand's
    block-slot capacity: distinct tiles can never exceed it, so a
    ``tiles_cap`` at (or above) that bound provably cannot overflow."""
    mt, kt = max(1, m // tile), max(1, k // tile)
    per_tile = (tile // block_size) ** 2
    d = min(max(density, 0.0), 1.0)
    p = 1.0 - (1.0 - d) ** per_tile
    n_tiles = mt * kt
    hard_max = n_tiles if slots is None else min(n_tiles, int(slots))
    if tiles_cap >= hard_max:
        return 0.0
    mu = n_tiles * p
    var = n_tiles * p * (1.0 - p)
    if var <= 0.0:
        return 0.0 if tiles_cap >= mu else 1.0
    z = (tiles_cap + 0.5 - mu) / math.sqrt(var)
    return 0.5 * (1.0 - math.erf(z / math.sqrt(2.0)))


def plan_grouped_capacity(m: int, k: int, block_size: int, d_max: float,
                          *, tile: int, slots: Optional[int] = None,
                          headroom: float = HEADROOM) -> GroupedCapacityPlan:
    """Size the ``dynamic_grouped`` tile-slot bucket the paper's way:
    expected occupancy times ``headroom``, clamped to the safe worst
    case.  ``slots`` is the operand's block-slot capacity (defaults to
    the ``d_max`` budget); the worst case is one tile per slot, capped
    at the tile grid."""
    mt, kt = max(1, m // tile), max(1, k // tile)
    if slots is None:
        slots = nnz_max_blocks(m, k, block_size, d_max)
    worst = max(1, min(int(slots), mt * kt))
    expected = expected_grouped_tiles(m, k, block_size, d_max, tile)
    cap = max(1, min(worst, math.ceil(expected * headroom)))
    return GroupedCapacityPlan(
        tile=tile, expected_tiles=expected, worst_tiles=worst,
        tiles_cap=cap, headroom=float(headroom),
        overflow_p=grouped_overflow_probability(m, k, block_size, d_max,
                                                tile, cap, slots=slots))
