"""Dynamic block-sparse matmul (PopSparse §3.3, Appendix A.2) -- public API.

Only the *maximum density* ``d_max`` is fixed at compile time; the pattern
is data.  The compile-time planner (``planner.plan_dynamic``) sizes fixed
buckets; the runtime **encoder** (the paper's "host utility", here a
jittable device function) packs the pattern into fixed-size slot arrays:

    values  [S, b, b]   non-zero blocks (zero-padded)
    row_idx [S]         block-row per slot
    col_idx [S]         block-col per slot

Padded slots carry zero values at (row 0, col 0): they contribute exactly
zero, which is the TPU analogue of the paper's overflow/propagation steps
-- the hardware still *executes* them (fixed grid), it just does no useful
work.  That cost asymmetry (dynamic pays padded slots + runtime encode,
static pays nothing) reproduces the paper's static-vs-dynamic gap by
construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.core.bsr import BlockSparseMatrix


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DynamicOperand:
    """Fixed-capacity encoded sparse operand (bucketed, runtime pattern)."""

    values: jax.Array    # [S, b, b]
    row_idx: jax.Array   # [S] int32
    col_idx: jax.Array   # [S] int32
    nnz: jax.Array       # [] int32 -- true block count this step
    shape: Tuple[int, int]
    block_size: int

    def __post_init__(self):
        # static-aux validation only (values/indices may be tracers or
        # placeholder leaves during pytree transformations)
        m, k = self.shape
        b = self.block_size
        if b <= 0:
            raise ValueError(f"block_size must be positive, got {b}")
        if m % b or k % b:
            raise ValueError(
                f"DynamicOperand shape {self.shape} is not divisible by "
                f"block_size {b}; pad the operand to block multiples "
                f"(ceil-div grids would leave partial blocks the encoded "
                f"slot arrays cannot address)")

    def tree_flatten(self):
        return ((self.values, self.row_idx, self.col_idx, self.nnz),
                (self.shape, self.block_size))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def capacity(self) -> int:
        return int(self.values.shape[0])

    @property
    def grid(self):
        # ceil-div, consistent with BlockSparseMatrix.grid (divisibility is
        # enforced in __post_init__, so this equals floor-div in practice;
        # ceil keeps the two containers interchangeable in grid math)
        b = self.block_size
        return (-(-self.shape[0] // b), -(-self.shape[1] // b))

    def to_dense(self) -> jax.Array:
        mb, kb = self.grid
        b = self.block_size
        out = jnp.zeros((mb, kb, b, b), self.values.dtype)
        out = out.at[self.row_idx, self.col_idx].add(self.values)
        return out.transpose(0, 2, 1, 3).reshape(self.shape)


def encode(dense_w: jax.Array, block_mask: jax.Array, *, block_size: int,
           nnz_max: int) -> DynamicOperand:
    """Runtime encoder: pack masked blocks of ``dense_w`` into ``nnz_max``
    slots.  Jit-compatible (static output shapes); overflowing blocks
    beyond capacity are dropped lowest-priority-last, mirroring bucket
    overflow in the paper.

    ``block_mask``: [mb, kb] bool (may be traced).
    """
    m, k = dense_w.shape
    b = block_size
    if m % b or k % b:
        raise ValueError(f"shape {dense_w.shape} not divisible by "
                         f"block {b}")
    mb, kb = m // b, k // b
    if block_mask.shape != (mb, kb):
        raise ValueError(f"mask shape {block_mask.shape} != grid "
                         f"{(mb, kb)}")
    flat = block_mask.reshape(-1)
    # stable order: active blocks first, in row-major order
    order = jnp.argsort(~flat, stable=True)
    sel = order[:nnz_max]
    count = jnp.minimum(jnp.sum(flat.astype(jnp.int32)), nnz_max)
    valid = jnp.arange(nnz_max) < count
    rows = jnp.where(valid, sel // kb, 0).astype(jnp.int32)
    cols = jnp.where(valid, sel % kb, 0).astype(jnp.int32)
    blocked = dense_w.reshape(mb, b, kb, b).transpose(0, 2, 1, 3)
    vals = blocked[rows, cols] * valid[:, None, None].astype(dense_w.dtype)
    return DynamicOperand(vals, rows, cols, count, (m, k), b)


def encode_from_bsr(bsr: BlockSparseMatrix, *, nnz_max: int) -> DynamicOperand:
    """Encode an existing (possibly static) BSR into fixed capacity slots."""
    m, k = bsr.shape
    if m % bsr.block_size or k % bsr.block_size:
        raise ValueError(
            f"BSR shape {bsr.shape} is not divisible by block_size "
            f"{bsr.block_size}; cannot encode partial blocks into fixed "
            f"slots -- pad the matrix to block multiples first")
    nnz = bsr.nnz_blocks
    if nnz > nnz_max:
        raise ValueError(
            f"pattern nnz {nnz} exceeds capacity nnz_max={nnz_max}; raise "
            f"nnz_max (or d_max upstream) to at least {nnz}, or prune the "
            f"pattern before encoding")
    b = bsr.block_size
    pad = nnz_max - nnz
    vals = jnp.concatenate(
        [jnp.asarray(bsr.values),
         jnp.zeros((pad, b, b), bsr.values.dtype)], axis=0)
    rows = jnp.concatenate([jnp.asarray(bsr.row_idx, jnp.int32),
                            jnp.zeros((pad,), jnp.int32)])
    cols = jnp.concatenate([jnp.asarray(bsr.col_idx, jnp.int32),
                            jnp.zeros((pad,), jnp.int32)])
    return DynamicOperand(vals, rows, cols, jnp.asarray(nnz, jnp.int32),
                          bsr.shape, b)


# ---------------------------------------------------------------------------
# Matmul -- same contraction as static, with runtime (traced) indices.
# segment_sum becomes a scatter-add; gathers are dynamic.  Differentiable
# w.r.t. values and x (indices are integer data).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _dspmm(values, row_idx, col_idx, x, mb: int, b: int):
    n = x.shape[-1]
    kb = x.shape[0] // b
    xb = x.reshape(kb, b, n)
    gathered = jnp.take(xb, col_idx, axis=0)
    partial = jnp.einsum("zab,zbn->zan", values, gathered)
    y = jax.ops.segment_sum(partial, row_idx, num_segments=mb)
    return y.reshape(mb * b, n)


def _dspmm_fwd(values, row_idx, col_idx, x, mb, b):
    return _dspmm(values, row_idx, col_idx, x, mb, b), \
        (values, row_idx, col_idx, x)


def _dspmm_bwd(mb, b, res, dy):
    values, row_idx, col_idx, x = res
    n = x.shape[-1]
    kb = x.shape[0] // b
    dyb = dy.reshape(mb, b, n)
    xb = x.reshape(kb, b, n)
    dyg = jnp.take(dyb, row_idx, axis=0)
    xg = jnp.take(xb, col_idx, axis=0)
    dvalues = jnp.einsum("zan,zbn->zab", dyg, xg).astype(values.dtype)
    partial = jnp.einsum("zab,zan->zbn", values, dyg)
    dx = jax.ops.segment_sum(partial, col_idx, num_segments=kb)
    return dvalues, None, None, dx.reshape(kb * b, n).astype(x.dtype)


_dspmm.defvjp(_dspmm_fwd, _dspmm_bwd)


def dspmm(op: DynamicOperand, x: jax.Array, *, backend: str = "auto",
          interpret: bool = False) -> jax.Array:
    """``Y = decode(op) @ X`` with ``X: [k, n]`` -> ``Y: [m, n]``.

    DEPRECATED shim: prefer ``repro.sparse.plan(op, n)``.  ``backend``
    maps onto the plan-first routes: "auto" lets the planner choose;
    "xla"/"pallas"/"grouped" force the corresponding dynamic route."""
    if x.shape[0] != op.shape[1]:
        raise ValueError(f"X rows {x.shape[0]} != k {op.shape[1]}")
    from repro.core import dispatch  # local import: dispatch imports us
    mode = {"auto": "auto", "xla": "dynamic_xla",
            "pallas": "dynamic_pallas",
            "grouped": "dynamic_grouped"}.get(backend)
    if mode is None:
        raise ValueError(f"unknown backend {backend!r}")
    ctx = dispatch.DispatchContext(mode=mode, interpret=interpret)
    return dispatch.spmm(op, x, ctx=ctx)


def dspmm_nt(op: DynamicOperand, x: jax.Array, **kw) -> jax.Array:
    """Activation-major form ``x: [..., k] -> [..., m]``."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, op.shape[1]).T
    y = dspmm(op, x2, **kw)
    return y.T.reshape(*lead, op.shape[0])


# ---------------------------------------------------------------------------
# Kernel contract (tools/lint/contracts.py cross-checks this against
# the dispatch admissibility gates)
# ---------------------------------------------------------------------------

from repro.kernels.contract import KernelContract, register as _register_contract  # noqa: E402

# one-hot scatter XLA formulation over the fixed slot array: any
# block-multiple shape, slot capacity = nnz_max, differentiable
CONTRACT = _register_contract(KernelContract(
    kernel="dynamic_xla",
    routes=("dynamic_xla",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=1024,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="no tile grid: slot-wise one-hot scatter-add over mb block rows",
    capacity="slot_capacity",
    pallas=False,
))
