from repro.train.step import (TrainState, init_train_state,  # noqa: F401
                              make_train_step, microbatch_grads)
