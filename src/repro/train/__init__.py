from repro.train.step import TrainState, make_train_step, init_train_state  # noqa: F401
