"""Train step: loss -> grad -> clip -> AdamW, with optional microbatch
gradient accumulation (lax.scan) and error-feedback int8 gradient
compression.

The returned ``train_step(state, batch)`` is what the multi-pod dry-run
lowers for every ``train_4k`` cell: params/opt-state shardings come from
``sharding/rules.py``; the batch is sharded over ('pod','data').
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.optim.adamw import (AdamState, adamw_init, adamw_update,
                               clip_by_global_norm)
from repro.optim.compress import EFState, compress_grads, ef_init
from repro.optim.schedule import warmup_cosine


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: AdamState
    ef: Optional[EFState]    # None unless gradient compression enabled


class TrainHParams(NamedTuple):
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    clip_norm: float = 1.0
    weight_decay: float = 0.1
    accum: int = 1                 # microbatch accumulation factor
    grad_compress: bool = False


def init_train_state(lm: LM, key, *, hp: TrainHParams = TrainHParams()
                     ) -> TrainState:
    params = lm.init(key)
    return TrainState(jnp.zeros((), jnp.int32), params, adamw_init(params),
                      ef_init(params) if hp.grad_compress else None)


def microbatch_grads(grad_fn, params, batch, accum: int):
    """Gradient accumulation over ``accum`` microbatches (lax.scan).

    ``grad_fn(params, microbatch) -> ((loss, metrics), grads)`` is a
    ``jax.value_and_grad(..., has_aux=True)`` of any loss -- including
    losses through ``repro.sparse`` plans: the plan-level ``custom_vjp``
    runs its planned backward products once per scan iteration, exactly
    like the forward route.  The batch is split on axis 0; fp32 grads
    accumulate sequentially (peak activation memory drops to 1/accum);
    loss/metrics/grads come back microbatch-averaged.

    Public so tests and custom training loops share the exact scan the
    production ``make_train_step`` compiles.
    """
    if accum == 1:
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def resplit(x):
        b = x.shape[0]
        return x.reshape(accum, b // accum, *x.shape[1:])

    micro = jax.tree.map(resplit, batch)
    first = jax.tree.map(lambda x: x[0], micro)
    # metrics structure is loss-defined: derive the zero carry from the
    # abstract output instead of hard-coding the LM metric names
    m_shape = jax.eval_shape(lambda p, mb: grad_fn(p, mb)[0][1],
                             params, first)
    zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)

    def acc_fn(carry, mb):
        tot_loss, tot_metrics, acc = carry
        (loss, metrics), grads = grad_fn(params, mb)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), acc, grads)
        tot_metrics = jax.tree.map(jnp.add, tot_metrics, metrics)
        return (tot_loss + loss, tot_metrics, acc), None

    zero_g = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, metrics, grads), _ = jax.lax.scan(
        acc_fn, (jnp.zeros((), jnp.float32), zero_m, zero_g), micro)
    inv = 1.0 / accum
    return (loss * inv, jax.tree.map(lambda m: m * inv, metrics),
            jax.tree.map(lambda g: g * inv, grads))


def rigl_evolve(plan_, values, dense_grad, *, fraction: float, rng):
    """One RigL topology step on a *static* sparse plan: drop the
    ``fraction`` lowest-|W| active blocks, regrow by largest |dense
    gradient|, then ``plan.evolve`` onto the new pattern and carry the
    surviving values (grown blocks start at zero, RigL's convention).

    ``dense_grad`` is the dense-position gradient ``dL/dW`` at every
    block (active and inactive) -- for an spmm plan ``y = W @ x`` that
    is ``dy @ x.T``.  Returns ``(new_plan, new_values)``.  Constant nnz
    by construction, so the evolved plan re-uses the parent's route and
    backward verdicts unless the drift guardrail trips.
    """
    import numpy as np

    from repro.core import pruning
    from repro.core.bsr import BlockSparseMatrix

    s = plan_.spec
    rows, cols = plan_.pattern
    b = s.block_size
    bsr = BlockSparseMatrix(values, rows, cols, (s.m, s.k), b)
    new_mask = pruning.rigl_update(
        bsr.to_dense(), jnp.asarray(dense_grad),
        jnp.asarray(bsr.block_mask()), block_size=b,
        fraction=fraction, rng=rng)
    new_plan = plan_.evolve(np.asarray(new_mask))
    return new_plan, new_plan.carry_values(values)


def make_train_step(lm: LM, hp: TrainHParams = TrainHParams()):
    def loss_fn(params, batch):
        loss, metrics = lm.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        return microbatch_grads(grad_fn, params, batch, hp.accum)

    def train_step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        ef = state.ef
        if hp.grad_compress:
            grads, ef = compress_grads(grads, ef)
        grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
        lr = warmup_cosine(state.step, peak_lr=hp.peak_lr,
                           warmup_steps=hp.warmup_steps,
                           total_steps=hp.total_steps)
        new_params, new_opt = adamw_update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=hp.weight_decay)
        new_state = TrainState(state.step + 1, new_params, new_opt, ef)
        out_metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, out_metrics

    return train_step
