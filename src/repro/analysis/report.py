"""Aggregate dry-run JSON records into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.analysis.report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def load_records(mesh: str | None = None, tag: str = ""):
    """Dry-run records matching ``mesh``/``tag``.  A missing records
    directory raises (an empty table used to silently hide a wrong
    path or an un-run dry-run step); an existing-but-unmatched dir
    returns [] -- that is a real "no records yet" answer."""
    dryrun = os.path.normpath(DRYRUN_DIR)
    if not os.path.isdir(dryrun):
        raise FileNotFoundError(
            f"dry-run records directory does not exist: {dryrun} -- "
            f"generate records first (see experiments/dryrun in "
            f"EXPERIMENTS.md) or check the working tree layout")
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*{tag}.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if tag and not base.endswith(tag):
            continue
        if not tag and len(parts[2].split("_")) > 1 and parts[2] not in (
                "16x16", "2x16x16"):
            continue
        with open(path) as f:
            r = json.load(f)
        if mesh and r["mesh"] != mesh:
            continue
        recs.append(r)
    return recs


def _fmt_ms(s):
    return f"{s*1e3:10.2f}"


def table(recs, *, fmt: str = "md") -> str:
    rows = []
    hdr = ["arch", "shape", "mesh", "t_comp(ms)", "t_mem(ms)",
           "t_coll(ms)", "bound", "useful_frac", "roofline_frac"]
    for r in recs:
        ro = r["roofline"]
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            f"{ro['t_compute']*1e3:.2f}", f"{ro['t_memory']*1e3:.2f}",
            f"{ro['t_collective']*1e3:.2f}", ro["dominant"],
            f"{ro.get('useful_flop_frac', 0):.3f}",
            f"{ro.get('roofline_frac', 0):.4f}"])
    if fmt == "md":
        out = ["| " + " | ".join(hdr) + " |",
               "|" + "---|" * len(hdr)]
        out += ["| " + " | ".join(map(str, row)) + " |" for row in rows]
        return "\n".join(out)
    w = [max(len(str(x)) for x in [h] + [row[i] for row in rows])
         for i, h in enumerate(hdr)]
    out = ["  ".join(h.ljust(w[i]) for i, h in enumerate(hdr))]
    out += ["  ".join(str(x).ljust(w[i]) for i, x in enumerate(row))
            for row in rows]
    return "\n".join(out)


def interesting_cells(recs):
    """The three hillclimb picks per the brief."""
    ranked = sorted((r for r in recs if "roofline_frac" in r["roofline"]),
                    key=lambda r: r["roofline"]["roofline_frac"])
    worst = ranked[0] if ranked else None
    coll = max(recs, key=lambda r: r["roofline"]["t_collective"] /
               max(r["roofline"]["bound_seconds"], 1e-12))
    return worst, coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--fmt", default="txt", choices=["md", "txt"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    recs = load_records(args.mesh, tag=args.tag)
    print(table(recs, fmt=args.fmt))
    if recs:
        worst, coll = interesting_cells(recs)
        print(f"\nworst roofline frac: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline']['roofline_frac']:.4f})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']}")


if __name__ == "__main__":
    main()
