"""Roofline terms from dry-run artifacts (TPU v5e target constants).

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

The analyzer inputs are already per-device (post-SPMD module), so no
further division by chip count is needed.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops_bf16: float
    hbm_bw: float
    ici_bw: float


V5E = HwSpec("tpu-v5e", 197e12, 819e9, 50e9)


def roofline_terms(cost: dict, hw: HwSpec = V5E, *, model_flops_per_device:
                   float | None = None) -> dict:
    t_compute = cost["flops"] / hw.peak_flops_bf16
    t_memory = cost["bytes"] / hw.hbm_bw
    t_collective = cost["collective_bytes"] / hw.ici_bw
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    out = dict(t_compute=t_compute, t_memory=t_memory,
               t_collective=t_collective, dominant=dominant,
               bound_seconds=max(terms.values()))
    if model_flops_per_device is not None and cost["flops"] > 0:
        out["model_flops"] = model_flops_per_device
        out["useful_flop_frac"] = model_flops_per_device / cost["flops"]
        # roofline fraction: useful work at peak / achievable step time
        out["roofline_frac"] = (model_flops_per_device / hw.peak_flops_bf16
                                ) / max(terms.values())
    return out


def route_efficiency(est_seconds: float, cost: dict, hw: HwSpec = V5E, *,
                     flag_headroom: float = 2.0) -> dict:
    """How close a route's (estimated or measured) time sits to its
    roofline bound for the work in ``cost`` (an analyzer-style dict:
    flops / bytes / collective_bytes).

    ``efficiency`` is bound/achieved in (0, 1]; ``headroom`` its
    reciprocal.  ``flagged`` marks routes leaving more than
    ``flag_headroom``x on the table -- the kernel-work signal the
    sparsity-roofline paper argues for (a route at 4x headroom is a
    kernel to fix, not a shape to avoid)."""
    bound = roofline_terms(cost, hw)
    achieved = max(float(est_seconds), 1e-12)
    eff = min(1.0, bound["bound_seconds"] / achieved)
    headroom = achieved / max(bound["bound_seconds"], 1e-12)
    return {
        "achieved_seconds": achieved,
        "bound_seconds": bound["bound_seconds"],
        "dominant": bound["dominant"],
        "efficiency": eff,
        "headroom": headroom,
        "flagged": headroom > flag_headroom,
    }


def model_flops_train(n_active_params: int, tokens: int) -> float:
    """6·N·D for a train step (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_active_params * tokens


def model_flops_forward(n_active_params: int, tokens: int) -> float:
    """2·N·D for inference (prefill/decode)."""
    return 2.0 * n_active_params * tokens
