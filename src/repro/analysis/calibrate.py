"""Fit the dispatch cost-model coefficients from the benchmark corpus.

``dispatch._estimate_raw`` prices each route from first principles
(MXU/bandwidth cycles, the grouped-capacity ``tiles_cap`` bucket, the
skew knee).  This module closes the loop against measurements: it
replays every (route, shape, time) observation in the committed
``benchmarks/baselines/BENCH_*.json`` corpus — plus any locally
produced bench JSONs — through the *uncalibrated* model and fits a
per-route affine correction

    t_cal = scale[route] * t_raw + fixed_us[route]

by ordinary least squares (median-ratio scale-only when a route has too
few observations for a stable intercept), plus the ``_skew_factor``
slopes from the skew-annotated records.  The result is written to
``benchmarks/baselines/cost_coeffs.json``; ``dispatch`` loads it at
import and mixes its content digest into every decision cache key and
plan fingerprint, so a refit invalidates stale verdicts like a schema
bump.

Design constraints, in order:

* **Tie stability.**  The corpus contains exact route ties
  (``static_pallas == dense_pallas`` on pallas-off grids) whose
  resolution is dict-insertion order.  Fitted corrections within noise
  of identity are snapped *to* identity (``SCALE_SNAP`` /
  ``FIXED_SNAP_US``) so calibration never perturbs an exact tie into a
  spurious crossover.
* **Idempotence.**  The fit always runs against the identity model
  (``_identity_model`` swaps it in), never against the currently
  installed coefficients — refitting from an unchanged corpus emits a
  byte-identical file.
* **Determinism.**  No RNG, no wall clock: the corpus is the only
  input, so `calibrate --update` is reproducible in CI (and repro-lint
  R005 has nothing to suppress here).

CLI::

    PYTHONPATH=src python -m repro.analysis.calibrate            # dry run
    PYTHONPATH=src python -m repro.analysis.calibrate --update   # (re)fit
    PYTHONPATH=src python -m repro.analysis.calibrate \
        --corpus benchmarks/out/BENCH_*.json --report fit.json

A refreshed ``cost_coeffs.json`` is a baseline re-sign: CI requires the
literal string ``re-sign`` in the commit/PR title (see docs/dev.md).
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import glob
import json
import os
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dispatch

BASELINE_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..",
    "benchmarks", "baselines"))
DEFAULT_OUT = os.path.join(BASELINE_DIR, "cost_coeffs.json")

COEFFS_VERSION = 1

# fit guard rails: a corpus glitch must not produce a model that
# reorders every race
SCALE_BOUNDS = (0.25, 4.0)
FIXED_BOUNDS_US = (0.0, 100.0)
SLOPE_BOUNDS = (0.0, 2.0)
# snap-to-identity tolerances (see module docstring: tie stability)
SCALE_SNAP = 0.02
FIXED_SNAP_US = 1.0
SLOPE_SNAP_REL = 0.05
MIN_AFFINE_OBS = 3          # fewer -> median-ratio scale, no intercept
MIN_SPREAD_REL = 0.05       # x-range below this -> intercept unidentifiable


@dataclasses.dataclass(frozen=True)
class Observation:
    """One (route, shape) -> measured-microseconds corpus point."""

    fig: str
    route: str
    m: int
    k: int
    n: int
    b: int
    density: float
    dtype: str = "float32"
    imbalance: float = 1.0
    cv: float = 0.0
    measured_us: float = 0.0
    source: str = ""


# ---------------------------------------------------------------------------
# Corpus extraction (one extractor per benchmark figure)
# ---------------------------------------------------------------------------

_KNOWN_ROUTES = frozenset(dispatch.ROUTES) | frozenset(dispatch.SDDMM_ROUTES)


def _candidate_obs(rec: dict, fig: str, source: str, *,
                   imbalance: float = 1.0, cv: float = 0.0,
                   ) -> List[Observation]:
    out = []
    m = int(rec["m"])
    for route, us in (rec.get("candidates") or {}).items():
        if route not in _KNOWN_ROUTES:
            continue
        out.append(Observation(
            fig=fig, route=route, m=m, k=m, n=int(rec["n"]),
            b=int(rec["b"]), density=float(rec["density"]),
            imbalance=imbalance, cv=cv,
            measured_us=float(us), source=source))
    return out


def _extract_dispatch(rec: dict, source: str) -> List[Observation]:
    return _candidate_obs(rec, "dispatch", source)


def _extract_skewed(rec: dict, source: str) -> List[Observation]:
    return _candidate_obs(
        rec, "skewed_patterns", source,
        imbalance=float(rec.get("imbalance", 1.0)),
        cv=float(rec.get("cv", 0.0)))


def _extract_train_grad(rec: dict, source: str) -> List[Observation]:
    # fwd and dx are SpMM over the (k=m) square patterns; dv is the
    # block SDDMM.  The dense baseline inside the record is derived,
    # not measured, so only the three routed legs become observations.
    out = []
    m = int(rec["m"])
    for leg in ("fwd", "dx", "dv"):
        route = rec.get(f"{leg}_route")
        us = rec.get(f"{leg}_us")
        if route in _KNOWN_ROUTES and us is not None:
            out.append(Observation(
                fig="train_grad", route=route, m=m, k=m,
                n=int(rec["n"]), b=int(rec["b"]),
                density=float(rec["density"]),
                measured_us=float(us), source=source))
    return out


# grouped_capacity records carry no time fields and tp records price
# through _tp_estimate (a different code path) -- both are excluded
EXTRACTORS = {
    "dispatch": _extract_dispatch,
    "skewed_patterns": _extract_skewed,
    "train_grad": _extract_train_grad,
}


def load_corpus(paths: Optional[Sequence[str]] = None,
                ) -> List[Observation]:
    """Observations from the committed baselines plus ``paths`` extras.

    Each file is either ``{fig: [records]}`` (the baseline format) or a
    bare record list (``benchmarks/run.py`` local output); records from
    figures without an extractor are ignored.
    """
    files = sorted(glob.glob(os.path.join(BASELINE_DIR, "BENCH_*.json")))
    for p in paths or ():
        hits = sorted(glob.glob(p))
        if not hits:
            raise FileNotFoundError(f"corpus glob matched nothing: {p}")
        files.extend(hits)
    obs: List[Observation] = []
    for path in files:
        with open(path) as f:
            blob = json.load(f)
        groups = (blob.items() if isinstance(blob, dict)
                  else [(None, blob)])
        src = os.path.basename(path)
        for fig, recs in groups:
            for rec in recs:
                extract = EXTRACTORS.get(fig or rec.get("fig", ""))
                if extract is not None:
                    obs.extend(extract(rec, src))
    return obs


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def _identity_model():
    """Evaluate ``_estimate_raw`` under the hand-tuned constants so a
    refit never compounds on the previously fitted coefficients."""
    prev = dispatch.cost_coeffs()
    dispatch.set_cost_coeffs(dispatch.IDENTITY_COEFFS)
    try:
        yield
    finally:
        dispatch.set_cost_coeffs(prev)


def _raw_us(o: Observation, *, skewless: bool = False) -> float:
    imb, cv = (1.0, 0.0) if skewless else (o.imbalance, o.cv)
    return dispatch._estimate_raw(
        o.route, o.m, o.k, o.n, o.b, o.density, o.dtype,
        imbalance=imb, cv=cv) * 1e6


def _snap(value: float, target: float, tol: float) -> float:
    return target if abs(value - target) <= tol else value


def _fit_route(xs: np.ndarray, ys: np.ndarray) -> Tuple[float, float]:
    """(scale, fixed_us) for one route: OLS when the corpus identifies
    an intercept, median-ratio scale otherwise."""
    spread = (xs.max() - xs.min()) / max(xs.mean(), 1e-12)
    if len(xs) >= MIN_AFFINE_OBS and spread >= MIN_SPREAD_REL:
        scale, fixed = np.polyfit(xs, ys, 1)
        if not (FIXED_BOUNDS_US[0] <= fixed <= FIXED_BOUNDS_US[1]):
            # negative / absurd intercept: refit through the origin
            scale, fixed = float(np.median(ys / xs)), 0.0
    else:
        scale, fixed = float(np.median(ys / xs)), 0.0
    scale = float(np.clip(scale, *SCALE_BOUNDS))
    fixed = float(np.clip(fixed, *FIXED_BOUNDS_US))
    return (_snap(scale, 1.0, SCALE_SNAP), _snap(fixed, 0.0, FIXED_SNAP_US))


def _fit_skew(obs: List[Observation],
              routes: Dict[str, dict]) -> Dict[str, float]:
    """Least-squares ``_skew_factor`` slopes from the skew-annotated
    observations (knees and cap stay at their hand-tuned values: the
    corpus does not sample the near-knee region densely enough to
    identify them).  Cap-censored points are excluded."""
    d = dispatch.IDENTITY_COEFFS
    skew = {"imb_knee": d.skew_imb_knee, "imb_slope": d.skew_imb_slope,
            "cv_knee": d.skew_cv_knee, "cv_slope": d.skew_cv_slope,
            "cap": d.skew_cap}
    rows, rhs = [], []
    for o in obs:
        if o.route not in dispatch._SKEW_SENSITIVE:
            continue
        x_imb = max(0.0, o.imbalance - skew["imb_knee"])
        x_cv = max(0.0, o.cv - skew["cv_knee"])
        if x_imb <= 0.0 and x_cv <= 0.0:
            continue
        c = routes.get(o.route, {})
        base = (c.get("scale", 1.0) * _raw_us(o, skewless=True)
                + c.get("fixed_us", 0.0))
        implied = o.measured_us / max(base, 1e-9)
        if implied >= skew["cap"] - 1e-6:     # censored at the cap
            continue
        rows.append([x_imb, x_cv])
        rhs.append(implied - 1.0)
    if len(rows) >= 2:
        A, y = np.asarray(rows), np.asarray(rhs)
        if np.linalg.matrix_rank(A) == 2:
            s_imb, s_cv = np.linalg.lstsq(A, y, rcond=None)[0]
            s_imb = float(np.clip(s_imb, *SLOPE_BOUNDS))
            s_cv = float(np.clip(s_cv, *SLOPE_BOUNDS))
            skew["imb_slope"] = _snap(
                s_imb, d.skew_imb_slope, SLOPE_SNAP_REL * d.skew_imb_slope)
            skew["cv_slope"] = _snap(
                s_cv, d.skew_cv_slope, SLOPE_SNAP_REL * d.skew_cv_slope)
    return skew


def fit(obs: List[Observation]) -> dict:
    """The full fit: per-route affine terms, then skew slopes, plus a
    per-route error report.  Returns the ``cost_coeffs.json`` blob."""
    if not obs:
        raise ValueError("empty corpus: nothing to fit")
    with _identity_model():
        by_route: Dict[str, List[Tuple[float, float]]] = {}
        for o in obs:
            by_route.setdefault(o.route, []).append(
                (_raw_us(o), o.measured_us))
        routes: Dict[str, dict] = {}
        all_rel: List[float] = []
        for route in sorted(by_route):
            pts = np.asarray(by_route[route], dtype=np.float64)
            scale, fixed = _fit_route(pts[:, 0], pts[:, 1])
            pred = scale * pts[:, 0] + fixed
            rel = np.abs(pred - pts[:, 1]) / np.maximum(pts[:, 1], 1e-9)
            all_rel.extend(rel.tolist())
            routes[route] = {
                "scale": round(scale, 6), "fixed_us": round(fixed, 6),
                "n_obs": int(len(pts)),
                "median_rel_err": round(float(np.median(rel)), 6),
            }
        skew = {k: round(v, 6)
                for k, v in _fit_skew(obs, routes).items()}
    digest = dispatch.coeffs_digest(routes, skew, COEFFS_VERSION)
    return {
        "version": COEFFS_VERSION,
        "digest": digest,
        "corpus": {
            "files": sorted({o.source for o in obs}),
            "n_obs": len(obs),
            "n_routes": len(routes),
        },
        "routes": routes,
        "skew": skew,
        "fit_median_rel_err": round(float(np.median(all_rel)), 6),
    }


def write_coeffs(blob: dict, out: str = DEFAULT_OUT) -> str:
    with open(out, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
        f.write("\n")
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="fit dispatch cost coefficients from the bench corpus")
    ap.add_argument("--corpus", nargs="*", default=None, metavar="GLOB",
                    help="extra bench JSONs beyond benchmarks/baselines/")
    ap.add_argument("--update", action="store_true",
                    help=f"write {os.path.relpath(DEFAULT_OUT)}")
    ap.add_argument("--out", default=None,
                    help="write the fitted coefficients to this path")
    ap.add_argument("--report", default=None,
                    help="write the full fit blob (with diagnostics) here")
    args = ap.parse_args(argv)

    obs = load_corpus(args.corpus)
    blob = fit(obs)
    print(f"calibrate: {blob['corpus']['n_obs']} observations from "
          f"{len(blob['corpus']['files'])} files, "
          f"{blob['corpus']['n_routes']} routes, "
          f"fit median rel err {blob['fit_median_rel_err']:.4%}")
    for route, c in blob["routes"].items():
        print(f"  {route:28s} scale={c['scale']:<8g} "
              f"fixed_us={c['fixed_us']:<8g} n={c['n_obs']:<3d} "
              f"err={c['median_rel_err']:.4%}")
    print(f"  skew: {blob['skew']}  digest={blob['digest']}")
    out = args.out or (DEFAULT_OUT if args.update else None)
    if out:
        print(f"calibrate: wrote {write_coeffs(blob, out)}")
    else:
        print("calibrate: dry run (pass --update to write)")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        print(f"calibrate: report -> {args.report}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
