"""Loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` visits every ``while`` body exactly once, so
a model whose layers run under ``lax.scan`` under-reports FLOPs by the
trip count (verified experimentally; see EXPERIMENTS.md §Methodology).
This module re-derives the three roofline inputs from
``compiled.as_text()`` -- the post-SPMD, post-fusion, *per-device*
module -- with while-loop trip counts multiplied through:

* ``flops``            dot/convolution (exact from dnums) + elementwise
* ``bytes``            HloCostAnalysis-style: operands + result per op
                       (fusion internals excluded -- they live in VMEM)
* ``collective_bytes`` all-gather / all-reduce / reduce-scatter /
                       all-to-all / collective-permute, result-shape bytes
                       (per collective opcode in ``collectives``)

Trip counts come from the loop condition computation (compare against a
constant -- the shape every ``lax.scan``/``fori_loop`` lowers to); loops
whose bound cannot be recovered are counted once and recorded in
``warnings``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+(?:\(.*\))?\s*->.*{")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "compare", "select", "and", "or",
    "xor", "not", "sign", "floor", "ceil", "round-nearest-afz", "remainder",
    "atan2", "clamp", "cosine", "sine", "erf", "logistic", "cbrt",
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape(type_str: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    by_name: Dict[str, Op] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    transcendentals: float = 0.0

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        self.transcendentals += other.transcendentals
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    self.collective_bytes * k,
                    {n: v * k for n, v in self.collectives.items()},
                    self.transcendentals * k)


def _split_operands(rest: str) -> Tuple[List[str], str]:
    """Split 'operand list ) , attrs' respecting nesting."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                ops, attrs = rest[:i], rest[i + 1:]
                break
            depth -= 1
    else:
        ops, attrs = rest, ""
    names = re.findall(r"%([\w\.\-]+)", ops)
    return names, attrs


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and "{" in line:
                cur = Computation(m.group(1))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        operands, attrs = _split_operands(rest)
        op = Op(name, opcode, type_str, operands, attrs, line)
        cur.ops.append(op)
        cur.by_name[name] = op
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _dims_attr(attrs: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([\d,]*)\}", attrs)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


class Analyzer:
    def __init__(self, comps: Dict[str, Computation], *,
                 vmem_dims: Optional[set] = None):
        self.comps = comps
        self.memo: Dict[str, Cost] = {}
        self.warnings: List[str] = []
        # tensors whose trailing dims are in vmem_dims are priced as
        # VMEM-resident (zero HBM bytes): the fused-flash-attention view,
        # where score-space tiles never leave the chip (the Pallas
        # kernels/bs_attn contract).  FLOPs are unaffected.
        self.vmem_dims = vmem_dims or set()

    def _sb(self, type_str: str) -> float:
        if self.vmem_dims:
            dtype, dims = _first_shape(type_str)
            if len(dims) >= 2 and tuple(dims[-2:]) in self.vmem_dims:
                return 0.0
        return _shape_bytes(type_str)

    def _fusion_operand_bytes(self, comp: Computation, op: Op,
                              callee: "Computation") -> float:
        """Operand bytes for a fusion, pricing parameters that are only
        consumed by dynamic-slice/gather *inside* the fusion at their
        sliced size (XLA reads just the slice, not the buffer)."""
        params = {}
        for cop in callee.ops:
            if cop.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", cop.line)
                if m:
                    params[int(m.group(1))] = cop.name
        consumers: Dict[str, List[Op]] = {}
        for cop in callee.ops:
            for o in cop.operands:
                consumers.setdefault(o, []).append(cop)

        def slice_reads(name, depth=0):
            """If every (transitive, through layout-free ops) consumer of
            ``name`` is a dynamic-slice/gather, return the sliced bytes;
            else None."""
            if depth > 4:
                return None
            cons = consumers.get(name, [])
            if not cons:
                return None
            total = 0.0
            for cop in cons:
                if cop.opcode in ("dynamic-slice", "gather"):
                    total += self._sb(cop.type_str)
                elif cop.opcode in ("bitcast", "reshape"):
                    sub = slice_reads(cop.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        total = 0.0
        for i, oname in enumerate(op.operands):
            full = self._sb(self._operand_type(comp, oname))
            pname = params.get(i)
            sliced = slice_reads(pname) if pname else None
            total += sliced if sliced is not None else full
        return total

    # -- shape lookup -------------------------------------------------------
    def _operand_type(self, comp: Computation, name: str) -> str:
        op = comp.by_name.get(name)
        return op.type_str if op else ""

    # -- trip count -----------------------------------------------------------
    def _trip_count(self, cond_name: str) -> Optional[int]:
        comp = self.comps.get(cond_name)
        if comp is None:
            return None
        consts = {}
        for op in comp.ops:
            if op.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    consts[op.name] = int(m.group(1))
        for op in comp.ops:
            if op.opcode == "compare" and "direction=LT" in op.attrs:
                for o in op.operands:
                    if o in consts:
                        return consts[o]
        return None

    def _called(self, attrs: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", attrs)
        return m.group(1) if m else None

    # -- per-op flops -----------------------------------------------------------
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        _, out_dims = _first_shape(op.type_str)
        lhs_t = self._operand_type(comp, op.operands[0]) if op.operands else ""
        _, lhs_dims = _first_shape(lhs_t)
        contr = _dims_attr(op.attrs, "lhs_contracting_dims")
        k = 1
        for d in contr:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
        n = 1
        for d in out_dims:
            n *= d
        return 2.0 * n * k

    def _conv_flops(self, comp: Computation, op: Op) -> float:
        _, out_dims = _first_shape(op.type_str)
        rhs_t = self._operand_type(comp, op.operands[1]) \
            if len(op.operands) > 1 else ""
        _, rhs_dims = _first_shape(rhs_t)
        n = 1
        for d in out_dims:
            n *= d
        k = 1
        for d in rhs_dims[:-1]:   # kernel spatial x in-channels (approx)
            k *= d
        return 2.0 * n * k

    # -- computation cost ----------------------------------------------------
    def _flops_only(self, comp_name: str) -> float:
        """FLOPs including fusion internals (dots inside fusions)."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += self._dot_flops(comp, op)
            elif op.opcode == "convolution":
                total += self._conv_flops(comp, op)
            elif op.opcode in _ELEMENTWISE:
                total += _numel(op.type_str)
            elif op.opcode == "fusion":
                callee = self._called(op.attrs, "calls")
                if callee:
                    total += self._flops_only(callee)
        return total

    def cost(self, comp_name: str) -> Cost:
        if comp_name in self.memo:
            return self.memo[comp_name]
        comp = self.comps.get(comp_name)
        c = Cost()
        if comp is None:
            return c
        self.memo[comp_name] = c   # breaks cycles defensively
        for op in comp.ops:
            if op.opcode in _SKIP_BYTES:
                continue
            opnd_bytes = sum(
                self._sb(self._operand_type(comp, o))
                for o in op.operands)
            res_bytes = self._sb(op.type_str)
            if op.opcode == "while":
                body = self._called(op.attrs, "body")
                cond = self._called(op.attrs, "condition")
                # primary source: XLA's own analysis in backend_config
                m = re.search(r'known_trip_count[^0-9]*(\d+)', op.attrs)
                trip = int(m.group(1)) if m else None
                if trip is None and cond:
                    trip = self._trip_count(cond)
                if trip is None:
                    trip = 1
                    self.warnings.append(
                        f"while {op.name}: trip count unknown, counted once")
                inner = Cost()
                if body:
                    inner += self.cost(body)
                if cond:
                    inner += self.cost(cond)
                c += inner.scaled(trip)
                continue
            if op.opcode in ("call", "async-start"):
                callee = self._called(op.attrs, "to_apply") or \
                    self._called(op.attrs, "calls")
                if callee:
                    c += self.cost(callee)
                continue
            if op.opcode == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.attrs)
                names = re.findall(r"%([\w\.\-]+)",
                                   branches[0]) if branches else []
                sub = [self.cost(b) for b in names]
                if sub:
                    worst = max(sub, key=lambda x: x.flops + x.bytes)
                    c += worst
                continue
            # leaf-ish ops -- in-place / slicing ops touch only the moved
            # region, not the whole buffer (XLA aliases loop buffers)
            if op.opcode in ("dynamic-update-slice", "scatter",
                             "scatter-add"):
                upd = (self._sb(self._operand_type(comp, op.operands[1]))
                       if len(op.operands) > 1 else 0.0)
                c.bytes += 3.0 * upd   # read slice + read update + write
                continue
            if op.opcode in ("dynamic-slice", "gather"):
                c.bytes += 2.0 * res_bytes
                continue
            if op.opcode == "fusion":
                callee_name = self._called(op.attrs, "calls")
                callee = self.comps.get(callee_name)
                root = callee.ops[-1] if callee and callee.ops else None
                if root is not None and root.opcode in (
                        "dynamic-update-slice", "scatter"):
                    # in-place rooted fusion: drop the aliased big operand
                    alias = max((
                        self._sb(self._operand_type(comp, o))
                        for o in op.operands), default=0.0)
                    small = max(opnd_bytes - alias, 0.0)
                    c.bytes += small + max(res_bytes - alias, 0.0) + \
                        2.0 * _update_bytes(callee, root)
                    c.flops += self._flops_only(callee_name)
                    continue
                if callee is not None:
                    c.bytes += self._fusion_operand_bytes(
                        comp, op, callee) + res_bytes
                    c.flops += self._flops_only(callee_name)
                    continue
            c.bytes += opnd_bytes + res_bytes
            if op.opcode in _COLLECTIVES:
                opc = op.opcode.replace("-start", "")
                moved = max(res_bytes, opnd_bytes)
                c.collective_bytes += moved
                c.collectives[opc] = c.collectives.get(opc, 0.0) + moved
            elif op.opcode == "dot":
                c.flops += self._dot_flops(comp, op)
            elif op.opcode == "convolution":
                c.flops += self._conv_flops(comp, op)
            elif op.opcode == "fusion":
                callee = self._called(op.attrs, "calls")
                if callee:
                    c.flops += self._flops_only(callee)
            elif op.opcode in _ELEMENTWISE:
                c.flops += _numel(op.type_str)
        self.memo[comp_name] = c
        return c


def _update_bytes(callee: "Computation", root: "Op") -> float:
    """Bytes of the update operand of a DUS/scatter fusion root."""
    if len(root.operands) > 1:
        upd = callee.by_name.get(root.operands[1])
        if upd is not None:
            return _shape_bytes(upd.type_str)
    return _shape_bytes(root.type_str) * 0.1  # conservative fallback


def _numel(type_str: str) -> float:
    _, dims = _first_shape(type_str)
    n = 1
    for d in dims:
        n *= d
    return float(n)


def analyze_hlo_text(text: str, *, vmem_dims=None) -> dict:
    """Full-module loop-aware cost.  Entry = the ENTRY computation.

    ``vmem_dims``: optional set of trailing-2-dim tuples priced as
    VMEM-resident (fused-kernel view; see Analyzer).
    """
    comps = parse_hlo(text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.MULTILINE)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k].ops)) if comps else None
    if entry is None:
        return dict(flops=0.0, bytes=0.0, collective_bytes=0.0,
                    collectives={}, warnings=["no computations parsed"])
    an = Analyzer(comps, vmem_dims=vmem_dims)
    c = an.cost(entry)
    return dict(flops=c.flops, bytes=c.bytes,
                collective_bytes=c.collective_bytes,
                collectives=c.collectives, warnings=an.warnings,
                num_computations=len(comps))


# ---------------------------------------------------------------------------
# Analytic cost dicts (no HLO required) -- roofline inputs for routes
# whose module we never compile on the planning path
# ---------------------------------------------------------------------------

def spmm_cost_dict(m: int, k: int, n: int, *, density: float = 1.0,
                   bytes_el: int = 2) -> dict:
    """Useful work of ``sparse[m, k] @ dense[k, n]`` at block density
    ``density``: the lower bound a perfect kernel would hit -- zero
    blocks never touched, dense operand and output streamed once.
    Shaped like an :func:`analyze_hlo_text` result so it feeds
    ``roofline.roofline_terms`` / ``route_efficiency`` directly."""
    d = min(max(float(density), 0.0), 1.0)
    return dict(
        flops=2.0 * m * k * n * d,
        bytes=(m * k * d + k * n + m * n) * float(bytes_el),
        collective_bytes=0.0,
        collectives={}, warnings=[])


def sddmm_cost_dict(m: int, k: int, n: int, *, density: float = 1.0,
                    bytes_el: int = 2) -> dict:
    """Useful work of the block-sampled ``dY[m, n] @ X[k, n]^T``
    (backward dL/dvalues): only the sampled ``[m, k]`` pattern blocks
    are computed and written, both dense factors are read once."""
    d = min(max(float(density), 0.0), 1.0)
    return dict(
        flops=2.0 * m * k * n * d,
        bytes=(m * n + k * n + m * k * d) * float(bytes_el),
        collective_bytes=0.0,
        collectives={}, warnings=[])
