from repro.analysis.hlo_cost import (  # noqa: F401
    analyze_hlo_text, sddmm_cost_dict, spmm_cost_dict)
from repro.analysis.roofline import (  # noqa: F401
    roofline_terms, route_efficiency, V5E)
