from repro.analysis.hlo_cost import analyze_hlo_text  # noqa: F401
from repro.analysis.roofline import roofline_terms, V5E  # noqa: F401
