from repro.kernels.gmm.ops import gmm  # noqa: F401
from repro.kernels.gmm.ref import gmm_ref  # noqa: F401
