from repro.kernels.gmm.ops import gmm  # noqa: F401
from repro.kernels.gmm.ref import gmm_ref  # noqa: F401
from repro.kernels.contract import KernelContract, register

# grouped (tile-bucketed) SpMM: needs one tile size t <= 128 that is a
# block multiple dividing both m and k (ops.grouped_tile_size raises
# otherwise); the bucket is sized expected-tiles x headroom (App. A.2)
CONTRACT = register(KernelContract(
    kernel="gmm",
    routes=("dynamic_grouped",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=128,
    divisibility=(
        "m % b == 0", "k % b == 0",
        "any(t % b == 0 and m % t == 0 and k % t == 0 "
        "for t in range(b, 129))",
    ),
    grid="tiles_cap x (n // tn): planned-capacity walk over packed "
         "t x t tiles, t = grouped_tile_size(m, k, b)",
    capacity="planned_bucket",
    pallas=True,
))

# row-swizzled slot order over the same planned-bucket pack: the pack,
# capacity semantics and overflow accounting are shared with gmm; only
# the (device-computed) slot visit order differs
BALANCED_CONTRACT = register(KernelContract(
    kernel="gmm_balanced",
    routes=("dynamic_grouped_balanced",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=128,
    divisibility=(
        "m % b == 0", "k % b == 0",
        "any(t % b == 0 and m % t == 0 and k % t == 0 "
        "for t in range(b, 129))",
    ),
    grid="tiles_cap x (n // tn): planned-capacity walk over packed "
         "t x t tiles in snake-binned (bin, row) order",
    capacity="planned_bucket",
    pallas=True,
))
