"""Pure-jnp oracle for gmm: per-row gather of expert weights."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w, expert_ids, *, tm: int):
    """out[t] = x[t] @ w[expert_of_row(t)] computed row-by-row."""
    t_rows = x.shape[0]
    per_row = jnp.repeat(expert_ids, tm, total_repeat_length=t_rows)
    wg = jnp.take(w, per_row, axis=0)            # [T, D, F]
    return jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                      wg.astype(jnp.float32)).astype(x.dtype)
