"""Jit'd wrappers for the grouped matmul kernel + the device-side tile
packer behind the ``dynamic_grouped`` dispatch route.

``dynamic_grouped`` is the TPU-native dynamic mode priced by
``cost_model.dsmm_grouped_time``: instead of walking ``b x b`` logical
blocks (which under-fill the 128x128 MXU for small ``b``), the runtime
pattern is packed *on device* into MXU-aligned ``t x t`` tile slots --
the grouped-layout idea of this kernel family applied to a runtime
block-sparse operand.  Dynamic costs stay visible: fixed tile capacity
(overflow tiles are dropped, the paper's bucket-overflow semantics) and
the on-device pack (sort + scatter) replace static mode's free
compile-time packing.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dynamic_sparse import DynamicOperand
from repro.kernels.gmm.gmm import gmm_call


def _fit(t, pref):
    v = pref
    while t % v:
        v //= 2
    return max(v, 1)


def grouped_tile_size(m: int, k: int, b: int, limit: int = 128) -> int:
    """Largest square tile ``t <= limit`` that is a multiple of the
    logical block ``b`` and divides both ``m`` and ``k``.  Worst case
    ``t == b`` (the pack degenerates to the plain block walk)."""
    t = b * max(1, limit // b)
    while t > b and (m % t or k % t):
        t -= b
    if m % t or k % t:
        raise ValueError(f"no tile size <= {limit} divides both m={m} and "
                         f"k={k} at block {b}")
    return t


def pack_tiles_device(op: DynamicOperand, *, tile: int,
                      tiles_cap: int) -> DynamicOperand:
    """Pack a runtime block pattern into ``tiles_cap`` dense ``tile x
    tile`` slots, entirely on device (jit-compatible, runtime indices).

    The device analogue of ``partitioner.plan_packing``/``pack_values``:
    blocks are sorted by their covering tile, each distinct tile gets one
    slot, and blocks sharing a tile scatter-add into it.  Tiles beyond
    ``tiles_cap`` are dropped (fixed-bucket overflow, paper §3.3); padded
    tile slots carry zero values at (0, 0) and contribute exactly zero.
    """
    m, k = op.shape
    b = op.block_size
    t = tile
    if t % b or m % t or k % t:
        raise ValueError(f"tile {t} must be a block-multiple divisor of "
                         f"shape {op.shape} (block {b})")
    rpb = cpb = t // b
    mt, kt = m // t, k // t
    s = op.capacity
    tiles_cap = max(1, tiles_cap)
    if s == 0:
        # empty operand: one zero tile at (0, 0) contributes exactly zero
        return DynamicOperand(
            jnp.zeros((tiles_cap, t, t), op.values.dtype),
            jnp.zeros((tiles_cap,), jnp.int32),
            jnp.zeros((tiles_cap,), jnp.int32),
            jnp.asarray(0, jnp.int32), (m, k), t)

    # padding slots (beyond op.nnz, zero values at row 0 / col 0) must
    # not claim a tile slot: send them past every real tile via a
    # sentinel so they land in the cropped scratch slot
    sentinel = mt * kt
    valid = jnp.arange(s) < op.nnz             # encoders pack real first
    t_r = op.row_idx // rpb
    t_c = op.col_idx // cpb
    lin = jnp.where(valid, t_r * kt + t_c, sentinel)  # tile per slot [S]
    order = jnp.argsort(lin)
    sl = lin[order]
    vmask = sl < sentinel                      # valid slots, sorted first
    new_tile = vmask & jnp.concatenate(
        [jnp.ones((1,), bool), sl[1:] != sl[:-1]])
    rank = jnp.cumsum(new_tile.astype(jnp.int32)) - 1  # per distinct tile
    num_tiles = jnp.minimum(jnp.sum(new_tile.astype(jnp.int32)), tiles_cap)
    # overflow + padding land in a scratch slot that is cropped afterwards
    dst = jnp.where(vmask & (rank < tiles_cap), rank, tiles_cap)

    vals = op.values[order]
    in_r = (op.row_idx[order] % rpb).astype(jnp.int32)
    in_c = (op.col_idx[order] % cpb).astype(jnp.int32)
    tiles = jnp.zeros((tiles_cap + 1, rpb, b, cpb, b), op.values.dtype)
    tiles = tiles.at[dst, in_r, :, in_c, :].add(vals)
    tiles = tiles.reshape(tiles_cap + 1, t, t)[:tiles_cap]

    safe_sl = jnp.where(vmask, sl, 0)
    tile_rows = jnp.zeros((tiles_cap + 1,), jnp.int32
                          ).at[dst].set((safe_sl // kt).astype(jnp.int32)
                                        )[:tiles_cap]
    tile_cols = jnp.zeros((tiles_cap + 1,), jnp.int32
                          ).at[dst].set((safe_sl % kt).astype(jnp.int32)
                                        )[:tiles_cap]
    return DynamicOperand(tiles, tile_rows, tile_cols, num_tiles,
                          (m, k), t)


def grouped_spmm(op: DynamicOperand, x, *, tile: int | None = None,
                 tiles_cap: int | None = None, interpret: bool = False):
    """``Y = decode(op) @ X`` through device-side tile packing + the
    full-tile slot-walk kernel (the ``dynamic_grouped`` route).

    ``tiles_cap`` defaults to the safe worst-case bound (every slot in a
    distinct tile); ``repro.sparse`` plans pass the expected-tiles +
    headroom capacity from the cost model instead.
    """
    m, k = op.shape
    t = tile or grouped_tile_size(m, k, op.block_size)
    mt, kt = m // t, k // t
    if tiles_cap is None:
        tiles_cap = min(op.capacity, mt * kt)
    tiles_cap = max(1, min(tiles_cap, mt * kt))
    packed = pack_tiles_device(op, tile=t, tiles_cap=tiles_cap)
    from repro.kernels.dsmm import ops as dsmm_ops
    return dsmm_ops.dsmm(packed, x, interpret=interpret)


def gmm(x, w, expert_ids, *, tm: int | None = None, tf: int | None = None,
        td: int | None = None, interpret: bool = False):
    """Grouped GEMM.  ``x: [T, D]`` grouped rows, ``w: [E, D, F]``,
    ``expert_ids: [T // tm]`` one expert per row tile."""
    t_rows, d = x.shape
    e, _, f = w.shape
    tm = tm or (t_rows // expert_ids.shape[0])
    tf = tf or _fit(f, 128)
    td = td or _fit(d, 128)
    if t_rows % tm:
        raise ValueError(f"rows {t_rows} not divisible by tile {tm}")
    if expert_ids.shape[0] != t_rows // tm:
        raise ValueError("expert_ids must have one entry per row tile")
    return gmm_call(expert_ids.astype(jnp.int32), x, w, tm=tm, tf=tf,
                    td=td, interpret=interpret)
