"""Jit'd wrappers for the grouped matmul kernel + the device-side tile
packer behind the ``dynamic_grouped`` dispatch route.

``dynamic_grouped`` is the TPU-native dynamic mode priced by
``cost_model.dsmm_grouped_time``: instead of walking ``b x b`` logical
blocks (which under-fill the 128x128 MXU for small ``b``), the runtime
pattern is packed *on device* into MXU-aligned ``t x t`` tile slots --
the grouped-layout idea of this kernel family applied to a runtime
block-sparse operand.  Dynamic costs stay visible: fixed tile capacity
(overflow tiles are dropped, the paper's bucket-overflow semantics) and
the on-device pack (sort + scatter) replace static mode's free
compile-time packing.

Capacity is *planned* (paper Appendix A.2): ``repro.sparse`` sizes
``tiles_cap`` at the planner's expected-tiles + headroom, not the safe
worst case, so overflow is possible by design -- and therefore counted
exactly (``GroupedPackStats``), never dropped silently.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.core.dynamic_sparse import DynamicOperand
from repro.kernels.gmm.gmm import gmm_call


class GroupedPackStats(NamedTuple):
    """Exact overflow accounting for one device-side pack (all fields are
    device scalars, jit-safe).  ``tiles_total`` counts the distinct
    non-empty tiles the runtime pattern actually occupies;
    ``tiles_dropped``/``blocks_dropped`` are the tiles/logical-blocks
    beyond ``tiles_cap`` (exact, not estimated); ``dropped_value_frac``
    is the fraction of L1 value mass those dropped blocks carried."""

    tiles_total: jnp.ndarray        # [] int32
    tiles_dropped: jnp.ndarray      # [] int32
    blocks_dropped: jnp.ndarray     # [] int32
    dropped_value_frac: jnp.ndarray  # [] float32


def _fit(t, pref):
    v = pref
    while t % v:
        v //= 2
    return max(v, 1)


def grouped_tile_size(m: int, k: int, b: int, limit: int = 128) -> int:
    """Largest square tile ``t <= limit`` that is a multiple of the
    logical block ``b`` and divides both ``m`` and ``k``.  Worst case
    ``t == b`` (the pack degenerates to the plain block walk)."""
    t = b * max(1, limit // b)
    while t > b and (m % t or k % t):
        t -= b
    if m % t or k % t:
        raise ValueError(f"no tile size <= {limit} divides both m={m} and "
                         f"k={k} at block {b}")
    return t


def pack_tiles_device(op: DynamicOperand, *, tile: int,
                      tiles_cap: int, with_stats: bool = True
                      ) -> Tuple[DynamicOperand, GroupedPackStats]:
    """Pack a runtime block pattern into ``tiles_cap`` dense ``tile x
    tile`` slots, entirely on device (jit-compatible, runtime indices).

    The device analogue of ``partitioner.plan_packing``/``pack_values``:
    blocks are sorted by their covering tile, each distinct tile gets one
    slot, and blocks sharing a tile scatter-add into it.  Tiles beyond
    ``tiles_cap`` overflow (fixed-bucket semantics, paper §3.3) -- they
    are dropped from the product but *counted exactly* in the returned
    ``GroupedPackStats`` (never silently); padded tile slots carry zero
    values at (0, 0) and contribute exactly zero.  ``with_stats=False``
    skips the accounting reductions (telemetry-off hot loops) and
    returns ``None`` in the stats slot.
    """
    m, k = op.shape
    b = op.block_size
    t = tile
    if t % b or m % t or k % t:
        raise ValueError(f"tile {t} must be a block-multiple divisor of "
                         f"shape {op.shape} (block {b})")
    rpb = cpb = t // b
    mt, kt = m // t, k // t
    s = op.capacity
    tiles_cap = max(1, tiles_cap)
    zero_i = jnp.asarray(0, jnp.int32)
    if s == 0:
        # empty operand: one zero tile at (0, 0) contributes exactly zero
        packed = DynamicOperand(
            jnp.zeros((tiles_cap, t, t), op.values.dtype),
            jnp.zeros((tiles_cap,), jnp.int32),
            jnp.zeros((tiles_cap,), jnp.int32),
            zero_i, (m, k), t)
        return packed, (GroupedPackStats(
            zero_i, zero_i, zero_i, jnp.asarray(0.0, jnp.float32))
            if with_stats else None)

    # padding slots (beyond op.nnz, zero values at row 0 / col 0) must
    # not claim a tile slot: send them past every real tile via a
    # sentinel so they land in the cropped scratch slot
    sentinel = mt * kt
    valid = jnp.arange(s) < op.nnz             # encoders pack real first
    t_r = op.row_idx // rpb
    t_c = op.col_idx // cpb
    lin = jnp.where(valid, t_r * kt + t_c, sentinel)  # tile per slot [S]
    order = jnp.argsort(lin)
    sl = lin[order]
    vmask = sl < sentinel                      # valid slots, sorted first
    new_tile = vmask & jnp.concatenate(
        [jnp.ones((1,), bool), sl[1:] != sl[:-1]])
    rank = jnp.cumsum(new_tile.astype(jnp.int32)) - 1  # per distinct tile
    tiles_total = jnp.sum(new_tile.astype(jnp.int32))
    num_tiles = jnp.minimum(tiles_total, tiles_cap)
    kept = vmask & (rank < tiles_cap)
    # overflow + padding land in a scratch slot that is cropped afterwards
    dst = jnp.where(kept, rank, tiles_cap)

    vals = op.values[order]
    in_r = (op.row_idx[order] % rpb).astype(jnp.int32)
    in_c = (op.col_idx[order] % cpb).astype(jnp.int32)
    tiles = jnp.zeros((tiles_cap + 1, rpb, b, cpb, b), op.values.dtype)
    tiles = tiles.at[dst, in_r, :, in_c, :].add(vals)
    tiles = tiles.reshape(tiles_cap + 1, t, t)[:tiles_cap]

    safe_sl = jnp.where(vmask, sl, 0)
    tile_rows = jnp.zeros((tiles_cap + 1,), jnp.int32
                          ).at[dst].set((safe_sl // kt).astype(jnp.int32)
                                        )[:tiles_cap]
    tile_cols = jnp.zeros((tiles_cap + 1,), jnp.int32
                          ).at[dst].set((safe_sl % kt).astype(jnp.int32)
                                        )[:tiles_cap]

    packed = DynamicOperand(tiles, tile_rows, tile_cols, num_tiles,
                            (m, k), t)
    if not with_stats:
        return packed, None

    # exact overflow accounting (the paper's bucket-overflow quantity,
    # surfaced like MoE dropped_frac instead of dropped silently)
    dropped = vmask & ~kept
    blocks_dropped = jnp.sum(dropped.astype(jnp.int32))
    mass = jnp.abs(vals.astype(jnp.float32)).sum(axis=(1, 2))
    total_mass = jnp.sum(jnp.where(vmask, mass, 0.0))
    dropped_mass = jnp.sum(jnp.where(dropped, mass, 0.0))
    dropped_frac = jnp.where(total_mass > 0.0,
                             dropped_mass / jnp.maximum(total_mass, 1e-30),
                             0.0).astype(jnp.float32)
    stats = GroupedPackStats(tiles_total.astype(jnp.int32),
                             (tiles_total - num_tiles).astype(jnp.int32),
                             blocks_dropped, dropped_frac)
    return packed, stats


_clamp_warned: set = set()


def clamped_tiles_cap(requested: int, m: int, k: int, tile: int,
                      *, warn: bool = True) -> Tuple[int, bool]:
    """Clamp a requested tile capacity into ``[1, (m/t)*(k/t)]``.

    Returns ``(effective_cap, was_clamped)``.  A reduced capacity is
    *signalled* -- warned once per (requested, grid) and reported to the
    caller -- never applied silently (the pre-PR-3 behaviour)."""
    mt, kt = m // tile, k // tile
    eff = max(1, min(int(requested), mt * kt))
    clamped = eff != int(requested)
    if clamped and warn:
        sig = (int(requested), mt * kt)
        if sig not in _clamp_warned:
            _clamp_warned.add(sig)
            warnings.warn(
                f"grouped_spmm: requested tiles_cap={requested} clamped "
                f"to {eff} (tile grid {mt}x{kt} = {mt * kt} slots); the "
                f"clamp is recorded in the plan report", stacklevel=3)
    return eff, clamped


def grouped_spmm(op: DynamicOperand, x, *, tile: int | None = None,
                 tiles_cap: int | None = None, interpret: bool = False,
                 return_stats: bool = False):
    """``Y = decode(op) @ X`` through device-side tile packing + the
    full-tile slot-walk kernel (the ``dynamic_grouped`` route).

    ``tiles_cap`` defaults to the safe worst-case bound (every slot in a
    distinct tile); ``repro.sparse`` plans pass the planned
    expected-tiles + headroom capacity (``planner.plan_grouped_capacity``)
    instead.  With ``return_stats=True`` the exact overflow accounting of
    the pack (``GroupedPackStats``) is returned alongside ``y``.
    """
    m, k = op.shape
    t = tile or grouped_tile_size(m, k, op.block_size)
    mt, kt = m // t, k // t
    if tiles_cap is None:
        tiles_cap = min(op.capacity, mt * kt)
    else:
        tiles_cap, _ = clamped_tiles_cap(tiles_cap, m, k, t)
    tiles_cap = max(1, tiles_cap)
    packed, stats = pack_tiles_device(op, tile=t, tiles_cap=tiles_cap,
                                      with_stats=return_stats)
    from repro.kernels.dsmm import ops as dsmm_ops
    y = dsmm_ops.dsmm(packed, x, interpret=interpret)
    if return_stats:
        return y, stats
    return y


def gmm(x, w, expert_ids, *, tm: int | None = None, tf: int | None = None,
        td: int | None = None, interpret: bool = False):
    """Grouped GEMM.  ``x: [T, D]`` grouped rows, ``w: [E, D, F]``,
    ``expert_ids: [T // tm]`` one expert per row tile."""
    t_rows, d = x.shape
    e, _, f = w.shape
    tm = tm or (t_rows // expert_ids.shape[0])
    tf = tf or _fit(f, 128)
    td = td or _fit(d, 128)
    if t_rows % tm:
        raise ValueError(f"rows {t_rows} not divisible by tile {tm}")
    if expert_ids.shape[0] != t_rows // tm:
        raise ValueError("expert_ids must have one entry per row tile")
    return gmm_call(expert_ids.astype(jnp.int32), x, w, tm=tm, tf=tf,
                    td=td, interpret=interpret)
