"""Jit'd wrapper for the grouped matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gmm.gmm import gmm_call


def _fit(t, pref):
    v = pref
    while t % v:
        v //= 2
    return max(v, 1)


def gmm(x, w, expert_ids, *, tm: int | None = None, tf: int | None = None,
        td: int | None = None, interpret: bool = False):
    """Grouped GEMM.  ``x: [T, D]`` grouped rows, ``w: [E, D, F]``,
    ``expert_ids: [T // tm]`` one expert per row tile."""
    t_rows, d = x.shape
    e, _, f = w.shape
    tm = tm or (t_rows // expert_ids.shape[0])
    tf = tf or _fit(f, 128)
    td = td or _fit(d, 128)
    if t_rows % tm:
        raise ValueError(f"rows {t_rows} not divisible by tile {tm}")
    if expert_ids.shape[0] != t_rows // tm:
        raise ValueError("expert_ids must have one entry per row tile")
    return gmm_call(expert_ids.astype(jnp.int32), x, w, tm=tm, tf=tf,
                    td=td, interpret=interpret)
