"""Grouped matmul Pallas TPU kernel -- MoE expert compute as dynamic
block-diagonal sparsity (MegaBlocks, cited by the paper §1.2, on TPU).

``out[t] = x[t] @ W[expert_of(t)]`` where rows of ``x`` are grouped by
expert and groups are padded to row-tile multiples by the dispatcher
(``models/moe.py``), so each ``tm``-row tile belongs to exactly one
expert.  ``expert_ids`` ([T/tm] int32) is scalar-prefetched and drives the
W index map -- this is the dynamic-sparsity pattern-as-data idea applied
to the block-diagonal structure of expert routing: d_max == 1/E per tile,
capacity fixed by the dispatcher, pattern (routing) changes every step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _gmm_kernel(ids_ref, x_ref, w_ref, o_ref, acc_ref):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(d == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tf", "td", "interpret",
                                             "out_dtype"))
def gmm_call(expert_ids, x, w, *, tm: int, tf: int, td: int,
             interpret: bool = False, out_dtype=None):
    """expert_ids: [T/tm] int32; x: [T, D]; w: [E, D, F] -> out [T, F]."""
    t_rows, d_model = x.shape
    _, _, f = w.shape
    out_dtype = out_dtype or x.dtype
    grid = (t_rows // tm, f // tf, d_model // td)

    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, td), lambda t, fj, dj, ids: (t, dj)),
                pl.BlockSpec((None, td, tf),
                             lambda t, fj, dj, ids: (ids[t], dj, fj)),
            ],
            out_specs=pl.BlockSpec((tm, tf), lambda t, fj, dj, ids: (t, fj)),
            scratch_shapes=[pltpu.VMEM((tm, tf), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((t_rows, f), out_dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(expert_ids, x, w)
