"""Balanced-walk grouped SpMM (the ``dynamic_grouped_balanced`` route).

``grouped_spmm`` hands the packed tile slots to the dsmm walk in
tile-sorted (row-major) order: on a skewed runtime pattern one hot
row-tile owns a long run of consecutive slots, and the walk serializes
on that run exactly like the static uniform walk does.  This variant
re-sorts the slots by a device-side row-swizzle -- the runtime analogue
of ``partitioner.plan_swizzle``: row-tiles are snake-binned by their
(runtime) tile counts and slots are ordered bin-contiguously, rows
ascending within a bin, so consecutive same-row runs are bounded by the
per-bin load instead of the hottest row's total.

Everything is jnp on runtime indices (jit-safe, no host metadata): the
dynamic-mode pendant of the static route's free plan-time swizzle, and
the same trade the paper makes for dynamic sparsity everywhere else --
the balance analysis itself costs device work per call.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dynamic_sparse import DynamicOperand
from repro.kernels.dsmm.dsmm import dsmm_call
from repro.kernels.gmm.ops import (clamped_tiles_cap, grouped_tile_size,
                                   pack_tiles_device)


def _encode_slots_balanced(op: DynamicOperand, num_bins: int):
    """Coverage slots + row-swizzled slot order (device-side).

    1. prepend one zero 'coverage' slot per output row-tile (identical
       to ``dsmm._encode_slots``) so every output tile is written;
    2. snake-bin row-tiles by their *valid* slot counts (descending),
       then stable-sort all slots by ``(bin, row)`` -- the walk stays
       row-contiguous (each row lives in exactly one bin), so the
       accumulate/flush invariant holds unchanged.
    """
    mt, _ = op.grid
    b = op.block_size
    nb = max(1, min(int(num_bins), mt))
    valid = jnp.arange(op.capacity) < op.nnz
    counts = jnp.zeros((mt,), jnp.int32).at[op.row_idx].add(
        valid.astype(jnp.int32))
    order_desc = jnp.argsort(-counts)
    i = jnp.arange(mt)
    pos, rnd = i % nb, i // nb
    dealt = jnp.where(rnd % 2 == 0, pos, nb - 1 - pos).astype(jnp.int32)
    bin_of_row = jnp.zeros((mt,), jnp.int32).at[order_desc].set(dealt)

    cov_rows = jnp.arange(mt, dtype=jnp.int32)
    rows = jnp.concatenate([cov_rows, op.row_idx])
    cols = jnp.concatenate([jnp.zeros((mt,), jnp.int32), op.col_idx])
    vals = jnp.concatenate(
        [jnp.zeros((mt, b, b), op.values.dtype), op.values])
    key = bin_of_row[rows] * jnp.int32(mt + 1) + rows
    order = jnp.argsort(key, stable=True)
    return rows[order], cols[order], vals[order]


def balanced_spmm(op: DynamicOperand, x, *, tile: int | None = None,
                  tiles_cap: int | None = None, num_bins: int = 8,
                  interpret: bool = False, return_stats: bool = False):
    """``Y = decode(op) @ X`` through device-side tile packing + the
    row-swizzled slot walk (the ``dynamic_grouped_balanced`` route).

    Capacity semantics (planned bucket, exact overflow accounting) are
    identical to ``grouped_spmm`` -- the pack is shared; only the slot
    visit order differs.
    """
    m, k = op.shape
    t = tile or grouped_tile_size(m, k, op.block_size)
    mt, kt = m // t, k // t
    if tiles_cap is None:
        tiles_cap = min(op.capacity, mt * kt)
    else:
        tiles_cap, _ = clamped_tiles_cap(tiles_cap, m, k, t)
    tiles_cap = max(1, tiles_cap)
    packed, stats = pack_tiles_device(op, tile=t, tiles_cap=tiles_cap,
                                      with_stats=return_stats)
    n = x.shape[-1]
    tn = 128
    while n % tn:
        tn //= 2
    tn = max(tn, 1)
    rows, cols, vals = _encode_slots_balanced(packed, num_bins)
    y = dsmm_call(rows, cols, vals, x, b=t, tn=tn, grid_m=m // t,
                  interpret=interpret)
    if return_stats:
        return y, stats
    return y
