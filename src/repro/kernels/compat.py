"""Version compatibility shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in
newer jax releases; the kernels go through this helper so they load on
both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def compiler_params(**kwargs):
    return _CompilerParams(**kwargs)
