"""Jit'd wrapper + runtime slot encoder for the dynamic sparse kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dynamic_sparse import DynamicOperand
from repro.kernels.dsmm.dsmm import dsmm_call


def _encode_slots(op: DynamicOperand):
    """Runtime re-partitioning (the paper's dynamic distribution phase):

    1. prepend one zero 'coverage' slot per output block-row so every
       output tile is written even if a row has no non-zeros this step;
    2. stable-sort all slots by row so the kernel's accumulate/flush walk
       is valid for *any* runtime pattern.
    """
    mb, _ = op.grid
    b = op.block_size
    cov_rows = jnp.arange(mb, dtype=jnp.int32)
    rows = jnp.concatenate([cov_rows, op.row_idx])
    cols = jnp.concatenate([jnp.zeros((mb,), jnp.int32), op.col_idx])
    vals = jnp.concatenate(
        [jnp.zeros((mb, b, b), op.values.dtype), op.values])
    order = jnp.argsort(rows, stable=True)
    return rows[order], cols[order], vals[order]


def dsmm(op: DynamicOperand, x, *, tn: int | None = None,
         interpret: bool = False):
    """Dynamic SpMM ``Y = decode(op) @ X`` through the Pallas kernel."""
    m, k = op.shape
    b = op.block_size
    n = x.shape[-1]
    if tn is None:
        tn = 128
        while n % tn:
            tn //= 2
        tn = max(tn, 1)
    rows, cols, vals = _encode_slots(op)
    return dsmm_call(rows, cols, vals, x, b=b, tn=tn, grid_m=m // b,
                     interpret=interpret)
