from repro.kernels.dsmm.ops import dsmm  # noqa: F401
from repro.kernels.dsmm.ref import dsmm_ref  # noqa: F401
from repro.kernels.contract import KernelContract, register

# dynamic slot-encoded SpMM: runtime pattern in a fixed nnz_max slot
# array (plus one coverage slot per block-row); tn shrinks to divide n
CONTRACT = register(KernelContract(
    kernel="dsmm",
    routes=("dynamic_pallas",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=128,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="(slots) x (n // tn) accumulate/flush walk over row-sorted "
         "slots, grid_m = m // b",
    capacity="slot_capacity",
    pallas=True,
))
