from repro.kernels.dsmm.ops import dsmm  # noqa: F401
from repro.kernels.dsmm.ref import dsmm_ref  # noqa: F401
