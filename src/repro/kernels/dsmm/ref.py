"""Pure-jnp oracle for dsmm: decode to dense then matmul."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dynamic_sparse import DynamicOperand


def dsmm_ref(op: DynamicOperand, x):
    return jnp.dot(op.to_dense(), x,
                   preferred_element_type=jnp.float32).astype(x.dtype)
