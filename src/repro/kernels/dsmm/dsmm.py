"""Dynamic block-sparse matmul Pallas TPU kernel (PopSparse §3.3 on MXU).

Same walk as ``bsmm`` -- accumulate over a row-sorted slot list, flush on
row change -- but everything the static kernel gets for free at compile
time is paid for at runtime, reproducing the paper's dynamic-mode cost
taxonomy exactly:

* the slot list is **runtime data** (scalar-prefetch operands are traced
  arrays), so DMA targets are resolved per step instead of pre-planned;
* the grid is sized for **capacity** (``d_max``), not the true nnz: padded
  slots execute as zero-contribution steps -- the analogue of the paper's
  overflow/propagation phases which "must account for the largest
  communication volume possible";
* values stay at logical ``b x b`` granularity (no host tile packing is
  possible), so MXU utilisation is intrinsically lower -- mirroring the
  paper's per-block on-tile compute with extra control flow.

The encoder that produces the slot arrays (sort-by-row + coverage) is in
``ops.py`` and is itself jit-compiled: its cycles are part of dynamic
mode's measured overhead, like PopSparse's runtime partitioner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _dsmm_kernel(rows_ref, cols_ref, a_ref, x_ref, o_ref, acc_ref):
    del cols_ref
    s = pl.program_id(1)
    t = pl.num_programs(1)

    @pl.when((s == 0) | (rows_ref[s] != rows_ref[jnp.maximum(s - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when((s == t - 1) | (rows_ref[s] != rows_ref[jnp.minimum(s + 1, t - 1)]))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("b", "tn", "grid_m",
                                             "interpret", "out_dtype"))
def dsmm_call(rows, cols, values, x, *, b: int, tn: int, grid_m: int,
              interpret: bool = False, out_dtype=None):
    """Raw kernel entry.

    rows/cols: [S] int32 runtime slot metadata, row-sorted, all rows covered
    values:    [S, b, b] slot values (zero for padding slots)
    x:         [K, N]
    returns    [grid_m * b, N]
    """
    s_cap = values.shape[0]
    k, n = x.shape
    out_dtype = out_dtype or x.dtype
    grid = (n // tn, s_cap)

    return pl.pallas_call(
        _dsmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, b, b),
                             lambda nj, s, rows, cols: (s, 0, 0)),
                pl.BlockSpec((b, tn),
                             lambda nj, s, rows, cols: (cols[s], nj)),
            ],
            out_specs=pl.BlockSpec((b, tn),
                                   lambda nj, s, rows, cols: (rows[s], nj)),
            scratch_shapes=[pltpu.VMEM((b, tn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((grid_m * b, n), out_dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rows, cols, values, x)
