"""Static kernel contracts: what each kernel package promises to accept.

Every kernel package (``kernels/{bsmm,dsmm,gmm,sddmm,dense_mm,bs_attn}``)
and every XLA-formulation module that backs a dispatch route declares a
frozen :class:`KernelContract` describing the shapes/dtypes it accepts:
supported dtypes, block-size range, divisibility constraints, the tile
grid it walks, and its capacity semantics.  The contracts are *static*
metadata -- importable without a TPU, evaluated without tracing -- so
``tools/lint/contracts.py`` can cross-check the dispatch admissibility
gates (``dispatch._candidates`` / ``dispatch.sddmm_candidates``) against
what the kernels actually accept before anything compiles.

Divisibility constraints are strings of Python over the free variables
``m, k, n, b`` (operand rows/cols, dense rhs cols, block size), e.g.
``"m % b == 0"`` or the grouped-tile rule
``"any(t % b == 0 and m % t == 0 and k % t == 0 for t in range(b, 129))"``.
They are evaluated with :meth:`KernelContract.admits`, which returns
``None`` (admitted) or a human-readable rejection reason.

Capacity vocabulary (how the kernel sizes its nonzero storage):

* ``"exact"``           static pattern, storage == nnz blocks
* ``"planned_bucket"``  expected-tiles x headroom bucket (Appendix A.2)
* ``"slot_capacity"``   fixed nnz_max slot array, runtime pattern
* ``"dense"``           no sparsity -- full dense operand
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

CAPACITY_KINDS = ("exact", "planned_bucket", "slot_capacity", "dense")

# the eval sandbox for divisibility expressions: no builtins beyond the
# comprehension helpers the grouped-tile rule needs
_EVAL_GLOBALS = {"__builtins__": {}, "any": any, "all": all,
                 "min": min, "max": max, "range": range}


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """Declared admissibility of one kernel (or XLA formulation).

    kernel        short package/module name ("bsmm", "static_xla", ...)
    routes        dispatch route ids this kernel serves (may be empty
                  for kernels outside the matmul route table, e.g.
                  bs_attn)
    dtypes        supported operand dtypes, by name
    min_block /   inclusive block-size range
    max_block
    divisibility  eval-able constraints over {m, k, n, b}; ALL must
                  hold for a shape to be admitted
    grid          human-readable tile-grid formula (documentation; the
                  lint rule only requires it to be non-empty)
    capacity      one of CAPACITY_KINDS
    pallas        True if execution requires a Pallas-capable backend
                  (TPU or interpret mode)
    """

    kernel: str
    routes: Tuple[str, ...]
    dtypes: Tuple[str, ...]
    min_block: int
    max_block: int
    divisibility: Tuple[str, ...]
    grid: str
    capacity: str
    pallas: bool

    def __post_init__(self):
        if self.capacity not in CAPACITY_KINDS:
            raise ValueError(f"contract {self.kernel!r}: capacity "
                             f"{self.capacity!r} not in {CAPACITY_KINDS}")
        if not (1 <= self.min_block <= self.max_block):
            raise ValueError(f"contract {self.kernel!r}: bad block range "
                             f"[{self.min_block}, {self.max_block}]")

    def admits(self, m: int, k: int, n: int, b: int,
               dtype: str = "float32") -> Optional[str]:
        """``None`` if the kernel accepts (m, k) @ (k, n) at block size
        ``b`` in ``dtype``; otherwise the reason it rejects."""
        if dtype not in self.dtypes:
            return f"dtype {dtype} not in supported {self.dtypes}"
        if not (self.min_block <= b <= self.max_block):
            return (f"block {b} outside [{self.min_block}, "
                    f"{self.max_block}]")
        for expr in self.divisibility:
            # free vars go in globals: comprehensions inside eval open a
            # new scope that cannot see the locals mapping
            env = dict(_EVAL_GLOBALS, m=m, k=k, n=n, b=b)
            if not eval(expr, env):  # noqa: S307 (sandboxed)
                return f"constraint {expr!r} fails for m={m} k={k} n={n} b={b}"
        return None


_REGISTRY: Dict[str, KernelContract] = {}


def register(contract: KernelContract) -> KernelContract:
    """Register ``contract`` under its kernel name (idempotent; a kernel
    re-imported under pytest must not trip the duplicate check)."""
    prev = _REGISTRY.get(contract.kernel)
    if prev is not None and prev != contract:
        raise ValueError(f"conflicting contract registration for "
                         f"{contract.kernel!r}")
    _REGISTRY[contract.kernel] = contract
    return contract


def all_contracts() -> Dict[str, KernelContract]:
    return dict(_REGISTRY)


def contract_for_route(route: str) -> Optional[KernelContract]:
    for c in _REGISTRY.values():
        if route in c.routes:
            return c
    return None


def load_all() -> Dict[str, KernelContract]:
    """Import every module that declares a CONTRACT and return the full
    registry.  This is the entry point the contract checker uses."""
    import repro.kernels.bsmm      # noqa: F401
    import repro.kernels.dsmm      # noqa: F401
    import repro.kernels.gmm       # noqa: F401
    import repro.kernels.sddmm     # noqa: F401
    import repro.kernels.dense_mm  # noqa: F401
    import repro.kernels.bs_attn   # noqa: F401
    import repro.core.static_sparse   # noqa: F401
    import repro.core.dynamic_sparse  # noqa: F401
    import repro.core.dispatch        # noqa: F401
    return all_contracts()
