"""Jit'd wrapper for block-sparse attention: mask -> visit pairs."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.bs_attn.bs_attn import bs_attn_call


def mask_to_pairs(block_mask: np.ndarray):
    """Host: flatten a block mask into row-sorted (q_tile, kv_tile) pairs.

    Raises if any q tile row is empty (an uncovered output tile would
    never be written) -- causal masks including the diagonal always pass.
    """
    mask = np.asarray(block_mask, bool)
    if not mask.any(axis=1).all():
        raise ValueError("every q block-row needs >=1 visible kv block")
    rows, cols = np.nonzero(mask)
    order = np.lexsort((cols, rows))
    return rows[order].astype(np.int32), cols[order].astype(np.int32)


def bs_attn(q, k, v, block_mask: np.ndarray, *, bq: int = 128,
            bkv: int = 128, scale: float | None = None, causal: bool = True,
            softcap: float | None = None, interpret: bool = False):
    """Block-sparse attention.  ``q: [H, Sq, dh]``, ``k/v: [H, Skv, dh]``,
    ``block_mask: [Sq/bq, Skv/bkv]`` host bool."""
    h, sq, dh = q.shape
    skv = k.shape[1]
    if block_mask.shape != (sq // bq, skv // bkv):
        raise ValueError(f"mask {block_mask.shape} != grid "
                         f"{(sq // bq, skv // bkv)}")
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    rows, cols = mask_to_pairs(block_mask)
    return bs_attn_call(jnp.asarray(rows), jnp.asarray(cols), q, k, v,
                        bq=bq, bkv=bkv, scale=float(scale), causal=causal,
                        softcap=softcap, interpret=interpret)
