"""Block-sparse flash attention Pallas TPU kernel.

The paper's *static* block sparsity applied to attention: a host-constant
block mask over (Sq/bq, Skv/bkv) tiles (e.g. local+global, banded --
``core/masks.py``) is flattened into (q_tile, kv_tile) visit pairs at
compile time, exactly like ``bsmm`` metadata.  The kernel walks pairs
sorted by q tile with an online-softmax accumulator and flushes when the
q tile changes; tiles outside the mask are never visited, so cost is
O(nnz_tiles) -- this is what makes the ``long_500k`` configs sub-
quadratic (DESIGN.md §3).

Supports causal intra-tile masking (derived from prefetch metadata, no
extra operands) and Gemma-2 style logit soft-capping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _bs_attn_kernel(rows_ref, cols_ref, q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, scale, causal, bq, bkv,
                    softcap):
    s = pl.program_id(1)
    t = pl.num_programs(1)

    @pl.when((s == 0) | (rows_ref[s] != rows_ref[jnp.maximum(s - 1, 0)]))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                   # (bq, dh) -- None dim pre-squeezed
    k = k_ref[...]                   # (bkv, dh)
    v = v_ref[...]                   # (bkv, dh)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if causal:
        r0 = rows_ref[s] * bq
        c0 = cols_ref[s] * bkv
        ri = r0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        ci = c0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        logits = jnp.where(ri >= ci, logits, NEG_INF)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    l_ref[:, :1] = l_ref[:, :1] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[:, :1] = m_new

    @pl.when((s == t - 1) | (rows_ref[s] != rows_ref[jnp.minimum(s + 1, t - 1)]))
    def _flush():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "scale", "causal",
                                             "softcap", "interpret"))
def bs_attn_call(tile_rows, tile_cols, q, k, v, *, bq: int, bkv: int,
                 scale: float, causal: bool = True,
                 softcap: float | None = None, interpret: bool = False):
    """q: [H, Sq, dh], k/v: [H, Skv, dh]; tile pairs sorted by q tile.

    Every q tile must be covered by >= 1 pair (guaranteed for causal
    masks that include the diagonal; the ops wrapper enforces it).
    """
    h, sq, dh = q.shape
    grid = (h, tile_rows.shape[0])
    kern = functools.partial(_bs_attn_kernel, scale=scale, causal=causal,
                             bq=bq, bkv=bkv, softcap=softcap)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, bq, dh),
                             lambda hh, s, rows, cols: (hh, rows[s], 0)),
                pl.BlockSpec((None, bkv, dh),
                             lambda hh, s, rows, cols: (hh, cols[s], 0)),
                pl.BlockSpec((None, bkv, dh),
                             lambda hh, s, rows, cols: (hh, cols[s], 0)),
            ],
            out_specs=pl.BlockSpec((None, bq, dh),
                                   lambda hh, s, rows, cols: (hh, rows[s], 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_rows, tile_cols, q, k, v)
