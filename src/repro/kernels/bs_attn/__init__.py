from repro.kernels.bs_attn.ops import bs_attn, mask_to_pairs  # noqa: F401
from repro.kernels.bs_attn.ref import bs_attn_ref  # noqa: F401
