from repro.kernels.bs_attn.ops import bs_attn, mask_to_pairs  # noqa: F401
from repro.kernels.bs_attn.ref import bs_attn_ref  # noqa: F401
from repro.kernels.contract import KernelContract, register

# block-sparse flash attention: outside the matmul route table (routes
# empty), declared so the contract checker still audits its gate; the
# static mask must give every query block-row at least one key block
# (mask_to_pairs raises otherwise) -- not expressible over m/k/n/b, so
# it stays a runtime check
CONTRACT = register(KernelContract(
    kernel="bs_attn",
    routes=(),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=128,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="heads x q-block-rows, inner walk over the row's visible "
         "(q, k) block pairs from mask_to_pairs",
    capacity="exact",
    pallas=True,
))
