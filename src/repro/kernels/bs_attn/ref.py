"""Pure-jnp oracle for block-sparse attention (dense softmax + mask)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def bs_attn_ref(q, k, v, block_mask: np.ndarray, *, bq: int = 128,
                bkv: int = 128, scale: float | None = None,
                causal: bool = True, softcap: float | None = None):
    h, sq, dh = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    el_mask = np.repeat(np.repeat(np.asarray(block_mask, bool), bq, axis=0),
                        bkv, axis=1)
    if causal:
        el_mask = el_mask & (np.arange(sq)[:, None] >= np.arange(skv)[None, :])
    logits = jnp.where(jnp.asarray(el_mask)[None], logits, -1e30)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hqk,hkd->hqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)
