"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper), ``ref.py`` (pure-jnp oracle).  Validated on CPU with
``interpret=True``; compiled path targets TPU v5e.

* ``bsmm``     static block-sparse matmul (paper §3.2)
* ``dsmm``     dynamic block-sparse matmul (paper §3.3)
* ``gmm``      grouped GEMM = dynamic block-diagonal (MoE / MegaBlocks)
* ``dense_mm`` dense tiled baseline (poplin::matMul analogue)
* ``bs_attn``  block-sparse flash attention (static mask, long-context)
"""
