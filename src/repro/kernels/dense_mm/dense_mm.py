"""Dense tiled matmul Pallas TPU kernel -- the paper's dense baseline
(IPU ``poplin::matMul`` / GPU ``cublasGemmEx`` analogue).

Classic 3-D tiling: ``grid = (M/tm, N/tn, K/tk)`` with a VMEM fp32
accumulator over the contraction dimension.  Exists so the benchmark
harness compares sparse kernels against a same-framework dense kernel,
like the paper compares popsparse:: against poplin::.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(kj == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tn", "interpret",
                                             "out_dtype"))
def dense_mm_call(a, b, *, tm: int, tk: int, tn: int,
                  interpret: bool = False, out_dtype=None):
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kj: (i, kj)),
            pl.BlockSpec((tk, tn), lambda i, j, kj: (kj, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kj: (i, j)),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
