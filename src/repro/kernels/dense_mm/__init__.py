from repro.kernels.dense_mm.ops import dense_mm  # noqa: F401
from repro.kernels.dense_mm.ref import dense_mm_ref  # noqa: F401
from repro.kernels.contract import KernelContract, register

# dense tiled baseline: tiles shrink to divisors of every dim, so any
# shape is admitted; block size is irrelevant (dense has no blocks)
CONTRACT = register(KernelContract(
    kernel="dense_mm",
    routes=("dense_pallas",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=1024,
    divisibility=(),
    grid="(m // tm) x (n // tn) x (k // tk), tm/tk/tn = largest "
         "power-of-two divisor <= 128 per dim",
    capacity="dense",
    pallas=True,
))
