from repro.kernels.dense_mm.ops import dense_mm  # noqa: F401
from repro.kernels.dense_mm.ref import dense_mm_ref  # noqa: F401
