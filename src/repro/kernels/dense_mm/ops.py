"""Jit'd wrapper for the dense matmul baseline kernel."""
from __future__ import annotations

from repro.kernels.dense_mm.dense_mm import dense_mm_call


def _fit(dim, pref=128):
    v = pref
    while dim % v:
        v //= 2
    return max(v, 1)


def dense_mm(a, b, *, tm=None, tk=None, tn=None, interpret: bool = False):
    m, k = a.shape
    _, n = b.shape
    return dense_mm_call(a, b, tm=tm or _fit(m), tk=tk or _fit(k),
                         tn=tn or _fit(n), interpret=interpret)
