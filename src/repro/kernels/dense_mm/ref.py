"""Pure-jnp oracle for dense_mm."""
import jax.numpy as jnp


def dense_mm_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
