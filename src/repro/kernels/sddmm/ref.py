"""Pure-jnp oracle for the block-sampled dense-dense matmul (SDDMM)."""
from __future__ import annotations

import jax.numpy as jnp


def sddmm_ref(row_idx, col_idx, dy, x, *, block_size: int):
    """``dW[z] = dY_block[row[z]] @ X_block[col[z]]^T`` for every pattern
    block -- the dense-compute reference: full ``dY @ X^T`` then gather
    the pattern blocks."""
    m, n = dy.shape
    k = x.shape[0]
    b = block_size
    dw = jnp.dot(dy, x.T, preferred_element_type=jnp.float32)
    blocked = dw.reshape(m // b, b, k // b, b).transpose(0, 2, 1, 3)
    return blocked[jnp.asarray(row_idx), jnp.asarray(col_idx)]
