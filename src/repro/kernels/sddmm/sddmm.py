"""Grouped block-sampled dense-dense matmul (SDDMM) Pallas TPU kernel.

The weight gradient of a static block-sparse matmul is
``dW = (dY @ X^T) ⊙ M`` -- only the pattern's blocks are needed (paper
§3.2: backward keeps the same compile-time pattern, so sparse *training*
stays sparse).  Computing the full dense product and masking throws away
``1 - d`` of the FLOPs; walking logical ``b x b`` blocks under-fills the
128x128 MXU for small ``b`` (the same under-utilisation the forward
``dsmm`` walk pays).

This kernel is the SDDMM face of the grouped-tile idea (``kernels/gmm``):
the pattern's *tile* occupancy -- the same ``partitioner.plan_packing``
metadata the static forward kernel uses, transposed into sampled-output
form -- drives a grid over the non-empty ``t x t`` output tiles only.
Step ``(i, nj)`` accumulates ``dY[tile_rows[i]] @ X[tile_cols[i]]^T``
over the contraction (``n``) dimension; tile metadata is compile-time
scalar prefetch, exactly like ``bsmm``.  The per-block extraction from
the tile stack is host-metadata gather work and lives in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _sddmm_kernel(trows_ref, tcols_ref, dy_ref, x_ref, o_ref, acc_ref):
    del trows_ref, tcols_ref
    nj = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(nj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dY_tile [t, tn] @ X_tile [t, tn]^T: contract the n (lane) axis
    acc_ref[...] += jax.lax.dot_general(
        dy_ref[...], x_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(nj == nt - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t", "tn", "interpret",
                                             "out_dtype"))
def sddmm_tiles_call(tile_rows, tile_cols, dy, x, *, t: int, tn: int,
                     interpret: bool = False, out_dtype=None):
    """Raw kernel entry: the sampled ``t x t`` output tiles.

    tile_rows/tile_cols: [T] int32 compile-time tile metadata (row-major
                         non-empty tiles of the pattern, from
                         ``partitioner.plan_packing``)
    dy:                  [M, N]    upstream cotangent
    x:                   [K, N]    forward rhs
    returns              [T, t, t] one sampled product tile per slot
    """
    n = dy.shape[1]
    num_tiles = tile_rows.shape[0]
    out_dtype = out_dtype or dy.dtype
    grid = (num_tiles, n // tn)

    return pl.pallas_call(
        _sddmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((t, tn),
                             lambda it, nj, tr, tc: (tr[it], nj)),
                pl.BlockSpec((t, tn),
                             lambda it, nj, tr, tc: (tc[it], nj)),
            ],
            out_specs=pl.BlockSpec((None, t, t),
                                   lambda it, nj, tr, tc: (it, 0, 0)),
            scratch_shapes=[pltpu.VMEM((t, t), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_tiles, t, t), out_dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_rows, tile_cols, dy, x)
