"""Jit'd wrapper for the grouped SDDMM kernel (the ``sddmm_grouped``
backward dispatch route).

``grouped_sddmm`` consumes the same one-time pattern analysis the static
forward routes use (``partitioner.plan_packing``): the non-empty tile
list becomes the kernel grid, and the per-block slot/offset metadata
extracts the ``[nnz, b, b]`` value gradient from the computed tile
stack.  Everything pattern-dependent is a host constant baked at plan
time -- the backward face of the paper's compile-time contract.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.partitioner import PackingPlan
from repro.kernels.sddmm.sddmm import sddmm_tiles_call


def sddmm_tile_size(m: int, k: int, b: int, limit: int = 128) -> int:
    """Largest square tile ``t <= limit`` that is a block-multiple
    divisor of both the ``m`` (dy rows) and ``k`` (x rows) extents --
    the same sizing rule as ``gmm.grouped_tile_size``, applied to the
    sampled-output grid."""
    t = b * max(1, limit // b)
    while t > b and (m % t or k % t):
        t -= b
    if m % t or k % t:
        raise ValueError(f"no tile size <= {limit} divides both m={m} "
                         f"and k={k} at block {b}")
    return t


def grouped_sddmm(meta: PackingPlan, dy, x, *, tn: int | None = None,
                  interpret: bool = False):
    """``dW[z] = dY_block[row[z]] @ X_block[col[z]]^T`` restricted to the
    pattern captured in ``meta`` (a square-tile ``plan_packing`` of the
    pattern over the ``(m, k)`` grid).

    dy: [M, N] upstream cotangent; x: [K, N] forward rhs.
    Returns [nnz, b, b] in ``meta``'s block order.
    """
    if meta.tm != meta.tk:
        raise ValueError(f"grouped_sddmm needs square tiles, got "
                         f"({meta.tm}, {meta.tk})")
    t = meta.tm
    b = meta.block_size
    n = dy.shape[1]
    if x.shape[1] != n:
        raise ValueError(f"dy cols {n} != x cols {x.shape[1]}")
    if tn is None:
        tn = 128
        while n % tn:
            tn //= 2
        tn = max(tn, 1)
    tiles = sddmm_tiles_call(jnp.asarray(meta.tile_rows, jnp.int32),
                             jnp.asarray(meta.tile_cols, jnp.int32),
                             dy, x, t=t, tn=tn, interpret=interpret)
    # host-metadata block extraction: tile stack -> [nnz, b, b] values
    rpb = t // b
    blocked = tiles.reshape(meta.num_tiles, rpb, b, rpb, b)
    return blocked[jnp.asarray(meta.block_slot),
                   jnp.asarray(np.asarray(meta.in_r)),
                   :, jnp.asarray(np.asarray(meta.in_c)), :]
