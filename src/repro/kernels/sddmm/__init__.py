from repro.kernels.sddmm.ops import grouped_sddmm, sddmm_tile_size  # noqa: F401
from repro.kernels.sddmm.ref import sddmm_ref  # noqa: F401
from repro.kernels.contract import KernelContract, register

# block-sampled dense-dense matmul (dL/dvalues backward product): same
# square-tile rule as gmm -- one t <= 128, block-multiple, dividing m, k
CONTRACT = register(KernelContract(
    kernel="sddmm",
    routes=("sddmm_grouped",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=128,
    divisibility=(
        "m % b == 0", "k % b == 0",
        "any(t % b == 0 and m % t == 0 and k % t == 0 "
        "for t in range(b, 129))",
    ),
    grid="tiles x 1: one program per pattern tile, t x t output block "
         "sampled from dY @ X^T, t = sddmm_tile_size(m, k, b)",
    capacity="exact",
    pallas=True,
))
