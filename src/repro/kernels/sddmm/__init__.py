from repro.kernels.sddmm.ops import grouped_sddmm, sddmm_tile_size  # noqa: F401
from repro.kernels.sddmm.ref import sddmm_ref  # noqa: F401
