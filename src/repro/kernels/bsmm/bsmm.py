"""Static block-sparse matmul Pallas TPU kernel (PopSparse §3.2 on MXU).

Design (see DESIGN.md §2 for the IPU->TPU mapping):

* Logical ``b x b`` blocks are packed into MXU-aligned ``(tm, tk)`` tiles
  by ``partitioner.pack_tiles`` -- the compile-time value re-ordering of
  the paper.  ``tile_rows/tile_cols`` are **host constants**: the grid is
  sized to exactly the number of non-empty tiles, so the kernel performs
  zero wasted steps (the defining property of static sparsity).
* Grid = ``(N/tn, T)`` with the sparse-tile walk innermost.  Tiles are
  row-major sorted, so a VMEM accumulator carries partial sums while the
  output row-tile stays the same and flushes exactly once per (row, n)
  pair -- the "local dot product + final reduction" of paper Fig. 1a,
  with the reduction living in VMEM instead of IPU exchange.
* ``X`` tiles are fetched by a scalar-prefetch index map
  (``cols[s]``), i.e. the sparsity metadata drives the DMA schedule --
  the analogue of PopSparse pre-planning tile exchange at compile time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _bsmm_kernel(rows_ref, cols_ref, a_ref, x_ref, o_ref, acc_ref):
    del cols_ref  # consumed by the index maps
    s = pl.program_id(1)
    t = pl.num_programs(1)

    @pl.when((s == 0) | (rows_ref[s] != rows_ref[jnp.maximum(s - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when((s == t - 1) | (rows_ref[s] != rows_ref[jnp.minimum(s + 1, t - 1)]))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tn", "grid_m",
                                             "interpret", "out_dtype"))
def bsmm_call(tile_rows, tile_cols, tiles, x, *, tm: int, tk: int, tn: int,
              grid_m: int, interpret: bool = False, out_dtype=None):
    """Raw kernel entry.

    tile_rows/cols: [T] int32 (host constants for static mode)
    tiles:          [T, tm, tk] packed sparse tiles
    x:              [K, N] dense operand
    returns         [grid_m * tm, N]
    """
    t = tiles.shape[0]
    k, n = x.shape
    out_dtype = out_dtype or x.dtype
    n_tiles = n // tn
    grid = (n_tiles, t)

    return pl.pallas_call(
        _bsmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, tm, tk),
                             lambda nj, s, rows, cols: (s, 0, 0)),
                pl.BlockSpec((tk, tn),
                             lambda nj, s, rows, cols: (cols[s], nj)),
            ],
            out_specs=pl.BlockSpec((tm, tn),
                                   lambda nj, s, rows, cols: (rows[s], nj)),
            scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((grid_m * tm, n), out_dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_rows, tile_cols, tiles, x)
