"""Jit'd wrapper for the static block-sparse matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bsr import BlockSparseMatrix
from repro.core.partitioner import (BalancedPacking, PackingPlan,
                                    TilePacking, pack_tiles, pack_values,
                                    plan_packing_balanced)
from repro.kernels.bsmm.balanced import bsmm_balanced_call
from repro.kernels.bsmm.bsmm import bsmm_call


def _pick_tiles(m: int, k: int, n: int, b: int):
    """MXU-aligned tile sizes, shrunk for small problems."""
    tm = min(128, m) if m % 128 else 128
    tk = min(128, k) if k % 128 else 128
    tn = min(128, n) if n % 128 else 128
    # keep divisibility with the logical block
    tm = max(b, tm - tm % b)
    tk = max(b, tk - tk % b)
    while m % tm:
        tm //= 2
    while k % tk:
        tk //= 2
    while n % tn:
        tn //= 2
    return max(tm, 1), max(tk, 1), max(tn, 1)


def bsmm_packed(packing: TilePacking, x, *, tn: int | None = None,
                interpret: bool = False):
    """SpMM from a pre-packed tile set (production path: pack once at
    weight-load, multiply every step)."""
    m, k = packing.shape
    n = x.shape[-1]
    tn = tn or _pick_tiles(m, k, n, packing.tk)[2]
    return bsmm_call(jnp.asarray(packing.tile_rows),
                     jnp.asarray(packing.tile_cols),
                     packing.values, x,
                     tm=packing.tm, tk=packing.tk, tn=tn,
                     grid_m=packing.grid[0], interpret=interpret)


def bsmm_from_plan(meta: PackingPlan, values, x, *, tn: int | None = None,
                   interpret: bool = False):
    """SpMM from a one-time ``partitioner.plan_packing`` analysis: the
    pattern metadata is a baked host constant, only the value relayout
    (``pack_values``) runs per call.  This is the ``repro.sparse``
    plan-execute path for the ``static_pallas`` route."""
    m, k = meta.shape
    n = x.shape[-1]
    tn = tn or _pick_tiles(m, k, n, meta.tk)[2]
    tiles = pack_values(meta, values)
    return bsmm_call(jnp.asarray(meta.tile_rows),
                     jnp.asarray(meta.tile_cols), tiles, x,
                     tm=meta.tm, tk=meta.tk, tn=tn,
                     grid_m=meta.grid[0], interpret=interpret)


def bsmm_balanced_from_plan(meta: BalancedPacking, values, x, *,
                            tn: int | None = None,
                            interpret: bool = False):
    """SpMM from a one-time ``partitioner.plan_packing_balanced``
    analysis (the ``static_balanced`` route's plan-execute path): the
    row-swizzled visit schedule is a baked host constant; per call only
    the value relayout (``pack_values``, identical to the uniform
    route's) plus the appended zero pad tile run."""
    base = meta.base
    m, k = base.shape
    n = x.shape[-1]
    tn = tn or _pick_tiles(m, k, n, base.tk)[2]
    tiles = pack_values(base, values)
    tiles = jnp.concatenate(
        [tiles, jnp.zeros((1, base.tm, base.tk), tiles.dtype)])
    return bsmm_balanced_call(jnp.asarray(meta.visit_rows),
                              jnp.asarray(meta.visit_cols),
                              jnp.asarray(meta.visit_slot), tiles, x,
                              tm=base.tm, tk=base.tk, tn=tn,
                              grid_m=base.grid[0], interpret=interpret)


def bsmm_balanced(bsr: BlockSparseMatrix, x, *, tm: int | None = None,
                  tk: int | None = None, tn: int | None = None,
                  num_bins: int | None = None, interpret: bool = False):
    """One-shot convenience: balanced plan + multiply.  ``x: [k, n]``."""
    if not bsr.is_static:
        raise ValueError("bsmm_balanced requires a static pattern")
    m, k = bsr.shape
    n = x.shape[-1]
    atm, atk, atn = _pick_tiles(m, k, n, bsr.block_size)
    meta = plan_packing_balanced(bsr.row_idx, bsr.col_idx, bsr.shape,
                                 bsr.block_size, tm or atm, tk or atk,
                                 num_bins=num_bins)
    return bsmm_balanced_from_plan(meta, bsr.values, x, tn=tn or atn,
                                   interpret=interpret)


def bsmm(bsr: BlockSparseMatrix, x, *, tm: int | None = None,
         tk: int | None = None, tn: int | None = None,
         interpret: bool = False):
    """One-shot convenience: pack + multiply.  ``x: [k, n]``."""
    if not bsr.is_static:
        raise ValueError("bsmm requires a static pattern (use dsmm)")
    m, k = bsr.shape
    n = x.shape[-1]
    atm, atk, atn = _pick_tiles(m, k, n, bsr.block_size)
    packing = pack_tiles(bsr, tm or atm, tk or atk)
    return bsmm_packed(packing, x, tn=tn or atn, interpret=interpret)
