"""Balanced-walk static block-sparse matmul (row-swizzle load balance).

The uniform ``bsmm`` walk visits the packed tiles row-major on one
``arbitrary`` grid axis: a power-law row profile serializes the walk on
the hot rows (most steps share one output row-tile, so the inter-step
flush/init bubbles pile onto a single lane).  Gale et al. 2020 (arxiv
2006.10901, §5.1) show row swizzling -- reordering rows so concurrent
lanes carry near-equal work -- recovers that loss on realistic (DLMC)
patterns.

This variant consumes ``partitioner.plan_packing_balanced``: row-tiles
are snake-binned by tile count at plan time, and the kernel walks a 3-D
grid ``(n // tn, num_bins, steps_per_bin)`` -- one *parallel* lane per
bin, each lane a short ``arbitrary`` walk over its bin's visit schedule
(scalar-prefetched ``[bins, steps]`` metadata).  Bins own disjoint
row-tile sets and every row-tile's tiles are contiguous within its
lane, so the accumulate/flush invariant of the uniform kernel holds per
lane unchanged.  Lanes shorter than ``steps_per_bin`` pad with an
appended all-zero tile and keep their last real row: the pad steps
accumulate zeros and defer that row's single flush to the lane end.
The inverse row permutation costs nothing at runtime -- the visit
schedule carries *original* row-tile ids, so the output index map
scatters each flush straight to its un-swizzled position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _bsmm_balanced_kernel(rows_ref, cols_ref, slots_ref, a_ref, x_ref,
                          o_ref, acc_ref):
    del cols_ref, slots_ref  # consumed by the index maps
    g = pl.program_id(1)
    s = pl.program_id(2)
    t = pl.num_programs(2)

    @pl.when((s == 0) | (rows_ref[g, s] != rows_ref[g, jnp.maximum(s - 1, 0)]))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], x_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when((s == t - 1)
             | (rows_ref[g, s] != rows_ref[g, jnp.minimum(s + 1, t - 1)]))
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tn", "grid_m",
                                             "interpret", "out_dtype"))
def bsmm_balanced_call(visit_rows, visit_cols, visit_slot, tiles, x, *,
                       tm: int, tk: int, tn: int, grid_m: int,
                       interpret: bool = False, out_dtype=None):
    """Raw kernel entry.

    visit_rows/cols/slot: [bins, steps] int32 (host constants)
    tiles:                [T + 1, tm, tk] packed tiles + trailing zero pad
    x:                    [K, N] dense operand
    returns               [grid_m * tm, N]
    """
    bins, steps = visit_rows.shape
    k, n = x.shape
    out_dtype = out_dtype or x.dtype
    grid = (n // tn, bins, steps)

    return pl.pallas_call(
        _bsmm_balanced_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, tm, tk),
                             lambda nj, g, s, rows, cols, slots:
                             (slots[g, s], 0, 0)),
                pl.BlockSpec((tk, tn),
                             lambda nj, g, s, rows, cols, slots:
                             (cols[g, s], nj)),
            ],
            out_specs=pl.BlockSpec((tm, tn),
                                   lambda nj, g, s, rows, cols, slots:
                                   (rows[g, s], nj)),
            scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((grid_m * tm, n), out_dtype),
        # bins write disjoint row-tile sets (pads keep the bin's own last
        # row), so the bin axis is safely parallel
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(visit_rows, visit_cols, visit_slot, tiles, x)
