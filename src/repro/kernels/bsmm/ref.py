"""Pure-jnp oracle for bsmm: densify then matmul."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bsr import BlockSparseMatrix


def bsmm_ref(bsr: BlockSparseMatrix, x):
    """Reference ``Y = (M ⊙ W) @ X`` -- maximally simple, O(m·k·n)."""
    return jnp.dot(bsr.to_dense(), x, preferred_element_type=jnp.float32
                   ).astype(x.dtype)
