from repro.kernels.bsmm.ops import bsmm, bsmm_packed  # noqa: F401
from repro.kernels.bsmm.ref import bsmm_ref  # noqa: F401
