from repro.kernels.bsmm.ops import bsmm, bsmm_balanced, bsmm_packed  # noqa: F401
from repro.kernels.bsmm.ref import bsmm_ref  # noqa: F401
from repro.kernels.contract import KernelContract, register

# static block-sparse SpMM: the BSR operand fixes m % b == k % b == 0 by
# construction; _pick_tiles shrinks tm/tk/tn to divisors, so n is free
CONTRACT = register(KernelContract(
    kernel="bsmm",
    routes=("static_pallas",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=128,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="(m // tm) x (n // tn), tm/tk/tn MXU-aligned divisors from "
         "_pick_tiles; inner walk over the row's packed tiles",
    capacity="exact",
    pallas=True,
))

# row-swizzled balanced walk: same operand constraints and value layout
# as bsmm; the visit schedule (plan_packing_balanced) adds one zero pad
# tile per lane and a [bins, steps] scalar-prefetch schedule
BALANCED_CONTRACT = register(KernelContract(
    kernel="bsmm_balanced",
    routes=("static_balanced",),
    dtypes=("float32", "bfloat16", "float16"),
    min_block=1,
    max_block=128,
    divisibility=("m % b == 0", "k % b == 0"),
    grid="(n // tn) x bins x steps_per_bin, tm/tk/tn as bsmm; one "
         "parallel lane per snake-assigned row bin, arbitrary walk "
         "inside the lane (pads -> appended zero tile)",
    capacity="exact",
    pallas=True,
))
