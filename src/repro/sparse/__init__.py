"""``repro.sparse`` -- the plan-first public sparse-matmul API.

PopSparse's headline speedups come from *ahead-of-time* planning: for
static sparsity the pattern is baked into the compiled graph (§3.2),
and even dynamic sparsity fixes its bucket plan up front (§3.3).  This
package makes that lifecycle explicit -- two phases:

    from repro import sparse

    # phase 1 (once): normalization, pattern analysis + tile packing,
    # route selection (cost model / measured autotune / disk cache),
    # dynamic bucket sizing, mesh-aware TP sharding
    p = sparse.plan(operand, n, ctx=sparse.PlanContext(...))

    # phase 2 (hot path): a decision-free direct call
    y = p(values, x)          # or p.apply(operand, x)

Measured verdicts persist to a versioned on-disk cache (configure via
``sparse.configure(cache_dir=...)`` or $REPRO_CACHE_DIR), so serving
restarts re-plan with zero re-measurement.

``sparse.spmm`` / ``spmm_nt`` / ``matmul`` / ``batched_matmul`` are
one-shot conveniences over the plan cache; ``repro.core.dispatch``'s
entry points remain as deprecation shims that build-and-call a plan.
"""
from repro.sparse.cache import SCHEMA_VERSION  # noqa: F401
from repro.sparse.plan import (  # noqa: F401
    MatmulPlan,
    analytic_plans,
    batched_matmul,
    cache_stats,
    capacity_report,
    configure,
    evolve,
    evolve_plans,
    explain,
    format_plan,
    matmul,
    plan,
    plan_report,
    pool_plans,
    record_dropped,
    remeasure_plan,
    reset,
    reset_telemetry,
    roofline_report,
    spmm,
    spmm_nt,
    tp_report,
    use_ctx,
)
from repro.sparse.spec import (  # noqa: F401
    CAPACITY_POLICIES,
    ESCALATION_MIN_CALLS,
    GRAD_DX_MODES,
    GRAD_SDDMM_MODES,
    CapacityStats,
    OpSpec,
    PlanContext,
    PLAN_MODES,
    PLAN_ROUTES,
    TP_ROUTES,
)
