"""Two-phase plan/execute sparse matmul (the plan-first public API).

    from repro import sparse

    p = sparse.plan(operand, n)            # phase 1: ALL one-time work
    y = p(values, x)                       # phase 2: zero-decision call
    y = p.apply(operand, x)                # payload extracted for you
    print(sparse.format_plan(p))           # what will run, and why

Phase 1 mirrors PopSparse's ahead-of-time planning: operand
normalization, pattern analysis (``partitioner.plan_packing`` /
``plan_k_shards`` -- the one-time halves of the packing and TP
sharding), route selection through the dispatch cost model (optionally
wall-clock measured), dynamic bucket sizing (``planner.plan_dynamic``),
and mesh-aware TP routes from ``core/tp.py``.  The result is a frozen
``MatmulPlan`` whose execute closure contains no decisions: safe under
``jax.jit`` / ``grad`` / ``vmap`` (XLA routes), and a plain direct call
in the steady state.

Verdicts persist to a versioned on-disk cache (``sparse.cache``), so a
serving restart re-plans without re-measuring.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.dispatch as dispatch
import repro.core.partitioner as partitioner
import repro.core.planner as planner_lib
import repro.core.static_sparse as _ssp
import repro.core.tp as tp_lib
from repro.core.bsr import BlockSparseMatrix
from repro.core.dynamic_sparse import DynamicOperand, _dspmm
from repro.sparse import cache as cache_lib
from repro.sparse.spec import (OpSpec, PlanContext, PLAN_ROUTES,
                               pattern_key, payload_of)

Operand = Union[jax.Array, np.ndarray, BlockSparseMatrix, DynamicOperand]

_plan_cache: Dict[tuple, "MatmulPlan"] = {}
_plan_lock = threading.Lock()


def reset(*, counters: bool = True):
    """Forget every in-memory plan, decision, and (optionally) counter.
    Disk cache files survive -- this simulates a fresh process."""
    with _plan_lock:
        _plan_cache.clear()
    cache_lib.reset(counters=counters)
    dispatch.clear_cache()


def cache_stats() -> dict:
    """Plan/decision counters + live cache sizes (see ``sparse.cache``)."""
    stats = cache_lib.cache_stats()
    stats["plan_entries"] = len(_plan_cache)
    return stats


def configure(cache_dir: Optional[str] = None):
    """Set the process-default persistent cache directory."""
    cache_lib.configure(cache_dir)


# ---------------------------------------------------------------------------
# MatmulPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class MatmulPlan:
    """Frozen verdict of ``sparse.plan``: route + one-time artifacts +
    a decision-free execute closure.

    Call ``plan(payload, x)`` with the per-call payload:

    * static kind  -- the ``[nnz, b, b]`` values (pattern is baked in)
    * dynamic kind -- the ``DynamicOperand`` (pattern is runtime data)
    * dense kind   -- the dense weight array

    ``apply(operand, x)`` extracts the payload from a full operand.
    """

    spec: OpSpec
    route: str
    source: str                      # analytic | measured | forced
    est_seconds: Dict[str, float]
    from_disk: bool
    ctx: PlanContext
    key: str                         # persistent-cache key string
    artifacts: Dict[str, Any]
    _execute: Optional[Callable] = None

    @property
    def executable(self) -> bool:
        return self._execute is not None

    def __call__(self, payload, x) -> jax.Array:
        if self._execute is None:
            raise ValueError(
                f"plan for {self.spec} was built from an OpSpec without a "
                f"concrete pattern; build it from the operand to execute "
                f"(spec-only static plans are explain/report-only)")
        s = self.spec
        # the contraction dim is baked into every route's metadata; a
        # mismatch must fail here, not deep inside a kernel.  (n may
        # differ from the planned n -- routes tile n at trace time.)
        if s.op == "spmm":
            if x.ndim != 2 or x.shape[0] != s.k:
                raise ValueError(f"plan expects x of shape [k={s.k}, n]; "
                                 f"got {x.shape}")
        elif s.op == "matmul":
            if x.shape[-1] != s.k or tuple(payload.shape) != (s.k, s.m):
                raise ValueError(
                    f"plan expects w [k={s.k}, n={s.m}] and x [..., "
                    f"{s.k}]; got w {payload.shape}, x {x.shape}")
        elif s.op == "batched_matmul":
            if payload.shape[-1] != s.k or x.shape[-2] != s.k:
                raise ValueError(
                    f"plan expects [..., C, D={s.k}] @ [..., D={s.k}, F]; "
                    f"got {payload.shape} @ {x.shape}")
        return self._execute(payload, x)

    def apply(self, operand: Operand, x) -> jax.Array:
        return self(payload_of(operand), x)

    def vjp(self, payload, x):
        """``(y, vjp_fn)`` through the planned route (XLA routes only --
        the Pallas kernels are forward-only)."""
        return jax.vjp(lambda v, xx: self(v, xx), payload, x)

    def explain(self) -> dict:
        """Full decision report (dispatch-report compatible + the plan's
        one-time artifacts)."""
        s = self.spec
        return {
            "problem": {"kind": s.kind, "m": s.m, "k": s.k, "n": s.n,
                        "block_size": s.block_size,
                        "density": round(s.density, 5),
                        "density_bucket":
                            dispatch._density_bucket(s.density),
                        "dtype": s.dtype},
            "mode": s.mode,
            "op": s.op,
            "pallas_admissible": dispatch._pallas_ok(self.ctx.dispatch_ctx()),
            "candidates": {r: self.est_seconds[r] for r in
                           sorted(self.est_seconds,
                                  key=self.est_seconds.get)},
            "chosen": self.route,
            "source": self.source,
            "cached": self.from_disk,
            "from_disk": self.from_disk,
            "cache_key": self.key,
            "plan": dict(self.artifacts, executable=self.executable),
        }


def format_plan(plan: MatmulPlan) -> str:
    """Human-readable plan report (quickstart / perf_cell / debugging)."""
    rep = plan.explain()
    lines = [dispatch.format_explain(rep)]
    art = rep["plan"]
    extra = []
    if "packing_tiles" in art:
        extra.append(f"packing: {art['packing_tiles']} MXU tiles, "
                     f"occupancy {art['packing_occupancy']:.3f}")
    if "bucket_blocks" in art:
        extra.append(f"buckets: {art['bucket_blocks']} blocks/bucket over "
                     f"q=({art['q_m']},{art['q_k']},{art['q_n']})")
    if "tp_q" in art:
        extra.append(f"tp: q={art['tp_q']} nnz-balanced k-shards over "
                     f"'{art['tp_axis']}'")
    if "grouped_tile" in art:
        t = art["grouped_tile"]
        cap = art.get("grouped_tiles_cap")   # exact only for static kind
        extra.append(f"grouped: {t}x{t} tile slots"
                     + (f" (cap {cap})" if cap is not None else ""))
    if extra:
        lines.append("   plan: " + "; ".join(extra))
    lines.append(f"   ({'disk-cached' if plan.from_disk else 'planned'} "
                 f"{'executable' if plan.executable else 'report-only'})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Decision (memory -> disk -> dispatch cost model / measurement)
# ---------------------------------------------------------------------------

def _fingerprint(spec: OpSpec, ctx: PlanContext) -> tuple:
    dctx = ctx.dispatch_ctx()
    base = dispatch._cache_key(spec.kind, spec.m, spec.k, spec.n,
                               spec.block_size, spec.density, spec.dtype,
                               dctx)
    q = ctx.resolved_tp_q()
    tp = ("tp", q, ctx.tp_axis) if q else ()
    return ("plan", spec.op, spec.mode) + base + tp


def _tp_estimate(spec: OpSpec, q: int) -> float:
    """Paper Fig 1a at mesh scale: nnz-balanced local SpMM (1/q of the
    static work) + the single output reduction over the TP axis."""
    t_local = dispatch._estimate("static_xla", spec.m, spec.k, spec.n,
                                 spec.block_size, spec.density,
                                 spec.dtype) / max(1, q)
    bytes_el = max(1, jnp.dtype(spec.dtype).itemsize)
    t_reduce = (spec.m * spec.n * bytes_el) * max(0, q - 1) / max(1, q) \
        / planner_lib.ICI_BW
    return t_local + t_reduce


def _decide(spec: OpSpec, ctx: PlanContext, operand: Optional[Operand],
            x) -> Tuple[str, Dict[str, float], str, bool]:
    """-> (route, est_seconds, source, from_disk)."""
    dctx = ctx.dispatch_ctx()
    key = cache_lib.key_string(_fingerprint(spec, ctx))
    use_disk = ctx.cache and ctx.persistence_on()
    if use_disk:
        rec = cache_lib.load_decision(ctx.resolved_cache_dir(), key)
        if rec is not None and rec.get("route") in PLAN_ROUTES:
            return (rec["route"], dict(rec.get("est_seconds", {})),
                    rec.get("source", "analytic"), True)

    cache_lib.bump("decisions")
    q = ctx.resolved_tp_q()
    forced_tp = spec.mode == "static_tp"
    if forced_tp:
        if spec.kind != "static":
            raise ValueError(f"mode 'static_tp' cannot execute a "
                             f"{spec.kind} operand")
        if not q:
            raise ValueError("mode 'static_tp' needs ctx.mesh (with "
                             "ctx.tp_axis) or an explicit ctx.tp_q")
        route = "static_tp"
        est = {"static_tp": _tp_estimate(spec, q)}
        source = "forced"
    elif operand is not None:
        dkey = dispatch._cache_key(spec.kind, spec.m, spec.k, spec.n,
                                   spec.block_size, spec.density,
                                   spec.dtype, dctx)
        already = dkey in dispatch._decision_cache
        dec = dispatch.decide(operand, spec.n, ctx=dctx, x=x)
        if dec.source == "measured" and not already:
            cache_lib.bump("measurements")
        route, est, source = dec.route, dict(dec.est_seconds), dec.source
    else:
        # OpSpec-only: analytic pricing straight off the cost model
        cands = dispatch._candidates(spec.kind, dctx)
        est = {r: dispatch._estimate(r, spec.m, spec.k, spec.n,
                                     spec.block_size, spec.density,
                                     spec.dtype) for r in cands}
        route = min(est, key=est.get)
        source = "forced" if len(cands) == 1 else "analytic"

    # mesh-aware TP candidate (auto mode, static pattern, mesh present)
    if (not forced_tp and spec.kind == "static" and spec.mode == "auto"
            and ctx.mesh is not None and q and q > 1
            and source != "measured"):
        est["static_tp"] = _tp_estimate(spec, q)
        if est["static_tp"] < est[route]:
            route = "static_tp"

    if use_disk:
        cache_lib.store_decision(
            ctx.resolved_cache_dir(), key,
            {"route": route, "source": source,
             "est_seconds": {r: float(s) for r, s in est.items()}})
    return route, est, source, False


# ---------------------------------------------------------------------------
# Execute-closure builders (one per (kind, route) arm; each closure is
# decision-free -- all metadata is a host constant baked at plan time)
# ---------------------------------------------------------------------------

def _promote_matmul(w, x, *, pallas: bool, interpret: bool):
    rt = jnp.result_type(w.dtype, x.dtype)
    if pallas:
        from repro.kernels.dense_mm import ops as dmm_ops
        return dmm_ops.dense_mm(w.astype(rt), x.astype(rt),
                                interpret=interpret)
    return jnp.matmul(w.astype(rt), x.astype(rt))


def _static_executor(spec: OpSpec, route: str, ctx: PlanContext,
                     operand: BlockSparseMatrix):
    m, k, b = spec.m, spec.k, spec.block_size
    mb, kb = m // b, k // b
    rows = np.asarray(operand.row_idx, np.int32)
    cols = np.asarray(operand.col_idx, np.int32)
    interpret = ctx.interpret
    art: Dict[str, Any] = {"nnz_blocks": len(rows)}

    if route == "static_xla":
        fn = _ssp.make_spmm(rows, cols, (mb, kb), b)
        return (lambda v, x: fn(jnp.asarray(v), x)), art

    if route == "static_pallas":
        from repro.kernels.bsmm import ops as bsmm_ops
        tm, tk, _ = bsmm_ops._pick_tiles(m, k, spec.n, b)
        meta = partitioner.plan_packing(rows, cols, (m, k), b, tm, tk)
        art.update(packing_tiles=meta.num_tiles,
                   packing_occupancy=meta.occupancy)
        # tn is picked at trace time from the actual x (calling the plan
        # with a different n than planned must not mis-tile the kernel)
        return (lambda v, x: bsmm_ops.bsmm_from_plan(
            meta, v, x, interpret=interpret)), art

    if route in ("dense_xla", "dense_pallas"):
        rows_j, cols_j = jnp.asarray(rows), jnp.asarray(cols)
        pallas = route == "dense_pallas"

        def run(v, x):
            v = jnp.asarray(v)
            w = jnp.zeros((mb, kb, b, b), v.dtype).at[rows_j, cols_j].add(v)
            w = w.transpose(0, 2, 1, 3).reshape(m, k)
            return _promote_matmul(w, x, pallas=pallas, interpret=interpret)
        return run, art

    if route in ("dynamic_xla", "dynamic_pallas", "dynamic_grouped"):
        rows_d = jnp.asarray(rows, jnp.int32)
        cols_d = jnp.asarray(cols, jnp.int32)
        nnz = jnp.asarray(len(rows), jnp.int32)
        if route == "dynamic_xla":
            return (lambda v, x: _dspmm(jnp.asarray(v), rows_d, cols_d, x,
                                        mb, b)), art

        def as_dyn(v):
            return DynamicOperand(jnp.asarray(v), rows_d, cols_d, nnz,
                                  (m, k), b)
        if route == "dynamic_grouped":
            from repro.kernels.gmm import ops as gmm_ops
            t = gmm_ops.grouped_tile_size(m, k, b)
            # static pattern -> the exact tile count is known at plan time
            meta = partitioner.plan_packing(rows, cols, (m, k), b, t, t)
            cap = meta.num_tiles
            art.update(grouped_tile=t, grouped_tiles_cap=cap)
            return (lambda v, x: gmm_ops.grouped_spmm(
                as_dyn(v), x, tile=t, tiles_cap=cap,
                interpret=interpret)), art
        from repro.kernels.dsmm import ops as dsmm_ops
        return (lambda v, x: dsmm_ops.dsmm(as_dyn(v), x,
                                           interpret=interpret)), art

    if route == "static_tp":
        q = ctx.resolved_tp_q()
        shard_meta = partitioner.plan_k_shards(operand, q)
        bal = partitioner.balance_report(shard_meta.real_counts)
        art.update(tp_q=q, tp_axis=ctx.tp_axis,
                   tp_imbalance=bal["imbalance"], tp_slots=shard_meta.slots)
        axis = ctx.tp_axis
        return (lambda v, x: tp_lib.tp_spmm_gspmd(
            partitioner.apply_k_shards(shard_meta, v), x, axis=axis)), art

    raise ValueError(f"unknown static route {route!r}")


def _dynamic_executor(spec: OpSpec, route: str, ctx: PlanContext):
    m, k, b = spec.m, spec.k, spec.block_size
    mb = m // b
    interpret = ctx.interpret
    dplan = planner_lib.plan_dynamic(m, k, spec.n, d_max=spec.density,
                                     block_size=b, units=ctx.units)
    art: Dict[str, Any] = dict(bucket_blocks=dplan.bucket_blocks,
                               nnz_max_blocks=dplan.nnz_max_blocks,
                               q_m=dplan.q_m, q_k=dplan.q_k, q_n=dplan.q_n)

    if route == "dynamic_xla":
        return (lambda op, x: _dspmm(op.values, op.row_idx, op.col_idx,
                                     x, mb, b)), art
    if route == "dynamic_pallas":
        from repro.kernels.dsmm import ops as dsmm_ops
        return (lambda op, x: dsmm_ops.dsmm(op, x,
                                            interpret=interpret)), art
    if route == "dynamic_grouped":
        from repro.kernels.gmm import ops as gmm_ops
        t = gmm_ops.grouped_tile_size(m, k, b)
        # runtime pattern: keep the safe worst-case tile capacity (no
        # silent overflow drops); the paper-style planned bucket stays
        # in the artifacts for reporting
        art.update(grouped_tile=t)
        return (lambda op, x: gmm_ops.grouped_spmm(
            op, x, tile=t, interpret=interpret)), art
    if route in ("dense_xla", "dense_pallas"):
        pallas = route == "dense_pallas"
        return (lambda op, x: _promote_matmul(op.to_dense(), x,
                                              pallas=pallas,
                                              interpret=interpret)), art
    raise ValueError(f"unknown dynamic route {route!r}")


def _dense_executor(spec: OpSpec, route: str, ctx: PlanContext):
    interpret = ctx.interpret
    art: Dict[str, Any] = {}
    if spec.op == "matmul":
        pallas = route == "dense_pallas"
        # activation-major: x2 @ w (operand order swapped vs spmm form)
        return (lambda w, x2: _promote_matmul(x2, w, pallas=pallas,
                                              interpret=interpret)), art
    if spec.op == "batched_matmul":
        pallas = route == "dense_pallas"

        def run(a, bb):
            rt = jnp.result_type(a.dtype, bb.dtype)
            if pallas:
                from repro.kernels.dense_mm import ops as dmm_ops
                f = lambda x_, y_: dmm_ops.dense_mm(x_, y_,
                                                    interpret=interpret)
                for _ in range(a.ndim - 2):
                    f = jax.vmap(f)
                return f(a.astype(rt), bb.astype(rt))
            return jnp.matmul(a.astype(rt), bb.astype(rt))
        return run, art
    pallas = route == "dense_pallas"
    return (lambda w, x: _promote_matmul(jnp.asarray(w), x, pallas=pallas,
                                         interpret=interpret)), art


def _build_executor(spec: OpSpec, route: str, ctx: PlanContext,
                    operand: Optional[Operand]):
    if spec.kind == "static":
        if operand is None or not isinstance(operand, BlockSparseMatrix):
            return None, {}          # spec-only static plan: report-only
        return _static_executor(spec, route, ctx, operand)
    if spec.kind == "dynamic":
        return _dynamic_executor(spec, route, ctx)
    return _dense_executor(spec, route, ctx)


# ---------------------------------------------------------------------------
# plan() + conveniences
# ---------------------------------------------------------------------------

_ctx_state = threading.local()


@contextlib.contextmanager
def use_ctx(ctx: PlanContext):
    """Install ``ctx`` as the ambient planning context (trace-scoped):
    every ``plan``/``matmul``/... call without an explicit ``ctx`` picks
    it up.  The serving engine wraps its traced programs with this so
    per-engine policy (persistent cache dir, Pallas admissibility) never
    leaks into process-global state."""
    prev = getattr(_ctx_state, "ctx", None)
    _ctx_state.ctx = ctx
    try:
        yield ctx
    finally:
        _ctx_state.ctx = prev


def _resolve_ctx(ctx) -> PlanContext:
    if ctx is None:
        ambient = getattr(_ctx_state, "ctx", None)
        if ambient is not None:
            return ambient
        return PlanContext.from_dispatch(dispatch.current_ctx())
    if isinstance(ctx, dispatch.DispatchContext):
        return PlanContext.from_dispatch(ctx)
    return ctx


def plan(operand_or_spec, n: Optional[int] = None, *, x=None,
         ctx: Optional[PlanContext] = None) -> MatmulPlan:
    """Phase 1 of the two-phase API: run all one-time work for
    ``operand @ [k, n]`` and return a frozen ``MatmulPlan``.

    ``operand_or_spec`` is a full operand (dense array /
    ``BlockSparseMatrix`` / ``DynamicOperand``) -- or an ``OpSpec`` for
    spec-only planning (dense/dynamic plans stay executable; static
    plans without the concrete pattern are report-only).  ``x`` is used
    only for measured autotune (``ctx.measure=True``, concrete inputs).
    """
    ctx = _resolve_ctx(ctx)
    if isinstance(operand_or_spec, OpSpec):
        spec, operand = operand_or_spec, None
        if ctx.mode != spec.mode:
            ctx = dataclasses.replace(ctx, mode=spec.mode)
    else:
        operand = operand_or_spec
        if n is None:
            raise ValueError("plan(operand, n): n is required when "
                             "planning from a concrete operand")
        spec = OpSpec.from_operand(operand, n, mode=ctx.mode)

    pkey = pattern_key(operand) if operand is not None else None
    fp = _fingerprint(spec, ctx)
    # the persistence policy is part of the plan-cache identity: a plan
    # built without persistence must not shadow a later persistent
    # request (which still needs to write/read the disk cache)
    persist_key = (ctx.resolved_cache_dir() if ctx.persistence_on()
                   else None)
    mem_key = (fp, pkey, persist_key)
    if ctx.cache:
        hit = _plan_cache.get(mem_key)
        if hit is not None:
            cache_lib.bump("plan_hits")
            return hit

    route, est, source, from_disk = _decide(spec, ctx, operand, x)
    execute, artifacts = _build_executor(spec, route, ctx, operand)
    p = MatmulPlan(spec=spec, route=route, source=source,
                   est_seconds=est, from_disk=from_disk, ctx=ctx,
                   key=cache_lib.key_string(fp), artifacts=artifacts,
                   _execute=execute)
    cache_lib.bump("plans_built")
    if ctx.cache:
        with _plan_lock:
            p = _plan_cache.setdefault(mem_key, p)
    return p


def explain(operand_or_spec, n: Optional[int] = None, *,
            ctx: Optional[PlanContext] = None) -> dict:
    """Plan and report in one step (non-executing)."""
    return plan(operand_or_spec, n, ctx=ctx).explain()


def spmm(operand: Operand, x, *, ctx: Optional[PlanContext] = None):
    """One-shot ``Y = W @ X`` (plan + execute; the plan is cached, so
    repeated calls are dict hits -- prefer holding the plan in hot
    loops)."""
    ctx = _resolve_ctx(ctx)
    _, _, k, _, _ = dispatch._normalize(operand)
    if x.ndim != 2:
        raise ValueError(f"x must be [k, n], got shape {x.shape}")
    if x.shape[0] != k:
        raise ValueError(f"X rows {x.shape[0]} != operand k {k}")
    p = plan(operand, int(x.shape[1]), x=x, ctx=ctx)
    return p.apply(operand, x)


def spmm_nt(operand: Operand, x, *, ctx: Optional[PlanContext] = None):
    """Activation-major form ``x: [..., k] -> [..., m]`` (y = x @ W^T)."""
    _, m, k, _, _ = dispatch._normalize(operand)
    lead = x.shape[:-1]
    y = spmm(operand, x.reshape(-1, k).T, ctx=ctx)
    return y.T.reshape(*lead, m)


def matmul(x, w, *, ctx: Optional[PlanContext] = None):
    """Dense-layer form ``y = x @ w`` (``x: [..., k]``, ``w: [k, n]``) --
    what ``models.layers.dense`` and the serving engine execute with."""
    ctx = _resolve_ctx(ctx)
    if isinstance(w, (BlockSparseMatrix, DynamicOperand)):
        raise ValueError("matmul() takes a dense rhs; use spmm_nt for "
                         "sparse operands")
    lead = x.shape[:-1]
    k, n_out = w.shape
    x2 = x.reshape(-1, k)
    spec = OpSpec(kind="dense", m=n_out, k=k, n=int(x2.shape[0]),
                  dtype=jnp.dtype(w.dtype).name, op="matmul",
                  mode=ctx.mode if ctx.mode in dispatch.MODES else "auto")
    y = plan(spec, ctx=ctx)(w, x2)
    return y.reshape(*lead, n_out)


def batched_matmul(a, b, *, ctx: Optional[PlanContext] = None):
    """Batched dense ``[..., C, D] @ [..., D, F]`` (MoE expert GEMMs):
    one plan for the per-slice problem, vmapped over the batch axes."""
    ctx = _resolve_ctx(ctx)
    cdim, ddim = a.shape[-2], a.shape[-1]
    fdim = b.shape[-1]
    spec = OpSpec(kind="dense", m=cdim, k=ddim, n=int(fdim),
                  dtype=jnp.dtype(a.dtype).name, op="batched_matmul",
                  mode=ctx.mode if ctx.mode in dispatch.MODES else "auto")
    return plan(spec, ctx=ctx)(a, b)
