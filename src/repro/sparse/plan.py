"""Two-phase plan/execute sparse matmul (the plan-first public API).

    from repro import sparse

    p = sparse.plan(operand, n)            # phase 1: ALL one-time work
    y = p(values, x)                       # phase 2: zero-decision call
    y = p.apply(operand, x)                # payload extracted for you
    print(sparse.format_plan(p))           # what will run, and why

Phase 1 mirrors PopSparse's ahead-of-time planning: operand
normalization, pattern analysis (``partitioner.plan_packing`` /
``plan_k_shards`` -- the one-time halves of the packing and TP
sharding), route selection through the dispatch cost model (optionally
wall-clock measured), dynamic bucket sizing (``planner.plan_dynamic``),
and mesh-aware TP routes from ``core/tp.py``.  The result is a frozen
``MatmulPlan`` whose execute closure contains no decisions: safe under
``jax.jit`` / ``grad`` / ``vmap`` on every route -- differentiable
plans carry a plan-level ``jax.custom_vjp`` whose backward runs two
planned sibling products (transposed-pattern SpMM for dL/dx, block
SDDMM for dL/dvalues), so even Pallas forwards train -- and a plain
direct call in the steady state.

Verdicts persist to a versioned on-disk cache (``sparse.cache``), so a
serving restart re-plans without re-measuring.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.dispatch as dispatch
import repro.core.partitioner as partitioner
import repro.core.planner as planner_lib
import repro.core.static_sparse as _ssp
import repro.core.tp as tp_lib
from repro.core.bsr import BlockSparseMatrix
from repro.core.dynamic_sparse import DynamicOperand, _dspmm
from repro.sparse import cache as cache_lib
from repro.sparse.spec import (CapacityStats, OpSpec, PlanContext,
                               PLAN_ROUTES, TP_ROUTES, pattern_key,
                               payload_of)

Operand = Union[jax.Array, np.ndarray, BlockSparseMatrix, DynamicOperand]

_plan_cache: Dict[tuple, "MatmulPlan"] = {}
_plan_lock = threading.Lock()

# per-problem running overflow telemetry (keyed by the plan's persistent
# key string, plus free-form names like "moe_dispatch"): outlives plan
# objects so escalation survives a plan-cache eviction and the serving
# engine can aggregate across its lifetime
_capacity_registry: Dict[str, CapacityStats] = {}
_capacity_lock = threading.Lock()

# process-wide evolution telemetry (MatmulPlan.evolve): how many
# topology updates ran, how many tripped the drift guardrail, and how
# many re-raced the routes -- surfaced in plan_report()["totals"]
_evolution_totals: Dict[str, int] = {"evolves": 0, "reraces": 0,
                                     "drift_trips": 0}

# serving-engine plan pools: ctx.pool label -> mem_keys of every plan
# built under that label.  Pool membership is runtime-only bookkeeping
# (the label joins neither the disk fingerprint nor the mem key -- see
# spec.PlanContext), so the engine can enumerate "my plans" without
# owning plan identity.  Guarded by _plan_lock.
_pool_registry: Dict[str, list] = {}

# background re-planner verdict overlay: persistent key string -> the
# upgraded (measured) decision record.  _decide consults this BEFORE
# the disk cache, so re-planned verdicts win even when persistence is
# off for the process; remeasure_plan also writes the record to disk
# when persistence is on, mirroring the escalation guardrail.
_replanned: Dict[str, dict] = {}


def reset(*, counters: bool = True):
    """Forget every in-memory plan, decision, capacity stat, and
    (optionally) counter.  Disk cache files survive -- this simulates a
    fresh process."""
    with _plan_lock:
        _plan_cache.clear()
        _shard_meta_cache.clear()
        _transpose_cache.clear()
        _sddmm_meta_cache.clear()
        _pool_registry.clear()
        _replanned.clear()
        for k in _evolution_totals:
            _evolution_totals[k] = 0
    with _capacity_lock:
        _capacity_registry.clear()
    cache_lib.reset(counters=counters)
    dispatch.clear_cache()


def reset_telemetry():
    """Zero the process-wide telemetry aggregates -- the running
    ``capacity_report()`` counters and the ``plan_report()`` evolution
    totals -- WITHOUT forgetting plans or verdicts (``reset()`` does
    that).  Stats objects referenced by live cached plans are zeroed in
    place (plans keep recording into them); orphaned registry entries
    are dropped.  The test suite runs this between tests so telemetry
    assertions never depend on execution order."""
    with _plan_lock:
        live = {id(p.capacity_stats) for p in _plan_cache.values()
                if p.capacity_stats is not None}
        for k in _evolution_totals:
            _evolution_totals[k] = 0
    with _capacity_lock:
        for key in list(_capacity_registry):
            stats = _capacity_registry[key]
            if id(stats) not in live:
                del _capacity_registry[key]
                continue
            with stats._lock:
                stats.calls = 0
                stats.overflow_calls = 0
                stats.tiles_dropped_total = 0
                stats.blocks_dropped_total = 0
                stats.dropped_frac_sum = 0.0
                stats.max_dropped_frac = 0.0
                stats.last_tiles_total = 0
                stats.last_tiles_dropped = 0


def _capacity_stats_for(key: str, **kw) -> CapacityStats:
    with _capacity_lock:
        stats = _capacity_registry.get(key)
        if stats is None:
            stats = _capacity_registry[key] = CapacityStats(key, **kw)
        return stats


def capacity_report() -> dict:
    """Aggregated overflow telemetry across every planned-capacity
    problem this process has executed (plus free-form streams such as
    MoE routing drops).  The serving engine folds this into
    ``plan_report()``."""
    with _capacity_lock:
        per_key = {k: s.report() for k, s in _capacity_registry.items()}
    return {
        "per_plan": per_key,
        "totals": {
            "calls": sum(r["calls"] for r in per_key.values()),
            "overflow_calls": sum(r["overflow_calls"]
                                  for r in per_key.values()),
            "tiles_dropped_total": sum(r["tiles_dropped_total"]
                                       for r in per_key.values()),
            "escalated_plans": sum(1 for r in per_key.values()
                                   if r["escalated"]),
        },
    }


def record_dropped(name: str, dropped_frac) -> None:
    """Best-effort drop telemetry for non-plan capacity buckets (e.g.
    MoE routing ``dropped_frac``): folds one step's dropped fraction
    into the named ``CapacityStats`` stream.  No-op under tracing --
    eager callers (tests, eval loops) get exact accounting, compiled
    training steps pay nothing."""
    if isinstance(dropped_frac, jax.core.Tracer):
        return
    frac = float(np.asarray(dropped_frac).max())
    stats = _capacity_stats_for(name)
    # fraction-only stream: no tiles/blocks -- overflow_calls still
    # counts via frac > 0, and tile-drop totals stay uninflated
    stats.record(0, 0, 0, frac)


def cache_stats() -> dict:
    """Plan/decision counters + live cache sizes (see ``sparse.cache``)."""
    stats = cache_lib.cache_stats()
    stats["plan_entries"] = len(_plan_cache)
    return stats


def tp_report() -> dict:
    """Every tensor-parallel decision this process has planned: per plan
    the raced candidates, the verdict's source (measured / analytic /
    disk), and the measured crossover (best-unsharded / best-TP time --
    > 1 means the problem is past the TP crossover).  The serving
    engine folds this into ``plan_report()``."""
    with _plan_lock:
        plans = list(_plan_cache.values())
    per = {}
    for p in plans:
        tp = p.artifacts.get("tp")
        if tp:
            per[p.key] = dict(tp, route=p.route, from_disk=p.from_disk)
    return {
        "per_plan": per,
        "totals": {
            "tp_planned": len(per),
            "tp_chosen": sum(1 for r in per.values() if r["chosen"]),
            "measured": sum(1 for r in per.values()
                            if r["source"] == "measured"),
        },
    }


def plan_report() -> dict:
    """Every plan this process holds, with its forward route AND its
    backward (grad) route choices -- the one-stop training view of the
    plan-first lifecycle.  ``grad.mode`` per plan is "planned" (the
    plan-level custom_vjp runs the raced sibling products), "native"
    (autodiff of the XLA formulation), or "unavailable" (forward-only
    Pallas plan; differentiating raises)."""
    with _plan_lock:
        plans = list(_plan_cache.values())
        evo_totals = dict(_evolution_totals)
    per = {}
    for p in plans:
        grad = p.artifacts.get("grad")
        ev = p.artifacts.get("evolution")
        # an evolve chain shares one pattern-free disk key; suffix the
        # generation so live generations do not shadow each other here
        rkey = p.key if not ev else f"{p.key}#gen{ev['generation']}"
        per[rkey] = {
            "route": p.route, "source": p.source,
            "from_disk": p.from_disk, "op": p.spec.op,
            "kind": p.spec.kind, "grad": grad,
            "evolution": p.artifacts.get("evolution"),
        }
    planned = [r for r in per.values()
               if (r["grad"] or {}).get("mode") == "planned"]
    evolved = [r for r in per.values() if r["evolution"]]
    return {
        "per_plan": per,
        "totals": {
            "plans": len(per),
            "grad_planned": len(planned),
            "grad_measured": sum(
                1 for r in planned
                if "dx" in r["grad"]
                and r["grad"]["dx"].get("source") == "measured"),
            "grad_from_disk": sum(1 for r in planned
                                  if r["grad"].get("from_disk")),
            "evolution": dict(evo_totals,
                              evolved_plans=len(evolved),
                              max_generation=max(
                                  (r["evolution"]["generation"]
                                   for r in evolved), default=0)),
        },
    }


def roofline_report() -> dict:
    """Roofline efficiency of every plan this process holds: the chosen
    route's achieved-vs-bound fraction plus the union of routes flagged
    for leaving >2x headroom (``kernel_work``) -- the serving engine
    folds this into ``plan_report()``."""
    with _plan_lock:
        plans = list(_plan_cache.values())
    per = {}
    flagged = set()
    for p in plans:
        r = p.roofline()
        per[p.key] = {"route": p.route, "chosen": r["chosen"],
                      "kernel_work": r["kernel_work"]}
        flagged.update(r["kernel_work"])
    chosen_eff = [r["chosen"]["efficiency"] for r in per.values()
                  if r["chosen"]]
    return {
        "per_plan": per,
        "totals": {
            "plans": len(per),
            "chosen_flagged": sum(1 for r in per.values()
                                  if r["chosen"] and r["chosen"]["flagged"]),
            "min_chosen_efficiency": (round(min(chosen_eff), 4)
                                      if chosen_eff else None),
            "kernel_work_routes": sorted(flagged),
        },
    }


def pool_plans(pool: str) -> list:
    """Every live plan built under ``ctx.pool == pool``, in build order.
    Plans evicted from the in-memory cache (capacity escalation, a
    re-planner upgrade) drop out until the holder rebuilds them."""
    with _plan_lock:
        keys = list(_pool_registry.get(pool, ()))
        plans = [_plan_cache.get(k) for k in keys]
    return [p for p in plans if p is not None]


def _remeasurable(p: "MatmulPlan") -> bool:
    """Can the background re-planner wall-clock this plan?  Analytic
    forward verdicts only; TP plans are excluded (their race needs the
    real mesh installed -- the foreground ``measure=True`` path owns
    that); spec-only static plans have no pattern to synthesize."""
    if p.source != "analytic" or p.key in _replanned:
        return False
    if p.ctx.resolved_tp_q():
        return False
    if p.spec.kind == "static" and p.pattern is None:
        return False
    return True


def analytic_plans(pool: Optional[str] = None) -> list:
    """The re-planner's worklist: live plans whose forward verdict is
    still analytic (cost-model priced, never wall-clocked) and that
    ``remeasure_plan`` can upgrade.  ``pool`` restricts to one serving
    engine's plans; None scans the whole process."""
    if pool is not None:
        plans = pool_plans(pool)
    else:
        with _plan_lock:
            plans = list(_plan_cache.values())
    return [p for p in plans if _remeasurable(p)]


def _synth_inputs(spec: OpSpec, pattern, seed: int):
    """Concrete ``(operand, x)`` realizing the plan's spec, for the
    background measurement race.  Route timing depends on shapes,
    density, and pattern layout -- not values -- so synthesized normal
    values measure what the foreground race would have."""
    kv, kp = jax.random.split(jax.random.PRNGKey(seed))
    dt = jnp.dtype(spec.dtype)
    if not jnp.issubdtype(dt, jnp.floating):
        dt = jnp.dtype("float32")
    x = jax.random.normal(kv, (spec.k, spec.n), dt)
    b = spec.block_size
    if spec.kind == "dense":
        return jax.random.normal(kp, (spec.m, spec.k), dt), x
    if spec.kind == "static":
        rows, cols = pattern
        mask = np.zeros((spec.m // b, spec.k // b), bool)
        mask[np.asarray(rows), np.asarray(cols)] = True
        return BlockSparseMatrix.from_mask(mask, b, dtype=dt,
                                           init="normal", key=kp), x
    # dynamic: capacity-shaped operand at the spec's d_max density
    from repro.core import masks
    mask = masks.random_block_mask(spec.m, spec.k, b, spec.density,
                                   seed=seed)
    rows, cols = np.nonzero(mask)
    cap = max(1, len(rows))
    operand = DynamicOperand(
        values=jax.random.normal(kp, (cap, b, b), dt),
        row_idx=jnp.asarray(rows.astype(np.int32)),
        col_idx=jnp.asarray(cols.astype(np.int32)),
        nnz=jnp.asarray(len(rows), jnp.int32),
        shape=(spec.m, spec.k), block_size=b)
    return operand, x


def remeasure_plan(p: "MatmulPlan", *, reps: int = 3,
                   seed: int = 0) -> Optional[dict]:
    """Upgrade one plan's analytic forward verdict to a measured one --
    the serving engine's background re-planner body.  Wall-clocks every
    runnable candidate on synthesized inputs of the plan's spec (the
    same harness as the foreground ``measure=True`` race), installs the
    winning verdict in the ``_replanned`` overlay + the disk cache (when
    persistence is on), and evicts the stale plan from the in-memory
    cache so the holder's next ``plan()`` call adopts the measured
    route.  Already-compiled closures keep running the analytic route --
    upgrades apply to new traces, exactly like capacity escalation.

    Returns ``{key, route_before, route_after, measured, upgraded}`` or
    None when the plan is not remeasurable (already measured / TP /
    spec-only static)."""
    if not _remeasurable(p):
        return None
    spec, ctx = p.spec, p.ctx
    dctx = _selection_ctx(spec, ctx)
    operand, x = _synth_inputs(spec, p.pattern, seed)
    cands = dispatch._candidates(spec.kind, dctx)
    runnable = [r for r in cands if dispatch._executable(r, dctx)]
    if not runnable:
        return None
    measured = {r: dispatch._measure_route(r, operand, x, dctx,
                                           reps=reps)
                for r in runnable}
    cache_lib.bump("measurements")
    est = dict(p.est_seconds)
    est.update(measured)
    route = min(measured, key=measured.get)
    rec = {"route": route, "source": "measured",
           "est_seconds": {r: float(s) for r, s in est.items()}}
    cap = p.artifacts.get("capacity")
    if cap:
        rec["capacity"] = {k2: v for k2, v in cap.items()
                           if k2 != "escalated"}
    grad_art = p.artifacts.get("grad")
    if grad_art and grad_art.get("mode") == "planned" \
            and "dx" in grad_art and _grad_covered(spec, ctx):
        rec["grad"] = {side: dict(grad_art[side])
                       for side in ("dx", "dvalues")}
    with _plan_lock:
        _replanned[p.key] = rec
        for mk in [mk for mk, q in _plan_cache.items() if q is p]:
            _plan_cache.pop(mk, None)
    if ctx.cache and ctx.persistence_on():
        cache_lib.store_decision(ctx.resolved_cache_dir(), p.key, rec)
    return {"key": p.key, "route_before": p.route, "route_after": route,
            "measured": {r: float(s) for r, s in measured.items()},
            "upgraded": True}


def configure(cache_dir: Optional[str] = None):
    """Set the process-default persistent cache directory."""
    cache_lib.configure(cache_dir)


# ---------------------------------------------------------------------------
# MatmulPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class MatmulPlan:
    """Frozen verdict of ``sparse.plan``: route + one-time artifacts +
    a decision-free execute closure.

    Call ``plan(payload, x)`` with the per-call payload:

    * static kind  -- the ``[nnz, b, b]`` values (pattern is baked in)
    * dynamic kind -- the ``DynamicOperand`` (pattern is runtime data)
    * dense kind   -- the dense weight array

    ``apply(operand, x)`` extracts the payload from a full operand.
    """

    spec: OpSpec
    route: str
    source: str                      # analytic | measured | forced
    est_seconds: Dict[str, float]
    from_disk: bool
    ctx: PlanContext
    key: str                         # persistent-cache key string
    artifacts: Dict[str, Any]
    _execute: Optional[Callable] = None
    # running overflow telemetry for planned-capacity routes (mutable by
    # design; lives in the process-wide registry keyed by ``key`` so it
    # survives plan-cache eviction -- see ``capacity_report``)
    capacity_stats: Optional[CapacityStats] = None

    @property
    def executable(self) -> bool:
        return self._execute is not None

    def __call__(self, payload, x) -> jax.Array:
        if self._execute is None:
            raise ValueError(
                f"plan for {self.spec} was built from an OpSpec without a "
                f"concrete pattern; build it from the operand to execute "
                f"(spec-only static plans are explain/report-only)")
        s = self.spec
        # the contraction dim is baked into every route's metadata; a
        # mismatch must fail here, not deep inside a kernel.  (n may
        # differ from the planned n -- routes tile n at trace time.)
        if s.op == "spmm":
            if x.ndim != 2 or x.shape[0] != s.k:
                raise ValueError(f"plan expects x of shape [k={s.k}, n]; "
                                 f"got {x.shape}")
        elif s.op == "matmul":
            if x.shape[-1] != s.k or tuple(payload.shape) != (s.k, s.m):
                raise ValueError(
                    f"plan expects w [k={s.k}, n={s.m}] and x [..., "
                    f"{s.k}]; got w {payload.shape}, x {x.shape}")
        elif s.op == "batched_matmul":
            if payload.shape[-1] != s.k or x.shape[-2] != s.k:
                raise ValueError(
                    f"plan expects [..., C, D={s.k}] @ [..., D={s.k}, F]; "
                    f"got {payload.shape} @ {x.shape}")
        return self._execute(payload, x)

    def apply(self, operand: Operand, x) -> jax.Array:
        return self(payload_of(operand), x)

    def vjp(self, payload, x):
        """``(y, vjp_fn)`` through the planned route.  Plans built with
        ``ctx.differentiable`` (the default) carry a plan-level
        ``custom_vjp`` whose backward runs the planned sibling products
        (transposed-SpMM dL/dx + block-SDDMM dL/dvalues -- see
        ``explain()["grad"]``), so this works on every route, Pallas
        included.  Forward-only plans raise a ValueError naming the
        route and the ``mode=`` workaround when differentiated."""
        return jax.vjp(lambda v, xx: self(v, xx), payload, x)

    def explain(self) -> dict:
        """Full decision report (dispatch-report compatible + the plan's
        one-time artifacts)."""
        s = self.spec
        return {
            "problem": {"kind": s.kind, "m": s.m, "k": s.k, "n": s.n,
                        "block_size": s.block_size,
                        "density": round(s.density, 5),
                        "density_bucket":
                            dispatch._density_bucket(s.density),
                        "dtype": s.dtype},
            "mode": s.mode,
            "op": s.op,
            "pallas_admissible": dispatch._pallas_ok(
                _selection_ctx(s, self.ctx)),
            "candidates": {r: self.est_seconds[r] for r in
                           sorted(self.est_seconds,
                                  key=self.est_seconds.get)},
            "chosen": self.route,
            "source": self.source,
            "cached": self.from_disk,
            "from_disk": self.from_disk,
            "cache_key": self.key,
            "tp": self.artifacts.get("tp"),
            "grad": self.artifacts.get("grad"),
            "evolution": self.artifacts.get("evolution"),
            "roofline": self.roofline(),
            # underscore artifacts are host-side working state (pattern
            # arrays, carry maps), not report material
            "plan": dict({k2: v for k2, v in self.artifacts.items()
                          if not k2.startswith("_")},
                         executable=self.executable),
            "capacity": (dict(self.artifacts.get("capacity", {}),
                              stats=self.capacity_stats.report())
                         if self.capacity_stats is not None else
                         self.artifacts.get("capacity")),
        }

    def roofline(self, *, flag_headroom: float = 2.0) -> dict:
        """Per-route roofline efficiency over the raced forward
        candidates: how close each route's (estimated or measured) time
        sits to the hardware bound for the work it executes.

        ``routes[r]["flagged"]`` marks routes leaving more than
        ``flag_headroom``x on the table; ``kernel_work`` collects them
        -- the sparsity-roofline signal that a route is a kernel to
        optimize, not a shape to avoid.  TP routes are excluded (their
        estimates are per-mesh collective times, priced by
        ``explain()["tp"]`` instead)."""
        from repro.analysis import roofline as roofline_lib
        routes = {}
        for route, est in self.est_seconds.items():
            if route in TP_ROUTES:
                continue
            eff = roofline_lib.route_efficiency(
                est, self.spec.roofline_cost(route),
                flag_headroom=flag_headroom)
            routes[route] = {
                "achieved_us": round(eff["achieved_seconds"] * 1e6, 3),
                "bound_us": round(eff["bound_seconds"] * 1e6, 3),
                "dominant": eff["dominant"],
                "efficiency": round(eff["efficiency"], 4),
                "headroom": round(eff["headroom"], 2),
                "flagged": eff["flagged"],
            }
        return {
            "hw": roofline_lib.V5E.name,
            "flag_headroom": flag_headroom,
            "chosen": routes.get(self.route),
            "routes": routes,
            "kernel_work": sorted(r for r, e in routes.items()
                                  if e["flagged"]),
        }

    def capacity_report(self) -> Optional[dict]:
        """Planned capacity + running overflow stats for this plan
        (None for routes without a planned bucket)."""
        if self.capacity_stats is None:
            return None
        return dict(self.artifacts.get("capacity", {}),
                    stats=self.capacity_stats.report())

    @property
    def pattern(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """``(row_idx, col_idx)`` host block indices baked into an
        executable static plan (None otherwise).  The slot order is the
        values order the plan executes with."""
        return self.artifacts.get("_pattern")

    def evolve(self, new_pattern, *, rerace: Optional[bool] = None,
               x=None) -> "MatmulPlan":
        """Incremental plan mutation for dynamic sparse training
        (RigL-style topology updates on a *static* plan).

        Re-runs only the cheap host pattern phases -- tile packing
        (``plan_packing``), backward transpose (``plan_transpose``),
        TP k-sharding (``plan_k_shards``), grouped-capacity sizing --
        and keeps the existing route verdict, backward verdicts, and
        disk decision record: a no-drift evolve performs **zero** route
        decisions and **zero** measurements.  A full re-race runs only
        when the pattern's density/tile-occupancy profile drifts past
        ``ctx.evolve_drift`` relative to the profile the verdicts were
        raced on (or when ``rerace=True`` forces it; ``rerace=False``
        suppresses even the drift trip).  The evolution lineage
        (parent/root keys, generation, drift, re-race verdict) rides in
        ``explain()["evolution"]`` and the persisted decision record.

        ``new_pattern`` is a static ``BlockSparseMatrix`` (values
        ignored), a bool block mask over the ``[m/b, k/b]`` grid, or a
        ``(row_idx, col_idx)`` tuple.  ``x`` is used only when a
        re-race measures (``ctx.measure`` + concrete inputs).  Use
        ``carry_values(old_values)`` on the result to map the old
        values stack into the new pattern's slots.
        """
        s = self.spec
        if s.kind != "static" or s.op != "spmm":
            raise ValueError(
                f"evolve() mutates static spmm plans; this plan is "
                f"kind={s.kind!r} op={s.op!r} (dynamic-kind patterns "
                f"are runtime data -- change the operand, not the plan)")
        if self._execute is None or self.pattern is None:
            raise ValueError(
                "cannot evolve a spec-only (report-only) plan: the "
                "concrete pattern is required; build the plan from the "
                "operand")
        return _evolve_plan(self, _as_static_bsr(new_pattern, s),
                            rerace, x)

    def carry_values(self, old_values) -> jax.Array:
        """Map the parent pattern's ``[nnz_old, b, b]`` values into this
        evolved plan's slots: carried blocks keep their values, grown
        blocks start at zero (RigL semantics).  Jit-compatible."""
        ep = self.artifacts.get("_evolve")
        if ep is None:
            raise ValueError(
                "carry_values() needs an evolved plan (the result of "
                "plan.evolve(...)); this plan has no evolution parent")
        return partitioner.apply_evolution(ep, old_values)


def format_plan(plan: MatmulPlan) -> str:
    """Human-readable plan report (quickstart / perf_cell / debugging)."""
    rep = plan.explain()
    lines = [dispatch.format_explain(rep)]
    art = rep["plan"]
    extra = []
    if "packing_tiles" in art:
        extra.append(f"packing: {art['packing_tiles']} MXU tiles, "
                     f"occupancy {art['packing_occupancy']:.3f}")
    if "bucket_blocks" in art:
        extra.append(f"buckets: {art['bucket_blocks']} blocks/bucket over "
                     f"q=({art['q_m']},{art['q_k']},{art['q_n']})")
    if "tp_q" in art:
        extra.append(
            f"tp: {art.get('tp_route', 'static_tp')} q={art['tp_q']} "
            f"{'nnz-balanced' if art.get('tp_balanced', True) else 'even'}"
            f" k-shards over '{art['tp_axis']}'")
    tpd = art.get("tp")
    if tpd and tpd.get("tp_speedup_vs_unsharded") is not None:
        extra.append(
            f"tp race ({tpd['source']}): best {tpd['best_tp_route']} "
            f"{tpd['tp_speedup_vs_unsharded']}x vs "
            f"{tpd['best_unsharded_route']}"
            + (" [past crossover]" if tpd["tp_wins"] else ""))
    g = art.get("grad")
    if g:
        if g.get("mode") == "planned" and "dx" in g:
            extra.append(
                f"grad: dx={g['dx']['route']} "
                f"dvalues={g['dvalues']['route']} "
                f"({g['dx']['source']}"
                + (", disk-cached" if g.get("from_disk") else "") + ")")
        else:
            extra.append(f"grad: {g.get('mode')}")
    roof = rep.get("roofline")
    if roof and roof.get("chosen"):
        ch = roof["chosen"]
        line = (f"roofline: {ch['efficiency']:.0%} of "
                f"{ch['dominant']}-bound ({ch['headroom']:.1f}x headroom"
                + (", >2x -- kernel work" if ch["flagged"] else "") + ")")
        others = [r for r in roof["kernel_work"] if r != rep["chosen"]]
        if others:
            line += f"; also flagged: {', '.join(others)}"
        extra.append(line)
    ev = art.get("evolution")
    if ev:
        thr = ev.get("drift_threshold")
        extra.append(
            f"evolution: gen {ev['generation']} "
            f"(+{ev['grown']}/-{ev['dropped']} blocks, drift "
            f"{ev['drift']:.3f}/{'off' if thr is None else thr}"
            + (", re-raced" if ev.get("reraced")
               else ", verdicts reused") + ")")
    if "grouped_tile" in art:
        t = art["grouped_tile"]
        cap = art.get("grouped_tiles_cap")   # exact for static kind
        extra.append(f"grouped: {t}x{t} tile slots"
                     + (f" (cap {cap})" if cap is not None else ""))
    capsec = art.get("capacity")
    if capsec:
        extra.append(
            f"capacity: {capsec['policy']} cap {capsec['tiles_cap']} "
            f"(E[tiles] {capsec['expected_tiles']:.0f} x headroom "
            f"{capsec['headroom']:.2f}, worst {capsec['worst_tiles']}, "
            f"P[overflow] {capsec['overflow_p']:.3f})"
            + (" [clamped]" if capsec.get("clamped") else ""))
        if plan.capacity_stats is not None and plan.capacity_stats.calls:
            s = plan.capacity_stats
            extra.append(f"overflow: {s.overflow_calls}/{s.calls} calls, "
                         f"{s.tiles_dropped_total} tiles dropped"
                         + (" [escalated]" if s.escalated else ""))
    if extra:
        lines.append("   plan: " + "; ".join(extra))
    lines.append(f"   ({'disk-cached' if plan.from_disk else 'planned'} "
                 f"{'executable' if plan.executable else 'report-only'})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Decision (memory -> disk -> dispatch cost model / measurement)
# ---------------------------------------------------------------------------

def _grad_covered(spec: OpSpec, ctx: PlanContext) -> bool:
    """Does this plan get the plan-level planned backward (custom_vjp
    over sibling transposed-SpMM + SDDMM products)?  Static patterns
    with a concrete-operand ``spmm`` op and a differentiable caller."""
    return (ctx.differentiable and spec.op == "spmm"
            and spec.kind == "static")


def _selection_ctx(spec: OpSpec, ctx: PlanContext) -> dispatch.DispatchContext:
    """The dispatch view used for *route selection*.  Plans with a
    plan-level backward (static/dynamic spmm) register their own
    ``custom_vjp``, so the forward kernel never needs a VJP of its own:
    Pallas forwards are admissible even for differentiable plans (the
    paper's fast path no longer falls away under training).  The plan
    fingerprint still carries the caller's ``differentiable`` flag --
    only the candidate gate is relaxed."""
    dctx = ctx.dispatch_ctx()
    if (dctx.differentiable and spec.op == "spmm"
            and spec.kind in ("static", "dynamic")):
        return dataclasses.replace(dctx, differentiable=False)
    return dctx


def _fingerprint(spec: OpSpec, ctx: PlanContext, operand=None) -> tuple:
    dctx = ctx.dispatch_ctx()
    # skew rides in the base key (dispatch.pattern_balance, bucketed):
    # a skewed pattern's verdict -- the balanced route winning -- must
    # not answer for a uniform pattern of the same shape/density
    base = dispatch._cache_key(spec.kind, spec.m, spec.k, spec.n,
                               spec.block_size, spec.density, spec.dtype,
                               dctx,
                               skew=dispatch.pattern_balance(operand))
    q = ctx.resolved_tp_q()
    # a TP verdict is a property of the mesh it was raced on: axis names
    # + sizes are part of the key (a verdict measured on a 1x8 mesh must
    # not answer for 2x4, nor for a tp_q-only plan without a mesh)
    tp = (("tp", q, ctx.tp_axis, ctx.tp_balanced)
          + ctx.mesh_fingerprint()) if q else ()
    # capacity *sizing* is part of the plan identity for dynamic
    # problems: a plan built at headroom 1.25 must not answer for
    # headroom 2.0.  The runtime-only knobs (overflow_threshold,
    # telemetry) deliberately stay OUT of this fingerprint -- they do
    # not change the route or the bucket, and splitting the disk key on
    # them would re-measure on restart whenever an operator toggles
    # them; they key the in-memory plan cache instead (see plan()).
    cap = (("cap", ctx.resolved_headroom(), ctx.capacity_policy)
           if spec.kind == "dynamic" else ())
    # the backward verdicts ride in the same record, so the backward
    # policy knobs are part of the plan identity: a plan whose dL/dx was
    # forced onto dynamic_xla must not answer for a grad_mode="auto" one
    grad = (("grad", ctx.grad_mode, ctx.sddmm_mode)
            if _grad_covered(spec, ctx) else ())
    return ("plan", spec.op, spec.mode) + base + tp + cap + grad


def _mem_key(fp: tuple, pkey, ctx: PlanContext) -> tuple:
    """In-memory plan-cache identity: fingerprint + concrete pattern +
    persistence policy + the runtime-only knobs that change plan
    *behavior* without changing the route or the disk verdict
    (overflow guardrail, telemetry, evolution drift threshold)."""
    persist_key = (ctx.resolved_cache_dir() if ctx.persistence_on()
                   else None)
    return (fp, pkey, persist_key, ctx.overflow_threshold,
            ctx.telemetry, ctx.evolve_drift)


def _tp_estimate(spec: OpSpec, q: int,
                 route: str = "static_tp") -> float:
    """Analytic prior for the TP routes (paper Fig 1a at mesh scale):
    nnz-balanced local SpMM (1/q of the static work) + the single output
    reduction over the TP axis.  This is only the *seed* of the race --
    with a mesh and ``measure=True`` both lowerings are wall-clocked on
    the real devices and the measured verdict wins (see ``_decide``)."""
    t_local = dispatch._estimate("static_xla", spec.m, spec.k, spec.n,
                                 spec.block_size, spec.density,
                                 spec.dtype) / max(1, q)
    bytes_el = max(1, jnp.dtype(spec.dtype).itemsize)
    t_reduce = (spec.m * spec.n * bytes_el) * max(0, q - 1) / max(1, q) \
        / planner_lib.ICI_BW
    # the gspmd lowering leaves the collective schedule to the compiler;
    # the explicit shard_map path pins it down -- mirror the small
    # xla-vs-pallas prior of dispatch._estimate so ties break toward the
    # pinned schedule when both are admissible and nothing was measured
    penalty = 1.05 if route == "static_tp" else 1.0
    return (t_local + t_reduce) * penalty


def _tp_candidates(spec: OpSpec, ctx: PlanContext,
                   q: Optional[int]) -> Tuple[str, ...]:
    """Admissible TP routes for this plan.  gspmd executes anywhere
    (the psum lowers to a local sum without a mesh); shard_map needs a
    concrete mesh whose tp_axis size equals q."""
    if spec.kind != "static" or not q or q < 2:
        return ()
    routes = ["static_tp"]
    if ctx.shardmap_executable():
        routes.append("static_tp_shardmap")
    return tuple(routes)


# one TP race + executor build calls _tp_closure up to three times for
# the same pattern; the host-side shard planning (argsort + scatter over
# all nnz blocks) is memoized per (pattern, q, balanced) so it runs once
_shard_meta_cache: Dict[tuple, partitioner.KShardPlan] = {}


def _shard_meta_for(operand, q: int,
                    balanced: bool) -> partitioner.KShardPlan:
    pk = pattern_key(operand)
    if pk is None:                       # no stable pattern identity
        return partitioner.plan_k_shards(operand, q, balanced=balanced)
    key = (pk, operand.shape, operand.block_size, q, balanced)
    with _plan_lock:
        meta = _shard_meta_cache.get(key)
    if meta is None:
        meta = partitioner.plan_k_shards(operand, q, balanced=balanced)
        with _plan_lock:
            meta = _shard_meta_cache.setdefault(key, meta)
    return meta


def _tp_closure(route: str, spec: OpSpec, ctx: PlanContext,
                operand: "BlockSparseMatrix"):
    """(execute_closure, artifacts) for one TP route -- shared by the
    executor builder and the measured race, so autotune wall-clocks
    exactly what the plan will run."""
    q = ctx.resolved_tp_q()
    shard_meta = _shard_meta_for(operand, q, ctx.tp_balanced)
    bal = partitioner.balance_report(shard_meta.real_counts)
    art = dict(tp_q=q, tp_axis=ctx.tp_axis, tp_route=route,
               tp_balanced=ctx.tp_balanced,
               tp_imbalance=bal["imbalance"], tp_slots=shard_meta.slots)
    axis = ctx.tp_axis
    if route == "static_tp_shardmap":
        mesh = ctx.mesh
        return (lambda v, x: tp_lib.tp_spmm_shard_map(
            partitioner.apply_k_shards(shard_meta, v), x, mesh=mesh,
            axis=axis)), art
    return (lambda v, x: tp_lib.tp_spmm_gspmd(
        partitioner.apply_k_shards(shard_meta, v), x, axis=axis)), art


def _measure_tp_route(route: str, spec: OpSpec, ctx: PlanContext,
                      operand, x) -> float:
    """Wall-clock one TP lowering on the real (or host-platform)
    devices.  The gspmd trace gets the mesh installed as the activation
    mesh so its sharding constraints are live -- the measurement covers
    the collective, not just the local math."""
    from repro.sharding import rules
    fn, _ = _tp_closure(route, spec, ctx, operand)
    if ctx.mesh is not None and route == "static_tp":
        with rules.activation_mesh(ctx.mesh):
            return dispatch.measure_callable(
                fn, jnp.asarray(operand.values), x)
    return dispatch.measure_callable(fn, jnp.asarray(operand.values), x)


def _decide(spec: OpSpec, ctx: PlanContext, operand: Optional[Operand],
            x) -> Tuple[str, Dict[str, float], str, bool, Optional[dict],
                        Optional[str], Optional[dict]]:
    """-> (route, est_seconds, source, from_disk, disk_capacity,
    tp_source, disk_grad).  ``tp_source`` labels the TP candidates'
    entries in ``est_seconds`` separately from the overall verdict: the
    unsharded side can be measured while the TP side stayed analytic
    (abstract inputs + a decision-cache replay), and the report must
    never call that ratio 'measured'.  ``disk_grad`` is the persisted
    backward-verdict section (dL/dx + dL/dvalues routes), replayed so a
    restart re-plans fwd+bwd with zero measurements.  The verdict is
    persisted by ``plan()`` (one store, after the executor -- and its
    capacity and grad sections -- are built)."""
    dctx = _selection_ctx(spec, ctx)
    key = cache_lib.key_string(_fingerprint(spec, ctx, operand))
    # background re-planner overlay first: an in-process upgraded
    # verdict wins over both the disk record (which store_decision has
    # already overwritten when persistence is on) and a fresh race
    rec = _replanned.get(key)
    if rec is not None and rec.get("route") in PLAN_ROUTES:
        return (rec["route"], dict(rec.get("est_seconds", {})),
                rec.get("source", "measured"), True,
                rec.get("capacity"),
                rec.get("tp_source", rec.get("source")),
                rec.get("grad"))
    use_disk = ctx.cache and ctx.persistence_on()
    if use_disk:
        rec = cache_lib.load_decision(ctx.resolved_cache_dir(), key)
        if rec is not None and rec.get("route") in PLAN_ROUTES:
            return (rec["route"], dict(rec.get("est_seconds", {})),
                    rec.get("source", "analytic"), True,
                    rec.get("capacity"),
                    rec.get("tp_source", rec.get("source")),
                    rec.get("grad"))

    cache_lib.bump("decisions")
    q = ctx.resolved_tp_q()
    forced_tp = spec.mode in TP_ROUTES
    tp_measurable = (operand is not None and x is not None
                     and dispatch._is_concrete(
                         x, *jax.tree_util.tree_leaves(operand)))
    if forced_tp:
        if spec.kind != "static":
            raise ValueError(f"mode {spec.mode!r} cannot execute a "
                             f"{spec.kind} operand")
        if not q:
            raise ValueError(f"mode {spec.mode!r} needs ctx.mesh (with "
                             "ctx.tp_axis) or an explicit ctx.tp_q")
        if spec.mode == "static_tp_shardmap":
            if not ctx.shardmap_executable():
                raise ValueError(
                    "mode 'static_tp_shardmap' needs a concrete "
                    f"ctx.mesh with axis {ctx.tp_axis!r} of size q={q} "
                    "(an AbstractMesh or bare tp_q can only execute "
                    "the 'static_tp' gspmd lowering)")
            cands = ("static_tp_shardmap",)
        else:
            # "static_tp" as a mode = the TP family: race both lowerings
            cands = _tp_candidates(spec, ctx, q) or ("static_tp",)
        est = {r: _tp_estimate(spec, q, r) for r in cands}
        source = "forced"
        if ctx.measure and len(cands) > 1 and tp_measurable:
            measured = {r: _measure_tp_route(r, spec, ctx, operand, x)
                        for r in cands}
            est.update(measured)
            cache_lib.bump("measurements")
            source = "measured"
        route = min(est, key=est.get)
        return route, est, source, False, None, source, None

    if operand is not None:
        dkey = dispatch._cache_key(spec.kind, spec.m, spec.k, spec.n,
                                   spec.block_size, spec.density,
                                   spec.dtype, dctx,
                                   skew=dispatch.pattern_balance(operand))
        already = dkey in dispatch._decision_cache
        dec = dispatch.decide(operand, spec.n, ctx=dctx, x=x)
        if dec.source == "measured" and not already:
            cache_lib.bump("measurements")
        route, est, source = dec.route, dict(dec.est_seconds), dec.source
    else:
        # OpSpec-only: analytic pricing straight off the cost model
        cands = dispatch._candidates(spec.kind, dctx)
        est = {r: dispatch._estimate(r, spec.m, spec.k, spec.n,
                                     spec.block_size, spec.density,
                                     spec.dtype) for r in cands}
        route = min(est, key=est.get)
        source = "forced" if len(cands) == 1 else "analytic"

    # mesh-aware TP candidates (auto mode, static pattern, mesh/tp_q
    # present): the measured-autotune race -- gspmd vs shard_map vs the
    # unsharded candidates -- or the analytic prior when not measuring
    tp_routes = (_tp_candidates(spec, ctx, q)
                 if spec.mode == "auto" and ctx.mesh is not None else ())
    tp_source = None
    if tp_routes:
        for r in tp_routes:
            est[r] = _tp_estimate(spec, q, r)
        tp_source = "analytic"
        if ctx.measure and tp_measurable:
            if source != "measured":
                # the unsharded side came back analytic (a decision-
                # cache replay from a traced first call): re-race it
                # cache-bypassed so both sides of the min() are wall
                # clocks -- analytic model seconds and host timings are
                # not comparable units
                dec2 = dispatch.decide(
                    operand, spec.n,
                    ctx=dataclasses.replace(dctx, cache=False), x=x)
                if dec2.source == "measured":
                    est.update(dec2.est_seconds)
                    route, source = dec2.route, dec2.source
                    cache_lib.bump("measurements")
            if source == "measured":
                measured_tp = {r: _measure_tp_route(r, spec, ctx,
                                                    operand, x)
                               for r in tp_routes}
                est.update(measured_tp)
                tp_source = "measured"
                cache_lib.bump("measurements")
                # compare measured against measured: the unsharded
                # race wall-clocked every runnable candidate
                runnable = {r: est[r] for r in est
                            if r in measured_tp
                            or dispatch._executable(r, dctx)}
                route = min(runnable, key=runnable.get)
        if (source != "measured"
                and est[min(tp_routes, key=est.get)] < est[route]):
            # analytic-vs-analytic only: never let a modeled TP number
            # overturn (or lose to) numbers of a different unit
            route = min(tp_routes, key=est.get)

    return route, est, source, False, None, tp_source, None


def _tp_decision(ctx: PlanContext, route: str, est: Dict[str, float],
                 source: str,
                 tp_source: Optional[str]) -> Optional[dict]:
    """The TP section of the plan report: what the race saw and where
    the crossover sits.  ``tp_speedup_vs_unsharded`` is best-unsharded
    time / best-TP time -- > 1 means TP is past the crossover for this
    problem on this mesh -- reported only when both sides carry the
    same units (both measured or both analytic); a mixed verdict (the
    unsharded side measured, the TP side stuck on its analytic prior
    because inputs were abstract) reports None rather than a
    model-seconds-vs-wall-clock ratio."""
    tp_est = {r: est[r] for r in TP_ROUTES if r in est}
    if not tp_est:
        return None
    q = ctx.resolved_tp_q()
    best_tp = min(tp_est, key=tp_est.get)
    unsh = {r: s for r, s in est.items() if r not in TP_ROUTES}
    best_un = min(unsh, key=unsh.get) if unsh else None
    tp_source = tp_source or source
    comparable = best_un is None or tp_source == source
    speedup = (est[best_un] / est[best_tp]
               if best_un is not None and comparable else None)
    mesh_fp = ctx.mesh_fingerprint()
    return {
        "q": q, "axis": ctx.tp_axis, "balanced": ctx.tp_balanced,
        "mesh": ({n: s for n, s in zip(*mesh_fp)} if mesh_fp else None),
        "candidates": {r: tp_est[r] for r in
                       sorted(tp_est, key=tp_est.get)},
        "chosen": route if route in TP_ROUTES else None,
        "best_tp_route": best_tp,
        "best_unsharded_route": best_un,
        "source": tp_source,
        "tp_speedup_vs_unsharded": (round(speedup, 4)
                                    if speedup is not None else None),
        "tp_wins": bool(speedup is not None and speedup > 1.0),
    }


# ---------------------------------------------------------------------------
# Execute-closure builders (one per (kind, route) arm; each closure is
# decision-free -- all metadata is a host constant baked at plan time)
# ---------------------------------------------------------------------------

def _promote_matmul(w, x, *, pallas: bool, interpret: bool):
    rt = jnp.result_type(w.dtype, x.dtype)
    if pallas:
        from repro.kernels.dense_mm import ops as dmm_ops
        return dmm_ops.dense_mm(w.astype(rt), x.astype(rt),
                                interpret=interpret)
    return jnp.matmul(w.astype(rt), x.astype(rt))


def _static_executor(spec: OpSpec, route: str, ctx: PlanContext,
                     operand: BlockSparseMatrix):
    m, k, b = spec.m, spec.k, spec.block_size
    mb, kb = m // b, k // b
    rows = np.asarray(operand.row_idx, np.int32)
    cols = np.asarray(operand.col_idx, np.int32)
    interpret = ctx.interpret
    # the baked pattern rides along (underscore = working state, not
    # report material): evolve() needs it to build the carry map and
    # the drift reference without re-deriving it from the caller
    art: Dict[str, Any] = {"nnz_blocks": len(rows),
                           "_pattern": (rows, cols)}

    if route == "static_xla":
        fn = _ssp.make_spmm(rows, cols, (mb, kb), b)
        return (lambda v, x: fn(jnp.asarray(v), x)), art

    if route == "static_pallas":
        from repro.kernels.bsmm import ops as bsmm_ops
        tm, tk, _ = bsmm_ops._pick_tiles(m, k, spec.n, b)
        meta = partitioner.plan_packing(rows, cols, (m, k), b, tm, tk)
        art.update(packing_tiles=meta.num_tiles,
                   packing_occupancy=meta.occupancy)
        # tn is picked at trace time from the actual x (calling the plan
        # with a different n than planned must not mis-tile the kernel)
        return (lambda v, x: bsmm_ops.bsmm_from_plan(
            meta, v, x, interpret=interpret)), art

    if route == "static_balanced":
        from repro.kernels.bsmm import ops as bsmm_ops
        tm, tk, _ = bsmm_ops._pick_tiles(m, k, spec.n, b)
        meta = partitioner.plan_packing_balanced(rows, cols, (m, k), b,
                                                 tm, tk)
        bal = partitioner.balance_report(meta.swizzle.loads)
        art.update(packing_tiles=meta.base.num_tiles,
                   packing_occupancy=meta.base.occupancy,
                   swizzle_bins=meta.num_bins,
                   swizzle_steps_per_bin=meta.steps_per_bin,
                   swizzle_imbalance=bal["imbalance"],
                   swizzle_cv=bal["cv"])
        return (lambda v, x: bsmm_ops.bsmm_balanced_from_plan(
            meta, v, x, interpret=interpret)), art

    if route in ("dense_xla", "dense_pallas"):
        rows_j, cols_j = jnp.asarray(rows), jnp.asarray(cols)
        pallas = route == "dense_pallas"

        def run(v, x):
            v = jnp.asarray(v)
            w = jnp.zeros((mb, kb, b, b), v.dtype).at[rows_j, cols_j].add(v)
            w = w.transpose(0, 2, 1, 3).reshape(m, k)
            return _promote_matmul(w, x, pallas=pallas, interpret=interpret)
        return run, art

    if route in ("dynamic_xla", "dynamic_pallas", "dynamic_grouped",
                 "dynamic_grouped_balanced"):
        rows_d = jnp.asarray(rows, jnp.int32)
        cols_d = jnp.asarray(cols, jnp.int32)
        nnz = jnp.asarray(len(rows), jnp.int32)
        if route == "dynamic_xla":
            return (lambda v, x: _dspmm(jnp.asarray(v), rows_d, cols_d, x,
                                        mb, b)), art

        def as_dyn(v):
            return DynamicOperand(jnp.asarray(v), rows_d, cols_d, nnz,
                                  (m, k), b)
        if route in ("dynamic_grouped", "dynamic_grouped_balanced"):
            from repro.kernels.gmm import ops as gmm_ops
            t = gmm_ops.grouped_tile_size(m, k, b)
            # static pattern -> the exact tile count is known at plan time
            meta = partitioner.plan_packing(rows, cols, (m, k), b, t, t)
            cap = meta.num_tiles
            art.update(grouped_tile=t, grouped_tiles_cap=cap)
            if route == "dynamic_grouped_balanced":
                from repro.kernels.gmm import balanced as gmm_balanced
                return (lambda v, x: gmm_balanced.balanced_spmm(
                    as_dyn(v), x, tile=t, tiles_cap=cap,
                    interpret=interpret)), art
            return (lambda v, x: gmm_ops.grouped_spmm(
                as_dyn(v), x, tile=t, tiles_cap=cap,
                interpret=interpret)), art
        from repro.kernels.dsmm import ops as dsmm_ops
        return (lambda v, x: dsmm_ops.dsmm(as_dyn(v), x,
                                           interpret=interpret)), art

    if route in TP_ROUTES:
        fn, tp_art = _tp_closure(route, spec, ctx, operand)
        art.update(tp_art)
        return fn, art

    raise ValueError(f"unknown static route {route!r}")


def _record_pack_stats(stats: CapacityStats, st) -> None:
    """Fold one pack's exact overflow accounting into the running stats.
    Concrete values record directly (eager calls); traced values go
    through ``jax.debug.callback`` so jitted programs (the serving
    engine's decode loop) still report."""
    leaves = (st.tiles_total, st.tiles_dropped, st.blocks_dropped,
              st.dropped_value_frac)
    if any(isinstance(v, jax.core.Tracer) for v in leaves):
        jax.debug.callback(stats.record, *leaves)
    else:
        stats.record(*leaves)


def _dynamic_executor(spec: OpSpec, route: str, ctx: PlanContext,
                      key: str, disk_capacity: Optional[dict] = None):
    m, k, b = spec.m, spec.k, spec.block_size
    mb = m // b
    interpret = ctx.interpret
    dplan = planner_lib.plan_dynamic(m, k, spec.n, d_max=spec.density,
                                     block_size=b, units=ctx.units)
    art: Dict[str, Any] = dict(bucket_blocks=dplan.bucket_blocks,
                               nnz_max_blocks=dplan.nnz_max_blocks,
                               q_m=dplan.q_m, q_k=dplan.q_k, q_n=dplan.q_n)

    if route == "dynamic_xla":
        return (lambda op, x: _dspmm(op.values, op.row_idx, op.col_idx,
                                     x, mb, b)), art
    if route == "dynamic_pallas":
        from repro.kernels.dsmm import ops as dsmm_ops
        return (lambda op, x: dsmm_ops.dsmm(op, x,
                                            interpret=interpret)), art
    if route in ("dynamic_grouped", "dynamic_grouped_balanced"):
        from repro.kernels.gmm import ops as gmm_ops
        if route == "dynamic_grouped_balanced":
            from repro.kernels.gmm.balanced import balanced_spmm as _gspmm
        else:
            _gspmm = gmm_ops.grouped_spmm
        t = gmm_ops.grouped_tile_size(m, k, b)
        # planned capacity (paper §3.3 bucket sizing): expected distinct
        # tiles at d_max, times the headroom knob -- NOT the safe worst
        # case.  Overflow is possible by design and counted exactly.
        slots = planner_lib.nnz_max_blocks(m, k, b, spec.density)
        capplan = planner_lib.plan_grouped_capacity(
            m, k, b, spec.density, tile=t, slots=slots,
            headroom=ctx.resolved_headroom())
        stats = _capacity_stats_for(
            key, tiles_cap=capplan.tiles_cap,
            worst_tiles=capplan.worst_tiles,
            overflow_threshold=ctx.overflow_threshold)
        stats.overflow_threshold = ctx.overflow_threshold
        # a persisted escalation (disk record at policy "worst") carries
        # across restarts: the guardrail's verdict is part of the plan,
        # not just process state
        if disk_capacity is not None and \
                disk_capacity.get("policy") == "worst":
            stats.escalated = True
        # guardrail: an escalated problem (observed overflow frequency
        # above ctx.overflow_threshold) re-plans at worst-case capacity
        policy = ("worst" if (ctx.capacity_policy == "worst"
                              or stats.escalated) else "planned")
        requested = (capplan.tiles_cap if policy == "planned"
                     else capplan.worst_tiles)
        cap, clamped = gmm_ops.clamped_tiles_cap(requested, m, k, t,
                                                 warn=False)
        stats.tiles_cap = cap
        stats.worst_tiles = capplan.worst_tiles
        stats.clamped = stats.clamped or clamped
        telemetry = ctx.telemetry
        art.update(grouped_tile=t, grouped_tiles_cap=cap,
                   capacity=dict(capplan.as_dict(), policy=policy,
                                 tiles_cap=cap, clamped=clamped,
                                 escalated=stats.escalated),
                   _capacity_stats=stats)

        def run(op, x):
            if not telemetry:        # skip the accounting reductions
                return _gspmm(op, x, tile=t, tiles_cap=cap,
                              interpret=interpret)
            y, st = _gspmm(op, x, tile=t, tiles_cap=cap,
                           interpret=interpret, return_stats=True)
            _record_pack_stats(stats, st)
            return y
        return run, art
    if route in ("dense_xla", "dense_pallas"):
        pallas = route == "dense_pallas"
        return (lambda op, x: _promote_matmul(op.to_dense(), x,
                                              pallas=pallas,
                                              interpret=interpret)), art
    raise ValueError(f"unknown dynamic route {route!r}")


def _dense_executor(spec: OpSpec, route: str, ctx: PlanContext):
    interpret = ctx.interpret
    art: Dict[str, Any] = {}
    if spec.op == "matmul":
        pallas = route == "dense_pallas"
        # activation-major: x2 @ w (operand order swapped vs spmm form)
        return (lambda w, x2: _promote_matmul(x2, w, pallas=pallas,
                                              interpret=interpret)), art
    if spec.op == "batched_matmul":
        pallas = route == "dense_pallas"

        def run(a, bb):
            rt = jnp.result_type(a.dtype, bb.dtype)
            if pallas:
                from repro.kernels.dense_mm import ops as dmm_ops
                def f(x_, y_):
                    return dmm_ops.dense_mm(x_, y_, interpret=interpret)
                for _ in range(a.ndim - 2):
                    f = jax.vmap(f)
                return f(a.astype(rt), bb.astype(rt))
            return jnp.matmul(a.astype(rt), bb.astype(rt))
        return run, art
    pallas = route == "dense_pallas"
    return (lambda w, x: _promote_matmul(jnp.asarray(w), x, pallas=pallas,
                                         interpret=interpret)), art


# ---------------------------------------------------------------------------
# Planned backward (the differentiable-plans tentpole): every executable
# spmm plan carries a plan-level jax.custom_vjp whose backward runs two
# sibling products chosen by the same decide/measure/persist machinery
# as the forward --
#
#   dL/dx       an SpMM on the *transposed* pattern (partitioner
#               metadata transposed once per pattern, cached), raced
#               over the dispatch route vocabulary;
#   dL/dvalues  a block SDDMM (static_sparse.make_sddmm, the
#               kernels/sddmm grouped tile kernel, or the dense
#               product), raced over dispatch.SDDMM_ROUTES.
#
# Verdicts join the persistent decision record under a "grad" section,
# so a training restart re-plans fwd+bwd with zero measurements.
# ---------------------------------------------------------------------------

_transpose_cache: Dict[tuple, partitioner.TransposePlan] = {}
_sddmm_meta_cache: Dict[tuple, partitioner.PackingPlan] = {}


def _transpose_plan_for(operand: BlockSparseMatrix) -> partitioner.TransposePlan:
    pk = pattern_key(operand)
    key = (pk, operand.shape, operand.block_size)
    with _plan_lock:
        tp = _transpose_cache.get(key)
    if tp is None:
        tp = partitioner.plan_transpose(operand.row_idx, operand.col_idx,
                                        operand.shape, operand.block_size)
        with _plan_lock:
            tp = _transpose_cache.setdefault(key, tp)
    return tp


def _sddmm_meta_for(operand: BlockSparseMatrix,
                    t: int) -> partitioner.PackingPlan:
    pk = pattern_key(operand)
    key = (pk, operand.shape, operand.block_size, t)
    with _plan_lock:
        meta = _sddmm_meta_cache.get(key)
    if meta is None:
        meta = partitioner.plan_packing(
            np.asarray(operand.row_idx), np.asarray(operand.col_idx),
            operand.shape, operand.block_size, t, t)
        with _plan_lock:
            meta = _sddmm_meta_cache.setdefault(key, meta)
    return meta


def _dx_closure(route: str, spec: OpSpec, ctx: PlanContext,
                operand: BlockSparseMatrix):
    """(values, dy) -> dL/dx for one candidate route: the forward
    executor vocabulary applied to the transposed pattern (value phase:
    permute + per-block transpose, a device gather per call)."""
    tplan = _transpose_plan_for(operand)
    spec_t = OpSpec(kind="static", m=spec.k, k=spec.m, n=spec.n,
                    block_size=spec.block_size, density=spec.density,
                    dtype=spec.dtype, op="spmm", mode="auto")
    # the executor arms close over the pattern metadata only and take
    # values per call, so any same-shape array works as the placeholder
    # -- the live values are re-permuted in run() below
    bsr_t = BlockSparseMatrix(operand.values, tplan.row_idx,
                              tplan.col_idx, tplan.shape,
                              tplan.block_size)
    inner, _ = _static_executor(spec_t, route, ctx, bsr_t)
    perm = jnp.asarray(tplan.perm)

    def run(v, dy):
        v_t = jnp.asarray(v)[perm].transpose(0, 2, 1)
        return inner(v_t, dy)
    return run


def _dv_closure(route: str, spec: OpSpec, ctx: PlanContext,
                operand: BlockSparseMatrix):
    """(dy, x) -> dL/dvalues ([nnz, b, b]) for one SDDMM route."""
    m, k, b = spec.m, spec.k, spec.block_size
    mb, kb = m // b, k // b
    rows = np.asarray(operand.row_idx, np.int32)
    cols = np.asarray(operand.col_idx, np.int32)
    if route == "sddmm_xla":
        return _ssp.make_sddmm(rows, cols, (mb, kb), b)
    if route == "sddmm_dense":
        rows_j, cols_j = jnp.asarray(rows), jnp.asarray(cols)

        def run(dy, x):
            rt = jnp.result_type(dy.dtype, x.dtype)
            dw = jnp.matmul(dy.astype(rt), x.astype(rt).T)
            blocked = dw.reshape(mb, b, kb, b).transpose(0, 2, 1, 3)
            return blocked[rows_j, cols_j]
        return run
    if route == "sddmm_grouped":
        from repro.kernels.sddmm import ops as sddmm_ops
        t = sddmm_ops.sddmm_tile_size(m, k, b)
        meta = _sddmm_meta_for(operand, t)
        interpret = ctx.interpret
        return lambda dy, x: sddmm_ops.grouped_sddmm(meta, dy, x,
                                                     interpret=interpret)
    raise ValueError(f"unknown sddmm route {route!r}")


def _grad_verdict(est, forced, *, measure_fns=None) -> dict:
    """One backward product's verdict: analytic ranking, optionally
    overturned by wall-clock measurement of the runnable candidates.
    A measured verdict publishes ONLY the wall-clocked entries --
    analytic model seconds and host timings are not comparable units,
    and a mixed dict labeled 'measured' would report bogus crossovers
    (the same rule PR 4's ``tp_source`` enforces for the TP race)."""
    source = "forced" if forced else "analytic"
    pick_from = est
    if measure_fns:
        pick_from = est = {r: dispatch.measure_callable(fn, *args)
                           for r, (fn, args) in measure_fns.items()}
        source = "measured"
    return {"route": min(pick_from, key=pick_from.get),
            "source": source,
            "est_seconds": {r: float(s) for r, s in est.items()}}


def _grad_decide(spec: OpSpec, ctx: PlanContext,
                 operand: BlockSparseMatrix, x,
                 disk_grad: Optional[dict]) -> dict:
    """Backward route verdicts (dx = transposed SpMM, dvalues = SDDMM):
    disk replay when the forward record carried them, else the analytic
    race, wall-clocked when ``ctx.measure`` and the inputs are concrete
    (the dy probe is shape data only -- zeros of the output shape)."""
    if disk_grad is not None and \
            disk_grad.get("dx", {}).get("route") in dispatch.ROUTES and \
            disk_grad.get("dvalues", {}).get("route") in dispatch.SDDMM_ROUTES:
        return dict(disk_grad, from_disk=True)
    bwd_ctx = dataclasses.replace(_selection_ctx(spec, ctx),
                                  differentiable=False, mode="auto")
    m, k, n, b = spec.m, spec.k, spec.n, spec.block_size
    d, dt = spec.density, spec.dtype
    dx_forced = ctx.grad_mode != "auto"
    dx_cands = ((ctx.grad_mode,) if dx_forced
                else dispatch._candidates("static", bwd_ctx))
    dv_forced = ctx.sddmm_mode != "auto"
    dv_cands = ((ctx.sddmm_mode,) if dv_forced
                else dispatch.sddmm_candidates(bwd_ctx))
    # dx is the transposed problem: [k, m] @ [m, n]
    dx_est = {r: dispatch._estimate(r, k, m, n, b, d, dt)
              for r in dx_cands}
    dv_est = {r: dispatch._estimate(r, m, k, n, b, d, dt)
              for r in dv_cands}
    dx_meas = dv_meas = None
    cache_lib.bump("decisions")
    if ctx.measure and x is not None and dispatch._is_concrete(
            x, *jax.tree_util.tree_leaves(operand)):
        dy = jnp.zeros((m, n), jnp.result_type(
            jnp.dtype(dt), jnp.asarray(x).dtype))
        v = jnp.asarray(operand.values)
        dx_run = [r for r in dx_cands if dispatch._executable(r, bwd_ctx)]
        dv_run = [r for r in dv_cands if dispatch._executable(r, bwd_ctx)]
        if dx_run:
            dx_meas = {r: (_dx_closure(r, spec, ctx, operand), (v, dy))
                       for r in dx_run}
        if dv_run:
            dv_meas = {r: (_dv_closure(r, spec, ctx, operand),
                           (dy, jnp.asarray(x)))
                       for r in dv_run}
        if dx_meas or dv_meas:
            cache_lib.bump("measurements")
    return {"dx": _grad_verdict(dx_est, dx_forced, measure_fns=dx_meas),
            "dvalues": _grad_verdict(dv_est, dv_forced,
                                     measure_fns=dv_meas),
            "from_disk": False}


def _planned_vjp(execute, dx_fn, dv_fn):
    """The plan-level custom_vjp for static plans: forward runs the
    planned route (Pallas included); backward runs the two sibling
    plans.  Built once at plan time, so the wrapped callable is a
    stable jit/vmap-safe identity."""
    @jax.custom_vjp
    def run(v, x):
        return execute(v, x)

    def fwd(v, x):
        return run(v, x), (v, x)

    def bwd(res, dy):
        v, x = res
        dv = dv_fn(dy, x)
        dx = dx_fn(v, dy)
        return (dv.astype(jnp.asarray(v).dtype), dx.astype(x.dtype))

    run.defvjp(fwd, bwd)
    return run


def _dynamic_planned_vjp(execute, spec: OpSpec):
    """Plan-level custom_vjp for dynamic-kind plans (runtime pattern):
    backward uses the runtime-index transposed-gather/scatter pair --
    the same products ``_dspmm``'s own vjp runs -- so the Pallas
    dynamic forwards (dsmm slot walk, grouped tile pack) become
    trainable.  Integer index/count leaves get no cotangent."""
    m, k, b = spec.m, spec.k, spec.block_size
    mb, kb = m // b, k // b

    @jax.custom_vjp
    def run(values, row_idx, col_idx, nnz, x):
        op = DynamicOperand(values, row_idx, col_idx, nnz, (m, k), b)
        return execute(op, x)

    def fwd(values, row_idx, col_idx, nnz, x):
        return run(values, row_idx, col_idx, nnz, x), \
            (values, row_idx, col_idx, x)

    def bwd(res, dy):
        values, row_idx, col_idx, x = res
        n = x.shape[-1]
        dyb = dy.reshape(mb, b, n)
        xb = x.reshape(kb, b, n)
        dyg = jnp.take(dyb, row_idx, axis=0)
        xg = jnp.take(xb, col_idx, axis=0)
        dvalues = jnp.einsum("zan,zbn->zab", dyg, xg).astype(values.dtype)
        partial = jnp.einsum("zab,zan->zbn", values, dyg)
        dx = jax.ops.segment_sum(partial, col_idx, num_segments=kb)
        return (dvalues, None, None, None,
                dx.reshape(kb * b, n).astype(x.dtype))

    run.defvjp(fwd, bwd)
    return lambda op, x: run(op.values, op.row_idx, op.col_idx, op.nnz, x)


def _dense_planned_vjp(execute, op: str):
    """custom_vjp for the dense_pallas forward kernel (no native VJP):
    backward is the two dense products via jnp.matmul."""
    @jax.custom_vjp
    def run(w, x):
        return execute(w, x)

    def fwd(w, x):
        return run(w, x), (w, x)

    if op == "matmul":     # execute(w, x2) = x2 @ w
        def bwd(res, dy):
            w, x2 = res
            return ((x2.T @ dy).astype(w.dtype),
                    (dy @ w.T).astype(x2.dtype))
    else:                  # spmm form: execute(w, x) = w @ x
        def bwd(res, dy):
            w, x = res
            return ((dy @ x.T).astype(w.dtype),
                    (w.T @ dy).astype(x.dtype))

    run.defvjp(fwd, bwd)
    return run


def _no_vjp_error(execute, route: str, workaround: str):
    """Forward-only plans (Pallas route, no planned backward): fail the
    backward *trace* with an actionable error instead of the opaque
    Pallas internal failure / silent wrong-gradient path."""
    @jax.custom_vjp
    def run(v, x):
        return execute(v, x)

    def fwd(v, x):
        return run(v, x), None

    def bwd(res, dy):
        raise ValueError(
            f"plan route {route!r} has no registered VJP (the Pallas "
            f"kernel is forward-only and this plan was built without a "
            f"planned backward); {workaround}")

    run.defvjp(fwd, bwd)
    return run


_PALLAS_FWD_ONLY = ("dense_pallas", "static_pallas", "static_balanced",
                    "dynamic_pallas", "dynamic_grouped",
                    "dynamic_grouped_balanced")


def _wrap_grad(spec: OpSpec, route: str, ctx: PlanContext,
               operand: Optional[Operand], x, execute,
               disk_grad: Optional[dict]):
    """-> (execute', grad_artifacts).  Attaches the plan-level backward
    (or the clear no-VJP error) to an executable plan's closure."""
    if route in TP_ROUTES:
        # gspmd / shard_map lowerings are jnp + psum: native autodiff
        # already runs sharded backward products
        return execute, ({"mode": "native"} if ctx.differentiable
                         else None)
    if spec.op == "spmm" and spec.kind == "static" \
            and isinstance(operand, BlockSparseMatrix):
        if _grad_covered(spec, ctx):
            grad = _grad_decide(spec, ctx, operand, x, disk_grad)
            dx_fn = _dx_closure(grad["dx"]["route"], spec, ctx, operand)
            dv_fn = _dv_closure(grad["dvalues"]["route"], spec, ctx,
                                operand)
            return (_planned_vjp(execute, dx_fn, dv_fn),
                    dict(grad, mode="planned"))
        if route in _PALLAS_FWD_ONLY:
            return _no_vjp_error(
                execute, route,
                "re-plan with PlanContext(differentiable=True) for the "
                "planned backward, or force an XLA route (e.g. "
                "mode='static_xla')"), {"mode": "unavailable"}
        return execute, None
    if spec.op == "spmm" and spec.kind == "dynamic":
        if ctx.differentiable:
            if route == "dynamic_xla":
                # _dspmm carries its own runtime-index custom_vjp
                return execute, {"mode": "native"}
            wrapped = _dynamic_planned_vjp(execute, spec)
            return wrapped, {
                "mode": "planned",
                "dx": {"route": "dynamic_xla", "source": "forced"},
                "dvalues": {"route": "sddmm_xla", "source": "forced"},
                "from_disk": False}
        if route in _PALLAS_FWD_ONLY:
            return _no_vjp_error(
                execute, route,
                "re-plan with PlanContext(differentiable=True) for the "
                "planned backward, or force an XLA route (e.g. "
                "mode='dynamic_xla')"), {"mode": "unavailable"}
        return execute, None
    # dense kind (spmm / matmul / batched_matmul ops)
    if route == "dense_pallas":
        if ctx.differentiable and spec.op in ("spmm", "matmul"):
            return (_dense_planned_vjp(execute, spec.op),
                    {"mode": "planned",
                     "dx": {"route": "dense_xla", "source": "forced"},
                     "dvalues": {"route": "dense_xla",
                                 "source": "forced"},
                     "from_disk": False})
        return _no_vjp_error(
            execute, route,
            "force the XLA route (mode='dense_xla') for differentiable "
            "callers"), {"mode": "unavailable"}
    return execute, ({"mode": "native"} if ctx.differentiable else None)


def _build_executor(spec: OpSpec, route: str, ctx: PlanContext,
                    operand: Optional[Operand], key: str,
                    disk_capacity: Optional[dict] = None):
    if spec.kind == "static":
        if operand is None or not isinstance(operand, BlockSparseMatrix):
            return None, {}          # spec-only static plan: report-only
        return _static_executor(spec, route, ctx, operand)
    if spec.kind == "dynamic":
        return _dynamic_executor(spec, route, ctx, key, disk_capacity)
    return _dense_executor(spec, route, ctx)


# ---------------------------------------------------------------------------
# Incremental plan mutation (MatmulPlan.evolve): dynamic sparse training
# with evolving static patterns.  A RigL topology step re-runs only the
# cheap host pattern phases (plan_packing / plan_transpose /
# plan_k_shards / grouped-capacity sizing -- all inside the executor
# builders) and inherits the parent's route + backward verdicts; the
# expensive decide/measure machinery re-runs only when the pattern
# profile drifts past ctx.evolve_drift (or rerace=True forces it).
# ---------------------------------------------------------------------------


def _as_static_bsr(new_pattern, spec: OpSpec) -> BlockSparseMatrix:
    """Normalize evolve()'s pattern argument to a static BSR with
    placeholder values (executor closures bake pattern metadata only;
    live values flow through the plan per call)."""
    b = spec.block_size
    mb, kb = spec.m // b, spec.k // b
    if isinstance(new_pattern, BlockSparseMatrix):
        if not new_pattern.is_static:
            raise ValueError(
                "evolve() needs a static (host-indexed) pattern; a "
                "runtime pattern is dynamic-kind data, not a plan "
                "mutation")
        if new_pattern.shape != (spec.m, spec.k) \
                or new_pattern.block_size != b:
            raise ValueError(
                f"evolved pattern shape {new_pattern.shape} block "
                f"{new_pattern.block_size} != plan's "
                f"({spec.m}, {spec.k}) block {b} -- evolve changes the "
                f"pattern, never the problem")
        return new_pattern.validate_pattern()
    if isinstance(new_pattern, tuple) and len(new_pattern) == 2:
        rows = np.asarray(new_pattern[0], np.int32)
        cols = np.asarray(new_pattern[1], np.int32)
        bsr = BlockSparseMatrix(jnp.zeros((len(rows), b, b), spec.dtype),
                                rows, cols, (spec.m, spec.k), b)
        return bsr.validate_pattern()
    mask = np.asarray(new_pattern, bool)
    if mask.shape != (mb, kb):
        raise ValueError(f"evolved block mask shape {mask.shape} != "
                         f"grid {(mb, kb)}")
    return BlockSparseMatrix.from_mask(mask, b, dtype=spec.dtype)


def _pattern_profile(rows: np.ndarray, cols: np.ndarray,
                     spec: OpSpec) -> Dict[str, float]:
    """The drift metric's inputs: block density + MXU-tile packing
    occupancy (the two pattern properties the dispatch cost model and
    the Pallas grid actually price)."""
    b = spec.block_size
    mb, kb = spec.m // b, spec.k // b
    t = b * max(1, 128 // b)
    meta = partitioner.plan_packing(rows, cols, (spec.m, spec.k), b,
                                    t, t)
    return {"density": len(rows) / max(1, mb * kb),
            "occupancy": meta.occupancy}


def _persist_lineage(ctx: PlanContext, p: "MatmulPlan", lineage: dict,
                     grad_art: Optional[dict] = None) -> None:
    """Write the evolved verdict + lineage at the evolved pattern's
    fingerprint, so a restart replays fwd+bwd for the evolved pattern
    with zero measurements and the lineage survives the process."""
    if not (ctx.cache and ctx.persistence_on()):
        return
    cdir = ctx.resolved_cache_dir()
    rec = cache_lib.load_decision(cdir, p.key)
    if rec is None:
        rec = {"route": p.route, "source": p.source,
               "est_seconds": {r: float(v)
                               for r, v in p.est_seconds.items()}}
        if grad_art and grad_art.get("mode") == "planned" \
                and "dx" in grad_art:
            rec["grad"] = {
                side: {k2: v for k2, v in grad_art[side].items()
                       if k2 in ("route", "source", "est_seconds")}
                for side in ("dx", "dvalues")}
    cache_lib.store_decision(cdir, p.key, dict(rec, evolution=lineage))


def _evolve_plan(parent: "MatmulPlan", new_bsr: BlockSparseMatrix,
                 rerace: Optional[bool], x) -> "MatmulPlan":
    ctx = parent.ctx
    old_rows, old_cols = parent.pattern
    new_rows = np.asarray(new_bsr.row_idx, np.int32)
    new_cols = np.asarray(new_bsr.col_idx, np.int32)
    new_spec = OpSpec.from_operand(new_bsr, parent.spec.n,
                                   mode=parent.spec.mode)
    b = new_spec.block_size
    grid = (new_spec.m // b, new_spec.k // b)
    eplan = partitioner.plan_evolution(old_rows, old_cols, new_rows,
                                       new_cols, grid)
    prof = _pattern_profile(new_rows, new_cols, new_spec)
    parent_ev = parent.artifacts.get("evolution")
    if parent_ev:
        # the drift reference is inherited through the evolve chain (it
        # is the profile the live verdicts were actually raced on) and
        # resets only on a re-race
        ref_d = parent_ev["ref_density"]
        ref_o = parent_ev["ref_occupancy"]
        gen = parent_ev["generation"] + 1
        root = parent_ev["root_key"]
    else:
        ref = _pattern_profile(np.asarray(old_rows),
                               np.asarray(old_cols), parent.spec)
        ref_d, ref_o = ref["density"], ref["occupancy"]
        gen, root = 1, parent.key
    thr = ctx.evolve_drift
    drift = max(abs(prof["density"] - ref_d) / max(ref_d, 1e-12),
                abs(prof["occupancy"] - ref_o) / max(ref_o, 1e-12))
    tripped = thr is not None and drift > thr
    do_rerace = tripped if rerace is None else bool(rerace)
    with _plan_lock:
        _evolution_totals["evolves"] += 1
        if tripped:
            _evolution_totals["drift_trips"] += 1
        if do_rerace:
            _evolution_totals["reraces"] += 1

    lineage = {
        "parent_key": parent.key, "root_key": root, "generation": gen,
        "drift": round(float(drift), 6), "drift_threshold": thr,
        "drift_tripped": bool(tripped), "reraced": bool(do_rerace),
        "carried": eplan.carried, "dropped": eplan.dropped,
        "grown": eplan.grown,
        "density": round(prof["density"], 6),
        "occupancy": round(prof["occupancy"], 6),
    }

    if do_rerace:
        # full plan(): decide (and measure, given ctx.measure + concrete
        # x) from scratch; the drift reference resets to this profile
        lineage.update(ref_density=round(prof["density"], 6),
                       ref_occupancy=round(prof["occupancy"], 6))
        p = plan(new_bsr, new_spec.n, x=x, ctx=ctx)
        p.artifacts["evolution"] = lineage
        p.artifacts["_evolve"] = eplan
        _persist_lineage(ctx, p, lineage, p.artifacts.get("grad"))
        return p

    lineage.update(ref_density=round(float(ref_d), 6),
                   ref_occupancy=round(float(ref_o), 6))
    # verdict-reuse path: rebuild the executor (the cheap host pattern
    # phases only) and replay the parent's route + backward verdicts --
    # zero decisions, zero measurements
    fp = _fingerprint(new_spec, ctx, new_bsr)
    key_str = cache_lib.key_string(fp)
    execute, artifacts = _static_executor(new_spec, parent.route, ctx,
                                          new_bsr)
    parent_grad = parent.artifacts.get("grad")
    inherited_grad = None
    if parent_grad and parent_grad.get("mode") == "planned" \
            and "dx" in parent_grad:
        inherited_grad = {"dx": dict(parent_grad["dx"]),
                          "dvalues": dict(parent_grad["dvalues"])}
    execute, grad_art = _wrap_grad(new_spec, parent.route, ctx, new_bsr,
                                   x, execute, inherited_grad)
    if grad_art is not None:
        if inherited_grad is not None \
                and grad_art.get("mode") == "planned":
            # _grad_decide's replay labels its input "from_disk"; these
            # verdicts were inherited from the parent plan in memory --
            # report the parent's disk provenance instead
            grad_art = dict(grad_art, evolved=True,
                            from_disk=parent_grad.get("from_disk",
                                                      False))
        artifacts["grad"] = grad_art
    if "tp" in parent.artifacts:
        artifacts["tp"] = parent.artifacts["tp"]
    artifacts["evolution"] = lineage
    artifacts["_evolve"] = eplan
    p = MatmulPlan(spec=new_spec, route=parent.route,
                   source=parent.source,
                   est_seconds=dict(parent.est_seconds),
                   from_disk=parent.from_disk, ctx=ctx, key=key_str,
                   artifacts=artifacts, _execute=execute,
                   capacity_stats=None)
    cache_lib.bump("plans_built")
    if ctx.cache:
        with _plan_lock:
            # overwrite, not setdefault: the evolved plan IS the
            # continuation for this pattern -- spmm()/SparseLinear calls
            # on the new pattern must hit it with zero decisions
            _plan_cache[_mem_key(fp, pattern_key(new_bsr), ctx)] = p
    _persist_lineage(ctx, p, lineage, grad_art)
    return p


def evolve(plan_: "MatmulPlan", new_pattern, *,
           rerace: Optional[bool] = None, x=None) -> "MatmulPlan":
    """Module-level spelling of ``plan.evolve(new_pattern)`` (see
    ``MatmulPlan.evolve``)."""
    return plan_.evolve(new_pattern, rerace=rerace, x=x)


def evolve_plans(old_pattern, new_pattern) -> int:
    """Evolve every cached executable static-spmm plan built on
    ``old_pattern`` onto ``new_pattern`` (any n / policy) -- the layer
    hook: after a RigL topology update the next forward on the new
    pattern is a plan-cache hit with zero decisions.  Both arguments
    are static ``BlockSparseMatrix`` (values ignored).  Returns the
    number of plans evolved."""
    pk_old = pattern_key(old_pattern)
    if pk_old is None:
        raise ValueError("evolve_plans() needs static patterns")
    with _plan_lock:
        matches = [p for mk, p in _plan_cache.items()
                   if mk[1] == pk_old]
    count = 0
    for p in matches:
        if (p.spec.kind == "static" and p.spec.op == "spmm"
                and p.executable):
            p.evolve(new_pattern)
            count += 1
    return count


# ---------------------------------------------------------------------------
# plan() + conveniences
# ---------------------------------------------------------------------------

_ctx_state = threading.local()


@contextlib.contextmanager
def use_ctx(ctx: PlanContext):
    """Install ``ctx`` as the ambient planning context (trace-scoped):
    every ``plan``/``matmul``/... call without an explicit ``ctx`` picks
    it up.  The serving engine wraps its traced programs with this so
    per-engine policy (persistent cache dir, Pallas admissibility) never
    leaks into process-global state."""
    prev = getattr(_ctx_state, "ctx", None)
    _ctx_state.ctx = ctx
    try:
        yield ctx
    finally:
        _ctx_state.ctx = prev


def _resolve_ctx(ctx) -> PlanContext:
    if ctx is None:
        ambient = getattr(_ctx_state, "ctx", None)
        if ambient is not None:
            return ambient
        return PlanContext.from_dispatch(dispatch.current_ctx())
    if isinstance(ctx, dispatch.DispatchContext):
        return PlanContext.from_dispatch(ctx)
    return ctx


def plan(operand_or_spec, n: Optional[int] = None, *, x=None,
         ctx: Optional[PlanContext] = None) -> MatmulPlan:
    """Phase 1 of the two-phase API: run all one-time work for
    ``operand @ [k, n]`` and return a frozen ``MatmulPlan``.

    ``operand_or_spec`` is a full operand (dense array /
    ``BlockSparseMatrix`` / ``DynamicOperand``) -- or an ``OpSpec`` for
    spec-only planning (dense/dynamic plans stay executable; static
    plans without the concrete pattern are report-only).  ``x`` is used
    only for measured autotune (``ctx.measure=True``, concrete inputs).
    """
    ctx = _resolve_ctx(ctx)
    if isinstance(operand_or_spec, OpSpec):
        spec, operand = operand_or_spec, None
        if ctx.mode != spec.mode:
            ctx = dataclasses.replace(ctx, mode=spec.mode)
    else:
        operand = operand_or_spec
        if n is None:
            raise ValueError("plan(operand, n): n is required when "
                             "planning from a concrete operand")
        spec = OpSpec.from_operand(operand, n, mode=ctx.mode)

    pkey = pattern_key(operand) if operand is not None else None
    fp = _fingerprint(spec, ctx, operand)
    # the persistence policy and the runtime-only knobs are part of the
    # in-memory plan-cache identity but not the disk fingerprint -- see
    # _mem_key / _fingerprint
    mem_key = _mem_key(fp, pkey, ctx)
    if ctx.cache:
        if ctx.pool:
            with _plan_lock:
                keys = _pool_registry.setdefault(ctx.pool, [])
                if mem_key not in keys:
                    keys.append(mem_key)
        hit = _plan_cache.get(mem_key)
        if hit is not None:
            cache_lib.bump("plan_hits")
            return hit

    route, est, source, from_disk, disk_cap, tp_source, disk_grad = \
        _decide(spec, ctx, operand, x)
    key_str = cache_lib.key_string(fp)
    execute, artifacts = _build_executor(spec, route, ctx, operand,
                                         key_str, disk_cap)
    if execute is not None:
        execute, grad_art = _wrap_grad(spec, route, ctx, operand, x,
                                       execute, disk_grad)
        if grad_art is not None:
            artifacts["grad"] = grad_art
    tp_info = _tp_decision(ctx, route, est, source, tp_source)
    if tp_info is not None:
        artifacts["tp"] = tp_info
    stats = artifacts.pop("_capacity_stats", None)
    p = MatmulPlan(spec=spec, route=route, source=source,
                   est_seconds=est, from_disk=from_disk, ctx=ctx,
                   key=key_str, artifacts=artifacts,
                   _execute=execute, capacity_stats=stats)
    cache_lib.bump("plans_built")

    # persist the verdict once, with the capacity/headroom section when
    # the route has a planned bucket -- so restarted processes allocate
    # the identical bucket (including an escalated policy="worst"
    # verdict).  store_decision short-circuits identical records, so a
    # disk-hit rebuild writes nothing.
    if ctx.cache and ctx.persistence_on():
        rec = {"route": route, "source": source,
               "est_seconds": {r: float(s) for r, s in est.items()}}
        if tp_source is not None:
            # TP entries can carry a different unit than the verdict
            # (analytic prior next to measured unsharded times); label
            # them so a disk replay reports the crossover honestly
            rec["tp_source"] = tp_source
        if "capacity" in artifacts:
            rec["capacity"] = {k2: v for k2, v in
                               artifacts["capacity"].items()
                               if k2 != "escalated"}
        grad_art = artifacts.get("grad")
        if grad_art and grad_art.get("mode") == "planned" \
                and "dx" in grad_art and _grad_covered(spec, ctx):
            # the backward verdicts ride in the forward record (one
            # entry per plan fingerprint): a restarted trainer replays
            # fwd route + dx route + dvalues route from one disk hit
            rec["grad"] = {side: dict(grad_art[side])
                           for side in ("dx", "dvalues")}
        cache_lib.store_decision(ctx.resolved_cache_dir(), key_str, rec)

    if ctx.cache:
        with _plan_lock:
            p = _plan_cache.setdefault(mem_key, p)
        if stats is not None and p.capacity_stats is stats:
            # guardrail plumbing: when observed overflow trips the
            # threshold, evict this plan so the next plan() re-plans at
            # worst-case capacity (already-compiled closures keep the
            # planned bucket -- escalation applies to new traces), and
            # persist the escalated verdict NOW -- a long-lived holder
            # of the plan (the serving engine) may never call plan()
            # again in this process, but the restart must see "worst"
            esc_rec = None
            if ctx.persistence_on() and "capacity" in artifacts:
                cap_art = {k2: v for k2, v in
                           artifacts["capacity"].items()
                           if k2 != "escalated"}
                cap_art["policy"] = "worst"
                cap_art["tiles_cap"] = cap_art["worst_tiles"]
                esc_rec = {"route": route, "source": source,
                           "est_seconds": {r: float(s)
                                           for r, s in est.items()},
                           "capacity": cap_art}
            esc_dir = ctx.resolved_cache_dir()

            def _escalate_trip():
                with _plan_lock:
                    _plan_cache.pop(mem_key, None)
                if esc_rec is not None:
                    cache_lib.store_decision(esc_dir, key_str, esc_rec)
            stats._on_escalate = _escalate_trip
    return p


def explain(operand_or_spec, n: Optional[int] = None, *,
            ctx: Optional[PlanContext] = None) -> dict:
    """Plan and report in one step (non-executing)."""
    return plan(operand_or_spec, n, ctx=ctx).explain()


def spmm(operand: Operand, x, *, ctx: Optional[PlanContext] = None):
    """One-shot ``Y = W @ X`` (plan + execute; the plan is cached, so
    repeated calls are dict hits -- prefer holding the plan in hot
    loops)."""
    ctx = _resolve_ctx(ctx)
    _, _, k, _, _ = dispatch._normalize(operand)
    if x.ndim != 2:
        raise ValueError(f"x must be [k, n], got shape {x.shape}")
    if x.shape[0] != k:
        raise ValueError(f"X rows {x.shape[0]} != operand k {k}")
    p = plan(operand, int(x.shape[1]), x=x, ctx=ctx)
    return p.apply(operand, x)


def spmm_nt(operand: Operand, x, *, ctx: Optional[PlanContext] = None):
    """Activation-major form ``x: [..., k] -> [..., m]`` (y = x @ W^T)."""
    _, m, k, _, _ = dispatch._normalize(operand)
    lead = x.shape[:-1]
    y = spmm(operand, x.reshape(-1, k).T, ctx=ctx)
    return y.T.reshape(*lead, m)


def matmul(x, w, *, ctx: Optional[PlanContext] = None):
    """Dense-layer form ``y = x @ w`` (``x: [..., k]``, ``w: [k, n]``) --
    what ``models.layers.dense`` and the serving engine execute with."""
    ctx = _resolve_ctx(ctx)
    if isinstance(w, (BlockSparseMatrix, DynamicOperand)):
        raise ValueError("matmul() takes a dense rhs; use spmm_nt for "
                         "sparse operands")
    lead = x.shape[:-1]
    k, n_out = w.shape
    x2 = x.reshape(-1, k)
    spec = OpSpec(kind="dense", m=n_out, k=k, n=int(x2.shape[0]),
                  dtype=jnp.dtype(w.dtype).name, op="matmul",
                  mode=ctx.mode if ctx.mode in dispatch.MODES else "auto")
    y = plan(spec, ctx=ctx)(w, x2)
    return y.reshape(*lead, n_out)


def batched_matmul(a, b, *, ctx: Optional[PlanContext] = None):
    """Batched dense ``[..., C, D] @ [..., D, F]`` (MoE expert GEMMs):
    one plan for the per-slice problem, vmapped over the batch axes."""
    ctx = _resolve_ctx(ctx)
    cdim, ddim = a.shape[-2], a.shape[-1]
    fdim = b.shape[-1]
    spec = OpSpec(kind="dense", m=cdim, k=ddim, n=int(fdim),
                  dtype=jnp.dtype(a.dtype).name, op="batched_matmul",
                  mode=ctx.mode if ctx.mode in dispatch.MODES else "auto")
    return plan(spec, ctx=ctx)(a, b)
