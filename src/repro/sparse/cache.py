"""Persistent autotune cache for the plan-first sparse API.

PopSparse's planning is ahead-of-time; ours additionally *persists*: a
measured (or analytic) route verdict is a stable property of
``(op, kind, m, k, n, block, density-bucket, dtype, mode)`` on a given
backend (the Sparsity Roofline observation), so it is written to a
versioned JSON file and reloaded by later processes -- a serving restart
re-plans with zero re-measurement.

Layout: one file per cache dir,

    <dir>/sparse-plans-v<SCHEMA_VERSION>.json
    {"env": {"schema": .., "backend": .., "jax": ..},
     "entries": {"<key>": {"route": .., "source": .., "est_seconds": ..}}}

A file whose ``env`` does not match the running process (schema bump,
different backend, different jax version) is *stale*: it is ignored on
read (counted in ``stale_drops``) and overwritten on the next store.

``cache_stats()`` exposes the counters the acceptance tests assert on:
``plans_built / plan_hits / decisions / measurements / disk_hits /
disk_misses / disk_writes / stale_drops``.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Dict, Optional

import jax

# v2: decision records grew a "capacity" section (planned grouped-tile
# bucket: tile/tiles_cap/headroom/...) and plan fingerprints grew the
# capacity knobs -- v1 files are ignored (different file name) so a
# pre-capacity cache can never be mis-read as a planned-capacity verdict
# v3: TP fingerprints grew the mesh identity (axis names + sizes +
# tp_balanced) and the route vocabulary grew "static_tp_shardmap" -- a
# v2 TP verdict was keyed on (q, axis) only, so it could answer for a
# different mesh topology; v2 files are invalidated wholesale
# v4: decision records grew a "grad" section (backward route verdicts:
# the dL/dx transposed-SpMM route + the dL/dvalues SDDMM route, each
# with source + est_seconds) and plan fingerprints grew the grad knobs
# (grad_mode / sddmm_mode) -- a v3 record carries no backward verdicts,
# so replaying one would silently re-race (or worse, skip) the backward
# decisions a restart is entitled to; v3 files are invalidated wholesale
# v5: decision records grew an "evolution" lineage section (parent/root
# keys, generation, observed drift vs the reference profile, re-race
# verdict) written by MatmulPlan.evolve -- an evolved pattern's record
# documents that its route verdicts were *inherited*, not raced, so the
# drift guardrail survives a restart; v4 files are invalidated wholesale
# v6: the route vocabulary grew the balanced-walk pair "static_balanced"
# / "dynamic_grouped_balanced" and plan fingerprints grew the pattern's
# bucketed skew (imbalance, cv) -- a v5 verdict was raced without the
# balanced candidates and keyed blind to skew, so it could answer a
# skewed pattern with the uniform walk; v5 files are invalidated
# wholesale
SCHEMA_VERSION = 6

_lock = threading.RLock()
_configured_dir: Optional[str] = None
# per-dir loaded entries: {dir: {key: record}}; None marks "load failed /
# stale" so we do not re-read the file every miss
_loaded: Dict[str, Optional[Dict[str, dict]]] = {}

_COUNTERS = ("plans_built", "plan_hits", "decisions", "measurements",
             "disk_hits", "disk_misses", "disk_writes", "stale_drops")
_stats: Dict[str, int] = {c: 0 for c in _COUNTERS}


def bump(counter: str, by: int = 1):
    with _lock:
        _stats[counter] += by


def cache_stats() -> dict:
    with _lock:
        return dict(_stats)


def configure(cache_dir: Optional[str] = None):
    """Set the process-default persistent cache directory (overrides
    $REPRO_CACHE_DIR; pass None to clear)."""
    global _configured_dir
    with _lock:
        _configured_dir = cache_dir
        _loaded.clear()


def configured_cache_dir() -> Optional[str]:
    return _configured_dir


def reset(*, counters: bool = True):
    """Forget all in-memory cache state (loaded files, counters).  Disk
    files are untouched -- this simulates a fresh process for tests."""
    with _lock:
        _loaded.clear()
        if counters:
            for c in _COUNTERS:
                _stats[c] = 0


def _env() -> dict:
    return {"schema": SCHEMA_VERSION,
            "backend": jax.default_backend(),
            "jax": jax.__version__}


def _path(cache_dir: str) -> str:
    return os.path.join(cache_dir, f"sparse-plans-v{SCHEMA_VERSION}.json")


def _load(cache_dir: str) -> Dict[str, dict]:
    with _lock:
        cached = _loaded.get(cache_dir, "missing")
        if cached != "missing":
            return cached or {}
        entries: Dict[str, dict] = {}
        try:
            with open(_path(cache_dir)) as f:
                blob = json.load(f)
            if blob.get("env") != _env():
                bump("stale_drops")
            else:
                entries = dict(blob.get("entries", {}))
        except FileNotFoundError:
            pass
        except (json.JSONDecodeError, OSError, TypeError, ValueError):
            bump("stale_drops")      # corrupt file == stale file
        _loaded[cache_dir] = entries
        return entries


def key_string(fingerprint: tuple) -> str:
    return "|".join(str(part) for part in fingerprint)


def load_decision(cache_dir: Optional[str],
                  key: str) -> Optional[dict]:
    """-> {"route", "source", "est_seconds"} or None.  Bumps
    disk_hits/disk_misses."""
    if not cache_dir:
        return None
    rec = _load(cache_dir).get(key)
    bump("disk_hits" if rec is not None else "disk_misses")
    return rec


def store_decision(cache_dir: Optional[str], key: str, record: dict):
    """Merge one verdict into the cache file (atomic replace)."""
    if not cache_dir:
        return
    with _lock:
        entries = dict(_load(cache_dir))
        if entries.get(key) == record:
            return
        entries[key] = record
        os.makedirs(cache_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"env": _env(), "entries": entries}, f, indent=1)
            os.replace(tmp, _path(cache_dir))
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return                     # persistence is best-effort
        _loaded[cache_dir] = entries
        bump("disk_writes")
