"""Problem + policy descriptions for the plan-first sparse API.

``OpSpec`` is the *logical problem*: everything the planner needs to
choose and price an execution strategy -- exactly the paper's
compile-time data (shape, block size, density, dtype) plus the operand
kind and the mode policy.  It is frozen and hashable: one OpSpec ==
one plan-cache fingerprint (modulo the concrete pattern, which static
plans additionally key on).

``PlanContext`` is the *planning policy*: the dispatch knobs
(measure / allow_pallas / interpret / differentiable) plus the
plan-first extras -- persistent cache location, mesh for TP-aware
routes, and the partition-budget the dynamic planner sizes buckets
with.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

import repro.core.dispatch as dispatch
import repro.core.planner as planner_lib
from repro.core.bsr import BlockSparseMatrix
from repro.core.dynamic_sparse import DynamicOperand

KINDS = ("dense", "static", "dynamic")
OPS = ("spmm", "matmul", "batched_matmul")

# sparse-level plannable routes = dispatch routes + the mesh-aware
# routes lifted from core/tp.py (dispatch cannot model them: they need
# the pattern artifacts and a mesh axis).  "static_tp" is the gspmd
# lowering; "static_tp_shardmap" the explicit shard_map + psum path --
# as a *mode*, "static_tp" races both TP lowerings (family semantics).
TP_ROUTES = ("static_tp", "static_tp_shardmap")
PLAN_ROUTES = dispatch.ROUTES + TP_ROUTES
PLAN_MODES = dispatch.MODES + TP_ROUTES

# backward (plan-level custom_vjp) route policies: dL/dx is an SpMM on
# the transposed pattern (dispatch route vocabulary), dL/dvalues is a
# block SDDMM (its own vocabulary, see dispatch.SDDMM_ROUTES)
GRAD_DX_MODES = ("auto",) + dispatch.ROUTES
GRAD_SDDMM_MODES = ("auto",) + dispatch.SDDMM_ROUTES


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Logical matmul problem for ``repro.sparse.plan``.

    kind        operand family: "dense" | "static" | "dynamic"
    m, k, n     ``[m, k] @ [k, n]`` logical sizes (for op="matmul" the
                canonical transposed view: m = out features, n = tokens;
                for op="batched_matmul" the per-slice problem)
    block_size  b (1 for dense)
    density     true block density (static) or d_max capacity (dynamic)
    dtype       operand dtype name (canonical jnp name)
    op          "spmm" (Y = W @ X) | "matmul" (x @ w, dense) |
                "batched_matmul" ([..., C, D] @ [..., D, F], dense)
    mode        dispatch mode: "auto", a family, a route id, or
                "static_tp"
    """

    kind: str
    m: int
    k: int
    n: int
    block_size: int = 1
    density: float = 1.0
    dtype: str = "float32"
    op: str = "spmm"
    mode: str = "auto"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown operand kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; expected one of "
                             f"{OPS}")
        if self.mode not in PLAN_MODES:
            raise ValueError(f"unknown plan mode {self.mode!r}; expected "
                             f"one of {PLAN_MODES}")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype).name)

    @classmethod
    def from_operand(cls, operand, n: int, *, op: str = "spmm",
                     mode: str = "auto") -> "OpSpec":
        """Describe ``operand @ [k, n]`` (normalizing BSR / DynamicOperand
        / dense arrays through the dispatch operand protocol)."""
        kind, m, k, b, density = dispatch._normalize(operand)
        dtype = dispatch._dtype_of(operand)
        return cls(kind=kind, m=m, k=k, n=int(n), block_size=b,
                   density=float(density), dtype=jnp.dtype(dtype).name,
                   op=op, mode=mode)

    def roofline_cost(self, route: str) -> dict:
        """Work cost dict for pricing ``route`` on this problem against
        the hardware roofline (``analysis.route_efficiency``).  Each
        route is bounded by the work *it* executes: dense-executing
        routes (``dense_*``, ``sddmm_dense``) pay the full product,
        sparse SpMM / SDDMM routes only the pattern's share -- so the
        headroom flag reads "this kernel is slow for what it does", not
        "a sparser algorithm exists".  A method, not persisted state:
        derived entirely from the spec fields, so it stays out of the
        plan fingerprint and the on-disk schema."""
        from repro.analysis import hlo_cost
        bytes_el = max(1, jnp.dtype(self.dtype).itemsize)
        d = 1.0 if (self.kind == "dense" or route.startswith("dense")
                    or route == "sddmm_dense") else self.density
        build = (hlo_cost.sddmm_cost_dict
                 if route in dispatch.SDDMM_ROUTES
                 else hlo_cost.spmm_cost_dict)
        return build(self.m, self.k, self.n, density=d, bytes_el=bytes_el)


def _default_cache_dir() -> Optional[str]:
    return os.environ.get("REPRO_CACHE_DIR") or None


# ---------------------------------------------------------------------------
# Capacity: planned bucket sizing + running overflow telemetry
# ---------------------------------------------------------------------------

CAPACITY_POLICIES = ("planned", "worst")

# the guardrail needs a frequency *estimate*, not a single sample: never
# escalate before this many observed calls (otherwise one unlucky
# overflow on call 1 reads as frequency 1.0 and permanently forfeits the
# planned-capacity win)
ESCALATION_MIN_CALLS = 4


class CapacityStats:
    """Running overflow telemetry for one planned-capacity problem.

    Mutable by design (the one deliberately stateful part of a frozen
    ``MatmulPlan``): every execution of a planned-capacity route records
    its *exact* pack overflow here -- the observable analogue of MoE's
    per-step ``dropped_frac``.  The stats outlive plan objects (they are
    registered per plan key), so the escalation guardrail survives a
    plan-cache eviction and ``serve.Engine.plan_report()`` can aggregate
    them across the engine's lifetime.
    """

    def __init__(self, key: str = "", *, tiles_cap: int = 0,
                 worst_tiles: int = 0, overflow_threshold: float = 0.0):
        self.key = key
        self.tiles_cap = tiles_cap
        self.worst_tiles = worst_tiles
        self.overflow_threshold = overflow_threshold
        self.calls = 0
        self.overflow_calls = 0
        self.tiles_dropped_total = 0
        self.blocks_dropped_total = 0
        self.dropped_frac_sum = 0.0
        self.max_dropped_frac = 0.0
        self.last_tiles_total = 0
        self.last_tiles_dropped = 0
        self.clamped = False          # requested cap was reduced to fit
        self.escalated = False        # guardrail tripped -> worst case
        self._lock = threading.Lock()
        self._on_escalate = None      # set by the plan layer

    def record(self, tiles_total, tiles_dropped, blocks_dropped,
               dropped_frac) -> None:
        """Fold one execution's exact pack accounting into the running
        stats; trips the escalation guardrail when the observed overflow
        frequency exceeds ``overflow_threshold``."""
        tiles_total = int(np.asarray(tiles_total).sum())
        tiles_dropped = int(np.asarray(tiles_dropped).sum())
        blocks_dropped = int(np.asarray(blocks_dropped).sum())
        dropped_frac = float(np.asarray(dropped_frac).max())
        trip = None
        with self._lock:
            self.calls += 1
            self.last_tiles_total = tiles_total
            self.last_tiles_dropped = tiles_dropped
            # a call overflowed if it dropped tiles OR value mass (the
            # latter covers fraction-only streams like MoE routing
            # drops, which have no tile notion)
            if tiles_dropped > 0 or dropped_frac > 0:
                self.overflow_calls += 1
            self.tiles_dropped_total += tiles_dropped
            self.blocks_dropped_total += blocks_dropped
            self.dropped_frac_sum += dropped_frac
            self.max_dropped_frac = max(self.max_dropped_frac,
                                        dropped_frac)
            if (not self.escalated
                    and self.overflow_threshold > 0.0
                    and self.calls >= ESCALATION_MIN_CALLS
                    and self.overflow_frequency > self.overflow_threshold):
                self.escalated = True
                trip = self._on_escalate
        if trip is not None:
            trip()

    @property
    def overflow_frequency(self) -> float:
        return self.overflow_calls / self.calls if self.calls else 0.0

    @property
    def mean_dropped_frac(self) -> float:
        return self.dropped_frac_sum / self.calls if self.calls else 0.0

    def report(self) -> dict:
        with self._lock:
            return {"tiles_cap": self.tiles_cap,
                    "worst_tiles": self.worst_tiles,
                    "calls": self.calls,
                    "overflow_calls": self.overflow_calls,
                    "overflow_frequency": round(self.overflow_frequency, 6),
                    "tiles_dropped_total": self.tiles_dropped_total,
                    "blocks_dropped_total": self.blocks_dropped_total,
                    "mean_dropped_frac": round(self.mean_dropped_frac, 6),
                    "max_dropped_frac": round(self.max_dropped_frac, 6),
                    "last_tiles_total": self.last_tiles_total,
                    "last_tiles_dropped": self.last_tiles_dropped,
                    "clamped": self.clamped,
                    "escalated": self.escalated,
                    "overflow_threshold": self.overflow_threshold}


@dataclasses.dataclass(frozen=True)
class PlanContext:
    """Planning policy for ``repro.sparse.plan``.

    The first six fields mirror ``dispatch.DispatchContext`` (same
    semantics).  Plan-first extras:

    persist     write/read decisions to the on-disk cache.  None (the
                default) enables persistence iff a cache dir is
                configured (``cache_dir`` here, ``sparse.configure``,
                or $REPRO_CACHE_DIR).
    cache_dir   directory for the persistent decision cache.
    mesh        a ``jax.sharding.Mesh``; when set (and the pattern is
                available) the nnz-balanced TP routes from ``core/tp.py``
                join the candidate set: ``static_tp`` (gspmd) always,
                ``static_tp_shardmap`` when the mesh is concrete with
                ``tp_axis`` sized to the shard count.  The mesh axis
                names + sizes are part of the plan fingerprint, so a
                verdict measured on one mesh never answers for another.
    tp_axis     mesh axis name the TP routes shard/reduce over.  A mesh
                whose axes do not include it is a configuration error
                and raises (never a silent unsharded fallback).
    tp_q        explicit shard count for the TP routes (defaults to the
                mesh axis size; lets tests force ``static_tp`` without a
                real multi-device mesh).
    tp_balanced nnz-balanced uneven k-splits (paper Fig. 1a, default)
                vs fixed even splits for the TP shard plan.
    units       parallel-unit budget for ``planner.plan_dynamic`` bucket
                sizing.

    Capacity policy (the ``dynamic_grouped`` planned-bucket knobs, paper
    §3.3 / Appendix A.2):

    headroom            multiplicative slack over the expected tile count
                        when sizing the grouped tile bucket.  None (the
                        default) uses ``planner.HEADROOM`` (1.25).
    capacity_policy     "planned" sizes the bucket at expected*headroom
                        (overflow possible, counted exactly); "worst"
                        keeps the pre-planned safe worst case (never
                        overflows -- the escalation target).
    overflow_threshold  observed overflow *frequency* above which the
                        guardrail escalates the plan to worst-case
                        capacity (evicts it from the plan cache so the
                        next ``plan()`` re-plans).  0 disables.
    telemetry           record per-call pack overflow into the plan's
                        ``CapacityStats`` (a host callback per call --
                        on by default; turn off for benchmark loops).

    Backward policy (the planned ``custom_vjp`` knobs -- used when
    ``differentiable`` is on and the plan has a concrete pattern):

    grad_mode   route policy for the dL/dx sibling product (an SpMM on
                the transposed pattern): "auto" races the dispatch
                candidates on the transposed problem; a route id forces
                it.  Part of the plan fingerprint.
    sddmm_mode  route policy for the dL/dvalues sibling product (block
                SDDMM): "auto" races ``dispatch.SDDMM_ROUTES``; a route
                id forces it.  Part of the plan fingerprint.

    Evolution policy (``MatmulPlan.evolve`` -- dynamic sparse training
    on static plans):

    evolve_drift  relative drift of the pattern *profile* (block density
                  and 128-tile packing occupancy, vs the profile the
                  route verdicts were raced on) above which ``evolve``
                  re-races the routes instead of reusing the verdicts.
                  RigL-style constant-nnz updates drift ~0 and keep the
                  cheap path; a pruning schedule that halves density
                  trips it.  0.0 re-races on any profile change; None
                  never auto-re-races.  A runtime-only knob (in-memory
                  plan-cache key, not the disk fingerprint); the value
                  and the observed drift are recorded in the decision
                  record's evolution lineage.

    Bucket-pool key (the serving engine's plan enumeration):

    pool          optional label grouping every plan built under this
                  context into a named *plan pool*
                  (``sparse.pool_plans(name)``).  The serving engine
                  tags each shape bucket's programs with its own pool so
                  the background re-planner and the stats endpoint can
                  enumerate exactly *their* plans instead of the
                  process-global cache.  Runtime-only: a label, not an
                  identity -- it joins neither the disk fingerprint nor
                  the in-memory plan-cache key, so pooled and unpooled
                  callers share one plan per problem.
    """

    mode: str = "auto"
    measure: bool = False
    allow_pallas: Optional[bool] = None
    interpret: bool = False
    differentiable: bool = True
    cache: bool = True
    persist: Optional[bool] = None
    cache_dir: Optional[str] = None
    mesh: Any = None
    tp_axis: str = "model"
    tp_q: Optional[int] = None
    tp_balanced: bool = True
    units: int = 16
    headroom: Optional[float] = None
    capacity_policy: str = "planned"
    overflow_threshold: float = 0.25
    telemetry: bool = True
    grad_mode: str = "auto"
    sddmm_mode: str = "auto"
    evolve_drift: Optional[float] = 0.25
    pool: Optional[str] = None

    def __post_init__(self):
        if self.evolve_drift is not None and self.evolve_drift < 0:
            raise ValueError(f"evolve_drift must be >= 0 or None, got "
                             f"{self.evolve_drift}")
        if self.mode not in PLAN_MODES:
            raise ValueError(f"unknown plan mode {self.mode!r}; expected "
                             f"one of {PLAN_MODES}")
        if self.grad_mode not in GRAD_DX_MODES:
            raise ValueError(f"unknown grad_mode {self.grad_mode!r}; "
                             f"expected one of {GRAD_DX_MODES}")
        if self.sddmm_mode not in GRAD_SDDMM_MODES:
            raise ValueError(f"unknown sddmm_mode {self.sddmm_mode!r}; "
                             f"expected one of {GRAD_SDDMM_MODES}")
        if self.capacity_policy not in CAPACITY_POLICIES:
            raise ValueError(
                f"unknown capacity_policy {self.capacity_policy!r}; "
                f"expected one of {CAPACITY_POLICIES}")
        if self.headroom is not None and self.headroom <= 0:
            raise ValueError(f"headroom must be positive, got "
                             f"{self.headroom}")

    def resolved_headroom(self) -> float:
        return float(self.headroom if self.headroom is not None
                     else planner_lib.HEADROOM)

    @classmethod
    def from_dispatch(cls, ctx: dispatch.DispatchContext) -> "PlanContext":
        return cls(mode=ctx.mode, measure=ctx.measure,
                   allow_pallas=ctx.allow_pallas, interpret=ctx.interpret,
                   differentiable=ctx.differentiable, cache=ctx.cache)

    def dispatch_ctx(self) -> dispatch.DispatchContext:
        # "static_tp" is a sparse-level route; the dispatch view of such
        # a plan prices the single-chip candidates under "auto"
        mode = self.mode if self.mode in dispatch.MODES else "auto"
        return dispatch.DispatchContext(
            mode=mode, measure=self.measure, allow_pallas=self.allow_pallas,
            interpret=self.interpret, differentiable=self.differentiable,
            cache=self.cache)

    def resolved_cache_dir(self) -> Optional[str]:
        from repro.sparse import cache as cache_lib
        return (self.cache_dir or cache_lib.configured_cache_dir()
                or _default_cache_dir())

    def persistence_on(self) -> bool:
        if self.persist is None:
            return self.resolved_cache_dir() is not None
        if self.persist and self.resolved_cache_dir() is None:
            raise ValueError(
                "PlanContext(persist=True) but no cache directory is "
                "configured; set PlanContext(cache_dir=...), call "
                "sparse.configure(cache_dir=...), or export "
                "REPRO_CACHE_DIR")
        return bool(self.persist)

    def resolved_tp_q(self) -> Optional[int]:
        if self.tp_q is not None:
            return int(self.tp_q)
        if self.mesh is not None:
            names = tuple(getattr(self.mesh, "axis_names", ()))
            if self.tp_axis not in names:
                # a mesh without the TP axis is a configuration error:
                # silently planning unsharded would hide the mistake
                # until a production profile showed no all-reduces
                raise ValueError(
                    f"PlanContext.mesh axes {names} do not include "
                    f"tp_axis {self.tp_axis!r}; pass "
                    f"PlanContext(tp_axis=...) naming the mesh axis to "
                    f"shard k over, or set tp_q explicitly to plan "
                    f"without a mesh")
            return int(self.mesh.shape[self.tp_axis])
        return None

    def mesh_fingerprint(self) -> tuple:
        """Mesh identity for the plan/disk fingerprint: axis names +
        sizes (device ids deliberately excluded -- a verdict holds for
        any same-shape mesh on this backend)."""
        if self.mesh is None:
            return ()
        names = tuple(str(n) for n in self.mesh.axis_names)
        return (names, tuple(int(self.mesh.shape[n]) for n in names))

    def shardmap_executable(self) -> bool:
        """Is the explicit shard_map TP lowering runnable here?"""
        from repro.core import tp as tp_lib
        q = self.resolved_tp_q()
        return bool(q) and tp_lib.shard_map_executable(
            self.mesh, self.tp_axis, q)


def pattern_key(operand) -> Optional[tuple]:
    """Hashable identity of a *static* pattern (None for runtime
    patterns / dense operands): plans bake the pattern in, so the plan
    cache must not collide two patterns that share a fingerprint."""
    if isinstance(operand, BlockSparseMatrix) and operand.is_static:
        return (np.asarray(operand.row_idx, np.int32).tobytes(),
                np.asarray(operand.col_idx, np.int32).tobytes())
    return None


def payload_of(operand):
    """The per-call payload a plan executes with: values for static
    patterns (the pattern itself is baked into the plan), the whole
    operand for runtime patterns, the array for dense."""
    if isinstance(operand, BlockSparseMatrix):
        if operand.is_static:
            return operand.values
        return DynamicOperand(
            jnp.asarray(operand.values),
            jnp.asarray(operand.row_idx, jnp.int32),
            jnp.asarray(operand.col_idx, jnp.int32),
            jnp.asarray(operand.nnz_blocks, jnp.int32),
            operand.shape, operand.block_size)
    return operand
