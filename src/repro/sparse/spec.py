"""Problem + policy descriptions for the plan-first sparse API.

``OpSpec`` is the *logical problem*: everything the planner needs to
choose and price an execution strategy -- exactly the paper's
compile-time data (shape, block size, density, dtype) plus the operand
kind and the mode policy.  It is frozen and hashable: one OpSpec ==
one plan-cache fingerprint (modulo the concrete pattern, which static
plans additionally key on).

``PlanContext`` is the *planning policy*: the dispatch knobs
(measure / allow_pallas / interpret / differentiable) plus the
plan-first extras -- persistent cache location, mesh for TP-aware
routes, and the partition-budget the dynamic planner sizes buckets
with.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

import repro.core.dispatch as dispatch
from repro.core.bsr import BlockSparseMatrix
from repro.core.dynamic_sparse import DynamicOperand

KINDS = ("dense", "static", "dynamic")
OPS = ("spmm", "matmul", "batched_matmul")

# sparse-level plannable routes = dispatch routes + the mesh-aware route
# lifted from core/tp.py (dispatch cannot model it: it needs the pattern
# artifacts and a mesh axis)
PLAN_ROUTES = dispatch.ROUTES + ("static_tp",)
PLAN_MODES = dispatch.MODES + ("static_tp",)


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Logical matmul problem for ``repro.sparse.plan``.

    kind        operand family: "dense" | "static" | "dynamic"
    m, k, n     ``[m, k] @ [k, n]`` logical sizes (for op="matmul" the
                canonical transposed view: m = out features, n = tokens;
                for op="batched_matmul" the per-slice problem)
    block_size  b (1 for dense)
    density     true block density (static) or d_max capacity (dynamic)
    dtype       operand dtype name (canonical jnp name)
    op          "spmm" (Y = W @ X) | "matmul" (x @ w, dense) |
                "batched_matmul" ([..., C, D] @ [..., D, F], dense)
    mode        dispatch mode: "auto", a family, a route id, or
                "static_tp"
    """

    kind: str
    m: int
    k: int
    n: int
    block_size: int = 1
    density: float = 1.0
    dtype: str = "float32"
    op: str = "spmm"
    mode: str = "auto"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown operand kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r}; expected one of "
                             f"{OPS}")
        if self.mode not in PLAN_MODES:
            raise ValueError(f"unknown plan mode {self.mode!r}; expected "
                             f"one of {PLAN_MODES}")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype).name)

    @classmethod
    def from_operand(cls, operand, n: int, *, op: str = "spmm",
                     mode: str = "auto") -> "OpSpec":
        """Describe ``operand @ [k, n]`` (normalizing BSR / DynamicOperand
        / dense arrays through the dispatch operand protocol)."""
        kind, m, k, b, density = dispatch._normalize(operand)
        dtype = dispatch._dtype_of(operand)
        return cls(kind=kind, m=m, k=k, n=int(n), block_size=b,
                   density=float(density), dtype=jnp.dtype(dtype).name,
                   op=op, mode=mode)


def _default_cache_dir() -> Optional[str]:
    return os.environ.get("REPRO_CACHE_DIR") or None


@dataclasses.dataclass(frozen=True)
class PlanContext:
    """Planning policy for ``repro.sparse.plan``.

    The first six fields mirror ``dispatch.DispatchContext`` (same
    semantics).  Plan-first extras:

    persist     write/read decisions to the on-disk cache.  None (the
                default) enables persistence iff a cache dir is
                configured (``cache_dir`` here, ``sparse.configure``,
                or $REPRO_CACHE_DIR).
    cache_dir   directory for the persistent decision cache.
    mesh        a ``jax.sharding.Mesh``; when set (and the pattern is
                available) the nnz-balanced TP route from ``core/tp.py``
                joins the candidate set.
    tp_axis     mesh axis name the TP route shards/reduces over.
    tp_q        explicit shard count for the TP route (defaults to the
                mesh axis size; lets tests force ``static_tp`` without a
                real multi-device mesh).
    units       parallel-unit budget for ``planner.plan_dynamic`` bucket
                sizing.
    """

    mode: str = "auto"
    measure: bool = False
    allow_pallas: Optional[bool] = None
    interpret: bool = False
    differentiable: bool = True
    cache: bool = True
    persist: Optional[bool] = None
    cache_dir: Optional[str] = None
    mesh: Any = None
    tp_axis: str = "model"
    tp_q: Optional[int] = None
    units: int = 16

    def __post_init__(self):
        if self.mode not in PLAN_MODES:
            raise ValueError(f"unknown plan mode {self.mode!r}; expected "
                             f"one of {PLAN_MODES}")

    @classmethod
    def from_dispatch(cls, ctx: dispatch.DispatchContext) -> "PlanContext":
        return cls(mode=ctx.mode, measure=ctx.measure,
                   allow_pallas=ctx.allow_pallas, interpret=ctx.interpret,
                   differentiable=ctx.differentiable, cache=ctx.cache)

    def dispatch_ctx(self) -> dispatch.DispatchContext:
        # "static_tp" is a sparse-level route; the dispatch view of such
        # a plan prices the single-chip candidates under "auto"
        mode = self.mode if self.mode in dispatch.MODES else "auto"
        return dispatch.DispatchContext(
            mode=mode, measure=self.measure, allow_pallas=self.allow_pallas,
            interpret=self.interpret, differentiable=self.differentiable,
            cache=self.cache)

    def resolved_cache_dir(self) -> Optional[str]:
        from repro.sparse import cache as cache_lib
        return (self.cache_dir or cache_lib.configured_cache_dir()
                or _default_cache_dir())

    def persistence_on(self) -> bool:
        if self.persist is None:
            return self.resolved_cache_dir() is not None
        if self.persist and self.resolved_cache_dir() is None:
            raise ValueError(
                "PlanContext(persist=True) but no cache directory is "
                "configured; set PlanContext(cache_dir=...), call "
                "sparse.configure(cache_dir=...), or export "
                "REPRO_CACHE_DIR")
        return bool(self.persist)

    def resolved_tp_q(self) -> Optional[int]:
        if self.tp_q is not None:
            return int(self.tp_q)
        if self.mesh is not None and self.tp_axis in getattr(
                self.mesh, "axis_names", ()):
            return int(self.mesh.shape[self.tp_axis])
        return None


def pattern_key(operand) -> Optional[tuple]:
    """Hashable identity of a *static* pattern (None for runtime
    patterns / dense operands): plans bake the pattern in, so the plan
    cache must not collide two patterns that share a fingerprint."""
    if isinstance(operand, BlockSparseMatrix) and operand.is_static:
        return (np.asarray(operand.row_idx, np.int32).tobytes(),
                np.asarray(operand.col_idx, np.int32).tobytes())
    return None


def payload_of(operand):
    """The per-call payload a plan executes with: values for static
    patterns (the pattern itself is baked into the plan), the whole
    operand for runtime patterns, the array for dense."""
    if isinstance(operand, BlockSparseMatrix):
        if operand.is_static:
            return operand.values
        return DynamicOperand(
            jnp.asarray(operand.values),
            jnp.asarray(operand.row_idx, jnp.int32),
            jnp.asarray(operand.col_idx, jnp.int32),
            jnp.asarray(operand.nnz_blocks, jnp.int32),
            operand.shape, operand.block_size)
    return operand
