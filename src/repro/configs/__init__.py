"""Assigned-architecture registry: ``get(name)`` full config,
``smoke(name)`` reduced same-family config, ``input_specs(name, shape)``
ShapeDtypeStruct stand-ins for every entry-point input.

Shape cells (assigned to every arch):

    train_4k      seq 4,096   global_batch 256   -> train_step
    prefill_32k   seq 32,768  global_batch 32    -> prefill
    decode_32k    seq 32,768  global_batch 128   -> serve_step (1 token)
    long_500k     seq 524,288 global_batch 1     -> serve_step (1 token)

``long_500k`` policy per DESIGN.md §Arch-applicability: SSM/hybrid archs
run natively; pure full-attention archs are *natively skipped* but run
here via the paper's static block sparsity (retained local+global KV
cache), recorded as a beyond-paper application.
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelCfg
from repro.models.model import LM

ARCH_IDS = [
    "deepseek_v2_lite_16b",
    "qwen3_moe_30b_a3b",
    "internvl2_1b",
    "glm4_9b",
    "qwen2_1_5b",
    "gemma2_2b",
    "llama3_2_1b",
    "jamba_v0_1_52b",
    "mamba2_130m",
    "seamless_m4t_medium",
]

# canonical external ids (brief spelling) -> module name
ALIASES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "internvl2-1b": "internvl2_1b",
    "glm4-9b": "glm4_9b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma2-2b": "gemma2_2b",
    "llama3.2-1b": "llama3_2_1b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str) -> ModelCfg:
    return _module(name).make_config()


def smoke(name: str) -> ModelCfg:
    return _module(name).make_smoke_config()


def is_native_long(cfg: ModelCfg) -> bool:
    """True when the arch handles 500k context natively (SSM state or
    hybrid with O(1)/windowed layers) -- no retained-cache approximation."""
    return cfg.family in ("ssm", "hybrid")


def input_specs(name: str, shape: str, *, cfg: ModelCfg | None = None):
    """ShapeDtypeStruct stand-ins for one (arch, shape) cell.

    Returns (kind, kwargs) where kwargs feed the corresponding launch
    entry point (train_step / prefill / serve_step).  No allocation.
    """
    cfg = cfg or get(name)
    sh = SHAPES[shape]
    b_, s = sh["batch"], sh["seq"]
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    lm = LM(cfg)

    extras = {}
    if cfg.frontend == "vision":
        extras["frontend"] = sds((b_, cfg.frontend_len, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.encoder_layers:
        extras["enc_frames"] = sds((b_, cfg.frontend_len, cfg.d_model),
                                   jnp.bfloat16)

    if sh["kind"] == "train":
        batch = {"tokens": sds((b_, s), i32), "targets": sds((b_, s), i32),
                 **extras}
        return "train", {"batch": batch}

    if sh["kind"] == "prefill":
        return "prefill", {"tokens": sds((b_, s), i32), **extras}

    # decode: one token against a cache of length s
    long = sh.get("long", False)
    retained = long and not is_native_long(cfg)
    if retained:
        max_len = cfg.retained_prefix + cfg.retained_window
    else:
        max_len = s + (cfg.frontend_len if cfg.frontend == "vision" else 0)
    memory_len = cfg.frontend_len if cfg.encoder_layers else 0
    caches = jax.eval_shape(
        lambda: lm.init_cache(b_, max_len, memory_len=memory_len))
    return "decode", {
        "tokens": sds((b_, 1), i32),
        "positions": sds((b_,), i32),
        "caches": caches,
        "retained": retained,
    }


def param_specs(name: str, *, cfg: ModelCfg | None = None):
    """ShapeDtypeStructs of the parameter pytree (no allocation)."""
    cfg = cfg or get(name)
    lm = LM(cfg)
    return jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
