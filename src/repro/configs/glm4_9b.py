"""glm4-9b [dense] -- RoPE + GQA (hf:THUDM/glm-4-9b).

40L d_model=4096 32H (GQA kv=2, head_dim=128) d_ff=13696 vocab=151552.
GLM4's partial-rotary (0.5) is approximated with full rotary; recorded
in DESIGN.md hardware/assumption notes.
"""
from repro.models.config import LayerSpec, ModelCfg


def make_config(**over) -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    kw = dict(
        name="glm4-9b",
        family="dense",
        d_model=4096,
        vocab_size=151552,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        groups=(((spec,), 40),),
        qkv_bias=True,
        rope_theta=10000.0,
        tie_embeddings=False,
        act="silu",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256,
        groups=(((spec,), 2),),
        attn_tile_q=64, attn_tile_kv=64,
    )
