"""gemma2-2b [dense] -- local+global alternating attention, logit
soft-capping, pre+post RMSNorm (arXiv:2408.00118).

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
Local layers use a 4096 sliding window -- which is *exactly* a banded
static block mask in the paper's terms (DESIGN.md §3).
"""
import numpy as np

from repro.models.config import LayerSpec, ModelCfg


def make_config(**over) -> ModelCfg:
    local = LayerSpec(mixer="attn_local", ffn="mlp")
    glob = LayerSpec(mixer="attn", ffn="mlp")
    kw = dict(
        name="gemma2-2b",
        family="dense",
        d_model=2304,
        vocab_size=256000,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        groups=(((local, glob), 13),),
        attn_softcap=50.0,
        final_softcap=30.0,
        attn_scale=1.0 / np.sqrt(256.0),
        local_window=4096,
        post_norm=True,
        embed_scale=True,
        rope_theta=10000.0,
        tie_embeddings=True,
        act="gelu",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    local = LayerSpec(mixer="attn_local", ffn="mlp")
    glob = LayerSpec(mixer="attn", ffn="mlp")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256,
        groups=(((local, glob), 1),),
        local_window=64, attn_scale=1.0 / np.sqrt(32.0),
        attn_tile_q=64, attn_tile_kv=64,
    )
