"""llama3.2-1b [dense] -- small Llama-3 (hf:meta-llama/Llama-3.2-1B).

16L d_model=2048 32H (GQA kv=8, head_dim=64) d_ff=8192 vocab=128256.
"""
from repro.models.config import LayerSpec, ModelCfg


def make_config(**over) -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    kw = dict(
        name="llama3.2-1b",
        family="dense",
        d_model=2048,
        vocab_size=128256,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        groups=(((spec,), 16),),
        rope_theta=500000.0,
        tie_embeddings=True,
        act="silu",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256,
        groups=(((spec,), 2),),
        attn_tile_q=64, attn_tile_kv=64,
    )
