"""mamba2-130m [ssm] -- SSD / state-space duality (arXiv:2405.21060).

24L d_model=768, attention-free, no FFN (d_ff=0), ssm_state=128,
vocab=50280.  d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads.

The paper's block-sparse matmul technique is inapplicable to the SSD
scan itself (DESIGN.md §Arch-applicability); the arch runs without it.
"""
from repro.models.config import LayerSpec, ModelCfg, SSMCfg


def make_config(**over) -> ModelCfg:
    spec = LayerSpec(mixer="mamba", ffn="none")
    kw = dict(
        name="mamba2-130m",
        family="ssm",
        d_model=768,
        vocab_size=50280,
        d_ff=0,
        groups=(((spec,), 24),),
        ssm=SSMCfg(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        tie_embeddings=True,
        act="silu",
        norm_eps=1e-5,
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    spec = LayerSpec(mixer="mamba", ffn="none")
    return make_config(
        d_model=128, vocab_size=512,
        groups=(((spec,), 2),),
        ssm=SSMCfg(d_state=32, d_conv=4, expand=2, head_dim=32, chunk=32),
    )
