"""deepseek-v2-lite-16b [moe] -- MLA + DeepSeekMoE (arXiv:2405.04434; hf).

27L d_model=2048 16H d_ff(dense L0)=10944 vocab=102400; MLA kv_lora=512
(no q_lora in Lite), qk_nope=128 qk_rope=64 v=128; MoE: 64 routed top-6 +
2 shared experts, expert d_ff=1408, first layer dense.

NOTE: the assignment line says both "MoE 64e top-6" and "160 routed";
the HF config (DeepSeek-V2-Lite) has 64 routed experts -- we follow the
HF-verified value and record the discrepancy in DESIGN.md.
"""
from repro.models.config import LayerSpec, ModelCfg, MoECfg


def make_config(**over) -> ModelCfg:
    dense = LayerSpec(mixer="mla", ffn="mlp")
    moe = LayerSpec(mixer="mla", ffn="moe")
    kw = dict(
        name="deepseek-v2-lite-16b",
        family="moe",
        d_model=2048,
        vocab_size=102400,
        num_heads=16,
        num_kv_heads=16,
        head_dim=192,            # qk_nope + qk_rope (bookkeeping only)
        d_ff=10944,              # first (dense) layer
        groups=(((dense,), 1), ((moe,), 26)),
        attn_impl="mla",
        q_lora_rank=None,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408,
                   num_shared=2, d_ff_shared=1408, norm_topk_prob=False),
        rope_theta=10000.0,
        tie_embeddings=False,
        act="silu",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    dense = LayerSpec(mixer="mla", ffn="mlp")
    moe = LayerSpec(mixer="mla", ffn="moe")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=4,
        head_dim=48, d_ff=256,
        groups=(((dense,), 1), ((moe,), 2)),
        kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
        moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=64,
                   num_shared=1, d_ff_shared=64, norm_topk_prob=False),
        attn_tile_q=64, attn_tile_kv=64,
    )
