"""jamba-v0.1-52b [hybrid] -- Mamba+attention 1:7 interleave with MoE
every second layer (arXiv:2403.19887).

32L d_model=4096; attention layers 32H (GQA kv=8, head_dim=128);
d_ff=14336; MoE 16 experts top-2; vocab=65536.  Period of 8 layers:
attention at offset 4 (attn_layer_period=8), MoE at odd offsets
(expert_layer_period=2, offset 1).  No positional encoding (the Mamba
layers carry position).

Adaptation note (DESIGN.md): Jamba v0.1 uses Mamba-1 (d_state=16,
per-channel B/C); we implement the SSD (Mamba-2) formulation at the same
d_state -- the state-space math is equivalent up to the scalar-A
restriction, and SSD is the TPU-native (MXU-friendly) form.
"""
from repro.models.config import LayerSpec, ModelCfg, MoECfg, SSMCfg


def _period():
    m_mlp = LayerSpec(mixer="mamba", ffn="mlp")
    m_moe = LayerSpec(mixer="mamba", ffn="moe")
    a_mlp = LayerSpec(mixer="attn", ffn="mlp")
    return (m_mlp, m_moe, m_mlp, m_moe, a_mlp, m_moe, m_mlp, m_moe)


def make_config(**over) -> ModelCfg:
    kw = dict(
        name="jamba-v0.1-52b",
        family="hybrid",
        d_model=4096,
        vocab_size=65536,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        groups=((_period(), 4),),
        use_rope=False,
        moe=MoECfg(num_experts=16, top_k=2, d_ff_expert=14336,
                   norm_topk_prob=True),
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
        tie_embeddings=False,
        act="silu",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    m_mlp = LayerSpec(mixer="mamba", ffn="mlp")
    m_moe = LayerSpec(mixer="mamba", ffn="moe")
    a_mlp = LayerSpec(mixer="attn", ffn="mlp")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256,
        groups=(((m_mlp, m_moe, a_mlp, m_moe), 1),),
        moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=64,
                   norm_topk_prob=True),
        ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        attn_tile_q=64, attn_tile_kv=64,
    )
