"""qwen2-1.5b [dense] -- GQA with QKV bias (arXiv:2407.10671).

28L d_model=1536 12H (GQA kv=2, head_dim=128) d_ff=8960 vocab=151936.
"""
from repro.models.config import LayerSpec, ModelCfg


def make_config(**over) -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    kw = dict(
        name="qwen2-1.5b",
        family="dense",
        d_model=1536,
        vocab_size=151936,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        groups=(((spec,), 28),),
        qkv_bias=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
        act="silu",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256,
        groups=(((spec,), 2),),
        attn_tile_q=64, attn_tile_kv=64,
    )
