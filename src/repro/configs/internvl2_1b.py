"""internvl2-1b [vlm] -- InternViT frontend (stub) + Qwen2-0.5B LM
backbone (arXiv:2404.16821; hf).

24L d_model=896 14H (GQA kv=2, head_dim=64) d_ff=4864 vocab=151655.
The vision frontend is a STUB per the brief: ``input_specs`` supplies
precomputed patch embeddings [B, 256, d_model] prepended to the tokens.
"""
from repro.models.config import LayerSpec, ModelCfg


def make_config(**over) -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    kw = dict(
        name="internvl2-1b",
        family="vlm",
        d_model=896,
        vocab_size=151655,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        groups=(((spec,), 24),),
        qkv_bias=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
        act="silu",
        frontend="vision",
        frontend_len=256,        # precomputed ViT patch embeddings
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="mlp")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256,
        groups=(((spec,), 2),),
        frontend_len=8,
        attn_tile_q=64, attn_tile_kv=64,
    )
