"""qwen3-moe-30b-a3b [moe] -- 128 experts top-8 (hf:Qwen/Qwen3-30B-A3B).

48L d_model=2048 32H (GQA kv=4, head_dim=128) expert d_ff=768
vocab=151936; QK-norm (no QKV bias), norm_topk_prob, no shared experts.
"""
from repro.models.config import LayerSpec, ModelCfg, MoECfg


def make_config(**over) -> ModelCfg:
    moe = LayerSpec(mixer="attn", ffn="moe")
    kw = dict(
        name="qwen3-moe-30b-a3b",
        family="moe",
        d_model=2048,
        vocab_size=151936,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        groups=(((moe,), 48),),
        qk_norm=True,
        moe=MoECfg(num_experts=128, top_k=8, d_ff_expert=768,
                   norm_topk_prob=True),
        rope_theta=1000000.0,
        tie_embeddings=False,
        act="silu",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    moe = LayerSpec(mixer="attn", ffn="moe")
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=64,
        groups=(((moe,), 2),),
        moe=MoECfg(num_experts=8, top_k=2, d_ff_expert=64,
                   norm_topk_prob=True),
        attn_tile_q=64, attn_tile_kv=64,
    )
