"""seamless-m4t-medium [audio] -- encoder-decoder, multimodal
(arXiv:2308.11596).

12L encoder + 12L decoder, d_model=1024, 16H (MHA, kv=16, head_dim=64),
d_ff=4096, vocab=256206.  The speech frontend is a STUB per the brief:
``input_specs`` supplies precomputed frame embeddings [B, T, d_model]
that feed the (bidirectional) encoder; decoder layers cross-attend over
the encoder memory.  RoPE stands in for the original learned positions
(recorded in DESIGN.md assumption notes).
"""
from repro.models.config import LayerSpec, ModelCfg


def make_config(**over) -> ModelCfg:
    dec = LayerSpec(mixer="attn", ffn="mlp", cross=True)
    kw = dict(
        name="seamless-m4t-medium",
        family="audio",
        d_model=1024,
        vocab_size=256206,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        groups=(((dec,), 12),),
        encoder_layers=12,
        frontend="audio",
        frontend_len=1024,       # precomputed speech frames
        tie_embeddings=True,
        act="gelu_plain",
    )
    kw.update(over)
    return ModelCfg(**kw)


def make_smoke_config() -> ModelCfg:
    dec = LayerSpec(mixer="attn", ffn="mlp", cross=True)
    return make_config(
        d_model=128, vocab_size=512, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256,
        groups=(((dec,), 2),),
        encoder_layers=2,
        frontend_len=16,
        attn_tile_q=64, attn_tile_kv=64,
    )
