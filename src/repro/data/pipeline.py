"""Deterministic sharded token pipeline.

Production contract (what matters at pod scale):

* **determinism**: batch content is a pure function of (seed, step,
  shard) -- restarts reproduce the exact token stream;
* **sharding**: each data-parallel shard / host reads only its slice;
* **checkpointable cursor**: the pipeline state is just ``step``; the
  trainer stores it in the checkpoint and resumes exactly;
* **elasticity**: because content is derived per (step, global example
  index), changing the number of shards re-partitions the same stream.

The corpus here is synthetic (structured pseudo-text: a Markov-ish
integer process so the model has something learnable, unlike uniform
noise) -- real deployments swap ``_example`` for a tokenized dataset
reader with the same (seed, index) contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    batch_per_shard: int
    seq_len: int
    num_shards: int = 1
    shard_id: int = 0
    seed: int = 0

    def _example(self, index: int) -> np.ndarray:
        """Deterministic pseudo-text: token_{t+1} depends on token_t."""
        rng = np.random.default_rng((self.seed, index))
        v = self.vocab_size
        base = rng.integers(0, v, size=self.seq_len + 1, dtype=np.int64)
        # second-order structure: with p=0.7 the next token is a fixed
        # affine function of the previous one (learnable signal)
        follow = rng.random(self.seq_len + 1) < 0.7
        out = base.copy()
        for t in range(1, self.seq_len + 1):
            if follow[t]:
                out[t] = (out[t - 1] * 31 + 7) % v
        return out

    def get_batch(self, step: int) -> dict:
        """Returns {"tokens": [B, S], "targets": [B, S]} for this shard."""
        gb = self.batch_per_shard * self.num_shards
        idx0 = step * gb + self.shard_id * self.batch_per_shard
        ex = np.stack([self._example(idx0 + i)
                       for i in range(self.batch_per_shard)])
        return {"tokens": ex[:, :-1].astype(np.int32),
                "targets": ex[:, 1:].astype(np.int32)}

    # -- checkpoint contract -------------------------------------------------
    def state(self, step: int) -> dict:
        return {"step": step, "seed": self.seed,
                "num_shards": self.num_shards}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])


def make_lm_batch(key, vocab: int, batch: int, seq: int):
    """Quick random batch for tests/examples (jax-side)."""
    import jax
    tokens = jax.random.randint(key, (batch, seq + 1), 0, vocab)
    return {"tokens": tokens[:, :-1].astype("int32"),
            "targets": tokens[:, 1:].astype("int32")}
