"""Attention mixers: GQA (RoPE, QK-norm, soft-cap, local windows) and MLA.

Three execution regimes, matching the assigned shape cells:

* ``attend_train``   -- full-sequence training/prefill.  Chunked online-
  softmax attention driven by a **static block visit list** -- the paper's
  static block sparsity applied to the attention score matrix.  Causal,
  local-window and local+global masks all reduce to a host block mask
  (``core/masks.py``); the XLA path scans the non-empty (q_tile, kv_tile)
  pairs, the TPU path hands the same pairs to ``kernels/bs_attn``.
* ``attend_decode``  -- one new token against a KV cache (decode_32k).
* retained-block decode for ``long_500k``: the cache keeps only the
  local-window + global-prefix blocks (static pattern ⇒ fixed cache
  shape), making decode O(window) instead of O(S) -- the paper's static
  sparsity is what makes the 500k cell feasible (DESIGN.md §3).

Scheduling note (see EXPERIMENTS.md §Perf): the baseline visit list for a
causal mask walks row-by-row, which makes the scan length the *max* row
population; ``schedule="balanced"`` pairs row i with row nq-1-i so every
scan step does uniform useful work -- ~2x fewer HLO FLOPs at equal output.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as masks_lib
from repro.models.layers import apply_rope, dense, dense_init, rms_norm
from repro.sharding.rules import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Static block-mask schedule: the PopSparse partitioner idea applied to the
# (q_tile, kv_tile) score grid.
# ---------------------------------------------------------------------------

class AttnSchedule(NamedTuple):
    """Static visit plan over score tiles, padded to a rectangular scan.

    ``cols[i, j]`` is the j-th kv tile visited by q tile i; ``valid`` masks
    padding.  Built on host at trace time -- compile-time metadata exactly
    like ``bsmm`` tile lists.
    """

    cols: np.ndarray    # [nq, width] int32
    valid: np.ndarray   # [nq, width] bool
    rows: np.ndarray    # [nq] int32 -- q tile processed at scan step i

    @property
    def width(self) -> int:
        return int(self.cols.shape[1])

    @property
    def waste(self) -> float:
        tot = self.valid.size
        return 1.0 - float(self.valid.sum()) / tot if tot else 0.0


def build_schedule(block_mask: np.ndarray, *, balanced: bool = False
                   ) -> AttnSchedule:
    """Turn a host block mask into a rectangular scan schedule.

    ``balanced=True`` reorders rows so row i is interleaved with row
    nq-1-i (folded causal pairing): for a lower-triangular mask the
    per-step tile count becomes ~uniform, cutting padded (wasted) visits
    from ~50% to ~0 -- a beyond-paper schedule optimization recorded in
    §Perf.
    """
    mask = np.asarray(block_mask, bool)
    nq = mask.shape[0]
    if not mask.any(axis=1).all():
        raise ValueError("every q tile needs >=1 visible kv tile")
    row_cols = [np.flatnonzero(mask[i]) for i in range(nq)]
    order = np.arange(nq)
    if balanced:
        # fold: 0, nq-1, 1, nq-2, ... then chunk back into rows of pairs;
        # a simple interleave keeps per-adjacent-pair work ~constant.
        half = (nq + 1) // 2
        folded = np.empty(nq, np.int64)
        folded[0::2] = np.arange(half)
        folded[1::2] = nq - 1 - np.arange(nq - half)
        order = folded
    width = max(len(row_cols[i]) for i in range(nq))
    if balanced and nq > 1:
        # width of the max *pair* is what matters once rows alternate;
        # rectangular pad still needed per row, but adjacent rows now
        # average out so total padding is near zero for causal masks.
        pass
    cols = np.zeros((nq, width), np.int32)
    valid = np.zeros((nq, width), bool)
    for i, r in enumerate(order):
        c = row_cols[r]
        cols[i, :len(c)] = c
        # park padding lanes on the row's first visible tile (in-mask, so
        # masking only needs the `valid` bit, never an OOB index)
        cols[i, len(c):] = c[0] if len(c) else 0
        valid[i, :len(c)] = True
    return AttnSchedule(cols, valid, order.astype(np.int32))


@functools.lru_cache(maxsize=None)
def _causal_schedule(nq: int, nkv: int, window_tiles: int, global_tiles: int,
                     tile_q: int, tile_kv: int, balanced: bool,
                     causal: bool = True) -> AttnSchedule:
    if not causal:
        mask = np.ones((nq, nkv), bool)
    elif window_tiles > 0:
        mask = masks_lib.local_global_attention_mask(
            nq, nkv, window_blocks=window_tiles, global_blocks=global_tiles,
            causal=True)
    else:
        i = np.arange(nq)[:, None]
        j = np.arange(nkv)[None, :]
        # q tile i covers rows [i*tq, (i+1)*tq); visible iff any (r,c) with
        # c <= r + (nkv*tkv - nq*tq) offset; for self-attention S_q == S_kv
        mask = (j * tile_kv) <= ((i + 1) * tile_q - 1)
    return build_schedule(mask, balanced=balanced)


class PairSchedule(NamedTuple):
    """Folded-causal schedule: step i processes q tiles (i, nq-1-i) with a
    fused lane list of uniform length nq+1 -- every lane does useful work,
    so the scan executes ~nq^2/2 tile visits instead of the rectangular
    row schedule's nq^2 (the causal triangle at zero padding waste)."""

    rows: np.ndarray    # [nsteps, 2]
    cols: np.ndarray    # [nsteps, W2]
    tag: np.ndarray     # [nsteps, W2] which of the two rows a lane feeds
    valid: np.ndarray   # [nsteps, W2]

    @property
    def width(self) -> int:
        return int(self.cols.shape[1])

    @property
    def waste(self) -> float:
        return 1.0 - float(self.valid.sum()) / self.valid.size


@functools.lru_cache(maxsize=None)
def build_pair_schedule(nq: int) -> PairSchedule:
    nsteps = (nq + 1) // 2
    w2 = nq + 1
    rows = np.zeros((nsteps, 2), np.int32)
    cols = np.zeros((nsteps, w2), np.int32)
    tag = np.zeros((nsteps, w2), np.int32)
    valid = np.zeros((nsteps, w2), bool)
    for i in range(nsteps):
        a, b = i, nq - 1 - i
        rows[i] = (a, b)
        la = a + 1
        cols[i, :la] = np.arange(la)
        tag[i, :la] = 0
        valid[i, :la] = True
        if b != a:
            lb = b + 1
            cols[i, la:la + lb] = np.arange(lb)
            tag[i, la:la + lb] = 1
            valid[i, la:la + lb] = True
    return PairSchedule(rows, cols, tag, valid)


def _attend_balanced_causal(q, k, v, *, scale, softcap, tile_q, tile_kv
                            ) -> jax.Array:
    """Causal full attention via the folded pair schedule (see
    EXPERIMENTS.md §Perf: ~2x fewer score-tile visits than the row
    schedule at identical output)."""
    b_, s, h, dh = q.shape
    nq = s // tile_q
    sched = build_pair_schedule(nq)
    qt = q.reshape(b_, nq, tile_q, h, dh).transpose(1, 0, 3, 2, 4)
    kt = k.reshape(b_, nq, tile_kv, h, dh).transpose(1, 0, 3, 2, 4)
    vt = v.reshape(b_, nq, tile_kv, h, dh).transpose(1, 0, 3, 2, 4)
    qt = constrain(qt, None, "batch", "model", None, None)
    kt = constrain(kt, None, "batch", "model", None, None)
    vt = constrain(vt, None, "batch", "model", None, None)
    rows = jnp.asarray(sched.rows)
    cols = jnp.asarray(sched.cols)
    tags = jnp.asarray(sched.tag)
    valid = jnp.asarray(sched.valid)

    def q_step(_, idx):
        qa = qt[rows[idx, 0]]
        qb = qt[rows[idx, 1]]

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def lane(carry, j):
            m, l, acc = carry                   # leading dim 2 (pair slot)
            c = cols[idx, j]
            t = tags[idx, j]
            ok = valid[idx, j]
            qsel = jnp.where(t == 0, qa, qb)
            kj, vj = kt[c], vt[c]
            logits = jnp.einsum("bhqd,bhkd->bhqk", qsel, kj,
                                preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                logits = softcap * jnp.tanh(logits / softcap)
            r0 = jnp.where(t == 0, rows[idx, 0], rows[idx, 1]) * tile_q
            ri = r0 + jax.lax.broadcasted_iota(jnp.int32,
                                               (tile_q, tile_kv), 0)
            ci = c * tile_kv + jax.lax.broadcasted_iota(
                jnp.int32, (tile_q, tile_kv), 1)
            emask = (ri >= ci) & ok
            logits = jnp.where(emask[None, None], logits, NEG_INF)
            m_t, l_t, acc_t = m[t], l[t], acc[t]
            m_new = jnp.maximum(m_t, logits.max(axis=-1))
            alpha = jnp.exp(m_t - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_t * alpha + p.sum(axis=-1)
            acc_new = acc_t * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m.at[t].set(m_new), l.at[t].set(l_new),
                    acc.at[t].set(acc_new)), None

        init = (jnp.full((2, b_, h, tile_q), NEG_INF, jnp.float32),
                jnp.zeros((2, b_, h, tile_q), jnp.float32),
                jnp.zeros((2, b_, h, tile_q, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(lane, init, jnp.arange(sched.width))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # [2, B, H, tq, dh]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(sched.rows.shape[0]))
    outs = outs.reshape(-1, b_, h, tile_q, dh)      # [2*nsteps, ...]
    # static inverse permutation: row r was emitted at flat slot inv[r]
    flat_rows = sched.rows.reshape(-1)
    inv = np.zeros(nq, np.int64)
    inv[flat_rows] = np.arange(flat_rows.shape[0])
    outs = outs[jnp.asarray(inv)]
    return outs.transpose(1, 0, 3, 2, 4).reshape(b_, s, h, dh)


# ---------------------------------------------------------------------------
# Core chunked attention (XLA path): scan q tiles, inner scan over the
# schedule's visit lanes with online softmax.
# ---------------------------------------------------------------------------

def _attend_scheduled(q, k, v, sched: AttnSchedule, *, scale: float,
                      causal: bool, window: int, softcap: Optional[float],
                      tile_q: int, tile_kv: int,
                      global_prefix: int = 0) -> jax.Array:
    """q: [B, S, H, dh]; k, v: [B, Skv, KV, dh] already head-repeated to H.

    Returns [B, S, H, dh].  fp32 softmax statistics, bf16 matmul inputs.
    """
    b_, s, h, dh = q.shape
    skv = k.shape[1]
    nq = s // tile_q
    qt = q.reshape(b_, nq, tile_q, h, dh).transpose(1, 0, 3, 2, 4)
    kt = k.reshape(b_, skv // tile_kv, tile_kv, h, dh).transpose(1, 0, 3, 2, 4)
    vt = v.reshape(b_, skv // tile_kv, tile_kv, h, dh).transpose(1, 0, 3, 2, 4)
    # re-anchor shardings: batch over DP axes, heads over the model axis
    # (GSPMD drops these through the nested scan otherwise)
    qt = constrain(qt, None, "batch", "model", None, None)
    kt = constrain(kt, None, "batch", "model", None, None)
    vt = constrain(vt, None, "batch", "model", None, None)
    cols = jnp.asarray(sched.cols)           # [nq, W]
    valid = jnp.asarray(sched.valid)
    rows = jnp.asarray(sched.rows)

    def q_step(_, idx):
        qi = qt[rows[idx]]                   # [B, H, tq, dh] (dynamic row)
        r0 = rows[idx] * tile_q

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def kv_step(carry, lane):
            # flash-style backward: nothing from the inner step is saved;
            # logits/probs are recomputed during bwd, so peak memory stays
            # O(tile) instead of O(S^2) (see EXPERIMENTS.md §Perf).
            m, l, acc = carry
            c = cols[idx, lane]
            ok = valid[idx, lane]
            kj = kt[c]                       # [B, H, tkv, dh]
            vj = vt[c]
            logits = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                                preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                logits = softcap * jnp.tanh(logits / softcap)
            c0 = c * tile_kv
            ri = r0 + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_kv), 0)
            ci = c0 + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_kv), 1)
            emask = jnp.full((tile_q, tile_kv), ok)
            if causal:
                emask &= ri >= ci
            if window > 0:
                emask &= (ri - ci < window) | (ci < global_prefix)
            logits = jnp.where(emask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (constrain(jnp.full((b_, h, tile_q), NEG_INF, jnp.float32),
                          "batch", "model", None),
                constrain(jnp.zeros((b_, h, tile_q), jnp.float32),
                          "batch", "model", None),
                constrain(jnp.zeros((b_, h, tile_q, dh), jnp.float32),
                          "batch", "model", None, None))
        (m, l, acc), _ = jax.lax.scan(kv_step, init,
                                      jnp.arange(sched.width))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, (rows[idx], constrain(out.astype(q.dtype),
                                           "batch", "model", None, None))

    _, (out_rows, outs) = jax.lax.scan(q_step, None, jnp.arange(nq))
    # un-permute rows (balanced schedule shuffles them)
    inv = jnp.zeros((nq,), jnp.int32).at[out_rows].set(jnp.arange(nq, dtype=jnp.int32))
    outs = outs[inv]                          # [nq, B, H, tq, dh]
    return outs.transpose(1, 0, 3, 2, 4).reshape(b_, s, h, dh)


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    b_, s, kv, dh = x.shape
    return jnp.broadcast_to(x[:, :, :, None], (b_, s, kv, n_rep, dh)
                            ).reshape(b_, s, kv * n_rep, dh)


def attend_train(q, k, v, *, causal: bool = True, window: int = 0,
                 global_prefix: int = 0, softcap: Optional[float] = None,
                 scale: Optional[float] = None, tile_q: int = 512,
                 tile_kv: int = 512, schedule: str = "row") -> jax.Array:
    """Full-sequence attention.  q: [B,S,H,dh], k/v: [B,Skv,KV,dh].

    ``window > 0`` restricts to a local causal window (+ ``global_prefix``
    always-visible leading tokens); both are folded into the static block
    schedule so out-of-window tiles are never visited.
    """
    b_, s, h, dh = q.shape
    kv_heads = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    k = _repeat_kv(k, h // kv_heads)
    v = _repeat_kv(v, h // kv_heads)
    tile_q = min(tile_q, s)
    tile_kv = min(tile_kv, k.shape[1])
    while s % tile_q:
        tile_q //= 2
    while k.shape[1] % tile_kv:
        tile_kv //= 2
    nq, nkv = s // tile_q, k.shape[1] // tile_kv
    if (schedule == "balanced" and causal and window == 0
            and nq == nkv and tile_q == tile_kv and nq > 1):
        return _attend_balanced_causal(q, k, v, scale=float(scale),
                                       softcap=softcap, tile_q=tile_q,
                                       tile_kv=tile_kv)
    # a query's window can straddle one extra back tile: the earliest
    # visible key for the first row of tile i is i*tq - (window-1), so
    # floor((window-1)/tkv) + 1 back tiles (+1 for the strict-< builder)
    wt = (window - 1) // tile_kv + 2 if window > 0 else 0
    gt = -(-global_prefix // tile_kv) if global_prefix > 0 else 0
    sched = _causal_schedule(nq, nkv, wt, gt, tile_q, tile_kv,
                             False, causal)
    return _attend_scheduled(q, k, v, sched, scale=float(scale),
                             causal=causal, window=window, softcap=softcap,
                             tile_q=tile_q, tile_kv=tile_kv,
                             global_prefix=global_prefix)


# ---------------------------------------------------------------------------
# Decode: one new token against a cache.
# ---------------------------------------------------------------------------

def attend_decode(q, k_cache, v_cache, *, lengths, softcap=None,
                  scale=None, window: int = 0, global_prefix: int = 0
                  ) -> jax.Array:
    """q: [B, 1, H, dh]; caches: [B, S, KV, dh]; lengths: [B] valid length.

    Dense over the cache (the cache itself is already the retained set for
    long-context configs).  fp32 logits; GQA repeat via reshape-free einsum.
    """
    b_, _, h, dh = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(b_, h, dh).reshape(b_, kv, g, dh)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(s)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    if window > 0:
        lo = lengths[:, None, None, None] - window
        keep = (pos >= lo) | (pos < global_prefix)
        mask &= keep
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b_, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, *, dtype=jnp.bfloat16):
    d = cfg.d_model
    qd, kvd = cfg.attn_dims
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, qd, bias=cfg.qkv_bias, dtype=dtype),
         "wk": dense_init(ks[1], d, kvd, bias=cfg.qkv_bias, dtype=dtype),
         "wv": dense_init(ks[2], d, kvd, bias=cfg.qkv_bias, dtype=dtype),
         "wo": dense_init(ks[3], qd, d, dtype=dtype)}
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((cfg.head_dim,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((cfg.head_dim,), jnp.float32)}
    return p


def _project_qkv(params, cfg, x, positions):
    b_, s, _ = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(params["wq"], x).reshape(b_, s, h, dh)
    k = dense(params["wk"], x).reshape(b_, s, kv, dh)
    v = dense(params["wv"], x).reshape(b_, s, kv, dh)
    if "q_norm" in params:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    if cfg.use_rope:
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def gqa_train(params, cfg, x, *, positions, local: bool = False,
              causal: bool = True, schedule: str = "row") -> jax.Array:
    """Full-sequence GQA.  ``local=True`` uses cfg.local_window."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    scale = cfg.attn_scale or 1.0 / np.sqrt(cfg.head_dim)
    out = attend_train(
        q, k, v, causal=causal,
        window=cfg.local_window if local else 0,
        global_prefix=cfg.global_prefix if local else 0,
        softcap=cfg.attn_softcap, scale=scale,
        tile_q=cfg.attn_tile_q, tile_kv=cfg.attn_tile_kv,
        schedule=schedule)
    b_, s = x.shape[:2]
    return dense(params["wo"], out.reshape(b_, s, -1))


def gqa_decode(params, cfg, x, cache, *, positions, slot=None,
               local: bool = False, window_filter: bool = True):
    """One-token decode.  cache: {"k": [B,S,KV,dh], "v": ...} updated in
    place at ``slot`` (ring-buffer slot for retained-block configs, where
    the window filter is off because the cache IS the retained set)."""
    q, k_new, v_new = _project_qkv(params, cfg, x, positions[:, None])
    slot = positions if slot is None else slot
    bidx = jnp.arange(x.shape[0])
    k_cache = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v_cache = cache["v"].at[bidx, slot].set(v_new[:, 0])
    lengths = jnp.minimum(positions + 1, k_cache.shape[1])
    scale = cfg.attn_scale or 1.0 / np.sqrt(cfg.head_dim)
    use_win = local and window_filter
    out = attend_decode(q, k_cache, v_cache, lengths=lengths,
                        softcap=cfg.attn_softcap, scale=scale,
                        window=cfg.local_window if use_win else 0,
                        global_prefix=cfg.global_prefix if use_win else 0)
    y = dense(params["wo"], out.reshape(x.shape[0], 1, -1))
    new_cache = dict(cache, k=k_cache, v=v_cache)
    return y, new_cache


def gqa_cache_init(cfg, batch: int, max_len: int, *, dtype=jnp.bfloat16):
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, kv, dh), dtype),
            "v": jnp.zeros((batch, max_len, kv, dh), dtype)}


def gqa_prefill(params, cfg, x, *, positions, max_len: int,
                local: bool = False, schedule: str = "row"):
    """Full-sequence forward that also emits the populated KV cache
    (padded to ``max_len``).  Roped K is cached, so decode never re-ropes."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    scale = cfg.attn_scale or 1.0 / np.sqrt(cfg.head_dim)
    out = attend_train(
        q, k, v, causal=True,
        window=cfg.local_window if local else 0,
        global_prefix=cfg.global_prefix if local else 0,
        softcap=cfg.attn_softcap, scale=scale,
        tile_q=cfg.attn_tile_q, tile_kv=cfg.attn_tile_kv, schedule=schedule)
    b_, s = x.shape[:2]
    y = dense(params["wo"], out.reshape(b_, s, -1))
    pad = [(0, 0), (0, max_len - s), (0, 0), (0, 0)]
    cache = {"k": jnp.pad(k, pad).astype(x.dtype),
             "v": jnp.pad(v, pad).astype(x.dtype)}
    return y, cache


# ---------------------------------------------------------------------------
# Cross attention (enc-dec decoder layers; no RoPE, non-causal over memory)
# ---------------------------------------------------------------------------

def cross_init(key, cfg, *, dtype=jnp.bfloat16):
    d = cfg.d_model
    qd, kvd = cfg.attn_dims
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, qd, dtype=dtype),
            "wk": dense_init(ks[1], d, kvd, dtype=dtype),
            "wv": dense_init(ks[2], d, kvd, dtype=dtype),
            "wo": dense_init(ks[3], qd, d, dtype=dtype)}


def cross_kv(params, cfg, memory):
    """Precompute memory K/V once (prefill); reused every decode step."""
    b_, t, _ = memory.shape
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    k = dense(params["wk"], memory).reshape(b_, t, kv, dh)
    v = dense(params["wv"], memory).reshape(b_, t, kv, dh)
    return k, v


def cross_apply(params, cfg, x, k, v):
    """x: [B, S, D] attends over memory K/V: [B, T, KV, dh]."""
    b_, s, _ = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    q = dense(params["wq"], x).reshape(b_, s, h, dh)
    out = attend_train(q, k, v, causal=False, scale=1.0 / np.sqrt(dh),
                       tile_q=cfg.attn_tile_q, tile_kv=cfg.attn_tile_kv)
    return dense(params["wo"], out.reshape(b_, s, -1))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg, *, dtype=jnp.bfloat16):
    d = cfg.d_model
    h = cfg.num_heads
    qk_nope, qk_rope, v_dim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    qd = h * (qk_nope + qk_rope)
    if cfg.q_lora_rank:
        p_q = {"a": dense_init(ks[0], d, cfg.q_lora_rank, dtype=dtype),
               "norm": {"scale": jnp.ones((cfg.q_lora_rank,), jnp.float32)},
               "b": dense_init(ks[1], cfg.q_lora_rank, qd, dtype=dtype)}
    else:
        p_q = {"w": dense_init(ks[0], d, qd, dtype=dtype)}
    return {
        "q": p_q,
        # joint down-projection: latent kv (r) + decoupled rope key
        "kv_a": dense_init(ks[2], d, r + qk_rope, dtype=dtype),
        "kv_norm": {"scale": jnp.ones((r,), jnp.float32)},
        "kv_b": dense_init(ks[3], r, h * (qk_nope + v_dim), dtype=dtype),
        "wo": dense_init(ks[4], h * v_dim, d, dtype=dtype),
    }


def _mla_q(params, cfg, x):
    b_, s, _ = x.shape
    h = cfg.num_heads
    if cfg.q_lora_rank:
        qa = rms_norm(params["q"]["norm"], dense(params["q"]["a"], x))
        q = dense(params["q"]["b"], qa)
    else:
        q = dense(params["q"]["w"], x)
    q = q.reshape(b_, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    return jnp.split(q, [cfg.qk_nope_dim], axis=-1)  # nope, rope


def _mla_kv(params, cfg, x):
    b_, s, _ = x.shape
    r = cfg.kv_lora_rank
    kv_a = dense(params["kv_a"], x)
    latent, k_rope = jnp.split(kv_a, [r], axis=-1)
    latent = rms_norm(params["kv_norm"], latent)
    return latent, k_rope.reshape(b_, s, 1, cfg.qk_rope_dim)


def _mla_expand(params, cfg, latent):
    """Expand latent -> per-head k_nope, v."""
    h = cfg.num_heads
    b_, s, _ = latent.shape
    kv = dense(params["kv_b"], latent).reshape(
        b_, s, h, cfg.qk_nope_dim + cfg.v_head_dim)
    return jnp.split(kv, [cfg.qk_nope_dim], axis=-1)


def mla_train(params, cfg, x, *, positions, schedule: str = "row"):
    b_, s, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(params, cfg, x)
    latent, k_rope = _mla_kv(params, cfg, x)
    k_nope, v = _mla_expand(params, cfg, latent)
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, theta=cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b_, s, h, cfg.qk_rope_dim))],
                        axis=-1)
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    # v padded to qk head dim for the shared attend path, then cropped
    pad = q.shape[-1] - cfg.v_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = attend_train(q, k, v_p, causal=True, scale=scale,
                       softcap=cfg.attn_softcap, tile_q=cfg.attn_tile_q,
                       tile_kv=cfg.attn_tile_kv, schedule=schedule)
    out = out[..., :cfg.v_head_dim].reshape(b_, s, -1)
    return dense(params["wo"], out)


def mla_cache_init(cfg, batch: int, max_len: int, *, dtype=jnp.bfloat16):
    """MLA decode caches the *latent* (r) + rope key -- the whole point of
    MLA: cache is r+rope wide, not h*(nope+v)."""
    return {"latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}


def mla_prefill(params, cfg, x, *, positions, max_len: int,
                schedule: str = "row"):
    b_, s, _ = x.shape
    y = mla_train(params, cfg, x, positions=positions, schedule=schedule)
    latent, k_rope = _mla_kv(params, cfg, x)
    k_rope = apply_rope(k_rope, positions, theta=cfg.rope_theta)
    pad2 = [(0, 0), (0, max_len - s), (0, 0)]
    cache = {"latent": jnp.pad(latent, pad2).astype(x.dtype),
             "k_rope": jnp.pad(k_rope[:, :, 0, :], pad2).astype(x.dtype)}
    return y, cache


def mla_decode(params, cfg, x, cache, *, positions, slot=None):
    b_ = x.shape[0]
    h = cfg.num_heads
    q_nope, q_rope = _mla_q(params, cfg, x)
    latent_new, k_rope_new = _mla_kv(params, cfg, x)
    q_rope = apply_rope(q_rope, positions[:, None], theta=cfg.rope_theta)
    k_rope_new = apply_rope(k_rope_new, positions[:, None],
                            theta=cfg.rope_theta)
    bidx = jnp.arange(b_)
    slot = positions if slot is None else slot
    latent_c = cache["latent"].at[bidx, slot].set(latent_new[:, 0])
    k_rope_c = cache["k_rope"].at[bidx, slot].set(k_rope_new[:, 0, 0])
    s = latent_c.shape[1]
    lengths = jnp.minimum(positions + 1, s)

    # absorbed attention: score = q_nope·W_uk·latent + q_rope·k_rope
    wkv = params["kv_b"]["w"].reshape(cfg.kv_lora_rank, h,
                                      cfg.qk_nope_dim + cfg.v_head_dim)
    w_uk = wkv[:, :, :cfg.qk_nope_dim]        # [r, h, nope]
    w_uv = wkv[:, :, cfg.qk_nope_dim:]        # [r, h, v]
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    logits = jnp.einsum("bqhr,bsr->bhqs", q_abs,
                        latent_c.astype(jnp.float32))
    logits += jnp.einsum("bqhn,bsn->bhqs", q_rope.astype(jnp.float32),
                         k_rope_c.astype(jnp.float32))
    logits *= 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, latent_c.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(jnp.float32))
    y = dense(params["wo"], out.reshape(b_, 1, -1).astype(x.dtype))
    return y, dict(cache, latent=latent_c, k_rope=k_rope_c)
