"""Decoder layers + scan-based stacks.

A model is ``groups = ((period, repeat), ...)`` (see ``config.py``); each
period is a tuple of ``LayerSpec`` and the whole period is scanned
``repeat`` times over stacked params -- HLO stays O(period) regardless of
depth, which keeps 80 pod-scale dry-run compiles tractable.

Remat: the period function is wrapped in ``jax.checkpoint`` with a
configurable policy (cfg.remat); "full" recomputes everything (baseline),
"dots" saves matmul outputs (a §Perf lever trading HBM for FLOPs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import sparse_layers
from repro.sharding.rules import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import LayerSpec, ModelCfg
from repro.models.layers import mlp, mlp_init, rms_norm


def _zero_metrics():
    z = jnp.zeros((), jnp.float32)
    return {"aux_loss": z, "z_loss": z, "dropped_frac": z}


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ModelCfg, spec: LayerSpec, *, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": {"scale": jnp.ones((cfg.d_model,), jnp.float32)}}
    if spec.mixer in ("attn", "attn_local"):
        p["attn"] = attn.gqa_init(ks[0], cfg, dtype=dtype)
    elif spec.mixer == "mla":
        p["attn"] = attn.mla_init(ks[0], cfg, dtype=dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm_lib.ssm_init(ks[0], cfg, dtype=dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.cross:
        p["cross"] = attn.cross_init(ks[2], cfg, dtype=dtype)
        p["norm_x"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if spec.ffn != "none":
        p["norm2"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if spec.ffn == "mlp":
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, act=cfg.act,
                            dtype=dtype)
    elif spec.ffn == "moe":
        p["ffn"] = moe_lib.moe_init(ks[1], cfg, dtype=dtype)
    elif spec.ffn == "sparse":
        p["ffn"] = _sparse_ffn(cfg).init(ks[1])
    if cfg.post_norm:
        p["post_norm1"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
        if spec.ffn != "none":
            p["post_norm2"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    return p


@functools.lru_cache(maxsize=None)
def _sparse_ffn_cached(d_model, d_ff, block, density, gated, dtype_str):
    return sparse_layers.SparseFFN(d_model, d_ff, block, density,
                                   gated=gated, dtype=jnp.bfloat16
                                   if dtype_str == "bfloat16" else jnp.float32)


def _sparse_ffn(cfg: ModelCfg):
    return _sparse_ffn_cached(cfg.d_model, cfg.d_ff, cfg.ffn_block_size,
                              cfg.ffn_density, cfg.act in ("silu", "gelu"),
                              cfg.dtype)


def _apply_ffn(params, cfg, spec, h):
    metrics = _zero_metrics()
    if spec.ffn == "none":
        return jnp.zeros_like(h), metrics
    hn = rms_norm(params["norm2"], h, eps=cfg.norm_eps,
                  plus_one=cfg.post_norm)
    if spec.ffn == "mlp":
        out = mlp(params["ffn"], hn, act=cfg.act)
    elif spec.ffn == "moe":
        out, m = moe_lib.moe_apply(params["ffn"], cfg, hn)
        metrics = {"aux_loss": m.aux_loss, "z_loss": m.z_loss,
                   "dropped_frac": m.dropped_frac}
    elif spec.ffn == "sparse":
        out = _sparse_ffn(cfg).apply(params["ffn"], hn)
    else:
        raise ValueError(spec.ffn)
    if cfg.post_norm:
        out = rms_norm(params["post_norm2"], out, eps=cfg.norm_eps,
                       plus_one=True)
    return out, metrics


def layer_apply(params, cfg: ModelCfg, spec: LayerSpec, h, *, positions,
                memory=None, schedule=None):
    """Training / prefill path: full sequence, no cache.

    ``memory``: encoder output [B, T, D] for cross layers.
    """
    hn = rms_norm(params["norm1"], h, eps=cfg.norm_eps,
                  plus_one=cfg.post_norm)
    sched = schedule or cfg.attn_schedule
    if spec.mixer in ("attn", "attn_local"):
        mix = attn.gqa_train(params["attn"], cfg, hn, positions=positions,
                             local=spec.mixer == "attn_local",
                             causal=spec.causal, schedule=sched)
    elif spec.mixer == "mla":
        mix = attn.mla_train(params["attn"], cfg, hn, positions=positions,
                             schedule=sched)
    else:
        mix = ssm_lib.ssm_train(params["mixer"], cfg, hn)
    if cfg.post_norm:
        mix = rms_norm(params["post_norm1"], mix, eps=cfg.norm_eps,
                       plus_one=True)
    h = h + mix
    if spec.cross:
        xk, xv = attn.cross_kv(params["cross"], cfg, memory)
        xn = rms_norm(params["norm_x"], h, eps=cfg.norm_eps,
                      plus_one=cfg.post_norm)
        h = h + attn.cross_apply(params["cross"], cfg, xn, xk, xv)
    out, metrics = _apply_ffn(params, cfg, spec, h)
    return h + out, metrics


def layer_cache_init(cfg: ModelCfg, spec: LayerSpec, batch: int,
                     max_len: int, *, dtype=jnp.bfloat16,
                     memory_len: int = 0):
    if spec.mixer in ("attn", "attn_local"):
        c = attn.gqa_cache_init(cfg, batch, max_len, dtype=dtype)
    elif spec.mixer == "mla":
        c = attn.mla_cache_init(cfg, batch, max_len, dtype=dtype)
    else:
        c = ssm_lib.ssm_cache_init(cfg, batch, dtype=dtype)
    if spec.cross:
        kv, dh = cfg.num_kv_heads, cfg.head_dim
        c["xk"] = jnp.zeros((batch, memory_len, kv, dh), dtype)
        c["xv"] = jnp.zeros((batch, memory_len, kv, dh), dtype)
    return c


def layer_prefill(params, cfg: ModelCfg, spec: LayerSpec, h, *, positions,
                  max_len: int, memory=None, schedule=None):
    """Full-sequence forward emitting (h, populated cache)."""
    hn = rms_norm(params["norm1"], h, eps=cfg.norm_eps,
                  plus_one=cfg.post_norm)
    sched = schedule or cfg.attn_schedule
    if spec.mixer in ("attn", "attn_local"):
        mix, cache = attn.gqa_prefill(params["attn"], cfg, hn,
                                      positions=positions, max_len=max_len,
                                      local=spec.mixer == "attn_local",
                                      schedule=sched)
    elif spec.mixer == "mla":
        mix, cache = attn.mla_prefill(params["attn"], cfg, hn,
                                      positions=positions, max_len=max_len,
                                      schedule=sched)
    else:
        mix, cache = ssm_lib.ssm_prefill(params["mixer"], cfg, hn)
    if cfg.post_norm:
        mix = rms_norm(params["post_norm1"], mix, eps=cfg.norm_eps,
                       plus_one=True)
    h = h + mix
    if spec.cross:
        xk, xv = attn.cross_kv(params["cross"], cfg, memory)
        cache["xk"], cache["xv"] = xk, xv
        xn = rms_norm(params["norm_x"], h, eps=cfg.norm_eps,
                      plus_one=cfg.post_norm)
        h = h + attn.cross_apply(params["cross"], cfg, xn, xk, xv)
    out, _ = _apply_ffn(params, cfg, spec, h)
    return h + out, cache


def layer_decode(params, cfg: ModelCfg, spec: LayerSpec, h, cache, *,
                 positions, slot=None, window_filter: bool = True):
    hn = rms_norm(params["norm1"], h, eps=cfg.norm_eps,
                  plus_one=cfg.post_norm)
    if spec.mixer in ("attn", "attn_local"):
        mix, cache = attn.gqa_decode(params["attn"], cfg, hn, cache,
                                     positions=positions, slot=slot,
                                     local=spec.mixer == "attn_local",
                                     window_filter=window_filter)
    elif spec.mixer == "mla":
        mix, cache = attn.mla_decode(params["attn"], cfg, hn, cache,
                                     positions=positions, slot=slot)
    else:
        mix, cache = ssm_lib.ssm_decode(params["mixer"], cfg, hn, cache)
    if cfg.post_norm:
        mix = rms_norm(params["post_norm1"], mix, eps=cfg.norm_eps,
                       plus_one=True)
    h = h + mix
    if spec.cross:
        xn = rms_norm(params["norm_x"], h, eps=cfg.norm_eps,
                      plus_one=cfg.post_norm)
        h = h + attn.cross_apply(params["cross"], cfg, xn,
                                 cache["xk"], cache["xv"])
    out, _ = _apply_ffn(params, cfg, spec, h)
    return h + out, cache


# ---------------------------------------------------------------------------
# Stack: scan each group's period over its repeat axis
# ---------------------------------------------------------------------------

def _remat_policy(cfg: ModelCfg):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def stack_init(key, cfg: ModelCfg, *, dtype=jnp.bfloat16):
    """Params: list (per group) of list (per period position) of stacked
    layer params with leading ``repeat`` axis."""
    groups = []
    for gi, (period, repeat) in enumerate(cfg.groups):
        period_params = []
        for si, spec in enumerate(period):
            keys = jax.random.split(
                jax.random.fold_in(key, gi * 64 + si), repeat)
            stacked = jax.vmap(
                lambda k: layer_init(k, cfg, spec, dtype=dtype))(keys)
            period_params.append(stacked)
        groups.append(period_params)
    return groups


def stack_apply(params, cfg: ModelCfg, h, *, positions, memory=None,
                schedule=None):
    """Full-sequence stack.  Returns (h, metrics-sum)."""
    total = _zero_metrics()

    for (period, repeat), period_params in zip(cfg.groups, params):
        seq_ax = "model" if cfg.seq_shard else None

        def period_fn(h, layer_params, period=period):
            ms = _zero_metrics()
            for spec, p in zip(period, layer_params):
                h = constrain(h, "batch", seq_ax, None)
                h, m = layer_apply(p, cfg, spec, h, positions=positions,
                                   memory=memory, schedule=schedule)
                ms = jax.tree.map(lambda a, b: a + b, ms, m)
            return constrain(h, "batch", seq_ax, None), ms

        pol = _remat_policy(cfg)
        if pol is not None:
            period_fn = jax.checkpoint(period_fn, policy=pol,
                                       prevent_cse=False)
        h, ms = jax.lax.scan(lambda c, p: period_fn(c, p), h,
                             tuple(period_params))
        total = jax.tree.map(lambda a, b: a + b.sum(), total, ms)
    return h, total


def stack_cache_init(cfg: ModelCfg, batch: int, max_len: int, *,
                     dtype=jnp.bfloat16, memory_len: int = 0):
    caches = []
    for period, repeat in cfg.groups:
        period_caches = []
        for spec in period:
            one = layer_cache_init(cfg, spec, batch, max_len, dtype=dtype,
                                   memory_len=memory_len)
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (repeat,) + x.shape).copy(),
                one)
            period_caches.append(stacked)
        caches.append(period_caches)
    return caches


def stack_prefill(params, cfg: ModelCfg, h, *, positions, max_len: int,
                  memory=None, schedule=None):
    """Full-sequence stack emitting (h, stacked caches)."""
    caches = []
    for (period, repeat), period_params in zip(cfg.groups, params):
        def period_fn(h, layer_params, period=period):
            cs = []
            for spec, p in zip(period, layer_params):
                h, c = layer_prefill(p, cfg, spec, h, positions=positions,
                                     max_len=max_len, memory=memory,
                                     schedule=schedule)
                cs.append(c)
            return h, tuple(cs)

        h, cs = jax.lax.scan(lambda c, p: period_fn(c, p), h,
                             tuple(period_params))
        caches.append(list(cs))
    return h, caches


def stack_decode(params, cfg: ModelCfg, h, caches, *, positions, slot=None,
                 window_filter: bool = True):
    new_caches = []
    for (period, repeat), period_params, period_caches in zip(
            cfg.groups, params, caches):
        def period_fn(h, inp, period=period):
            layer_params, layer_caches = inp
            new_lc = []
            for spec, p, c in zip(period, layer_params, layer_caches):
                h, c2 = layer_decode(p, cfg, spec, h, c, positions=positions,
                                     slot=slot, window_filter=window_filter)
                new_lc.append(c2)
            return h, tuple(new_lc)

        h, nc = jax.lax.scan(period_fn, h,
                             (tuple(period_params), tuple(period_caches)))
        new_caches.append(list(nc))
    return h, new_caches
