"""Model configuration schema covering all assigned architecture families.

A model is a stack of *layer groups*; each group is a repeating period of
layer specs scanned ``repeat`` times (``jax.lax.scan`` over stacked
params).  Heterogeneous stacks (gemma2 local/global alternation, jamba's
attn:mamba 1:7 interleave with MoE every other layer) are expressed as
periods, keeping HLO size O(period) regardless of depth -- the compile-
time discipline that makes 80 pod-scale dry-run compiles tractable
(DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    router_z_weight: float = 0.0
    router_score: str = "softmax"     # "softmax" | "sigmoid" (deepseek v3)
    norm_topk_prob: bool = True
    # perf levers (EXPERIMENTS.md §Perf): baseline values are the
    # paper-faithful/naive choices, the alternatives are the hillclimbed ones
    combine_dtype: str = "float32"    # "bfloat16" halves the combine
                                      # all-reduce volume over `model`
    ranking: str = "cumsum"           # "sort": O(Tk logTk) slot ranking vs
                                      # the O(Tk*E) cumsum-over-onehot
    impl: str = "gspmd"               # "shard_map": explicit local EP
                                      # dispatch + one psum (see §Perf)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer within a period."""
    mixer: str = "attn"        # "attn" | "attn_local" | "mla" | "mamba"
    ffn: str = "mlp"           # "mlp" | "moe" | "sparse" | "none"
    cross: bool = False        # add cross-attention over encoder memory
    causal: bool = True        # False for encoder self-attention


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                       # dense|moe|vlm|hybrid|ssm|audio
    d_model: int
    vocab_size: int
    # attention geometry (ignored for pure-SSM)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    # layer stacking: list of (period, repeat)
    groups: Tuple[Tuple[Tuple[LayerSpec, ...], int], ...] = ()
    # attention options
    attn_impl: str = "gqa"            # "gqa" | "mla"
    qkv_bias: bool = False
    qk_norm: bool = False             # qwen3-style per-head RMS on q/k
    use_rope: bool = True             # False: no positional encoding (jamba)
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    attn_scale: Optional[float] = None   # override 1/sqrt(dh) (gemma2)
    local_window: int = 4096          # for attn_local layers
    global_prefix: int = 0            # block-sparse global tokens
    attn_tile_q: int = 512            # XLA chunked-attention tile sizes
    attn_tile_kv: int = 512
    attn_schedule: str = "row"        # "row" | "balanced" (see §Perf)
    # long-context (long_500k) retained-block cache: local window blocks +
    # global prefix kept, O(window) decode -- the paper's static block
    # sparsity making the 500k cell feasible (DESIGN.md §3)
    retained_window: int = 4096
    retained_prefix: int = 1024
    # MLA geometry (deepseek)
    q_lora_rank: Optional[int] = None
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # activation / norm
    act: str = "silu"                 # silu (gated) | gelu (gated) | gelu_plain
    norm_eps: float = 1e-6
    post_norm: bool = False           # gemma2 uses pre+post norms
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma-style sqrt(d_model) scaling
    # sub-configs
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # enc-dec
    encoder_layers: int = 0
    # modality frontend stub (precomputed embeddings per the brief)
    frontend: Optional[str] = None    # "vision" | "audio" | None
    frontend_len: int = 0             # prepended embedding positions
    # --- the paper's technique -------------------------------------------
    ffn_density: Optional[float] = None  # static block-sparse FFN if set
    ffn_block_size: int = 16
    long_attention: str = "full"      # "full" | "block_sparse"
    # numerics
    dtype: str = "bfloat16"
    remat: str = "full"               # "full" | "dots" | "none"
    # sequence-parallel residual stream: shard S over 'model' between
    # layers so TP-boundary all-reduces become reduce-scatter/all-gather
    # pairs and norms run on S/|model| rows (§Perf lever)
    seq_shard: bool = False

    # ---------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return sum(len(period) * rep for period, rep in self.groups)

    @property
    def attn_dims(self) -> Tuple[int, int]:
        """(q_dim, kv_dim) of the projected attention space."""
        return (self.num_heads * self.head_dim,
                self.num_kv_heads * self.head_dim)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline term)."""
        d = self.d_model
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for period, rep in self.groups:
            for spec in period:
                total += rep * self._layer_params(spec)
        total += d  # final norm
        if self.encoder_layers:
            enc_spec = LayerSpec(mixer="attn", ffn="mlp")
            total += self.encoder_layers * self._layer_params(enc_spec)
            # cross-attention in every decoder layer
            total += self.num_layers * self._attn_params()
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_impl == "mla":
            qd = self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
            p = d * qd if self.q_lora_rank is None else (
                d * self.q_lora_rank + self.q_lora_rank * qd)
            p += d * (self.kv_lora_rank + self.qk_rope_dim)
            p += self.kv_lora_rank * self.num_heads * (
                self.qk_nope_dim + self.v_head_dim)
            p += self.num_heads * self.v_head_dim * d
            return p
        qd, kvd = self.attn_dims
        return d * qd + 2 * d * kvd + qd * d

    def _ffn_params(self, kind: str) -> int:
        d = self.d_model
        if kind == "none":
            return 0
        if kind == "moe":
            m = self.moe
            gated = 3 if self.act in ("silu", "gelu") else 2
            p = d * m.num_experts  # router
            p += m.num_experts * gated * d * m.d_ff_expert
            p += m.num_shared * gated * d * m.d_ff_shared
            return p
        gated = 3 if self.act in ("silu", "gelu") else 2
        p = gated * d * self.d_ff
        if kind == "sparse" and self.ffn_density is not None:
            p = int(p * self.ffn_density)
        return p

    def _layer_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        p = 2 * d  # two norms
        if spec.mixer in ("attn", "attn_local"):
            p += self._attn_params()
        elif spec.mixer == "mla":
            p += self._attn_params()
        elif spec.mixer == "mamba":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            p += d * (2 * di + 2 * s.d_state + nh)  # in_proj (z,x,B,C,dt)
            p += (di + 2 * s.d_state) * s.d_conv    # conv
            p += nh * 2                             # A, D
            p += di * d                             # out_proj
        p += self._ffn_params(spec.ffn)
        return p

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k active; sparse FFN at
        density) -- the ``N_active`` of the 6·N_active·D MoE roofline."""
        if self.moe is None:
            return self.param_count()
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total += self.vocab_size * self.d_model
        m = self.moe
        gated = 3
        active_expert = m.top_k * gated * self.d_model * m.d_ff_expert + \
            m.num_shared * gated * self.d_model * m.d_ff_shared + \
            self.d_model * m.num_experts
        for period, rep in self.groups:
            for spec in period:
                if spec.ffn == "moe":
                    p = 2 * self.d_model + active_expert
                    if spec.mixer != "none":
                        p += self._attn_params() if spec.mixer != "mamba" \
                            else (self._layer_params(
                                LayerSpec("mamba", "none")) - 2 * self.d_model)
                    total += rep * p
                else:
                    total += rep * self._layer_params(spec)
        total += self.d_model
        return total


def uniform_groups(n_layers: int, spec: LayerSpec):
    return ((( spec,), n_layers),)
