"""Base layers: norms, dense projections, embeddings, rotary, MLP.

Functional convention: ``init(key, ...) -> params dict``;
``apply(params, x, ...) -> y``.  Static structure lives in closures /
dataclass configs, trainable leaves in the params pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import sparse as sparse_api


def _norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, *, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = params["scale"] + 1.0 if plus_one else params["scale"]
    return (y * scale).astype(x.dtype)


def dense_init(key, d_in, d_out, *, bias: bool = False, dtype=jnp.bfloat16,
               scale: float | None = None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x):
    # routed through the plan-first sparse API so serving/training pick
    # up the ambient context (dense Pallas kernel on TPU, XLA elsewhere);
    # the per-shape plan is built once and reused across calls/steps
    y = sparse_api.matmul(x, params["w"])
    if "b" in params:
        y = y + params["b"]
    return y


def embed_init(key, vocab, d, *, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, *, softcap: float | None = None):
    logits = x @ params["table"].T
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# --- rotary ----------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, *, theta: float = 10000.0):
    """x: [..., S, H, dh] (or [..., H, dh] with scalar positions),
    positions: [..., S] int32."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- FFN (dense path) --------------------------------------------------------

def mlp_init(key, d_model, d_ff, *, act: str = "silu", dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    gated = act in ("silu", "gelu")
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype=dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp(params, x, *, act: str = "silu"):
    h = dense(params["up"], x)
    if "gate" in params:
        g = dense(params["gate"], x)
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        h = g * h
    else:
        h = jax.nn.gelu(h)
    return dense(params["down"], h)
