"""Top-level language model: embeddings + stack(s) + loss / prefill / decode.

One class covers all 10 assigned architectures; family differences are
entirely config-driven (``configs/*.py``):

* dense / MoE / hybrid / SSM decoder-only LMs,
* VLM (``frontend="vision"``): precomputed patch embeddings are prepended
  to the token sequence (frontend itself is a stub per the brief),
* audio enc-dec (``encoder_layers > 0``): precomputed frame embeddings run
  through a bidirectional encoder; decoder layers cross-attend.

Entry points map 1:1 onto the assigned shape cells:

* ``loss``         -> train_4k (train_step)
* ``prefill``      -> prefill_32k (returns last-token logits + caches)
* ``decode_step``  -> decode_32k / long_500k (one token against a cache;
  ``retained=True`` switches to the ring-buffer local+global cache that
  makes 500k-context decode O(window) -- the paper's static block
  sparsity applied to the KV cache, DESIGN.md §3)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.config import LayerSpec, ModelCfg
from repro.models.layers import embed, embed_init, rms_norm, unembed
from repro.sharding.rules import constrain


def _dtype(cfg: ModelCfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


@dataclasses.dataclass(frozen=True)
class LM:
    cfg: ModelCfg

    # -- encoder structure (enc-dec archs) ---------------------------------
    @property
    def encoder_groups(self):
        if not self.cfg.encoder_layers:
            return ()
        spec = LayerSpec(mixer="attn", ffn="mlp", causal=False)
        return (((spec,), self.cfg.encoder_layers),)

    def _encoder_cfg(self) -> ModelCfg:
        return dataclasses.replace(self.cfg, groups=self.encoder_groups)

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        ks = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dt),
            "stack": tfm.stack_init(ks[1], cfg, dtype=dt),
            "final_norm": {"scale": jnp.ones((cfg.d_model,), jnp.float32)},
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(ks[2], cfg.vocab_size,
                                           cfg.d_model, dtype=dt)
        if cfg.encoder_layers:
            ecfg = self._encoder_cfg()
            params["encoder"] = tfm.stack_init(ks[3], ecfg, dtype=dt)
            params["enc_norm"] = {"scale": jnp.ones((cfg.d_model,),
                                                    jnp.float32)}
        return params

    # -- shared plumbing -----------------------------------------------------
    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        h = embed(params["embed"], tokens)
        if cfg.embed_scale:
            h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
        return h

    def _unembed(self, params, h):
        cfg = self.cfg
        table = params["lm_head" if "lm_head" in params else "embed"]
        return unembed(table, h, softcap=cfg.final_softcap)

    def _encode(self, params, enc_frames):
        """Bidirectional encoder over precomputed frame embeddings."""
        ecfg = self._encoder_cfg()
        t = enc_frames.shape[1]
        positions = jnp.arange(t)[None, :]
        h, _ = tfm.stack_apply(params["encoder"], ecfg, enc_frames,
                               positions=positions)
        return rms_norm(params["enc_norm"], h, eps=ecfg.norm_eps,
                        plus_one=ecfg.post_norm)

    def _prepare(self, params, tokens, frontend, enc_frames):
        """Returns (h, positions, memory, n_prefix)."""
        h = self._embed_tokens(params, tokens)
        n_prefix = 0
        if frontend is not None:
            h = jnp.concatenate([frontend.astype(h.dtype), h], axis=1)
            n_prefix = frontend.shape[1]
        h = constrain(h, "batch", None, None)
        positions = jnp.arange(h.shape[1])[None, :]
        memory = None
        if enc_frames is not None:
            memory = constrain(self._encode(params, enc_frames),
                               "batch", None, None)
        return h, positions, memory, n_prefix

    # -- training forward + loss ---------------------------------------------
    def forward(self, params, tokens, *, frontend=None, enc_frames=None,
                schedule=None):
        """Full-sequence logits [B, S(+F), V] and stack metrics."""
        cfg = self.cfg
        h, positions, memory, n_prefix = self._prepare(
            params, tokens, frontend, enc_frames)
        h, metrics = tfm.stack_apply(params["stack"], cfg, h,
                                     positions=positions, memory=memory,
                                     schedule=schedule)
        h = rms_norm(params["final_norm"], h, eps=cfg.norm_eps,
                     plus_one=cfg.post_norm)
        if n_prefix:
            h = h[:, n_prefix:]
        return self._unembed(params, h), metrics

    def loss(self, params, batch, *, loss_chunk: int = 1024,
             schedule=None):
        """Next-token cross entropy.  batch: {"tokens": [B,S] int32,
        "targets": [B,S] int32 (-1 = pad), "frontend"?, "enc_frames"?}.

        The unembed projection + softmax run chunked over the sequence so
        the [B, S, V] logits tensor is never materialized (the V-dim is
        vocab-sharded under pjit; the chunk loop bounds the fp32 buffer).
        """
        cfg = self.cfg
        h, positions, memory, n_prefix = self._prepare(
            params, batch["tokens"], batch.get("frontend"),
            batch.get("enc_frames"))
        h, metrics = tfm.stack_apply(params["stack"], cfg, h,
                                     positions=positions, memory=memory,
                                     schedule=schedule)
        h = rms_norm(params["final_norm"], h, eps=cfg.norm_eps,
                     plus_one=cfg.post_norm)
        if n_prefix:
            h = h[:, n_prefix:]
        targets = batch["targets"]
        b_, s = targets.shape
        c = min(loss_chunk, s)
        while s % c:
            c //= 2
        hc = constrain(h.reshape(b_, s // c, c, -1).transpose(1, 0, 2, 3),
                       None, "batch", None, None)
        tc = targets.reshape(b_, s // c, c).transpose(1, 0, 2)

        def chunk_loss(carry, inp):
            hx, tx = inp
            hx = constrain(hx, "batch", None, None)
            logits = self._unembed(params, hx).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(tx, 0)[..., None], axis=-1)[..., 0]
            valid = (tx >= 0).astype(jnp.float32)
            nll = (lse - gold) * valid
            tot, cnt = carry
            return (tot + nll.sum(), cnt + valid.sum()), None

        (tot, cnt), _ = jax.lax.scan(chunk_loss,
                                     (jnp.zeros((), jnp.float32),
                                      jnp.zeros((), jnp.float32)),
                                     (hc, tc))
        xent = tot / jnp.maximum(cnt, 1.0)
        loss = xent
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * metrics["aux_loss"] \
                + cfg.moe.router_z_weight * metrics["z_loss"]
        metrics = dict(metrics, xent=xent)
        return loss, metrics

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *,
                   memory_len: int = 0):
        return tfm.stack_cache_init(self.cfg, batch, max_len,
                                    dtype=_dtype(self.cfg),
                                    memory_len=memory_len)

    def prefill(self, params, tokens, *, max_len: int, frontend=None,
                enc_frames=None, schedule=None, last_index=None):
        """Returns (last-token logits [B, V], populated caches).

        ``last_index`` (``[B]`` int32, optional) gathers the logits at a
        per-row position instead of the literal last one -- the serving
        engine's bucketed prefill pads prompts up to a shape bucket, so
        the *true* last prompt token sits at ``len(prompt) - 1``, not at
        ``bucket - 1``.  Indices are into the (frontend-concatenated)
        sequence; a traced value is fine (dynamic gather, no recompile
        per prompt length).  Causality makes the pad suffix inert here:
        positions ``<= last_index`` never attend to it, and decode masks
        cache slots ``> position``, so padded rows are never read before
        they are overwritten."""
        cfg = self.cfg
        h, positions, memory, n_prefix = self._prepare(
            params, tokens, frontend, enc_frames)
        h, caches = tfm.stack_prefill(params["stack"], cfg, h,
                                      positions=positions, max_len=max_len,
                                      memory=memory, schedule=schedule)
        if last_index is None:
            h = h[:, -1:]
        else:
            idx = jnp.asarray(last_index, jnp.int32).reshape(-1, 1, 1)
            h = jnp.take_along_axis(
                h, jnp.broadcast_to(idx, (h.shape[0], 1, h.shape[2])),
                axis=1)
        h = rms_norm(params["final_norm"], h, eps=cfg.norm_eps,
                     plus_one=cfg.post_norm)
        return self._unembed(params, h)[:, 0], caches

    def _ring_slot(self, positions):
        """Physical cache slot for retained-block (local+global) caches."""
        cfg = self.cfg
        g, w = cfg.retained_prefix, cfg.retained_window
        return jnp.where(positions < g + w, positions,
                         g + (positions - g) % w)

    def decode_step(self, params, tokens, caches, positions, *,
                    retained: bool = False):
        """One token: tokens [B, 1], positions [B].  Returns
        (logits [B, V], new caches)."""
        cfg = self.cfg
        h = self._embed_tokens(params, tokens)
        slot = self._ring_slot(positions) if retained else positions
        h, caches = tfm.stack_decode(params["stack"], cfg, h, caches,
                                     positions=positions, slot=slot,
                                     window_filter=not retained)
        h = rms_norm(params["final_norm"], h, eps=cfg.norm_eps,
                     plus_one=cfg.post_norm)
        return self._unembed(params, h)[:, 0], caches
