"""Mamba-2 (SSD, state-space duality) mixer -- attention-free archs.

Chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060): the sequence is
split into chunks of length L; within a chunk the recurrence is expanded
into a (masked) quadratic form that runs on the MXU, across chunks a
cheap sequential ``lax.scan`` carries the [H, P, N] state.  Decode is the
O(1) recurrent update.

The SSD *intra-chunk* computation is itself a block-lower-triangular
structured matmul; the block-sparse machinery applies only in that
degenerate (block-diagonal) sense -- recorded as inapplicable for the
paper's technique in DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense, dense_init, rms_norm


def ssm_init(key, cfg, *, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    g = s.n_groups
    conv_dim = di + 2 * g * s.d_state
    ks = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * g * s.d_state + nh   # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], d, in_dim, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim))
                   * (1.0 / np.sqrt(s.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), jnp.float32)},
        "out_proj": dense_init(ks[2], di, d, dtype=dtype),
    }


def _split_in(proj, cfg):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    g = s.n_groups
    zs, xs, bs, cs, dts = jnp.split(
        proj, np.cumsum([di, di, g * s.d_state, g * s.d_state]), axis=-1)
    return zs, xs, bs, cs, dts


def _causal_conv(x, w, b):
    """Depthwise causal conv, x: [B, S, C], w: [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(dA):
    """log-space cumulative decay matrix: out[i,j] = sum_{j<l<=i} dA[l],
    -inf above diagonal.  dA: [..., L] -> [..., L, L]."""
    seq = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(seq)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, A, B, C, *, chunk: int):
    """Chunked SSD.  x: [B,S,H,P], dt: [B,S,H] (post-softplus),
    A: [H] (negative), B/C: [B,S,G,N].  Returns y: [B,S,H,P] and final
    state [B,H,P,N].
    """
    b_, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    lc = min(chunk, s)
    while s % lc:
        lc //= 2
    nc = s // lc
    rep = h // g

    def cshape(t):  # [B,S,...] -> [B,nc,L,...]
        return t.reshape(b_, nc, lc, *t.shape[2:])

    xc, dtc = cshape(x), cshape(dt)
    Bc = jnp.repeat(cshape(B), rep, axis=3)          # [B,nc,L,H,N]
    Cc = jnp.repeat(cshape(C), rep, axis=3)
    dA = dtc * A                                      # [B,nc,L,H]
    dA_cs = jnp.cumsum(dA, axis=2)                    # within-chunk cumsum

    # intra-chunk (dual quadratic form on the MXU)
    L_mat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # [B,nc,H,L,L]
    scores = jnp.einsum("bclhn,bcshn,bchls->bchls", Cc, Bc, L_mat)
    y_intra = jnp.einsum("bchls,bcshp,bcsh->bclhp", scores, xc, dtc)

    # chunk end-states
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)       # [B,nc,L,H]
    states = jnp.einsum("bclhn,bclhp,bclh,bclh->bchpn",
                        Bc, xc, dtc, decay_to_end)            # [B,nc,H,P,N]

    # inter-chunk sequential recurrence over nc
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                 # [B,nc,H]

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit prev

    init = jnp.zeros((b_, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # [B,nc,H,P,N]

    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                         Cc, prev_states.astype(Cc.dtype),
                         jnp.exp(dA_cs).astype(Cc.dtype))
    y = (y_intra + y_inter).reshape(b_, s, h, p)
    return y, final


def ssm_train(params, cfg, x):
    """Full-sequence Mamba-2 block.  x: [B, S, D] -> [B, S, D]."""
    s_cfg = cfg.ssm
    b_, s, d = x.shape
    nh = s_cfg.num_heads(d)
    di = s_cfg.d_inner(d)
    proj = dense(params["in_proj"], x)
    z, xs, B, C, dt = _split_in(proj, cfg)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(conv_out, np.cumsum(
        [di, s_cfg.n_groups * s_cfg.d_state]), axis=-1)
    xs = xs.reshape(b_, s, nh, s_cfg.head_dim)
    B = B.reshape(b_, s, s_cfg.n_groups, s_cfg.d_state)
    C = C.reshape(b_, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, _ = ssd_scan(xs.astype(jnp.float32), dt, A,
                    B.astype(jnp.float32), C.astype(jnp.float32),
                    chunk=s_cfg.chunk)
    y = y + xs.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(b_, s, di).astype(x.dtype)
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y)


def ssm_prefill(params, cfg, x):
    """Full-sequence forward that also returns the recurrent cache."""
    s_cfg = cfg.ssm
    b_, s, d = x.shape
    nh = s_cfg.num_heads(d)
    di = s_cfg.d_inner(d)
    proj = dense(params["in_proj"], x)
    z, xs, B, C, dt = _split_in(proj, cfg)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    tail = conv_in[:, -(s_cfg.d_conv - 1):]
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(conv_out, np.cumsum(
        [di, s_cfg.n_groups * s_cfg.d_state]), axis=-1)
    xs = xs.reshape(b_, s, nh, s_cfg.head_dim)
    B = B.reshape(b_, s, s_cfg.n_groups, s_cfg.d_state)
    C = C.reshape(b_, s, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, state = ssd_scan(xs.astype(jnp.float32), dt, A,
                        B.astype(jnp.float32), C.astype(jnp.float32),
                        chunk=s_cfg.chunk)
    y = y + xs.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(b_, s, di).astype(x.dtype)
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y), {"state": state, "conv": tail}


def ssm_cache_init(cfg, batch: int, *, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    nh = s.num_heads(d)
    conv_dim = s.d_inner(d) + 2 * s.n_groups * s.d_state
    return {
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def ssm_decode(params, cfg, x, cache):
    """One-token recurrent update.  x: [B, 1, D]."""
    s_cfg = cfg.ssm
    b_, _, d = x.shape
    nh = s_cfg.num_heads(d)
    di = s_cfg.d_inner(d)
    proj = dense(params["in_proj"], x)
    z, xs, B, C, dt = _split_in(proj, cfg)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)      # [B, 1, conv_dim]
    hist = jnp.concatenate([cache["conv"], conv_in], axis=1)
    w = params["conv_w"]
    conv_out = jax.nn.silu((hist * w[None]).sum(axis=1, keepdims=True)
                           + params["conv_b"])
    new_conv = hist[:, 1:]
    xs, B, C = jnp.split(conv_out, np.cumsum(
        [di, s_cfg.n_groups * s_cfg.d_state]), axis=-1)
    xs = xs.reshape(b_, nh, s_cfg.head_dim).astype(jnp.float32)
    B = B.reshape(b_, s_cfg.n_groups, s_cfg.d_state).astype(jnp.float32)
    C = C.reshape(b_, s_cfg.n_groups, s_cfg.d_state).astype(jnp.float32)
    rep = nh // s_cfg.n_groups
    B = jnp.repeat(B, rep, axis=1)                      # [B, H, N]
    C = jnp.repeat(C, rep, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                             # [B, H]
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bh,bhp,bhn->bhpn", dt, xs, B)
    y = jnp.einsum("bhpn,bhn->bhp", state, C) + xs * params["D"][:, None]
    y = y.reshape(b_, 1, di).astype(x.dtype)
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    return dense(params["out_proj"], y), {"state": state, "conv": new_conv}
