"""Mixture-of-Experts FFN -- the paper's *dynamic* block sparsity at layer
scale.

MegaBlocks (Gale et al. 2022, cited in paper §1.2) frames MoE expert
compute as block-sparse matmul whose pattern (the routing) changes every
step with a capacity bound -- exactly PopSparse dynamic mode: ``d_max``
== top_k/E * capacity_factor is fixed at compile time, the pattern is
runtime data, and overflow (capacity drops) is the analogue of the
paper's bucket overflow.

Dispatch is sort-free "capacity gather": for each expert, take the first
C tokens routed to it (stable priority by token order), compute the
batched expert GEMM [E, C, D] @ [E, D, F], and scatter-combine weighted by
router probs.  Shardings: E over the ``model`` mesh axis (expert
parallelism), C inherits the token batch sharding -- the GSPMD view of the
paper's q^m x q^k x q^n partition grid.

TPU path: ``kernels/gmm`` grouped GEMM consumes the same (sorted) layout.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sparse as sparse_api
from repro.sharding.rules import constrain


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array        # load-balance loss (switch-style)
    z_loss: jax.Array          # router logit magnitude penalty
    dropped_frac: jax.Array    # fraction of assignments over capacity


def moe_init(key, cfg, *, dtype=jnp.bfloat16):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, m.num_experts))
                         * scale).astype(jnp.float32)},
        # stacked expert weights [E, ...] -- the EP shard axis
        "w_gate": (jax.random.normal(ks[1], (m.num_experts, d, m.d_ff_expert))
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (m.num_experts, d, m.d_ff_expert))
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(
            ks[3], (m.num_experts, m.d_ff_expert, d))
            * (1.0 / np.sqrt(m.d_ff_expert))).astype(dtype),
    }
    if m.num_shared:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d, m.num_shared * m.d_ff_shared,
                               act=cfg.act, dtype=dtype)
    return p


def _capacity(tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(np.ceil(tokens * m.top_k / m.num_experts * m.capacity_factor))
    # keep the gather shape MXU-friendly and nonzero
    return max(8, -(-c // 8) * 8)


def moe_apply(params, cfg, x: jax.Array) -> tuple[jax.Array, MoEMetrics]:
    """x: [B, S, D] -> (y, metrics).  Capacity-bounded top-k routing.

    Two distribution strategies (cfg.moe.impl, see EXPERIMENTS.md §Perf):

    * "gspmd": single-program dispatch; GSPMD infers the collectives.
      Simple, but the data-sharded-tokens -> expert-sharded-buckets
      gather lowers to a full-bucket all-reduce (measured dominant on
      qwen3-moe train_4k).
    * "shard_map": explicit local dispatch -- tokens stay on their DP
      shard (replicated over 'model'), each model shard computes only
      its owned experts, one bf16 psum over 'model' combines.  This is
      the paper's static-partition philosophy applied to the dynamic
      pattern: local work from locally-available operands + one final
      reduction.
    """
    from repro.sharding.rules import batch_axes, current_mesh
    m = cfg.moe
    mesh = current_mesh()
    out = None
    if (m.impl == "shard_map" and mesh is not None
            and "model" in mesh.axis_names
            and m.num_experts % mesh.shape["model"] == 0):
        ba = batch_axes(mesh)
        dp = 1
        for a in ba:
            dp *= mesh.shape[a]
        if ba and x.shape[0] % dp == 0:
            out = _moe_shard_map(params, cfg, x, mesh, ba)
    if out is None:
        out = _moe_gspmd(params, cfg, x)
    y, metrics = out
    # the routing drop is the MoE face of the paper's bucket overflow:
    # fold it into the same capacity telemetry the dynamic_grouped plans
    # report through (eager calls only -- no-op under tracing)
    sparse_api.record_dropped("moe_dispatch", metrics.dropped_frac)
    return y, metrics


def _moe_gspmd(params, cfg, x: jax.Array) -> tuple[jax.Array, MoEMetrics]:
    """GSPMD-friendly dispatch: only the *index* map (token_for_slot
    [E, C]) is built by scatter; embeddings move through a single gather
    so the big [E, C, D] tensor is born expert-sharded.  Empty slots
    gather token 0 with combine-weight 0 -- wasted FLOPs on padding slots
    are exactly the paper's dynamic-mode overflow cost (§3.3), surfaced
    per-step in ``dropped_frac``.
    """
    m = cfg.moe
    b_, s, d = x.shape
    t = b_ * s
    xf = x.reshape(t, d)
    cap = _capacity(t, cfg)

    logits = xf.astype(jnp.float32) @ params["router"]["w"]     # [T, E]
    if m.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(scores, m.top_k)               # [T, k]
    if m.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- capacity assignment (the d_max bucket bound, paper §3.3) --------
    # position within expert queue = running count of that expert over the
    # flattened (T*k) assignment priority order.
    flat_e = top_e.reshape(-1)                                  # [T*k]
    if m.ranking == "sort":
        # O(Tk log Tk) HBM-light ranking (§Perf): stable-sort by expert,
        # rank within each run = index - first-index-of-expert
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, jnp.arange(m.num_experts))
        rank_sorted = jnp.arange(flat_e.shape[0]) - first[sorted_e]
        slot = jnp.zeros_like(flat_e).at[order].set(rank_sorted)
        counts = jnp.bincount(flat_e, length=m.num_experts)
    else:
        onehot = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) * onehot          # 1-based
        slot = (pos_in_e.sum(-1) - 1)                           # [T*k]
        counts = onehot.sum(0)
    keep = slot < cap
    dropped = 1.0 - keep.mean(dtype=jnp.float32)

    # index map + combine weights (scatter of scalars only; overflow goes
    # to a scratch column that is cropped -- the paper's bucket overflow)
    e_idx = jnp.where(keep, flat_e, m.num_experts - 1)
    c_idx = jnp.where(keep, slot, cap)
    tok_idx = jnp.repeat(jnp.arange(t), m.top_k)
    token_for_slot = jnp.zeros((m.num_experts, cap + 1), jnp.int32
                               ).at[e_idx, c_idx].set(tok_idx)[:, :cap]
    w_slot = jnp.zeros((m.num_experts, cap + 1), jnp.float32
                       ).at[e_idx, c_idx].set(top_p.reshape(-1))[:, :cap]

    # --- expert compute: gather + batched GEMM over the E axis.
    # Sharding anchors (§Perf): E over 'model' (EP) and the capacity dim
    # over the DP axes -- without the C anchor GSPMD all-reduces the full
    # [E_loc, C, D] bucket tensor across data shards (measured 5.4 GB/
    # layer on qwen3-moe train_4k).
    buckets = constrain(jnp.take(xf, token_for_slot, axis=0),
                        "model", "batch", None)                 # [E, C, D]
    # expert GEMMs go through the plan-first sparse API (one plan for
    # the per-expert [C, D] @ [D, F] problem, built at first trace and
    # reused every step, vmapped over E)
    h_g = sparse_api.batched_matmul(buckets, params["w_gate"])
    h_u = sparse_api.batched_matmul(buckets, params["w_up"])
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = constrain(act(h_g) * h_u, "model", "batch", None)
    out_e = constrain(
        sparse_api.batched_matmul(h, params["w_down"]),
        "model", "batch", None)                                 # [E, C, D]

    # --- combine: expert-side weighted scatter-add (associative, so GSPMD
    # keeps experts sharded and all-reduces the [T, D] partials).
    # combine_dtype="bfloat16" halves that all-reduce volume (§Perf).
    cdt = jnp.bfloat16 if m.combine_dtype == "bfloat16" else jnp.float32
    contrib = out_e.astype(cdt) * w_slot[..., None].astype(cdt)
    y = jnp.zeros((t, d), cdt).at[
        token_for_slot.reshape(-1)].add(contrib.reshape(-1, d))
    y = constrain(y, "batch", None).astype(jnp.float32)

    if m.num_shared:
        from repro.models.layers import mlp
        y += mlp(params["shared"], xf, act=cfg.act).astype(jnp.float32)

    # --- aux losses (switch load-balance + z-loss) ------------------------
    probs_mean = jax.nn.softmax(logits, axis=-1).mean(0)        # [E]
    frac = counts.astype(jnp.float32) / (t * m.top_k)
    aux = m.num_experts * jnp.sum(frac * probs_mean)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return (y.reshape(b_, s, d).astype(x.dtype),
            MoEMetrics(aux, z, dropped))


def _route_and_rank(xf, router_w, cfg, cap):
    """Shared routing core: top-k + capacity slot assignment on a local
    token set.  Returns (top_p, slot index maps, metrics pieces)."""
    m = cfg.moe
    t = xf.shape[0]
    logits = xf.astype(jnp.float32) @ router_w
    scores = jax.nn.sigmoid(logits) if m.router_score == "sigmoid" \
        else jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(scores, m.top_k)
    if m.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(m.num_experts))
    rank_sorted = jnp.arange(flat_e.shape[0]) - first[sorted_e]
    slot = jnp.zeros_like(flat_e).at[order].set(rank_sorted)
    counts = jnp.bincount(flat_e, length=m.num_experts)
    keep = slot < cap
    e_idx = jnp.where(keep, flat_e, m.num_experts - 1)
    c_idx = jnp.where(keep, slot, cap)
    tok_idx = jnp.repeat(jnp.arange(t), m.top_k)
    token_for_slot = jnp.zeros((m.num_experts, cap + 1), jnp.int32
                               ).at[e_idx, c_idx].set(tok_idx)[:, :cap]
    w_slot = jnp.zeros((m.num_experts, cap + 1), jnp.float32
                       ).at[e_idx, c_idx].set(top_p.reshape(-1))[:, :cap]
    dropped = 1.0 - keep.mean(dtype=jnp.float32)
    probs_mean = jax.nn.softmax(logits, axis=-1).mean(0)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return token_for_slot, w_slot, counts, dropped, probs_mean, z


def _moe_shard_map(params, cfg, x, mesh, ba) -> tuple[jax.Array, MoEMetrics]:
    """Explicit local EP dispatch (§Perf, cell B):

    * tokens: sharded over the DP axes, replicated over 'model';
    * expert weights: E over 'model' (+ FSDP 'data' shard all-gathered
      locally, reduce-scattered in the backward);
    * each model shard routes the *local* tokens, computes only its
      E/|model| experts, and contributes a partial [T_loc, D];
    * ONE psum over 'model' (bf16 if combine_dtype says so) combines.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    b_, s, d = x.shape
    ep = mesh.shape["model"]
    e_loc = m.num_experts // ep
    cdt = jnp.bfloat16 if m.combine_dtype == "bfloat16" else jnp.float32
    bspec = ba if len(ba) > 1 else ba[0]

    def local_fn(x_loc, router_w, w_gate, w_up, w_down):
        bl, s_, d_ = x_loc.shape
        xf = x_loc.reshape(bl * s_, d_)
        cap = _capacity(bl * s_, cfg)
        tfs, w_slot, counts, dropped, probs_mean, z = _route_and_rank(
            xf, router_w, cfg, cap)
        # this shard's experts
        e0 = jax.lax.axis_index("model") * e_loc
        tfs_loc = jax.lax.dynamic_slice_in_dim(tfs, e0, e_loc, 0)
        w_slot_loc = jax.lax.dynamic_slice_in_dim(w_slot, e0, e_loc, 0)
        # FSDP: gather the weight shards over 'data' (bwd: reduce-scatter)
        if "data" in mesh.axis_names and w_gate.shape[1] != d_:
            w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up, "data", axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, "data", axis=1, tiled=True)
        buckets = jnp.take(xf, tfs_loc, axis=0)          # [E_loc, C, D]
        h_g = sparse_api.batched_matmul(buckets, w_gate)
        h_u = sparse_api.batched_matmul(buckets, w_up)
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        out_e = sparse_api.batched_matmul(act(h_g) * h_u, w_down)
        contrib = out_e.astype(cdt) * w_slot_loc[..., None].astype(cdt)
        y = jnp.zeros((bl * s_, d_), cdt).at[
            tfs_loc.reshape(-1)].add(contrib.reshape(-1, d_))
        y = jax.lax.psum(y, "model")                     # THE combine
        # metrics: mean over DP shards (identical across 'model')
        aux = m.num_experts * jnp.sum(
            counts.astype(jnp.float32) / (bl * s_ * m.top_k) * probs_mean)
        metrics = jax.lax.pmean(
            jnp.stack([aux, z, dropped]), ba[0]) if len(ba) == 1 else \
            jax.lax.pmean(jax.lax.pmean(
                jnp.stack([aux, z, dropped]), ba[0]), ba[1])
        return y.reshape(bl, s_, d_).astype(jnp.float32), metrics

    # expert weights: E over 'model', FSDP over 'data' on axis 1
    # (w_gate/w_up: D; w_down: F -- same rule as sharding/rules.py)
    w_spec = P("model", "data" if "data" in mesh.axis_names else None,
               None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  w_spec, w_spec, w_spec),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False)
    y, metrics = fn(x, params["router"]["w"], params["w_gate"],
                    params["w_up"], params["w_down"])
    if m.num_shared:
        from repro.models.layers import mlp
        b2, s2, d2 = x.shape
        xf = x.reshape(-1, d2)
        y = y + mlp(params["shared"], xf, act=cfg.act).astype(
            jnp.float32).reshape(b2, s2, d2)
    return (y.astype(x.dtype),
            MoEMetrics(metrics[0], metrics[1], metrics[2]))


def moe_flops_per_token(cfg) -> float:
    """Active-path FLOPs (the 6·N_active·D numerator's layer share)."""
    m = cfg.moe
    d = cfg.d_model
    f = 2.0 * d * m.d_ff_expert * 3 * m.top_k
    f += 2.0 * d * m.num_experts                 # router
    if m.num_shared:
        f += 2.0 * d * m.num_shared * m.d_ff_shared * 3
    return f
