"""Batched serving engine: continuous batching over a fixed decode batch.

A fixed [B, max_len] cache is compiled once (one prefill program per
bucketed prompt length, one decode program); requests are admitted into
free slots as others finish -- vLLM-style continuous batching reduced to
its TPU-friendly static-shape core:

* slot state lives in the cache pytree (positions per slot);
* admission = prefill the prompt in the slot-batch view, then copy its
  cache row into the live batch (jitted per-slot dynamic update);
* every engine.step() decodes ONE token for all live slots.

``retained=True`` serves long contexts with the ring-buffer local+global
cache -- the paper's static block sparsity keeping 500k-token decode
O(window) (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sparse as sparse_api
from repro.core import dispatch
from repro.models.model import LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, lm: LM, params, *, batch: int, max_len: int,
                 retained: bool = False, sample: str = "greedy",
                 dispatch_ctx: Optional[dispatch.DispatchContext] = None,
                 plan_cache_dir: Optional[str] = None,
                 warm_plans: bool = True, telemetry: bool = True,
                 mesh=None, tp_axis: str = "model"):
        self.lm = lm
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.retained = retained
        # every matmul in the traced programs consults this context (the
        # decode/prefill matmul plans are built at engine startup);
        # serving is forward-only, so Pallas routes are admissible
        self.dispatch_ctx = dispatch_ctx or dispatch.DispatchContext(
            differentiable=False)
        # per-engine planning policy: the dispatch knobs plus persistent
        # autotune (measured/analytic route verdicts survive serving
        # restarts via the repro.sparse disk cache); scoped to THIS
        # engine's traced programs, not process-global state
        # telemetry=False drops the per-call overflow recording (a host
        # callback per planned-capacity matmul per decode step) for
        # latency-critical deployments; plan_report() then shows only
        # plan-time capacity verdicts, no running overflow counts
        # mesh=... makes the engine's plans TP-aware: the k-sharded
        # routes (gspmd + shard_map) join every static plan's measured
        # race, and verdicts are keyed on this mesh's axis names+sizes
        self.plan_ctx = dataclasses.replace(
            sparse_api.PlanContext.from_dispatch(self.dispatch_ctx),
            telemetry=telemetry, mesh=mesh, tp_axis=tp_axis)
        if plan_cache_dir is not None:
            self.plan_ctx = dataclasses.replace(
                self.plan_ctx, cache_dir=plan_cache_dir, persist=True)
        self.caches = lm.init_cache(batch, max_len)
        self.positions = np.zeros((batch,), np.int32)
        self.live: Dict[int, Request] = {}       # slot -> request
        self.free = list(range(batch))

        def decode_fn(p, t, c, pos):
            with dispatch.use_ctx(self.dispatch_ctx), \
                    sparse_api.use_ctx(self.plan_ctx):
                return lm.decode_step(p, t, c, pos, retained=retained)

        def prefill_fn(p, t):
            with dispatch.use_ctx(self.dispatch_ctx), \
                    sparse_api.use_ctx(self.plan_ctx):
                return lm.prefill(p, t, max_len=max_len)

        self._decode = jax.jit(decode_fn)
        self._prefill = jax.jit(prefill_fn)

        def write_slot(caches, row, slot):
            return jax.tree.map(
                lambda c, r: c.at[:, slot].set(r[:, 0]), caches, row)
        self._write_slot = jax.jit(write_slot)

        # plan-first startup: abstractly trace the decode program once so
        # every matmul plan it needs is constructed NOW -- steady-state
        # decode then issues zero dispatch decisions (plan-cache hits
        # only, and after the first compile no Python at all)
        self.plan_stats: Dict[str, int] = {}
        if warm_plans:
            before = sparse_api.cache_stats()
            jax.eval_shape(
                decode_fn, self.params,
                jax.ShapeDtypeStruct((batch, 1), jnp.int32), self.caches,
                jax.ShapeDtypeStruct((batch,), jnp.int32))
            after = sparse_api.cache_stats()
            self.plan_stats = {k: after[k] - before.get(k, 0)
                               for k in ("plans_built", "plan_hits",
                                         "decisions", "measurements",
                                         "disk_hits")}

    def plan_report(self) -> dict:
        """Plans built at engine startup (decode program) + live cache
        counters + aggregated capacity/overflow telemetry (per-plan
        planned-bucket stats and MoE routing drops) + every
        tensor-parallel decision (raced candidates, measured crossover)
        + the per-plan forward/backward route table
        (``sparse.plan_report()`` -- serving plans are forward-only, so
        ``grad`` is absent here unless the engine shares a process with
        training) + per-plan roofline efficiency with the
        ``kernel_work`` routes leaving >2x headroom
        (``sparse.roofline_report()``) -- the serving view of the
        plan-first lifecycle."""
        return {"startup": dict(self.plan_stats),
                "now": sparse_api.cache_stats(),
                "capacity": sparse_api.capacity_report(),
                "tp": sparse_api.tp_report(),
                "plans": sparse_api.plan_report(),
                "roofline": sparse_api.roofline_report()}

    # -- admission --------------------------------------------------------------
    def admit(self, req: Request) -> bool:
        if not self.free:
            return False
        slot = self.free.pop()
        prompt = np.asarray(req.prompt, np.int32)[None, :]   # [1, S]
        logits, row_caches = self._prefill(self.params, prompt)
        self.caches = self._write_slot(self.caches, row_caches, slot)
        self.positions[slot] = prompt.shape[1]
        tok = int(jnp.argmax(logits[0]))
        req.output.append(tok)
        self.live[slot] = req
        return True

    # -- one decode tick -----------------------------------------------------------
    def step(self):
        if not self.live:
            return
        tokens = np.zeros((self.batch, 1), np.int32)
        for slot, req in self.live.items():
            tokens[slot, 0] = req.output[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(self.positions))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, req in self.live.items():
            tok = int(nxt[slot])
            req.output.append(tok)
            self.positions[slot] += 1
            full = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and tok == req.eos_id
            oom = self.positions[slot] >= self.max_len - 1
            if full or hit_eos or oom:
                req.done = True
                finished.append(slot)
        for slot in finished:
            del self.live[slot]
            self.free.append(slot)

    def run(self, requests: List[Request],
            on_finish: Optional[Callable[[Request], None]] = None):
        """Drive until every request completes (continuous batching)."""
        pending = list(requests)
        done: List[Request] = []
        while pending or self.live:
            while pending and self.free:
                self.admit(pending.pop(0))
            self.step()
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
                    if on_finish:
                        on_finish(r)
        return requests
