"""Request-queue continuous-batching engine over bucketed prefill pools.

A fixed [B, max_len] cache is compiled once; requests are admitted into
free slots as others finish -- vLLM-style continuous batching reduced to
its TPU-friendly static-shape core, with the plan-first lifecycle
running end to end:

* **Bucketed prefill**: prompts are right-padded to a shape bucket, so
  prefill compiles once per *bucket*, not once per prompt length.  The
  bucket ladder is chosen analytically at startup by the calibrated
  cost model (``dispatch.price_tokens`` over the model's matmul stack):
  buckets grow geometrically until the priced padding waste of the
  worst-padded prompt would exceed ``pad_max_frac``.  Padding is
  correct because logits are gathered at the *true* last prompt token
  (``LM.prefill(last_index=...)``) and decode attention masks cache
  slots beyond each slot's true position; SSM/hybrid stacks carry
  recurrent state that padding WOULD corrupt, so the engine detects
  them and falls back to exact-length prefill.
* **Plan pools**: every matmul plan the engine's programs build is
  registered under this engine's ``ctx.pool`` label; warmup abstractly
  traces the decode program and every bucket's prefill program
  (``jax.eval_shape``), so steady-state serving issues zero dispatch
  decisions and (with ``warm_compile=True``) zero recompiles.
* **Cost-priced admission**: each admission picks the cheapest
  admissible bucket and accounts the priced padding waste; prompts no
  bucket can hold under ``pad_max_frac`` fall back to exact-length
  prefill (counted -- an operator signal that the ladder is wrong).
* **Async re-planner**: a background thread upgrades the pool's
  analytic route verdicts to measured ones (``sparse.remeasure_plan``)
  while serving, so cold starts never block on a measurement race.
* **Live stats**: ``stats()`` / ``plan_report()["engine"]`` expose
  per-bucket prefill p50/p99 latency, decode-step p50/p99, queue depth,
  padding waste (tokens and priced seconds), capacity overflow, and
  ``dropped_frac`` under a bounded queue.

Termination contract: ``Request.output`` INCLUDES the token generated
at prefill, so a request finishes once ``len(output) >=
max_new_tokens`` -- ``max_new_tokens=4`` yields exactly 4 tokens, the
prefill token plus 3 decode tokens.  ``eos_id`` is honored everywhere a
token is produced, including at prefill (the slot frees immediately,
before a single decode step).

``retained=True`` serves long contexts with the ring-buffer
local+global cache -- the paper's static block sparsity keeping
500k-token decode O(window) (DESIGN.md §3).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import (Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro import sparse as sparse_api
from repro.core import dispatch
from repro.models.config import ModelCfg
from repro.models.model import LM

# engine pool labels must be process-unique: two engines over the same
# checkpoint would otherwise share a pool and re-plan each other's work
_ENGINE_SEQ = itertools.count()

_LATENCY_WINDOW = 2048          # rolling percentile window (per stream)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    bucket: Optional[int] = None        # prefill bucket used (None=exact)
    dropped: bool = False               # rejected by a bounded queue


def _pad_safe(cfg: ModelCfg) -> bool:
    """May prompts be right-padded to a shape bucket?  Attention-only
    stacks: pad rows beyond a slot's true position are never attended
    (decode masks ``slot > position``).  Any recurrent mixer (mamba)
    folds every input row into its state, so padding would corrupt it --
    those stacks serve with exact-length prefill."""
    return all(spec.mixer != "mamba"
               for period, _ in cfg.groups for spec in period)


def _stack_shapes(cfg: ModelCfg) -> List[Tuple[int, int]]:
    """The ``[m, k]`` matmul stack one token traverses -- the pricing
    model behind bucket selection and admission (``price_tokens``).  A
    per-layer proxy (MLA priced at GQA geometry, MoE at top-k expert
    FFNs, mamba at its in/out projections): admission pricing needs
    relative cost across token counts, not kernel-exact FLOPs."""
    d = cfg.d_model
    qd, kvd = cfg.attn_dims
    gated = cfg.act in ("silu", "gelu")
    shapes: List[Tuple[int, int]] = []
    for period, rep in cfg.groups:
        for spec in period:
            for _ in range(rep):
                if spec.mixer == "mamba" and cfg.ssm is not None:
                    di = cfg.ssm.d_inner(d)
                    shapes += [(2 * di, d), (d, di)]
                else:
                    shapes += [(qd + 2 * kvd, d), (d, qd)]
                if spec.ffn == "none":
                    continue
                if spec.ffn == "moe" and cfg.moe is not None:
                    m = cfg.moe
                    shapes.append((m.num_experts, d))        # router
                    ff = m.d_ff_expert * (m.top_k + m.num_shared)
                    shapes += [(ff * (2 if gated else 1), d), (d, ff)]
                else:
                    ff = cfg.d_ff
                    if spec.ffn == "sparse" and cfg.ffn_density:
                        ff = max(1, int(ff * cfg.ffn_density))
                    shapes += [(ff * (2 if gated else 1), d), (d, ff)]
    shapes.append((cfg.vocab_size, d))                       # unembed
    return shapes


def _auto_buckets(top: int, shapes: Sequence[Tuple[int, int]],
                  pad_max_frac: float, *,
                  granularity: int = 16) -> Tuple[int, ...]:
    """Analytic bucket ladder: starting from the smallest bucket, each
    next bucket is the largest size whose *priced* padding waste for
    the worst-padded prompt (one token past the previous bucket) stays
    under ``pad_max_frac`` -- cost-model geometry instead of blind
    powers of two, so fixed per-call overheads (which make short
    prefills cheap to pad) widen the small buckets and the ladder stays
    short.  Always ends at ``top`` (= max_len - 1, the longest
    admissible prompt)."""
    if top <= granularity:
        return (top,)
    price = {}

    def _p(n: int) -> float:
        if n not in price:
            price[n] = dispatch.price_tokens(shapes, n)
        return price[n]

    buckets = [granularity]
    while buckets[-1] < top:
        lo = buckets[-1]
        nxt = min(lo + granularity, top)
        cand = nxt + granularity
        while cand <= top:
            if 1.0 - _p(lo + 1) / _p(cand) > pad_max_frac:
                break
            nxt = cand
            cand += granularity
        buckets.append(nxt)
    return tuple(buckets)


def _percentiles(samples: Sequence[float]) -> dict:
    if not samples:
        return {"count": 0, "p50_ms": None, "p99_ms": None}
    arr = np.asarray(samples, np.float64) * 1e3
    return {"count": int(arr.size),
            "p50_ms": round(float(np.percentile(arr, 50)), 4),
            "p99_ms": round(float(np.percentile(arr, 99)), 4)}


class Engine:
    def __init__(self, lm: LM, params, *, batch: int, max_len: int,
                 retained: bool = False, sample: str = "greedy",
                 dispatch_ctx: Optional[dispatch.DispatchContext] = None,
                 plan_cache_dir: Optional[str] = None,
                 warm_plans: bool = True, warm_compile: bool = False,
                 telemetry: bool = True,
                 mesh=None, tp_axis: str = "model",
                 buckets: Optional[Sequence[int]] = None,
                 pad_max_frac: float = 0.75,
                 max_queue: Optional[int] = None,
                 replanner: bool = False,
                 replanner_interval: float = 0.25,
                 replanner_reps: int = 3):
        self.lm = lm
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.retained = retained
        self.pool = f"engine:{lm.cfg.name}:{next(_ENGINE_SEQ)}"
        # every matmul in the traced programs consults this context (the
        # decode/prefill matmul plans are built at engine startup);
        # serving is forward-only, so Pallas routes are admissible
        self.dispatch_ctx = dispatch_ctx or dispatch.DispatchContext(
            differentiable=False)
        # per-engine planning policy: the dispatch knobs plus persistent
        # autotune (measured/analytic route verdicts survive serving
        # restarts via the repro.sparse disk cache); scoped to THIS
        # engine's traced programs, not process-global state.  The
        # ``pool`` label lets the engine enumerate exactly its own plans
        # (sparse.pool_plans) -- the re-planner's worklist.
        # telemetry=False drops the per-call overflow recording (a host
        # callback per planned-capacity matmul per decode step) for
        # latency-critical deployments; plan_report() then shows only
        # plan-time capacity verdicts, no running overflow counts
        # mesh=... makes the engine's plans TP-aware: the k-sharded
        # routes (gspmd + shard_map) join every static plan's measured
        # race, and verdicts are keyed on this mesh's axis names+sizes
        self.plan_ctx = dataclasses.replace(
            sparse_api.PlanContext.from_dispatch(self.dispatch_ctx),
            telemetry=telemetry, mesh=mesh, tp_axis=tp_axis,
            pool=self.pool)
        if plan_cache_dir is not None:
            self.plan_ctx = dataclasses.replace(
                self.plan_ctx, cache_dir=plan_cache_dir, persist=True)
        self.caches = lm.init_cache(batch, max_len)
        self.positions = np.zeros((batch,), np.int32)
        self.live: Dict[int, Request] = {}       # slot -> request
        self.free = list(range(batch))
        self.queue: Deque[Request] = collections.deque()
        self.max_queue = max_queue

        # -- bucket ladder (cost-model geometry) ---------------------------
        self.pad_max_frac = float(pad_max_frac)
        self._shapes = _stack_shapes(lm.cfg)
        self.pad_safe = _pad_safe(lm.cfg)
        top = max_len - 1
        if not self.pad_safe:
            self.buckets: Tuple[int, ...] = ()   # exact-length prefill
        elif buckets is not None:
            ladder = sorted({int(b) for b in buckets if 1 <= b <= top})
            if not ladder or ladder[-1] < top:
                ladder.append(top)
            self.buckets = tuple(ladder)
        else:
            self.buckets = _auto_buckets(top, self._shapes,
                                         self.pad_max_frac)
        self._price_cache: Dict[int, float] = {}

        # -- stats ----------------------------------------------------------
        self._stats_lock = threading.Lock()
        self._counters = collections.Counter()
        self._steps = 0
        self._peak_queue = 0
        self._step_lat: Deque[float] = collections.deque(
            maxlen=_LATENCY_WINDOW)
        self._bucket_stats: Dict[int, dict] = {
            L: {"prefills": 0, "prompt_tokens": 0, "pad_tokens": 0,
                "priced_waste_s": 0.0,
                "latency": collections.deque(maxlen=_LATENCY_WINDOW)}
            for L in self.buckets}

        # -- traced programs ------------------------------------------------
        def decode_fn(p, t, c, pos):
            with dispatch.use_ctx(self.dispatch_ctx), \
                    sparse_api.use_ctx(self.plan_ctx):
                return lm.decode_step(p, t, c, pos, retained=retained)

        def prefill_fn(p, t, last_index):
            with dispatch.use_ctx(self.dispatch_ctx), \
                    sparse_api.use_ctx(self.plan_ctx):
                return lm.prefill(p, t, max_len=max_len,
                                  last_index=last_index)

        self._decode = jax.jit(decode_fn)
        # one jitted program; XLA caches per token-length -- so exactly
        # one compile per bucket (plus one per exact-length fallback)
        self._prefill = jax.jit(prefill_fn)

        def write_slot(caches, row, slot):
            return jax.tree.map(
                lambda c, r: c.at[:, slot].set(r[:, 0]), caches, row)
        self._write_slot = jax.jit(write_slot)

        # plan-first startup: abstractly trace the decode program AND
        # every bucket's prefill program once, so every matmul plan the
        # engine needs is constructed NOW (disk-cached verdicts replay
        # with zero measurements) -- steady-state serving then issues
        # zero dispatch decisions: plan-cache hits only, and after the
        # per-bucket compile no Python at all
        self.plan_stats: Dict[str, int] = {}
        if warm_plans:
            before = sparse_api.cache_stats()
            jax.eval_shape(
                decode_fn, self.params,
                jax.ShapeDtypeStruct((batch, 1), jnp.int32), self.caches,
                jax.ShapeDtypeStruct((batch,), jnp.int32))
            for L in self.buckets:
                jax.eval_shape(
                    prefill_fn, self.params,
                    jax.ShapeDtypeStruct((1, L), jnp.int32),
                    jax.ShapeDtypeStruct((1,), jnp.int32))
            after = sparse_api.cache_stats()
            self.plan_stats = {k: after[k] - before.get(k, 0)
                               for k in ("plans_built", "plan_hits",
                                         "decisions", "measurements",
                                         "disk_hits")}
        if warm_compile:
            self._warm_compile()

        self._replan_thread: Optional[threading.Thread] = None
        self._replan_stop: Optional[threading.Event] = None
        self._replanner_reps = replanner_reps
        if replanner:
            self.start_replanner(interval=replanner_interval,
                                 reps=replanner_reps)

    # -- warmup -----------------------------------------------------------
    def _warm_compile(self):
        """Compile every foreground program up front (one prefill per
        bucket, the decode step, the slot writer) so the serving loop
        never hits an XLA compile.  Results are discarded; engine cache
        state is untouched."""
        row = None
        for L in self.buckets:
            logits, row = self._prefill(
                self.params, jnp.zeros((1, L), jnp.int32),
                jnp.zeros((1,), jnp.int32))
            logits.block_until_ready()
        if row is not None:
            jax.block_until_ready(
                self._write_slot(self.caches, row, 0))
        logits, _ = self._decode(
            self.params, jnp.zeros((self.batch, 1), jnp.int32),
            self.caches, jnp.zeros((self.batch,), jnp.int32))
        logits.block_until_ready()

    # -- pricing ----------------------------------------------------------
    def _price(self, n_tokens: int) -> float:
        """Calibrated model-seconds for one prefill of ``n_tokens``
        through this model's matmul stack (memoized)."""
        p = self._price_cache.get(n_tokens)
        if p is None:
            p = self._price_cache[n_tokens] = dispatch.price_tokens(
                self._shapes, n_tokens)
        return p

    def bucket_for(self, prompt_len: int) -> Optional[int]:
        """Admission's padding policy: the smallest bucket holding the
        prompt, unless its priced padding waste exceeds
        ``pad_max_frac`` -- then None (exact-length prefill; larger
        buckets only waste more)."""
        for L in self.buckets:
            if L >= prompt_len:
                waste = 1.0 - self._price(prompt_len) / self._price(L)
                if waste <= self.pad_max_frac:
                    return L
                break
        return None

    # -- reports ----------------------------------------------------------
    def stats(self) -> dict:
        """Live serving telemetry -- the engine section of
        ``plan_report()``.  Latency percentiles are over a rolling
        window of the last ``2048`` samples per stream."""
        with self._stats_lock:
            c = dict(self._counters)
            buckets = {
                L: {"prefills": b["prefills"],
                    "prompt_tokens": b["prompt_tokens"],
                    "pad_tokens": b["pad_tokens"],
                    "priced_waste_s": round(b["priced_waste_s"], 9),
                    "latency": _percentiles(b["latency"])}
                for L, b in self._bucket_stats.items()}
            step_lat = _percentiles(self._step_lat)
            steps = self._steps
            peak_queue = self._peak_queue
            replan = {
                "running": self._replan_thread is not None
                and self._replan_thread.is_alive(),
                "sweeps": c.pop("replan_sweeps", 0),
                "upgrades": c.pop("replan_upgrades", 0),
            }
        submitted = c.get("submitted", 0)
        prompt_tokens = sum(b["prompt_tokens"] for b in buckets.values())
        pad_tokens = sum(b["pad_tokens"] for b in buckets.values())
        denom = prompt_tokens + pad_tokens
        return {
            "buckets": buckets,
            "pad_safe": self.pad_safe,
            "queue_depth": len(self.queue),
            "peak_queue_depth": peak_queue,
            "live_slots": len(self.live),
            "free_slots": len(self.free),
            "steps": steps,
            "step_latency": step_lat,
            "padding": {
                "prompt_tokens": prompt_tokens,
                "pad_tokens": pad_tokens,
                "waste_frac": (round(pad_tokens / denom, 6)
                               if denom else 0.0),
                "priced_waste_s": round(
                    sum(b["priced_waste_s"] for b in buckets.values()),
                    9),
            },
            "admission": {
                "submitted": submitted,
                "admitted": c.get("admitted", 0),
                "finished": c.get("finished", 0),
                "eos_at_prefill": c.get("eos_at_prefill", 0),
                "exact_prefills": c.get("exact_prefills", 0),
                "dropped": c.get("dropped", 0),
                "dropped_frac": (round(c.get("dropped", 0) / submitted, 6)
                                 if submitted else 0.0),
            },
            "capacity_overflow":
                sparse_api.capacity_report()["totals"],
            "replanner": replan,
        }

    def plan_report(self) -> dict:
        """Plans built at engine startup (decode + every prefill
        bucket) + live cache counters + aggregated capacity/overflow
        telemetry (per-plan planned-bucket stats and MoE routing drops)
        + every tensor-parallel decision (raced candidates, measured
        crossover) + the per-plan forward/backward route table
        (``sparse.plan_report()`` -- serving plans are forward-only, so
        ``grad`` is absent here unless the engine shares a process with
        training) + per-plan roofline efficiency with the
        ``kernel_work`` routes leaving >2x headroom
        (``sparse.roofline_report()``) + this engine's live serving
        stats (``engine`` section: per-bucket latency, queue depth,
        padding waste, dropped_frac) -- the serving view of the
        plan-first lifecycle."""
        return {"startup": dict(self.plan_stats),
                "now": sparse_api.cache_stats(),
                "capacity": sparse_api.capacity_report(),
                "tp": sparse_api.tp_report(),
                "plans": sparse_api.plan_report(),
                "roofline": sparse_api.roofline_report(),
                "engine": self.stats()}

    # -- admission --------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue a request (validated now, admitted when a slot
        frees).  Under a bounded queue (``max_queue``) a full queue
        drops the request -- ``req.dropped`` is set and the drop counts
        toward ``stats()["admission"]["dropped_frac"]``."""
        self._validate(req)
        with self._stats_lock:
            self._counters["submitted"] += 1
            if (self.max_queue is not None
                    and len(self.queue) >= self.max_queue):
                self._counters["dropped"] += 1
                req.dropped = True
                return False
        self.queue.append(req)
        with self._stats_lock:
            self._peak_queue = max(self._peak_queue, len(self.queue))
        return True

    def _validate(self, req: Request):
        n = int(np.asarray(req.prompt).size)
        if n < 1:
            raise ValueError("empty prompt: a request needs at least "
                             "one prompt token")
        if n >= self.max_len:
            raise ValueError(
                f"prompt length {n} does not fit the engine cache: "
                f"max_len={self.max_len} admits prompts of at most "
                f"{self.max_len - 1} tokens (one cache slot must remain "
                f"for decode)")

    def admit(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot (False when none is free).
        The prompt is padded to the cheapest admissible bucket; the
        first generated token is appended to ``req.output``.  EOS at
        prefill (or ``max_new_tokens <= 1``) finishes the request here
        -- the slot frees immediately, no decode step is spent."""
        self._validate(req)
        if not self.free:
            return False
        slot = self.free.pop()
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        n = prompt.shape[0]
        bucket = self.bucket_for(n)
        if bucket is None:
            padded = prompt[None, :]
        else:
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :n] = prompt
        t0 = time.perf_counter()
        logits, row_caches = self._prefill(
            self.params, jnp.asarray(padded),
            jnp.asarray([n - 1], jnp.int32))
        tok = int(np.asarray(logits[0]).argmax())
        dt = time.perf_counter() - t0
        self.caches = self._write_slot(self.caches, row_caches, slot)
        self.positions[slot] = n
        req.output.append(tok)
        req.bucket = bucket
        with self._stats_lock:
            self._counters["admitted"] += 1
            if bucket is None:
                self._counters["exact_prefills"] += 1
            else:
                b = self._bucket_stats[bucket]
                b["prefills"] += 1
                b["prompt_tokens"] += n
                b["pad_tokens"] += bucket - n
                b["priced_waste_s"] += self._price(bucket) \
                    - self._price(n)
                b["latency"].append(dt)
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(req.output) >= req.max_new_tokens:
            req.done = True
            self.free.append(slot)
            with self._stats_lock:
                self._counters["finished"] += 1
                if hit_eos:
                    self._counters["eos_at_prefill"] += 1
            return True
        self.live[slot] = req
        return True

    # -- one decode tick ---------------------------------------------------
    def step(self) -> List[Request]:
        """One decode token for every live slot.  Returns the requests
        that finished THIS step (their slots are already free) -- the
        slot-release bookkeeping `run` fires ``on_finish`` from, so no
        caller ever rescans the full request list."""
        if not self.live:
            return []
        t0 = time.perf_counter()
        tokens = np.zeros((self.batch, 1), np.int32)
        for slot, req in self.live.items():
            tokens[slot, 0] = req.output[-1]
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens), self.caches,
            jnp.asarray(self.positions))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished: List[Request] = []
        released: List[int] = []
        for slot, req in self.live.items():
            tok = int(nxt[slot])
            req.output.append(tok)
            self.positions[slot] += 1
            full = len(req.output) >= req.max_new_tokens
            hit_eos = req.eos_id is not None and tok == req.eos_id
            oom = self.positions[slot] >= self.max_len - 1
            if full or hit_eos or oom:
                req.done = True
                finished.append(req)
                released.append(slot)
        for slot in released:
            del self.live[slot]
            self.free.append(slot)
        with self._stats_lock:
            self._steps += 1
            self._step_lat.append(time.perf_counter() - t0)
            self._counters["finished"] += len(finished)
        return finished

    # -- the serving loop ---------------------------------------------------
    def serve(self,
              on_finish: Optional[Callable[[Request], None]] = None):
        """Drive until the queue and every live slot drain.
        ``on_finish`` fires exactly once per finished request, straight
        from admission / slot-release bookkeeping."""
        while self.queue or self.live:
            while self.queue and self.free:
                req = self.queue.popleft()
                self.admit(req)
                if req.done and on_finish:
                    on_finish(req)
            for req in self.step():
                if on_finish:
                    on_finish(req)

    def run(self, requests: List[Request],
            on_finish: Optional[Callable[[Request], None]] = None):
        """Enqueue ``requests`` and serve until done (continuous
        batching).  Dropped requests (bounded queue) never fire
        ``on_finish``; check ``req.dropped``."""
        for r in requests:
            self.submit(r)
        self.serve(on_finish=on_finish)
        return requests

    # -- background re-planner ----------------------------------------------
    def replan_once(self, *, reps: Optional[int] = None) -> int:
        """One synchronous re-planner sweep: upgrade every analytic
        route verdict in this engine's plan pool to a measured one
        (``sparse.remeasure_plan``).  Returns the number of upgrades.
        Safe to call while serving: already-compiled programs keep
        their route; upgrades apply to new traces and, via the disk
        cache, to restarts."""
        n = 0
        for p in sparse_api.analytic_plans(self.pool):
            info = sparse_api.remeasure_plan(
                p, reps=self._replanner_reps if reps is None else reps)
            if info:
                n += 1
        with self._stats_lock:
            self._counters["replan_sweeps"] += 1
            self._counters["replan_upgrades"] += n
        return n

    def start_replanner(self, *, interval: float = 0.25,
                        reps: Optional[int] = None):
        """Start the async re-planner thread: periodically sweeps this
        engine's pool, upgrading analytic verdicts to measured ones in
        the background so serving never blocks on a measurement race.
        Idempotent; stop with ``stop_replanner()`` (also safe to leave
        running -- the thread is a daemon)."""
        if self._replan_thread is not None \
                and self._replan_thread.is_alive():
            return
        stop = threading.Event()

        def loop():
            while not stop.is_set():
                self.replan_once(reps=reps)
                if stop.wait(interval):
                    return

        self._replan_stop = stop
        self._replan_thread = threading.Thread(
            target=loop, name=f"replanner[{self.pool}]", daemon=True)
        self._replan_thread.start()

    def stop_replanner(self, timeout: float = 10.0):
        if self._replan_stop is not None:
            self._replan_stop.set()
        if self._replan_thread is not None:
            self._replan_thread.join(timeout)
        self._replan_thread = None
        self._replan_stop = None
