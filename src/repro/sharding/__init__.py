from repro.sharding.rules import (  # noqa: F401
    batch_axes, cache_specs, make_shardings, param_specs, train_batch_specs,
    train_state_specs)
