"""Named-axis sharding rules: pytree paths -> PartitionSpec.

Strategy (DESIGN.md §3 Parallelism):

* weights: TP axis over ``model`` (heads / d_ff / experts / vocab) and an
  FSDP axis over ``data`` on the other large dim where divisible --
  optimizer state inherits the same specs, so Adam moments are spread
  over data*model chips (ZeRO-flavoured without extra machinery);
* weights are replicated over ``pod``; gradients all-reduce across pods;
* the paper's partitioner analogy: the ``model``-axis split of a sparse
  operand is nnz-balanced by ``core/partitioner.shard_blocks_by_k``, and
  the TP SpMM reduction is the paper's "final reduction across tiles";
* activations: batch over ('pod','data'); KV cache sequence over 'model'
  (flash-decoding style split-K softmax falls out of GSPMD); batch-1
  long-context shards sequence over ('data','model').

Divisibility fallback: any dim not divisible by its axis product is left
unsharded (replicated on that axis) -- the "logical rules + fallback"
contract that lets one rule set serve all ten architectures.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
import re
from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(mesh, dim: int, names):
    """Return ``names`` if dim divides by their product, else None."""
    if isinstance(names, str):
        names = (names,)
    names = tuple(n for n in names if n in mesh.axis_names)
    if not names:
        return None
    return names if dim % _axis_size(mesh, names) == 0 else None


def _spec(mesh, shape, base_ndim, last_dims):
    """PartitionSpec: leading (stacking) dims None, trailing per rule.

    ``last_dims``: tuple of axis-name-or-None for the final ``base_ndim``
    dims, each checked for divisibility.
    """
    lead = len(shape) - base_ndim
    spec = [None] * lead
    for d, names in zip(shape[lead:], last_dims):
        fit = _fit(mesh, d, names) if names else None
        if fit is None:
            spec.append(None)
        else:
            spec.append(fit if len(fit) > 1 else fit[0])
    return P(*spec)


# -- activation constraints ----------------------------------------------------
#
# GSPMD sharding propagation through nested lax.scan carries is best-effort
# and in practice drops the batch sharding at loop boundaries (verified on
# the llama train_4k dry-run: score-space ops ran with global batch).  The
# model code therefore re-anchors activations at block boundaries with
# ``constrain`` -- a no-op unless a mesh was installed via
# ``activation_mesh`` (smoke tests / single-device runs never see it).

_ACT_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_activation_mesh", default=None)

_GROUPS = {
    "batch": ("pod", "data"),
    "model": ("model",),
    "seq": ("pod", "data", "model"),
}


@contextlib.contextmanager
def activation_mesh(mesh):
    """Install ``mesh`` for activation constraints during tracing."""
    tok = _ACT_MESH.set(mesh)
    try:
        yield
    finally:
        _ACT_MESH.reset(tok)


def current_mesh():
    """The mesh installed by ``activation_mesh`` (None outside)."""
    return _ACT_MESH.get()


def constrain(x, *dims):
    """with_sharding_constraint by logical dim group names.

    ``dims``: per-dimension group name ('batch'|'model'|'seq'|mesh axis)
    or None; shorter than ndim is padded with None.  Axes absent from the
    installed mesh or non-divisible dims degrade to None.
    """
    mesh = _ACT_MESH.get()
    if mesh is None:
        return x
    spec = []
    padded = list(dims) + [None] * (x.ndim - len(dims))
    for d, names in zip(x.shape, padded):
        if names is None:
            spec.append(None)
            continue
        cand = _GROUPS.get(names, (names,))
        cand = tuple(n for n in cand if n in mesh.axis_names)
        prod = math.prod(mesh.shape[n] for n in cand) if cand else 1
        if cand and d % prod == 0:
            spec.append(cand if len(cand) > 1 else cand[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# -- parameter rules ---------------------------------------------------------

_PARAM_RULES = [
    # (path regex, base_ndim, last-dim axes)
    (r"\['table'\]$",                2, ("model", "data")),
    (r"\['(wq|wk|wv)'\]\['w'\]$",    2, ("data", "model")),
    (r"\['(wq|wk|wv)'\]\['b'\]$",    1, ("model",)),
    (r"\['wo'\]\['w'\]$",            2, ("model", "data")),
    (r"\['q'\]\['a'\]\['w'\]$",      2, ("data", None)),
    (r"\['q'\]\['b'\]\['w'\]$",      2, (None, "model")),
    (r"\['q'\]\['w'\]\['w'\]$",      2, ("data", "model")),
    (r"\['kv_a'\]\['w'\]$",          2, ("data", None)),
    (r"\['kv_b'\]\['w'\]$",          2, (None, "model")),
    (r"\['(up|gate)'\]\['w'\]$",     2, ("data", "model")),
    (r"\['(up|gate)'\]\['b'\]$",     1, ("model",)),
    (r"\['down'\]\['w'\]$",          2, ("model", "data")),
    (r"\['down'\]\['b'\]$",          1, (None,)),
    (r"\['(w_gate|w_up)'\]$",        3, ("model", "data", None)),
    (r"\['w_down'\]$",               3, ("model", "data", None)),
    (r"\['router'\]",                2, (None, None)),
    (r"\['in_proj'\]\['w'\]$",       2, ("data", None)),
    (r"\['out_proj'\]\['w'\]$",      2, ("model", "data")),
    (r"\['values'\]$",               3, ("model", None, None)),  # BSR blocks
]


def _param_spec_for(mesh, path_str: str, shape) -> P:
    for pat, base, dims in _PARAM_RULES:
        if re.search(pat, path_str):
            return _spec(mesh, shape, base, dims)
    if len(shape) >= 2 and shape[-1] >= 128 and shape[-2] >= 128:
        return _spec(mesh, shape, 2, ("data", "model"))  # generic 2D weight
    return P()  # norms, scalars, biases: replicated


def param_specs(params, mesh):
    """Pytree of PartitionSpec matching ``params`` (SDS or arrays)."""
    def f(path, leaf):
        return _param_spec_for(mesh, jax.tree_util.keystr(path), leaf.shape)
    return jax.tree_util.tree_map_with_path(f, params)


# -- train state --------------------------------------------------------------

def train_state_specs(state, mesh):
    """TrainState: params/master/moments share param specs; scalars
    replicated."""
    def f(path, leaf):
        ps = jax.tree_util.keystr(path)
        if leaf.ndim == 0:
            return P()
        # strip the TrainState field prefix so param rules match
        return _param_spec_for(mesh, ps, leaf.shape)
    return jax.tree_util.tree_map_with_path(f, state)


def train_batch_specs(batch, mesh):
    ba = batch_axes(mesh)

    def f(_, leaf):
        if leaf.ndim == 0:
            return P()
        fit = _fit(mesh, leaf.shape[0], ba)
        first = (fit if fit and len(fit) > 1 else
                 (fit[0] if fit else None))
        return P(first, *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(f, batch)


# -- caches --------------------------------------------------------------------

def cache_specs(caches, mesh, *, batch: int):
    """KV / state caches.  Layout [L, B, S, ...] (stacked scan axis first).

    batch >= |pod|*|data|  -> B over ('pod','data'), S over 'model';
    batch == 1 (long ctx)  -> S over ('data','model') (+'pod' if present).
    """
    ba = batch_axes(mesh)
    b_fit = batch % _axis_size(mesh, ba) == 0 if ba else False
    seq_axes = ("model",) if b_fit else tuple(
        a for a in ("pod", "data", "model") if a in mesh.axis_names)

    def f(path, leaf):
        ps = jax.tree_util.keystr(path)
        shape = leaf.shape
        spec = [None] * leaf.ndim
        # dim 0 = stacked layer axis; dim 1 = batch
        if leaf.ndim >= 2 and shape[1] == batch and b_fit:
            spec[1] = ba if len(ba) > 1 else ba[0]
        if re.search(r"\['(k|v|latent|k_rope|xk|xv)'\]$", ps) and leaf.ndim >= 3:
            fit = _fit(mesh, shape[2], seq_axes)
            if fit:
                spec[2] = fit if len(fit) > 1 else fit[0]
        elif re.search(r"\['state'\]$", ps) and leaf.ndim >= 3:
            fit = _fit(mesh, shape[2], "model")   # heads
            if fit:
                spec[2] = fit[0]
        return P(*spec)
    return jax.tree_util.tree_map_with_path(f, caches)


# -- convenience ----------------------------------------------------------------

def make_shardings(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
