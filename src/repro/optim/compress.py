"""Error-feedback int8 gradient compression.

Reduces DP all-reduce volume 4x (fp32->int8 + per-tensor scale).  The
quantization error is carried in a residual buffer and added back next
step (error feedback, Seide et al. 2014 / Karimireddy et al. 2019), which
preserves convergence (tested in tests/test_optim.py).

On a real pod this wraps the gradient all-reduce inside ``shard_map``
(quantize -> psum int32 -> dequantize); under GSPMD-only programs we
apply quantize+dequantize around the (automatic) all-reduce, which
models the numerics exactly and the wire volume analytically.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: object    # same structure as grads, fp32


def ef_init(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(x):
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef: EFState):
    """Returns (decompressed grads as seen post-allreduce, new EF state)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x)
        d = _dequantize(q, s)
        return d, x - d

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    deq = jax.tree.unflatten(treedef, [o[0] for o in out])
    res = jax.tree.unflatten(treedef, [o[1] for o in out])
    return deq, EFState(res)


def wire_bytes(grads) -> int:
    """Analytic all-reduce volume with/without compression."""
    n = sum(int(jnp.size(g)) for g in jax.tree.leaves(grads))
    return {"fp32": 4 * n, "int8": n + 4 * len(jax.tree.leaves(grads))}
