"""Optimizer substrate: AdamW with fp32 master weights, global-norm
clipping, warmup+cosine schedule, and error-feedback int8 gradient
compression (DP all-reduce volume reduction)."""
from repro.optim.adamw import (  # noqa: F401
    adamw_init, adamw_update, clip_by_global_norm, global_norm)
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim import compress  # noqa: F401
