"""AdamW with fp32 master weights.

Model params stay in their compute dtype (bf16); the optimizer carries an
fp32 master copy + moments.  All state tensors inherit the param sharding
(ZeRO-style sharding comes from the param specs already spreading large
axes over 'data'/'model'; see sharding/rules.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    count: jax.Array      # [] int32
    master: object        # fp32 copy of params
    mu: object            # first moment (fp32)
    nu: object            # second moment (fp32)


def adamw_init(params) -> AdamState:
    # copy=True: fp32 leaves must not alias params (donation safety)
    def f32(p):
        return jnp.array(p, jnp.float32, copy=True)

    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamState(jnp.zeros((), jnp.int32),
                     jax.tree.map(f32, params),
                     jax.tree.map(zeros, params),
                     jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """Returns (new_params, new_state).  ``lr`` may be a traced scalar."""
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, w):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        w = w - lr * (step + weight_decay * w)
        return m, v, w

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_w = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    flat_p = treedef.flatten_up_to(params)
    new_params = jax.tree.unflatten(
        treedef, [w.astype(p.dtype) for w, p in
                  zip([o[2] for o in out], flat_p)])
    return new_params, AdamState(count, master, mu, nu)
