"""Fault-tolerant checkpointing: atomic, async, elastic.

* **atomic**: writes land in ``step_N.tmp`` and are renamed to ``step_N``
  only after a manifest with content hashes is complete -- a preempted
  writer can never corrupt the latest checkpoint;
* **async**: ``Checkpointer.save_async`` snapshots to host memory
  synchronously (cheap) and writes in a daemon thread, bounding the
  training-loop stall to the device->host copy;
* **sharded**: each leaf is saved per-host as its addressable shards with
  index metadata (single-process here, but the format keeps the
  (global_shape, index) contract so multi-host writers merge);
* **elastic**: the manifest stores *logical* PartitionSpecs (axis names),
  not device ids; ``restore(..., mesh=new_mesh, specs=...)`` re-shards
  onto a different mesh -- restart on 2 pods from a 1-pod checkpoint.

Leaves are .npy files addressed by the flattened pytree path; the tree
structure is serialized separately, so params may be restored into a
differently-ordered (but same-keyed) pytree.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}, \
        jax.tree_util.tree_structure(tree)


def _leaf_file(key: str) -> str:
    return hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"


def save(path: str, tree, *, step: int, extra: Optional[dict] = None):
    """Synchronous atomic save of a pytree."""
    flat, _ = _flatten(tree)
    final = os.path.join(path, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_file(key)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)         # atomicity point
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str, like, *, step: Optional[int] = None,
            mesh=None, specs=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``mesh``+``specs`` the leaves are placed
    as sharded global arrays on that mesh (elastic re-sharding)."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, _ = _flatten(like)
    flat_specs = _flatten(specs)[0] if specs is not None else {}
    out = {}
    for key, leaf in flat_like.items():
        info = manifest["leaves"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(d, info["file"]))
        if arr.dtype.kind == "V":
            # numpy round-trips ml_dtypes (bf16 etc.) as raw void bytes;
            # reinterpret using the dtype recorded in the manifest
            arr = arr.view(np.dtype(jax.numpy.dtype(info["dtype"])))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        if mesh is not None and key in flat_specs:
            sharding = jax.sharding.NamedSharding(mesh, flat_specs[key])
            out[key] = jax.device_put(arr.astype(leaf.dtype), sharding)
        else:
            out[key] = jax.numpy.asarray(arr.astype(leaf.dtype))
    # rebuild tree in like's structure
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    rebuilt = [out[jax.tree_util.keystr(p)] for p, _ in leaves]
    return jax.tree_util.tree_unflatten(treedef, rebuilt), \
        manifest["extra"], step


class Checkpointer:
    """Async writer with bounded in-flight saves + retention policy."""

    def __init__(self, path: str, *, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(path, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, tree, *, step: int, extra: Optional[dict] = None):
        self.wait()                       # bound in-flight saves to 1
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save(self.path, host_tree, step=step, extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.path)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s}"),
                          ignore_errors=True)
