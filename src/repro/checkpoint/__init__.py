from repro.checkpoint.checkpoint import (  # noqa: F401
    Checkpointer, latest_step, restore, save)
