"""Benchmark regression gate: compare a fresh BENCH_*.json against the
committed baseline and fail on a >tolerance regression of any checked
ratio.

    PYTHONPATH=src python tools/bench_check.py \
        experiments/bench/BENCH_dispatch.json \
        experiments/bench/BENCH_grouped_capacity.json \
        experiments/bench/BENCH_tp.json [--tolerance 0.15] [--update]

Baselines live in ``benchmarks/baselines/<same file name>`` and are
committed; ``--update`` rewrites them from the current files (do this
deliberately, in the PR that changes the cost model or the planner, so
the diff review *is* the regression sign-off).

Checked ratios are the **deterministic** ones -- pure cost-model /
planner outputs that move only when code changes, never with runner
noise -- so a 15% tolerance is a real gate, not flake insurance:

* ``dispatch``          speedup of the chosen route vs dense_xla
                        (candidates are analytic estimates), plus the
                        chosen route itself (a route flip at the same
                        grid point is exactly the crossover regression
                        this gate exists to catch);
* ``grouped_capacity``  ``speedup_vs_worst`` of the planned bucket;
* ``tp_crossover``      ``est_tp_speedup`` (analytic TP-vs-unsharded
                        ratio at q=8).  Measured wall-clock fields are
                        deliberately NOT gated.
* ``train_grad``        fwd+bwd speedup vs dense plus the route triple
                        (fwd / dL-dx / dL-dW verdicts).
* ``pattern_evolution`` evolved-plan fwd+bwd speedup vs dense, the
                        evolve-vs-measured-re-plan advantage (capped at
                        2.0 in the suite, so it is effectively a
                        boolean "evolve stayed cheap"), and the evolve
                        chain's decision/measurement event count folded
                        into the gated route string (must stay ``ev0``).
* ``skewed_patterns``   per-family cost-model advantage of the balanced
                        walk over the uniform walk, plus the winning
                        route at each skew point (a skew crossover that
                        stops picking the balanced variant flips the
                        route gate).
* ``serving``           sustained requests/sec at the inter-token
                        latency SLO on the cost-model virtual clock,
                        the bucketed-vs-pad-to-max advantage, and the
                        sparse-vs-dense serving speedup; the analytic
                        bucket ladder + SLO-chosen batch ride the route
                        gate.

A config present in the baseline but missing from the current run (or
vice versa) fails: a silently shrunk grid is a coverage regression.
"""
from __future__ import annotations

import argparse
import json
import os

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "..",
                            "benchmarks", "baselines")


def _key(rec: dict, fields: tuple) -> str:
    return "|".join(f"{f}={rec[f]}" for f in fields)


def _dispatch_ratios(recs):
    out = {}
    for r in recs:
        k = _key(r, ("kind", "m", "b", "density", "n"))
        cands = r["candidates"]
        dense = cands.get("dense_xla")
        chosen = cands.get(r["chosen"])
        if dense and chosen:
            out[k] = {"ratio": dense / chosen, "route": r["chosen"]}
    return out


def _capacity_ratios(recs):
    return {_key(r, ("m", "b", "density", "headroom")):
            {"ratio": r["speedup_vs_worst"]} for r in recs}


def _tp_ratios(recs):
    return {_key(r, ("m", "b", "density", "n")):
            {"ratio": r["est_tp_speedup"]} for r in recs}


def _train_grad_ratios(recs):
    # the route triple is one gate unit: a flip of ANY of the three
    # (fwd / dL-dx / dL-dW) verdicts at the same grid point is a
    # crossover regression
    return {_key(r, ("m", "b", "density", "n")):
            {"ratio": r["train_speedup_vs_dense"],
             "route": f"{r['fwd_route']}+{r['dx_route']}"
                      f"+{r['dv_route']}"}
            for r in recs}


def _pattern_evolution_ratios(recs):
    # two gated ratios per grid point: the evolved plan's deterministic
    # fwd+bwd speedup over dense, and the (noise-capped at 2.0) measured
    # advantage of one evolve over a measured from-scratch re-plan.  The
    # route string folds in the chain's decision/measurement event count
    # -- an evolve that starts racing routes again flips the route gate,
    # not just a ratio
    out = {}
    for r in recs:
        k = _key(r, ("m", "b", "density", "n"))
        route = (f"{r['route']}+{r['dx_route']}+{r['dv_route']}"
                 f"+ev{r['evolve_measurements']}")
        out[f"{k}|step"] = {"ratio": r["step_speedup_vs_dense"],
                            "route": route}
        out[f"{k}|amortized"] = {"ratio": r["replan_vs_evolve"]}
    return out


def _skewed_ratios(recs):
    # two gated ratios per grid point: the deterministic cost-model
    # advantage of the balanced walk over the uniform walk for each
    # family; the chosen route rides the static entry -- a skew
    # crossover that stops picking the balanced variant is exactly the
    # regression this gate exists to catch
    out = {}
    for r in recs:
        k = _key(r, ("mask", "m", "b", "density", "n"))
        out[f"{k}|static"] = {"ratio": r["static_balance_ratio"],
                              "route": r["chosen"]}
        out[f"{k}|dynamic"] = {"ratio": r["dynamic_balance_ratio"]}
    return out


def _serving_ratios(recs):
    # three gated ratios per serving arm, all deterministic cost-model
    # outputs: sustained requests/sec at the SLO (absolute model-seconds
    # throughput), the bucketed-vs-pad-to-max advantage, and (sparse
    # arms) the sparse-vs-dense serving speedup.  The "route" is the
    # engine's analytic bucket ladder + the SLO-chosen batch -- a ladder
    # or batch flip at the same grid point is a serving-policy
    # regression, exactly what this gate exists to catch
    out = {}
    for r in recs:
        k = _key(r, ("model", "ffn", "max_len"))
        ladder = "/".join(str(b) for b in r["buckets"])
        out[f"{k}|rps"] = {"ratio": r["rps_at_slo"],
                           "route": f"b{ladder}@{r['batch_at_slo']}"}
        out[f"{k}|padmax"] = {"ratio": r["throughput_vs_padmax"]}
        if "serving_speedup_vs_dense" in r:
            out[f"{k}|vs_dense"] = {
                "ratio": r["serving_speedup_vs_dense"]}
    return out


EXTRACTORS = {
    "dispatch": _dispatch_ratios,
    "grouped_capacity": _capacity_ratios,
    "tp_crossover": _tp_ratios,
    "train_grad": _train_grad_ratios,
    "pattern_evolution": _pattern_evolution_ratios,
    "skewed_patterns": _skewed_ratios,
    "serving": _serving_ratios,
}

# runner-dependent fields stripped from baselines on --update, so a
# baseline regenerated on a laptop diffs cleanly against one from CI
# (the gate never reads these; `dispatch` keeps chosen/source -- they
# are deterministic analytic outputs and chosen IS gate-checked)
STRIP_FIELDS = {
    "dispatch": (),
    "grouped_capacity": ("t_planned_us", "t_worst_us"),
    "tp_crossover": ("measured_us", "tp_speedup_measured",
                     "tp_wins_measured", "chosen", "source",
                     "q_measured"),
    "train_grad": (),      # all fields are deterministic model outputs
    # raw evolve/re-plan timings are runner wall-clock; the gate reads
    # only the capped replan_vs_evolve ratio
    "pattern_evolution": ("evolve_ms", "replan_ms"),
    "skewed_patterns": (),     # all fields are deterministic model outputs
    "serving": (),             # virtual-clock simulation: deterministic
}


def check_file(current_path: str, baseline_path: str,
               tolerance: float) -> list:
    """-> list of failure strings (empty == pass)."""
    with open(current_path) as f:
        current = json.load(f)
    if not os.path.exists(baseline_path):
        return [f"missing baseline {baseline_path} -- run with --update "
                f"and commit it"]
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    for fig, extract in EXTRACTORS.items():
        cur, base = current.get(fig), baseline.get(fig)
        if cur is None and base is None:
            continue
        if cur is None or base is None:
            failures.append(f"{fig}: present in only one of "
                            f"current/baseline")
            continue
        cur_r, base_r = extract(cur), extract(base)
        for k in sorted(set(base_r) | set(cur_r)):
            if k not in cur_r:
                failures.append(f"{fig}[{k}]: missing from current run")
                continue
            if k not in base_r:
                failures.append(f"{fig}[{k}]: not in baseline -- "
                                f"grid changed? --update the baseline")
                continue
            b, c = base_r[k], cur_r[k]
            if c["ratio"] < b["ratio"] * (1.0 - tolerance):
                failures.append(
                    f"{fig}[{k}]: ratio {c['ratio']:.3f} regressed "
                    f">{tolerance:.0%} from baseline {b['ratio']:.3f}")
            if b.get("route") and c.get("route") != b.get("route"):
                failures.append(
                    f"{fig}[{k}]: chosen route {c.get('route')} != "
                    f"baseline {b['route']} (crossover moved)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+",
                    help="fresh BENCH_*.json files to check")
    ap.add_argument("--tolerance", type=float, default=0.15)
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baselines from the current files")
    args = ap.parse_args()

    rc = 0
    for path in args.files:
        baseline = os.path.join(args.baseline_dir, os.path.basename(path))
        if args.update:
            os.makedirs(args.baseline_dir, exist_ok=True)
            with open(path) as f:
                blob = json.load(f)
            # strip runner-dependent fields: baselines hold only what
            # the gate checks, so their diffs review cleanly
            for fig, recs in blob.items():
                drop = STRIP_FIELDS.get(fig)
                if drop:
                    blob[fig] = [{k: v for k, v in r.items()
                                  if k not in drop} for r in recs]
            with open(baseline, "w") as f:
                json.dump(blob, f, indent=1)
            print(f"updated {baseline}")
            continue
        failures = check_file(path, baseline, args.tolerance)
        tag = os.path.basename(path)
        if failures:
            rc = 1
            print(f"[{tag}] FAIL ({len(failures)} regressions):")
            for msg in failures:
                print(f"  {msg}")
        else:
            print(f"[{tag}] OK (within {args.tolerance:.0%} of baseline)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
