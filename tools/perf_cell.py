"""Hillclimb driver: lower one (arch, shape) cell with a named variant
and report the roofline delta vs the recorded baseline.

    python tools/perf_cell.py <arch> <shape> <variant> [multipod]

Variants (composable with '+'):
    base      paper-faithful baseline (row attention schedule, fp32 MoE
              combine, cumsum ranking)
    bal       balanced (folded-causal pair) attention schedule
    moe       bf16 MoE combine + sort-based slot ranking
    pad16     pad attention heads to a model-axis multiple (internvl2)
    dots      remat policy "dots" (save matmul outputs)
    flash     analysis-only: price attention score tiles as VMEM-resident
              (the Pallas bs_attn fused-kernel view)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import json
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.analysis.hlo_cost import analyze_hlo_text  # noqa: E402
from repro.analysis.roofline import V5E, roofline_terms  # noqa: E402
from repro.launch.dryrun import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding import rules  # noqa: E402


def apply_variant(cfg, variant: str):
    parts = set(variant.split("+"))
    kw = {}
    if "bal" in parts:
        kw["attn_schedule"] = "balanced"
    if "moe" in parts and cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, combine_dtype="bfloat16",
                                        ranking="sort")
    if "smmoe" in parts and cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, combine_dtype="bfloat16",
                                        ranking="sort", impl="shard_map")
    if "pad16" in parts:
        kw["num_heads"] = ((cfg.num_heads + 15) // 16) * 16
    if "dots" in parts:
        kw["remat"] = "dots"
    if "sp" in parts:
        kw["seq_shard"] = True
    return dataclasses.replace(cfg, **kw) if kw else cfg


def main():
    arch, shape, variant = sys.argv[1], sys.argv[2], sys.argv[3]
    multipod = len(sys.argv) > 4 and sys.argv[4] == "multipod"
    cfg = apply_variant(configs.get(arch), variant)
    mesh = make_production_mesh(multi_pod=multipod)
    from repro.train.step import TrainHParams
    hp = TrainHParams(accum=8) if "accum8" in variant else TrainHParams()
    fn, args, in_sh, out_sh, meta = build_cell(arch, shape, mesh, cfg=cfg,
                                               hp=hp)
    with mesh, rules.activation_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    vmem = None
    if "flash" in variant:
        # replicate attend_train's tile-shrink to find the score dims
        s_tot = configs.SHAPES[shape]["seq"] + (
            cfg.frontend_len if cfg.frontend == "vision" else 0)
        tq, tkv = min(cfg.attn_tile_q, s_tot), min(cfg.attn_tile_kv, s_tot)
        while s_tot % tq:
            tq //= 2
        while s_tot % tkv:
            tkv //= 2
        vmem = {(tq, tkv)}
    cost = analyze_hlo_text(text, vmem_dims=vmem)
    roof = roofline_terms(cost, V5E,
                          model_flops_per_device=meta["model_flops_device"])
    rec = dict(meta, variant=variant,
               mesh="2x16x16" if multipod else "16x16",
               temp_mb=mem.temp_size_in_bytes / 2**20,
               hlo=dict(flops=cost["flops"], bytes=cost["bytes"],
                        collective_bytes=cost["collective_bytes"],
                        collectives=cost["collectives"]),
               roofline=roof)
    mesh_tag = "__2x16x16" if multipod else ""
    out = (f"experiments/perf/{configs.ALIASES.get(arch, arch)}__{shape}"
           f"__{variant.replace('+','_')}{mesh_tag}.json")
    os.makedirs("experiments/perf", exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"== {arch} x {shape} [{variant}] ==")
    print(f"  flops {cost['flops']:.3e}  bytes {cost['bytes']:.3e}  "
          f"coll {cost['collective_bytes']:.3e}  temp {rec['temp_mb']:.0f}MB")
    print(f"  compute {roof['t_compute']*1e3:.1f}ms | "
          f"memory {roof['t_memory']*1e3:.1f}ms | "
          f"collective {roof['t_collective']*1e3:.1f}ms -> "
          f"{roof['dominant']}-bound, frac {roof.get('roofline_frac', 0):.4f}")

    # plan report: what the plan-first API would run for this cell's
    # FFN matmul (per-device shapes on the production mesh)
    from repro import sparse
    tokens = meta.get("tokens_device") or configs.SHAPES[shape].get("seq", 0)
    if cfg.d_ff and tokens:
        pctx = sparse.PlanContext(allow_pallas=True, differentiable=False)
        spec = sparse.OpSpec(kind="dense", m=cfg.d_ff, k=cfg.d_model,
                             n=int(tokens), dtype="bfloat16")
        print(sparse.format_plan(sparse.plan(spec, ctx=pctx)))


if __name__ == "__main__":
    main()
