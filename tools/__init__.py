"""Repo maintenance tools (bench gate, repro-lint, perf reports)."""
