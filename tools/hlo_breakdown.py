"""Ad-hoc: top ops by bytes/flops with loop trip multipliers."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, 'src')
import jax
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.analysis import hlo_cost as hc
from repro.sharding import rules

arch, shape = sys.argv[1], sys.argv[2]
mesh = make_production_mesh(multi_pod='multipod' in sys.argv)
fn, args, in_sh, out_sh, meta = build_cell(arch, shape, mesh)
with mesh, rules.activation_mesh(mesh):
    compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
text = compiled.as_text()
comps = hc.parse_hlo(text)
an = hc.Analyzer(comps)

# compute trip multiplier per computation by walking from entry
import collections
import re
entry = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.MULTILINE).group(1)
mult = collections.defaultdict(float)
def walk(name, k):
    comp = comps.get(name)
    if comp is None:
        return
    mult[name] += k
    for op in comp.ops:
        if op.opcode == 'while':
            m = re.search(r'known_trip_count[^0-9]*(\d+)', op.attrs)
            trip = int(m.group(1)) if m else 1
            body = an._called(op.attrs, 'body')
            cond = an._called(op.attrs, 'condition')
            if body:
                walk(body, k*trip)
            if cond:
                walk(cond, k*trip)
        elif op.opcode in ('call',):
            cal = an._called(op.attrs, 'to_apply')
            if cal:
                walk(cal, k)
walk(entry, 1.0)

rows = []
for cname, k in mult.items():
    comp = comps[cname]
    for op in comp.ops:
        if op.opcode in hc._SKIP_BYTES or op.opcode in ('while','call'):
            continue
        c = hc.Cost()
        # reuse single-op logic crudely
        opnd = sum(hc._shape_bytes(an._operand_type(comp, o)) for o in op.operands)
        res = hc._shape_bytes(op.type_str)
        if op.opcode in ('dynamic-update-slice','scatter'):
            b = 3*(hc._shape_bytes(an._operand_type(comp, op.operands[1])) if len(op.operands)>1 else 0)
        elif op.opcode in ('dynamic-slice','gather'):
            b = 2*res
        elif op.opcode == 'fusion':
            callee_name = an._called(op.attrs, 'calls')
            callee = comps.get(callee_name)
            root = callee.ops[-1] if callee and callee.ops else None
            if root is not None and root.opcode in ('dynamic-update-slice','scatter'):
                alias = max((hc._shape_bytes(an._operand_type(comp,o)) for o in op.operands), default=0)
                b = max(opnd-alias,0)+max(res-alias,0)+2*hc._update_bytes(callee, root)
            else:
                b = opnd+res
        else:
            b = opnd+res
        f = 0.0
        if op.opcode=='dot':
            f = an._dot_flops(comp, op)
        elif op.opcode=='fusion':
            cal = an._called(op.attrs,'calls')
            if cal:
                f = an._flops_only(cal)
        rows.append((b*k, f*k, k, cname, op.opcode, op.name, op.type_str[:60]))

rows.sort(reverse=True)
print('TOP 25 BY BYTES (bytes*trip, flops*trip, trip, comp, opcode, name, type)')
for r in rows[:25]:
    print(f'{r[0]:.3e} {r[1]:.3e} {r[2]:8.0f} {r[3][:30]:30s} {r[4]:22s} {r[5][:28]:28s} {r[6]}')
rows.sort(key=lambda r: -r[1])
print('\nTOP 15 BY FLOPS')
for r in rows[:15]:
    print(f'{r[0]:.3e} {r[1]:.3e} {r[2]:8.0f} {r[3][:30]:30s} {r[4]:22s} {r[5][:28]:28s} {r[6]}')
