"""The repro-lint rule catalog (R001-R005).  See docs/dev.md.

R001  dispatch-bypass      direct ``repro.kernels.*`` imports outside
                           the dispatch/plan layers and kernel tests
R002  tracer-unsafe branch Python ``if``/``while`` on traced values
                           inside jit/plan-execute functions
R003  host-sync-in-hot-path  block_until_ready / device_get /
                           non-telemetry debug.callback inside plan
                           execute paths
R004  persisted-schema drift  sparse/spec.py + sparse/cache.py persisted
                           field lists vs the committed golden baseline
R005  nondeterministic benchmark  unseeded RNG / wall-clock outside the
                           measurement harness in benchmarks/
"""
from __future__ import annotations

import ast
import json
import os
from typing import Dict, List, Optional, Set

from tools.lint.engine import (FileContext, Finding, RepoRule, Rule,
                               register_rule)


def _attr_chain(node) -> Optional[str]:
    """Dotted name of a Name/Attribute chain ('jax.debug.callback'),
    or None when the chain bottoms out in a call/subscript."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _parent_map(root) -> Dict[ast.AST, ast.AST]:
    parents = {}
    for node in ast.walk(root):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


# ---------------------------------------------------------------------------
# R001 dispatch-bypass
# ---------------------------------------------------------------------------

# the layers that legitimately enter kernels directly
_R001_ALLOWED_PREFIXES = ("src/repro/kernels/", "tools/lint/")
_R001_ALLOWED_FILES = {
    "src/repro/core/dispatch.py",
    "src/repro/sparse/plan.py",
    "tests/test_kernels.py",          # kernel conformance tests
    "tests/test_gmm_capacity.py",     # grouped-kernel capacity tests
}
# contract/compat are kernel *metadata*, not kernel entry points
_R001_EXEMPT_MODULES = ("repro.kernels.contract", "repro.kernels.compat")


@register_rule
class DispatchBypass(Rule):
    id = "R001"
    name = "dispatch-bypass"
    description = ("kernels must be entered via core.dispatch / the plan "
                   "layer, not imported directly")

    def check(self, ctx: FileContext) -> List[Finding]:
        if (ctx.path in _R001_ALLOWED_FILES
                or ctx.path.startswith(_R001_ALLOWED_PREFIXES)):
            return []
        out = []
        for node in ast.walk(ctx.tree):
            # the *effective* imported modules: "from repro.kernels
            # import contract" imports repro.kernels.contract, so the
            # exemptions must be checked per-alias, not on the bare
            # "from" module
            mods: List[str] = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                mods = [f"{node.module}.{a.name}" for a in node.names]
            for mod in mods:
                if not (mod == "repro.kernels"
                        or mod.startswith("repro.kernels.")):
                    continue
                if mod.startswith(_R001_EXEMPT_MODULES):
                    continue
                out.append(Finding(
                    self.id, ctx.path, node.lineno,
                    f"direct kernel import {mod!r}: go through "
                    f"repro.core.dispatch or repro.sparse instead"))
                break
        return out


# ---------------------------------------------------------------------------
# jit-scope detection shared by R002/R003
# ---------------------------------------------------------------------------

# names of plan-execute closures: functions with these names *nested in
# another function* are the callables MatmulPlan jits / custom_vjp runs
_EXECUTE_CLOSURE_NAMES = {"run", "fwd", "bwd"}


def _jit_wrapped_names(tree) -> Set[str]:
    """Function names passed positionally to jax.jit(...) in this file."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if chain in ("jax.jit", "jit") and node.args:
                a = node.args[0]
                if isinstance(a, ast.Name):
                    out.add(a.id)
    return out


def _is_jit_decorated(fn) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        chain = _attr_chain(target) or ""
        leaf = chain.rsplit(".", 1)[-1]
        if leaf in ("jit", "custom_vjp", "custom_jvp"):
            return True
    return False


def jit_scoped_functions(ctx: FileContext):
    """Yield (FunctionDef, reason) for every function repro-lint treats
    as traced: jit/custom_vjp-decorated, passed to ``jax.jit(...)`` by
    name, or a plan-execute closure (a def named run/fwd/bwd nested
    inside another function -- methods and module-level defs excluded).
    """
    wrapped = _jit_wrapped_names(ctx.tree)
    parents = _parent_map(ctx.tree)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if _is_jit_decorated(node):
            yield node, "jit/custom_vjp decorated"
        elif node.name in wrapped:
            yield node, "wrapped by jax.jit(...)"
        elif (node.name in _EXECUTE_CLOSURE_NAMES
              and isinstance(parents.get(node),
                             (ast.FunctionDef, ast.AsyncFunctionDef))):
            yield node, "plan-execute closure"


def _param_names(fn) -> Set[str]:
    a = fn.args
    names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n != "self"}


# attribute reads that stay static under tracing
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}


def _traced_value_uses(test, params: Set[str]) -> List[ast.Name]:
    """Name nodes in ``test`` that read a traced parameter's *value*
    (not a static property such as .shape/.ndim, isinstance, is-None)."""
    parents = _parent_map(test)
    parents[test] = None
    bad = []
    for node in ast.walk(test):
        if not (isinstance(node, ast.Name) and node.id in params):
            continue
        p = parents.get(node)
        if isinstance(p, ast.Attribute) and p.attr in _STATIC_ATTRS:
            continue
        if isinstance(p, ast.Call):
            chain = _attr_chain(p.func) or ""
            if chain.rsplit(".", 1)[-1] in ("isinstance", "len", "type",
                                            "getattr", "hasattr"):
                continue
        if isinstance(p, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in p.ops):
            continue
        bad.append(node)
    return bad


# ---------------------------------------------------------------------------
# R002 tracer-unsafe branching
# ---------------------------------------------------------------------------

@register_rule
class TracerUnsafeBranch(Rule):
    id = "R002"
    name = "tracer-unsafe-branch"
    description = ("Python control flow on traced values inside "
                   "jit/plan-execute functions")

    def check(self, ctx: FileContext) -> List[Finding]:
        if not ctx.path.startswith("src/repro/"):
            return []
        out = []
        for fn, reason in jit_scoped_functions(ctx):
            params = _param_names(fn)
            if not params:
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                    exprs = [node.test]
                elif isinstance(node, ast.Assert):
                    exprs = [node.test]
                else:
                    continue
                for expr in exprs:
                    for use in _traced_value_uses(expr, params):
                        out.append(Finding(
                            self.id, ctx.path, use.lineno,
                            f"branch on traced value {use.id!r} inside "
                            f"{fn.name!r} ({reason}): use lax.cond/"
                            f"jnp.where or hoist to plan time"))
        return out


# ---------------------------------------------------------------------------
# R003 host sync in hot path
# ---------------------------------------------------------------------------

@register_rule
class HostSyncInHotPath(Rule):
    id = "R003"
    name = "host-sync-in-hot-path"
    description = ("block_until_ready / device_get / non-telemetry "
                   "debug.callback inside plan execute paths")

    def check(self, ctx: FileContext) -> List[Finding]:
        if not ctx.path.startswith("src/repro/"):
            return []
        out = []
        for fn, reason in jit_scoped_functions(ctx):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = _attr_chain(node.func) or ""
                leaf = chain.rsplit(".", 1)[-1]
                if leaf == "block_until_ready":
                    out.append(Finding(
                        self.id, ctx.path, node.lineno,
                        f"block_until_ready inside {fn.name!r} "
                        f"({reason}): host sync in a hot path"))
                elif leaf == "device_get":
                    out.append(Finding(
                        self.id, ctx.path, node.lineno,
                        f"device_get inside {fn.name!r} ({reason}): "
                        f"host transfer in a hot path"))
                elif chain.endswith("debug.callback"):
                    # telemetry convention: CapacityStats.record sinks
                    # are the one sanctioned callback in execute paths
                    first = node.args[0] if node.args else None
                    is_telemetry = (isinstance(first, ast.Attribute)
                                    and first.attr == "record")
                    if not is_telemetry:
                        out.append(Finding(
                            self.id, ctx.path, node.lineno,
                            f"non-telemetry debug.callback inside "
                            f"{fn.name!r} ({reason})"))
        return out


# ---------------------------------------------------------------------------
# R004 persisted-schema drift
# ---------------------------------------------------------------------------

SPEC_PATH = "src/repro/sparse/spec.py"
CACHE_PATH = "src/repro/sparse/cache.py"
BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "schema_baseline.json")
# the dataclasses whose fields reach the persisted decision records
_PERSISTED_CLASSES = ("OpSpec", "PlanContext", "CapacityStats")


def _class_fields(cls: ast.ClassDef) -> List[str]:
    """Field list of a persisted class: dataclass annotations plus
    ``self.x = ...`` assignments in ``__init__`` (public names only)."""
    fields = set()
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            fields.add(stmt.target.id)
        elif (isinstance(stmt, ast.FunctionDef)
              and stmt.name == "__init__"):
            for node in ast.walk(stmt):
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        if (isinstance(t, ast.Attribute)
                                and isinstance(t.value, ast.Name)
                                and t.value.id == "self"):
                            fields.add(t.attr)
    return sorted(f for f in fields if not f.startswith("_"))


def compute_schema_fingerprint(repo_root: str = ".") -> dict:
    """Parse spec.py/cache.py and return the persisted-schema
    fingerprint {schema_version, fields: {class: [field, ...]}}."""
    with open(os.path.join(repo_root, SPEC_PATH)) as f:
        spec_tree = ast.parse(f.read())
    with open(os.path.join(repo_root, CACHE_PATH)) as f:
        cache_tree = ast.parse(f.read())
    version = None
    for node in ast.walk(cache_tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "SCHEMA_VERSION":
                    version = ast.literal_eval(node.value)
    fields = {}
    for node in spec_tree.body:
        if (isinstance(node, ast.ClassDef)
                and node.name in _PERSISTED_CLASSES):
            fields[node.name] = _class_fields(node)
    return {"schema_version": version, "fields": fields}


def _schema_version_line(repo_root: str) -> int:
    with open(os.path.join(repo_root, CACHE_PATH)) as f:
        for i, line in enumerate(f, 1):
            if line.startswith("SCHEMA_VERSION"):
                return i
    return 1


@register_rule
class PersistedSchemaDrift(RepoRule):
    id = "R004"
    name = "persisted-schema-drift"
    description = ("persisted dataclass fields changed without a "
                   "SCHEMA_VERSION bump + baseline update")

    def check_repo(self, files, repo_root: str) -> List[Finding]:
        # only meaningful when the persisted modules are in scope
        if not os.path.exists(os.path.join(repo_root, SPEC_PATH)):
            return []
        current = compute_schema_fingerprint(repo_root)
        line = _schema_version_line(repo_root)
        if not os.path.exists(BASELINE_PATH):
            return [Finding(
                self.id, CACHE_PATH, line,
                "missing persisted-schema baseline "
                "tools/lint/schema_baseline.json -- run "
                "`python -m tools.lint --update-baseline` and commit it")]
        with open(BASELINE_PATH) as f:
            baseline = json.load(f)
        out = []
        same_version = (current["schema_version"]
                        == baseline.get("schema_version"))
        for cls in _PERSISTED_CLASSES:
            cur = current["fields"].get(cls, [])
            base = baseline.get("fields", {}).get(cls, [])
            if cur == base:
                continue
            added = sorted(set(cur) - set(base))
            removed = sorted(set(base) - set(cur))
            diff = "".join([f" +{f}" for f in added]
                           + [f" -{f}" for f in removed])
            if same_version:
                out.append(Finding(
                    self.id, SPEC_PATH, line,
                    f"persisted schema drift in {cls}:{diff} without a "
                    f"SCHEMA_VERSION bump (cache.py still "
                    f"{current['schema_version']}) -- bump it, then run "
                    f"`python -m tools.lint --update-baseline`"))
            else:
                out.append(Finding(
                    self.id, SPEC_PATH, line,
                    f"persisted schema changed in {cls}:{diff} and "
                    f"SCHEMA_VERSION bumped -- refresh the baseline with "
                    f"`python -m tools.lint --update-baseline`"))
        if not out and not same_version:
            out.append(Finding(
                self.id, CACHE_PATH, line,
                f"SCHEMA_VERSION {current['schema_version']} != baseline "
                f"{baseline.get('schema_version')} -- run "
                f"`python -m tools.lint --update-baseline`"))
        return out


# ---------------------------------------------------------------------------
# R005 nondeterministic benchmark code
# ---------------------------------------------------------------------------

# the one file allowed to read the wall clock: the measurement harness
_R005_HARNESS = "benchmarks/bench_walltime.py"
_WALLCLOCK_CHAINS = {"time.time", "time.monotonic", "time.time_ns",
                     "time.monotonic_ns", "datetime.now",
                     "datetime.datetime.now", "datetime.utcnow",
                     "datetime.datetime.utcnow"}
_GLOBAL_NP_RANDOM = {"rand", "randn", "randint", "random", "choice",
                     "permutation", "shuffle", "uniform", "normal",
                     "seed"}


@register_rule
class NondeterministicBenchmark(Rule):
    id = "R005"
    name = "nondeterministic-benchmark"
    description = ("unseeded RNG / wall-clock outside the measurement "
                   "harness in benchmark code")

    def check(self, ctx: FileContext) -> List[Finding]:
        if not ctx.path.startswith("benchmarks/"):
            return []
        imports_stdlib_random = any(
            isinstance(n, ast.Import)
            and any(a.name == "random" for a in n.names)
            for n in ast.walk(ctx.tree))
        out = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func) or ""
            if chain in _WALLCLOCK_CHAINS:
                out.append(Finding(
                    self.id, ctx.path, node.lineno,
                    f"wall-clock {chain}() in benchmark code: route "
                    f"timing through the measurement harness"))
            elif chain == "time.perf_counter" and ctx.path != _R005_HARNESS:
                out.append(Finding(
                    self.id, ctx.path, node.lineno,
                    "perf_counter outside the measurement harness "
                    f"({_R005_HARNESS}): use measure_callable"))
            elif chain.endswith("random.default_rng") and not node.args:
                out.append(Finding(
                    self.id, ctx.path, node.lineno,
                    "unseeded default_rng(): pass an explicit seed"))
            elif (chain.startswith(("np.random.", "numpy.random."))
                  and chain.rsplit(".", 1)[-1] in _GLOBAL_NP_RANDOM):
                out.append(Finding(
                    self.id, ctx.path, node.lineno,
                    f"global numpy RNG {chain}(): use a seeded "
                    f"default_rng(seed) generator"))
            elif (imports_stdlib_random
                  and chain.startswith("random.")):
                out.append(Finding(
                    self.id, ctx.path, node.lineno,
                    f"stdlib global RNG {chain}(): use a seeded "
                    f"generator"))
        return out
