"""CLI: ``PYTHONPATH=src python -m tools.lint [paths...]``.

Default scope is ``src tools benchmarks`` (CI's blocking set; the
nightly job adds ``tests``).  Exits nonzero when any finding survives
suppression.  ``--json FILE`` additionally writes the findings as a
JSON report (the nightly artifact); ``--update-baseline`` rewrites the
R004 persisted-schema fingerprint, mirroring
``tools/bench_check.py --update``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: repo-specific static analysis "
                    "(rule catalog: docs/dev.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src tools "
                         "benchmarks)")
    ap.add_argument("--json", dest="json_out", metavar="FILE",
                    help="also write findings as JSON")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite tools/lint/schema_baseline.json from "
                         "the current spec.py/cache.py (commit the "
                         "diff in the PR that bumps SCHEMA_VERSION)")
    args = ap.parse_args(argv)

    # the rules and the contract checker import repro
    src = os.path.join(REPO_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)

    from tools.lint import engine
    from tools.lint import rules
    from tools.lint import contracts  # noqa: F401 (registers C000)

    if args.update_baseline:
        fp = rules.compute_schema_fingerprint(REPO_ROOT)
        with open(rules.BASELINE_PATH, "w") as f:
            json.dump(fp, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"updated {os.path.relpath(rules.BASELINE_PATH, REPO_ROOT)} "
              f"(schema_version={fp['schema_version']})")
        return 0

    paths = args.paths or ["src", "tools", "benchmarks"]
    findings, files = engine.lint_paths(paths, repo_root=REPO_ROOT)

    for fd in findings:
        print(fd.format())
    if args.json_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.json_out)),
                    exist_ok=True)
        with open(args.json_out, "w") as f:
            json.dump({"findings": [fd.to_json() for fd in findings],
                       "files_checked": len(files)}, f, indent=1)
        print(f"wrote {args.json_out}")
    n = len(findings)
    print(f"repro-lint: {len(files)} files checked, {n} finding"
          f"{'' if n == 1 else 's'}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
