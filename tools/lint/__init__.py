"""repro-lint: repo-specific static analysis for the sparse-matmul stack.

Run as ``PYTHONPATH=src python -m tools.lint [paths...]``.  The rule
catalog, suppression syntax, and the kernel-contract checker are
documented in docs/dev.md.
"""
from tools.lint.engine import (  # noqa: F401
    FileContext, Finding, Rule, RepoRule, all_rules, lint_paths, register_rule,
)
