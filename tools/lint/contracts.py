"""Kernel contract checker: every dispatch route vs its declared
CONTRACT vs the admissibility gates.

Findings (all repo-level, anchored at core/dispatch.py):

C001  route coverage     every route in ``dispatch.ROUTES`` +
                         ``dispatch.SDDMM_ROUTES`` has exactly one
                         registered contract, and no contract names a
                         route outside that vocabulary
C002  dtype coverage     every routed contract covers the authoritative
                         ``dispatch.SUPPORTED_DTYPES`` vocabulary
C003  admissibility      the gates (``_candidates`` /
                         ``sddmm_candidates`` with allow_pallas=True)
                         only offer routes whose contract admits the
                         canonical block-divisible probe shapes; where a
                         kernel ships a host-side validator
                         (grouped_tile_size / sddmm_tile_size) the
                         contract and the validator must agree on a
                         probe grid that includes un-tileable shapes
C004  declaration sanity the pallas flag matches the route family and
                         the grid formula is documented

Requires ``repro`` importable (run via ``PYTHONPATH=src``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from tools.lint.engine import FileContext, Finding, RepoRule, register_rule

ANCHOR = "src/repro/core/dispatch.py"

# canonical probe blocks (the paper's Table 3 block sizes); shapes per
# block are m = 4b, k = 4b, n = 2b -- block-divisible by construction
PROBE_BLOCKS = (4, 8, 16, 32, 64, 128)

# (m, k, b) grid for validator agreement, including un-tileable shapes
VALIDATOR_PROBES = (
    (128, 128, 32), (96, 160, 32), (192, 320, 64), (512, 512, 128),
    (100, 64, 32),      # m not a block multiple -> both must reject
    (96, 100, 32),      # k not a block multiple -> both must reject
    (132, 132, 33),     # t=33,66,99,132: 132%33==0 -> both must admit
)


def _validator_verdict(fn, m: int, k: int, b: int) -> Optional[str]:
    """None if the host-side sizing validator accepts, else the reason."""
    try:
        fn(m, k, b)
        return None
    except ValueError as e:
        return str(e)


def check_contracts(*, registry: Optional[Dict] = None,
                    routes: Optional[Sequence[str]] = None,
                    sddmm_routes: Optional[Sequence[str]] = None
                    ) -> List[Finding]:
    """Cross-check contracts against the dispatch gates.  ``registry``/
    ``routes``/``sddmm_routes`` default to the live ones; tests inject
    deliberately broken registries here."""
    from repro.core import dispatch
    from repro.kernels import contract as contract_mod
    from repro.kernels.gmm import ops as gmm_ops
    from repro.kernels.sddmm import ops as sddmm_ops

    if registry is None:
        registry = contract_mod.load_all()
    routes = tuple(dispatch.ROUTES if routes is None else routes)
    sddmm_routes = tuple(dispatch.SDDMM_ROUTES if sddmm_routes is None
                         else sddmm_routes)
    vocabulary = set(routes) | set(sddmm_routes)
    dtypes = dispatch.SUPPORTED_DTYPES
    out: List[Finding] = []

    # C001: route <-> contract bijection over the vocabulary
    by_route: Dict[str, object] = {}
    for c in registry.values():
        for r in c.routes:
            if r not in vocabulary:
                out.append(Finding(
                    "C001", ANCHOR, 1,
                    f"contract {c.kernel!r} names unknown route {r!r}: "
                    f"not in ROUTES + SDDMM_ROUTES {sorted(vocabulary)}"))
                continue
            if r in by_route:
                out.append(Finding(
                    "C001", ANCHOR, 1,
                    f"route {r!r} claimed by both "
                    f"{by_route[r].kernel!r} and {c.kernel!r}"))
            by_route[r] = c
    for r in routes + sddmm_routes:
        if r not in by_route:
            out.append(Finding(
                "C001", ANCHOR, 1,
                f"route {r!r} has no declared kernel CONTRACT "
                f"(register one via repro.kernels.contract)"))

    # C002: every routed contract covers the supported-dtype vocabulary
    for r, c in sorted(by_route.items()):
        missing = [d for d in dtypes if d not in c.dtypes]
        if missing:
            out.append(Finding(
                "C002", ANCHOR, 1,
                f"route {r!r} (contract {c.kernel!r}) does not cover "
                f"supported dtypes {missing}"))

    # C003a: the gates only offer routes whose contract admits the
    # canonical block-divisible probes (a gate admitting shapes its
    # kernel rejects is the statically-catchable crash)
    ctx = dispatch.DispatchContext(differentiable=False, allow_pallas=True)
    gated = set()
    for kind in ("dense", "static", "dynamic"):
        gated.update(dispatch._candidates(kind, ctx))
    gated.update(dispatch.sddmm_candidates(ctx))
    for r in sorted(gated & set(by_route)):
        c = by_route[r]
        for b in PROBE_BLOCKS:
            if not (c.min_block <= b <= c.max_block):
                continue
            for dt in dtypes:
                reason = c.admits(4 * b, 4 * b, 2 * b, b, dt)
                if reason is not None:
                    out.append(Finding(
                        "C003", ANCHOR, 1,
                        f"gate offers route {r!r} but contract "
                        f"{c.kernel!r} rejects the canonical probe "
                        f"m={4*b} k={4*b} n={2*b} b={b} {dt}: {reason}"))

    # C003b: contract vs host-side sizing validator agreement
    validators = {"dynamic_grouped": gmm_ops.grouped_tile_size,
                  "sddmm_grouped": sddmm_ops.sddmm_tile_size}
    for r, fn in sorted(validators.items()):
        c = by_route.get(r)
        if c is None:
            continue
        for m, k, b in VALIDATOR_PROBES:
            cv = c.admits(m, k, 2 * b, b)
            vv = _validator_verdict(fn, m, k, b)
            if (cv is None) != (vv is None):
                out.append(Finding(
                    "C003", ANCHOR, 1,
                    f"route {r!r}: contract {c.kernel!r} says "
                    f"{cv or 'admit'} but {fn.__name__} says "
                    f"{vv or 'admit'} for m={m} k={k} b={b}"))

    # C004: pallas flag matches the route family; grid is documented
    for r, c in sorted(by_route.items()):
        needs_pallas = not (r.endswith("_xla") or r == "sddmm_dense")
        if c.pallas != needs_pallas:
            out.append(Finding(
                "C004", ANCHOR, 1,
                f"route {r!r}: contract {c.kernel!r} declares "
                f"pallas={c.pallas} but the route "
                f"{'requires' if needs_pallas else 'must not require'} "
                f"a Pallas backend"))
    for c in registry.values():
        if not c.grid.strip():
            out.append(Finding(
                "C004", ANCHOR, 1,
                f"contract {c.kernel!r} has an empty grid formula"))
    return out


@register_rule
class KernelContractChecker(RepoRule):
    id = "C000"
    name = "kernel-contracts"
    description = ("every dispatch route has a kernel CONTRACT that "
                   "agrees with the admissibility gates")

    def check_repo(self, files: Sequence[FileContext],
                   repo_root: str) -> List[Finding]:
        # only run when the dispatch layer is part of the lint scope
        if not any(f.path == ANCHOR for f in files):
            return []
        return check_contracts()
