"""repro-lint core: findings, rule registry, suppressions, file walking.

Two rule shapes:

* :class:`Rule`      -- per-file; gets a :class:`FileContext` (source +
                        AST) and returns findings for that file.
* :class:`RepoRule`  -- whole-run; gets every collected file at once
                        (cross-file invariants such as the persisted
                        schema fingerprint).

Suppressions (checked per finding, by rule id):

* ``# repro-lint: disable=R001``            this line
* ``# repro-lint: disable-next-line=R001``  the following line
* ``# repro-lint: disable-file=R001``       whole file (first 20 lines)

Multiple ids separate with commas: ``disable=R001,R005``.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-next-line|disable-file)"
    r"\s*=\s*([A-Z]\d+(?:\s*,\s*[A-Z]\d+)*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """One parsed source file: path (repo-relative, '/'-separated),
    source text, split lines, AST, and the parsed suppression table."""

    def __init__(self, path: str, source: str):
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self._line_disable: Dict[int, Set[str]] = {}
        self._file_disable: Set[str] = set()
        for i, text in enumerate(self.lines, 1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            kind, ids_text = m.group(1), m.group(2)
            ids = {s.strip() for s in ids_text.split(",")}
            if kind == "disable":
                self._line_disable.setdefault(i, set()).update(ids)
            elif kind == "disable-next-line":
                self._line_disable.setdefault(i + 1, set()).update(ids)
            elif kind == "disable-file" and i <= 20:
                self._file_disable.update(ids)

    def suppressed(self, rule_id: str, line: int) -> bool:
        if rule_id in self._file_disable:
            return True
        return rule_id in self._line_disable.get(line, set())


class Rule:
    """Per-file rule: subclass, set ``id``/``name``/``description``,
    implement ``check``."""

    id: str = ""
    name: str = ""
    description: str = ""

    def check(self, ctx: FileContext) -> List[Finding]:
        raise NotImplementedError


class RepoRule(Rule):
    """Whole-run rule: sees every collected file at once."""

    def check_repo(self, files: Sequence[FileContext],
                   repo_root: str) -> List[Finding]:
        raise NotImplementedError

    def check(self, ctx: FileContext) -> List[Finding]:
        return []


_RULES: Dict[str, Rule] = {}


def register_rule(cls):
    """Class decorator: instantiate and register by rule id."""
    inst = cls()
    if not inst.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if inst.id in _RULES and type(_RULES[inst.id]) is not cls:
        raise ValueError(f"duplicate rule id {inst.id}")
    _RULES[inst.id] = inst
    return cls


def all_rules() -> Dict[str, Rule]:
    return dict(_RULES)


def iter_py_files(paths: Sequence[str], repo_root: str = ".") -> List[str]:
    """Expand files/directories into a sorted repo-relative .py list."""
    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(repo_root, p)
        if os.path.isfile(full):
            out.append(os.path.relpath(full, repo_root))
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for f in filenames:
                if f.endswith(".py"):
                    out.append(os.path.relpath(
                        os.path.join(dirpath, f), repo_root))
    return sorted(set(out))


def lint_paths(paths: Sequence[str], *, repo_root: str = ".",
               rules: Optional[Dict[str, Rule]] = None
               ) -> Tuple[List[Finding], List[FileContext]]:
    """Lint ``paths`` (files or directories) with ``rules`` (default:
    every registered rule).  Returns (findings, file contexts); syntax
    errors surface as E000 findings rather than crashing the run."""
    rules = _RULES if rules is None else rules
    files: List[FileContext] = []
    findings: List[Finding] = []
    for rel in iter_py_files(paths, repo_root):
        try:
            with open(os.path.join(repo_root, rel)) as f:
                files.append(FileContext(rel, f.read()))
        except SyntaxError as e:
            findings.append(Finding("E000", rel.replace(os.sep, "/"),
                                    e.lineno or 0, f"syntax error: {e.msg}"))
    by_path = {fc.path: fc for fc in files}
    for rule in rules.values():
        raw: List[Finding] = []
        if isinstance(rule, RepoRule):
            raw = rule.check_repo(files, repo_root)
        else:
            for fc in files:
                raw.extend(rule.check(fc))
        for fd in raw:
            fc = by_path.get(fd.path)
            if fc is not None and fc.suppressed(fd.rule, fd.line):
                continue
            findings.append(fd)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, files
