"""Cost-model accuracy gate: replay the committed benchmark corpus
through the *calibrated* dispatch estimates and fail when the model
stops being trustworthy.

    PYTHONPATH=src python tools/cost_check.py [--report cost_report.json]
    PYTHONPATH=src python tools/cost_check.py \
        --corpus benchmarks/out/BENCH_*.json --max-median-err 0.15

Sibling of ``tools/bench_check.py``, but checking the opposite
direction: bench_check asks "did the *numbers* move?", cost_check asks
"does the *model* still predict them?".  Two blocking criteria (the
ROADMAP's "trusted to ~10%" bar, with margin):

1. **Median relative error** of calibrated-predicted vs corpus time
   over every (route, shape) observation must stay <= ``15%``
   (``--max-median-err``).  Per-route medians ride in the report
   artifact for triage but do not gate individually -- thin routes
   (one observation) would make that gate pure noise.

2. **Zero route-crossover flips** on the deterministic grids: for every
   corpus record that carries a raced candidate set, the calibrated
   model's argmin over those candidates must equal the corpus argmin.
   Exact ties (the pallas-off grids tie ``static_pallas`` with
   ``dense_pallas``) resolve by the record's candidate order -- same
   rule as ``dispatch.decide`` -- so calibration snapping to identity
   keeps them stable by construction.

Exit codes: 0 pass, 1 gate failure, 2 cannot run (no
``cost_coeffs.json`` -- fit one with
``python -m repro.analysis.calibrate --update`` and commit it; a
refreshed coefficients file is a baseline re-sign, see docs/dev.md).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# benchmarks.cost_model must be importable or _estimate silently prices
# through its crude roofline fallback and this gate measures the wrong
# model; repo root (for benchmarks/) + src/ (for repro) both join the
# path, matching benchmarks/run.py
_REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.analysis import calibrate                      # noqa: E402
from repro.core import dispatch                           # noqa: E402

COEFFS_PATH = (os.environ.get(dispatch._COEFFS_ENV)
               or calibrate.DEFAULT_OUT)


def _predict_us(o: calibrate.Observation) -> float:
    return dispatch._estimate(
        o.route, o.m, o.k, o.n, o.b, o.density, o.dtype,
        imbalance=o.imbalance, cv=o.cv) * 1e6


def _argmin_stable(times: dict) -> str:
    """First-wins argmin over candidate insertion order -- the same tie
    rule as ``dispatch.decide`` (min() keeps the earliest key on exact
    ties), so a tied race never reads as a flip."""
    return min(times, key=times.get)


def _crossover_flips(files: list) -> list:
    """Replay every candidates-bearing corpus record: the calibrated
    argmin must match the corpus argmin."""
    flips = []
    for path in files:
        with open(path) as f:
            blob = json.load(f)
        groups = blob.items() if isinstance(blob, dict) else [(None, blob)]
        for fig, recs in groups:
            for rec in recs:
                cands = rec.get("candidates")
                if not cands:
                    continue
                known = {r: us for r, us in cands.items()
                         if r in calibrate._KNOWN_ROUTES}
                if len(known) < 2:
                    continue
                m = int(rec["m"])
                imb = float(rec.get("imbalance", 1.0))
                cv = float(rec.get("cv", 0.0))
                pred = {r: _predict_us(calibrate.Observation(
                            fig=fig or rec.get("fig", ""), route=r,
                            m=m, k=m, n=int(rec["n"]), b=int(rec["b"]),
                            density=float(rec["density"]),
                            imbalance=imb, cv=cv))
                        for r in known}
                want, got = _argmin_stable(known), _argmin_stable(pred)
                if want != got:
                    flips.append({
                        "file": os.path.basename(path),
                        "fig": fig or rec.get("fig", ""),
                        "point": f"m={m} b={rec['b']} "
                                 f"d={rec['density']} n={rec['n']}",
                        "corpus": want, "model": got,
                        "corpus_us": known, "model_us":
                            {r: round(v, 3) for r, v in pred.items()},
                    })
    return flips


def run_check(extra_corpus=None, max_median_err: float = 0.15) -> dict:
    """-> report dict with ``pass`` plus per-route error detail."""
    obs = calibrate.load_corpus(extra_corpus)
    per_route: dict = {}
    errs = []
    for o in obs:
        rel = abs(_predict_us(o) - o.measured_us) / max(o.measured_us,
                                                        1e-9)
        errs.append(rel)
        per_route.setdefault(o.route, []).append(rel)

    def _med(v):
        v = sorted(v)
        n = len(v)
        return v[n // 2] if n % 2 else 0.5 * (v[n // 2 - 1] + v[n // 2])

    files = sorted(calibrate.glob.glob(
        os.path.join(calibrate.BASELINE_DIR, "BENCH_*.json")))
    for p in extra_corpus or ():
        files.extend(sorted(calibrate.glob.glob(p)))
    flips = _crossover_flips(files)
    median = _med(errs) if errs else float("inf")
    coeffs = dispatch.cost_coeffs()
    return {
        "pass": bool(median <= max_median_err and not flips and errs),
        "n_obs": len(obs),
        "median_rel_err": round(median, 6),
        "max_median_err": max_median_err,
        "per_route": {r: {"n_obs": len(v),
                          "median_rel_err": round(_med(v), 6),
                          "max_rel_err": round(max(v), 6)}
                      for r, v in sorted(per_route.items())},
        "crossover_flips": flips,
        "coeffs": {"digest": coeffs.digest, "version": coeffs.version,
                   "identity": coeffs.is_identity},
    }


def main() -> int:
    ap = argparse.ArgumentParser(
        description="gate dispatch cost-model accuracy on the bench corpus")
    ap.add_argument("--corpus", nargs="*", default=None, metavar="GLOB",
                    help="extra bench JSONs beyond benchmarks/baselines/ "
                         "(nightly passes the full-grid run outputs)")
    ap.add_argument("--max-median-err", type=float, default=0.15)
    ap.add_argument("--report", default=None,
                    help="write the full per-route error report here")
    args = ap.parse_args()

    if not os.path.exists(COEFFS_PATH):
        print(f"cost_check: NO COEFFICIENTS at "
              f"{os.path.relpath(COEFFS_PATH)} -- fit and commit one:\n"
              f"  PYTHONPATH=src python -m repro.analysis.calibrate "
              f"--update")
        return 2
    rep = run_check(args.corpus, args.max_median_err)
    if args.report:
        with open(args.report, "w") as f:
            json.dump(rep, f, indent=1, sort_keys=True)
    print(f"cost_check: {rep['n_obs']} observations, median rel err "
          f"{rep['median_rel_err']:.4%} (gate {rep['max_median_err']:.0%}),"
          f" {len(rep['crossover_flips'])} crossover flips "
          f"[coeffs {rep['coeffs']['digest']}]")
    for route, d in rep["per_route"].items():
        print(f"  {route:28s} n={d['n_obs']:<3d} "
              f"median={d['median_rel_err']:.4%} "
              f"max={d['max_rel_err']:.4%}")
    for flip in rep["crossover_flips"]:
        print(f"  FLIP {flip['fig']}[{flip['point']}]: corpus picks "
              f"{flip['corpus']}, model picks {flip['model']}")
    if not rep["pass"]:
        print("cost_check: FAIL -- re-fit with `python -m "
              "repro.analysis.calibrate --update` (and `re-sign` in the "
              "PR title) if the model legitimately changed")
        return 1
    print("cost_check: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
