"""End-to-end driver: train a ~100M-param LM with block-sparse FFNs for a
few hundred steps and compare against the dense baseline at equal step
count -- the paper's technique as a first-class training feature.

    PYTHONPATH=src python examples/sparse_pretrain.py --steps 200

(defaults are sized for this CPU container: a reduced-width model and a
small token budget; pass --full for the ~100M config if you have time.)
Fault tolerance is live: ctrl-C / SIGTERM checkpoints, rerun resumes.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax

from repro.launch.train import train_loop
from repro.models.config import LayerSpec, ModelCfg
from repro.train.step import TrainHParams


def make_cfg(*, full: bool, sparse: bool) -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="sparse" if sparse else "mlp")
    if full:
        # ~100M params: 12L x 512 wide, 32k vocab
        dims = dict(d_model=512, d_ff=2048, num_heads=8, num_kv_heads=4,
                    head_dim=64, vocab_size=32000, layers=12)
    else:
        dims = dict(d_model=256, d_ff=1024, num_heads=4, num_kv_heads=2,
                    head_dim=64, vocab_size=2048, layers=4)
    return ModelCfg(
        name=f"sparse-pretrain-{'sparse' if sparse else 'dense'}",
        family="dense",
        d_model=dims["d_model"], vocab_size=dims["vocab_size"],
        num_heads=dims["num_heads"], num_kv_heads=dims["num_kv_heads"],
        head_dim=dims["head_dim"], d_ff=dims["d_ff"],
        groups=(((spec,), dims["layers"]),),
        ffn_density=0.25, ffn_block_size=16,
        attn_tile_q=128, attn_tile_kv=128,
        dtype="float32",        # CPU-friendly numerics for the example
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/sparse_pretrain_ckpt")
    ap.add_argument("--skip-dense", action="store_true")
    args = ap.parse_args()

    hp = TrainHParams(peak_lr=1e-3, warmup_steps=max(1, args.steps // 10),
                      total_steps=args.steps)

    print("=== block-sparse FFN model (density 0.25, b=16) ===")
    cfg_s = make_cfg(full=args.full, sparse=True)
    _, losses_s = train_loop(
        cfg_s, steps=args.steps, batch_per_shard=args.batch, seq=args.seq,
        ckpt_dir=os.path.join(args.ckpt_dir, "sparse"), hp=hp,
        log_every=max(1, args.steps // 10))

    if not args.skip_dense:
        print("=== dense baseline (same arch, dense FFN) ===")
        cfg_d = make_cfg(full=args.full, sparse=False)
        _, losses_d = train_loop(
            cfg_d, steps=args.steps, batch_per_shard=args.batch,
            seq=args.seq, ckpt_dir=os.path.join(args.ckpt_dir, "dense"),
            hp=hp, log_every=max(1, args.steps // 10))
        print(f"\nsparse: {losses_s[0]:.3f} -> {losses_s[-1]:.3f} | "
              f"dense: {losses_d[0]:.3f} -> {losses_d[-1]:.3f} | "
              f"sparse FFN FLOPs = 25% of dense")
    else:
        print(f"\nsparse: {losses_s[0]:.3f} -> {losses_s[-1]:.3f}")


if __name__ == "__main__":
    main()
