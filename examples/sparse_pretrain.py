"""End-to-end driver: train a ~100M-param LM with block-sparse FFNs for a
few hundred steps and compare against the dense baseline at equal step
count -- the paper's technique as a first-class training feature.

    PYTHONPATH=src python examples/sparse_pretrain.py --steps 200

(defaults are sized for this CPU container: a reduced-width model and a
small token budget; pass --full for the ~100M config if you have time.)
Fault tolerance is live: ctrl-C / SIGTERM checkpoints, rerun resumes.

Dynamic sparse training (RigL, Evci et al. 2019) rides on the same
plans: ``--rigl-every N`` trains a block-sparse FFN projection of a real
config (``--config llama3_2_1b``) against a dense teacher, evolving the
pattern every N steps via ``MatmulPlan.evolve`` -- topology updates cost
a host re-pack, not a route re-race:

    PYTHONPATH=src python examples/sparse_pretrain.py \\
        --rigl-every 20 --steps 200 --config llama3_2_1b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


import jax

from repro.launch.train import train_loop
from repro.models.config import LayerSpec, ModelCfg
from repro.train.step import TrainHParams


def make_cfg(*, full: bool, sparse: bool) -> ModelCfg:
    spec = LayerSpec(mixer="attn", ffn="sparse" if sparse else "mlp")
    if full:
        # ~100M params: 12L x 512 wide, 32k vocab
        dims = dict(d_model=512, d_ff=2048, num_heads=8, num_kv_heads=4,
                    head_dim=64, vocab_size=32000, layers=12)
    else:
        dims = dict(d_model=256, d_ff=1024, num_heads=4, num_kv_heads=2,
                    head_dim=64, vocab_size=2048, layers=4)
    return ModelCfg(
        name=f"sparse-pretrain-{'sparse' if sparse else 'dense'}",
        family="dense",
        d_model=dims["d_model"], vocab_size=dims["vocab_size"],
        num_heads=dims["num_heads"], num_kv_heads=dims["num_kv_heads"],
        head_dim=dims["head_dim"], d_ff=dims["d_ff"],
        groups=(((spec,), dims["layers"]),),
        ffn_density=0.25, ffn_block_size=16,
        attn_tile_q=128, attn_tile_kv=128,
        dtype="float32",        # CPU-friendly numerics for the example
    )


def run_rigl(args):
    """RigL dynamic sparse training on a real config's FFN up-projection:
    sparse student regresses a dense teacher; every ``--rigl-every``
    steps the dense-position gradient drives a drop/grow topology update
    through ``rigl_evolve`` (plan evolves in place of a re-plan)."""
    import jax.numpy as jnp
    import numpy as np

    from repro import configs, sparse
    from repro.core import masks
    from repro.core.bsr import BlockSparseMatrix
    from repro.train.step import rigl_evolve

    cfg = (configs.get if args.full else configs.smoke)(args.config)
    m, k, b = cfg.d_ff, cfg.d_model, 16
    n, density, lr = args.batch * 16, 1 / 16, 0.3
    print(f"=== RigL on {cfg.name} FFN up-proj W[{m}x{k}] b={b} "
          f"d={density} (evolve every {args.rigl_every} steps) ===")

    key = jax.random.PRNGKey(0)
    key, kt, kp = jax.random.split(key, 3)
    # block-sparse teacher (2x the student budget): RigL must *discover*
    # the support -- gradient-driven regrowth moves student blocks onto
    # teacher blocks, so the loss falls as the topology improves
    t_mask = masks.random_block_mask(m, k, b, 2 * density, seed=7)
    teacher = BlockSparseMatrix.from_mask(
        t_mask, b, init="normal", key=kt).to_dense() / np.sqrt(k * density)
    mask = masks.random_block_mask(m, k, b, density, seed=0)
    bsr = BlockSparseMatrix.from_mask(mask, b, init="normal", key=kp)
    p = sparse.plan(bsr, n, ctx=sparse.PlanContext(differentiable=True))
    values = bsr.values * (1.0 / np.sqrt(k))
    print(sparse.format_plan(p))

    losses = []
    for step in range(args.steps):
        key, kx, kr = jax.random.split(key, 3)
        x = jax.random.normal(kx, (k, n))
        y_t = teacher @ x

        # 0.5*|y - y_t|^2 averaged over samples only: with E[xx'] = I
        # the gradient wrt W is ~(W - teacher), so plain SGD converges
        # at lr independent of the problem size
        def loss_fn(v, plan=p):
            return 0.5 * jnp.sum((plan(v, x) - y_t) ** 2) / n

        loss, g = jax.value_and_grad(loss_fn)(values)
        values = values - lr * g
        losses.append(float(loss) / m)     # log per-row error
        if args.rigl_every and (step + 1) % args.rigl_every == 0:
            # dense-position gradient: dL/dW = dL/dy @ x.T at EVERY
            # block, the grow criterion RigL scores inactive blocks by
            dy = (p(values, x) - y_t) / n
            p, values = rigl_evolve(p, values, dy @ x.T,
                                    fraction=0.3, rng=kr)
        if step % max(1, args.steps // 10) == 0:
            print(f"  step {step:4d}  loss {losses[-1]:.5f}")

    ev = p.explain()["evolution"]
    totals = sparse.plan_report()["totals"]["evolution"]
    print(f"\nloss: {losses[0]:.4f} -> {losses[-1]:.4f}")
    if ev:
        print(f"final plan: generation {ev['generation']}, "
              f"last update +{ev['grown']}/-{ev['dropped']} blocks, "
              f"drift {ev['drift']:.3f} "
              f"(threshold {ev['drift_threshold']})")
    print(f"evolution totals: {totals['evolves']} evolves, "
          f"{totals['reraces']} re-races, "
          f"{totals['drift_trips']} drift trips, "
          f"max generation {totals['max_generation']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/sparse_pretrain_ckpt")
    ap.add_argument("--skip-dense", action="store_true")
    ap.add_argument("--rigl-every", type=int, default=0,
                    help="evolve the sparse pattern every N steps "
                         "(RigL demo on --config's FFN shape)")
    ap.add_argument("--config", default="llama3_2_1b",
                    help="assigned-arch config for the RigL demo")
    args = ap.parse_args()

    if args.rigl_every:
        run_rigl(args)
        return

    hp = TrainHParams(peak_lr=1e-3, warmup_steps=max(1, args.steps // 10),
                      total_steps=args.steps)

    print("=== block-sparse FFN model (density 0.25, b=16) ===")
    cfg_s = make_cfg(full=args.full, sparse=True)
    _, losses_s = train_loop(
        cfg_s, steps=args.steps, batch_per_shard=args.batch, seq=args.seq,
        ckpt_dir=os.path.join(args.ckpt_dir, "sparse"), hp=hp,
        log_every=max(1, args.steps // 10))

    if not args.skip_dense:
        print("=== dense baseline (same arch, dense FFN) ===")
        cfg_d = make_cfg(full=args.full, sparse=False)
        _, losses_d = train_loop(
            cfg_d, steps=args.steps, batch_per_shard=args.batch,
            seq=args.seq, ckpt_dir=os.path.join(args.ckpt_dir, "dense"),
            hp=hp, log_every=max(1, args.steps // 10))
        print(f"\nsparse: {losses_s[0]:.3f} -> {losses_s[-1]:.3f} | "
              f"dense: {losses_d[0]:.3f} -> {losses_d[-1]:.3f} | "
              f"sparse FFN FLOPs = 25% of dense")
    else:
        print(f"\nsparse: {losses_s[0]:.3f} -> {losses_s[-1]:.3f}")


if __name__ == "__main__":
    main()
