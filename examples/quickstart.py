"""Quickstart: the PopSparse-on-TPU core library in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's two modes (static §3.2 / dynamic §3.3), the
partitioner, the Pallas kernels (interpret mode on CPU), and the
sparse NN layers.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import dispatch, dynamic_sparse as dsp, \
    static_sparse as ssp
from repro.core.bsr import BlockSparseMatrix
from repro.core.partitioner import balance_report, pack_tiles, \
    shard_blocks_by_k


def main():
    key = jax.random.PRNGKey(0)
    m = k = 1024
    n = 256
    b = 16
    density = 1 / 16

    print("== 1. build a block-sparse weight (paper §3) ==")
    w = BlockSparseMatrix.random(key, m, k, b, density)
    print(f"  {m}x{k}, block {b}x{b}, {w.nnz_blocks} non-zero blocks "
          f"(density {w.density:.4f})")

    print("== 2. static SpMM: pattern folded at compile time (§3.2) ==")
    x = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    y = ssp.spmm(w, x)                       # XLA path
    y_ref = jnp.asarray(w.to_dense()) @ x
    print(f"  y = (M . W) @ X -> {y.shape}, max err vs dense "
          f"{float(jnp.abs(y - y_ref).max()):.2e}")

    print("== 3. the static partitioner (paper Fig 1a) ==")
    sb = shard_blocks_by_k(w, q=8)
    rep = balance_report(sb.real_counts)
    print(f"  8 nnz-balanced k-splits: max/mean load = "
          f"{rep['imbalance']:.3f} (1.0 = perfect)")
    packing = pack_tiles(w, 128, 128)
    print(f"  MXU tile packing: {packing.num_tiles} tiles, "
          f"occupancy {packing.occupancy:.3f}")

    print("== 4. dynamic SpMM: runtime pattern, fixed capacity (§3.3) ==")
    mask = jnp.asarray(w.block_mask())
    cap = int(w.grid[0] * w.grid[1] * density * 1.25)
    op = dsp.encode(jnp.asarray(w.to_dense()), mask, block_size=b,
                    nnz_max=cap)
    y_dyn = dsp.dspmm(op, x)
    print(f"  capacity {cap} block slots, true nnz {int(op.nnz)}, "
          f"max err {float(jnp.abs(y_dyn - y_ref).max()):.2e}")

    print("== 5. Pallas TPU kernel (interpret mode on CPU) ==")
    # the tour deliberately shows the raw kernel entry point last
    from repro.kernels.bsmm import ops as bsmm_ops  # repro-lint: disable=R001
    y_pal = bsmm_ops.bsmm(w, x, interpret=True)
    print(f"  bsmm kernel max err {float(jnp.abs(y_pal - y_ref).max()):.2e}")

    print("== 6. plan-first API: plan once, execute forever (Table 3) ==")
    from repro import sparse
    plan = sparse.plan(w, n)                 # phase 1: ALL one-time work
    y_auto = plan(w.values, x)               # phase 2: zero-decision call
    print(f"  sparse.plan(...)(values, x) max err "
          f"{float(jnp.abs(y_auto - y_ref).max()):.2e}")
    print("  " + sparse.format_plan(plan).replace("\n", "\n  "))
    y_dauto = sparse.plan(op, n).apply(op, x)   # same API, dynamic operand
    stats = sparse.cache_stats()
    print(f"  dynamic operand via plan max err "
          f"{float(jnp.abs(y_dauto - y_ref).max()):.2e}; "
          f"plan cache: {stats['plan_entries']} plans, "
          f"{stats['plan_hits']} hits")
    y_shim = dispatch.spmm(w, x)             # deprecation shim, same plan
    print(f"  legacy dispatch.spmm shim max err "
          f"{float(jnp.abs(y_shim - y_ref).max()):.2e} "
          f"(now {sparse.cache_stats()['plan_hits']} plan-cache hits)")

    print("== 7. sparse layers: the technique as a model feature ==")
    from repro.core.sparse_layers import SparseFFN
    ffn = SparseFFN(d_model=256, d_ff=1024, block_size=16, density=0.25)
    params = ffn.init(jax.random.PRNGKey(2))
    h = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
    out = ffn.apply(params, h)
    dense_flops = 2 * 256 * 1024 * 3
    print(f"  SparseFFN {out.shape}, {ffn.flops_per_token():.0f} "
          f"FLOPs/token vs {dense_flops} dense "
          f"({ffn.flops_per_token()/dense_flops:.2%})")
    print("done.")


if __name__ == "__main__":
    main()
