"""Batched serving with continuous batching + the retained-block
(local+global) KV cache -- the paper's static block sparsity making
long-context decode O(window).

    PYTHONPATH=src python examples/serve_blocksparse.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro import configs
from repro.models.model import LM
from repro.serve import Engine, Request


def main():
    cfg = configs.smoke("llama3_2_1b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))

    print("== continuous batching: 6 requests through 2 slots ==")
    eng = Engine(lm, params, batch=2, max_len=96, warm_compile=True,
                 replanner=True, replanner_interval=0.05)
    print(f"  plan-first startup: {eng.plan_stats['plans_built']} matmul "
          f"plans built before the first request (decode + every "
          f"prefill bucket)")
    print(f"  analytic bucket ladder: {list(eng.buckets)} -- prefill "
          f"compiles once per bucket, not once per prompt length")
    reqs = [Request(uid=i,
                    prompt=np.random.default_rng(i).integers(
                        0, cfg.vocab_size, size=8 + 4 * i),
                    max_new_tokens=6 + i)
            for i in range(6)]
    order = []
    eng.run(reqs, on_finish=lambda r: order.append(r.uid))
    eng.stop_replanner()
    for r in reqs:
        print(f"  req {r.uid}: prompt {len(r.prompt)} toks -> bucket "
              f"{r.bucket}, {len(r.output)} generated {r.output[:8]}...")
    print(f"  finish order: {order} (shorter budgets finish first)")
    st = eng.stats()
    pad = st["padding"]
    print(f"  live stats: {st['steps']} decode steps, step p50 "
          f"{st['step_latency']['p50_ms']}ms; padding "
          f"{pad['pad_tokens']}/{pad['pad_tokens'] + pad['prompt_tokens']} "
          f"tokens (waste_frac {pad['waste_frac']}); re-planner swept "
          f"{st['replanner']['sweeps']}x, upgraded "
          f"{st['replanner']['upgrades']} analytic verdicts")

    print("== retained-block cache: decode far past the cache length ==")
    import dataclasses
    import jax.numpy as jnp
    cfg_l = dataclasses.replace(cfg, retained_prefix=16,
                                retained_window=48)
    lm_l = LM(cfg_l)
    params_l = lm_l.init(jax.random.PRNGKey(0))
    cache_len = cfg_l.retained_prefix + cfg_l.retained_window
    caches = lm_l.init_cache(1, cache_len)
    tok = jnp.zeros((1, 1), jnp.int32)
    for pos in (0, 50, 500, 5000, 500_000):
        lg, caches = lm_l.decode_step(
            params_l, tok, caches,
            jnp.asarray([pos], jnp.int32), retained=True)
        print(f"  position {pos:>7d}: cache stays {cache_len} slots, "
              f"logits finite={bool(jnp.isfinite(lg.astype(jnp.float32)).all())}")
    print("done. (500k-token decode with a 64-slot cache: the long_500k "
          "cells lower exactly this path at production shapes)")


if __name__ == "__main__":
    main()
